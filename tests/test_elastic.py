"""Elastic mesh (docs/elasticity.md): the p=8 conformance tier runs in a
subprocess (tests/_elastic_main.py — the 8-device host-platform flag must
never leak into this process); here live the single-device pieces — resize
validation, the groups-cache revalidation regression, the pure reshard
planner/mover units, the ``ignis.elastic.*`` property surface, the
``elastic.reshard`` fault-plan sugar, and hypothesis property tests pitting
``ElasticPolicy``/``plan_reshard`` against pure-Python oracles."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import ICluster, IProperties, IWorker, faults
from repro.core.faults import FaultPlan
from repro.core.partition import Block, block_devices
from repro.core.properties import REGISTRY
from repro.distributed.elastic import (
    ElasticPolicy, plan_reshard, repad_block, restore_elastic)


@pytest.mark.timeout(900)
def test_elastic_suite():
    here = os.path.dirname(__file__)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "..", "src"), env.get("PYTHONPATH", "")]
    )
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(here, "_elastic_main.py")],
        env=env, capture_output=True, text=True, timeout=880,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "ALL_ELASTIC_OK" in r.stdout


# ---------------------------------------------------------------------------
# resize validation at p=1 (a single-device world can neither grow — no free
# devices — nor shrink below one survivor)
# ---------------------------------------------------------------------------

def _worker():
    return IWorker(ICluster(IProperties()), "python")


def test_grow_without_free_devices_raises():
    w = _worker()
    with pytest.raises(ValueError, match="free device"):
        w.grow(len(jax.devices()))  # every visible device is already ranked


def test_grow_rejects_nonpositive():
    w = _worker()
    with pytest.raises(ValueError):
        w.grow(0)


def test_shrink_validation():
    w = _worker()
    with pytest.raises(ValueError):
        w.shrink(w.executors)  # would leave zero survivors
    with pytest.raises(ValueError):
        w.shrink([99])  # rank out of range
    with pytest.raises(ValueError):
        w.shrink([])


def test_resize_same_world_rebuilds_context():
    """Degenerate resize: same device list still swaps the base context,
    re-spreads world partitions, and bumps the counters consistently."""
    w = _worker()
    df = w.parallelize(np.arange(16, dtype=np.int32)).persist()
    assert df.count() == 16
    old_ctx = w._base_context
    assert w._resize(w._world_devices()) == w.executors
    assert w._base_context is not old_ctx
    st = w.metrics("elastic")
    assert st["reshard_moves"] > 0 and st["reshard_recomputes"] == 0
    assert sorted(int(x) for x in df.collect()) == list(range(16))


def test_groups_cache_revalidates_on_new_base_context():
    """Regression: the groups(n) cache used to revalidate only against the
    executor blacklist, so a resize would keep handing out sub-meshes of the
    RETIRED world. It must rebuild whenever the base context changed."""
    w = _worker()
    gs = w.groups(1)
    assert w.groups(1)[0] is gs[0]  # cached while the world stands still
    w._resize(w._world_devices())   # new base context, same world
    gs2 = w.groups(1)
    assert gs2[0] is not gs[0]
    assert gs2[0].parent is w._base_context


# ---------------------------------------------------------------------------
# the pure planner/mover units
# ---------------------------------------------------------------------------

def test_plan_reshard_rules():
    old = frozenset({0, 1, 2, 3})
    grown = frozenset({0, 1, 2, 3, 4, 5})
    shrunk = frozenset({0, 1})
    # uncommitted blocks always move
    assert plan_reshard(None, old, grown) == "move"
    # world-bound partitions re-spread on ANY resize
    assert plan_reshard(old, old, grown) == "move"
    assert plan_reshard(old, old, shrunk) == "move"
    # a block touching a retired device moves
    assert plan_reshard(frozenset({2, 3}), old, shrunk) == "move"
    # a block outside the new world moves
    assert plan_reshard(frozenset({7}), old, grown) == "move"
    # resident wholly on a surviving strict sub-group: unaffected
    assert plan_reshard(frozenset({0, 1}), old, grown) == "keep"
    assert plan_reshard(frozenset({0, 1}), old, shrunk) == "keep"


def test_repad_block_preserves_rows():
    w = _worker()
    df = w.parallelize(np.arange(10, dtype=np.int32))
    blk = df.node.result[0]
    out = repad_block(blk, 4, w.context.mesh, w.context.axis)
    assert isinstance(out, Block)
    assert out.capacity % 4 == 0 and out.capacity >= blk.capacity
    valid = np.asarray(out.valid)
    assert valid.sum() == 10
    assert np.array_equal(np.asarray(out.data)[valid], np.arange(10))
    assert block_devices(out) == frozenset(w.context.mesh.devices.flat)


# ---------------------------------------------------------------------------
# property surface + fault-plan sugar
# ---------------------------------------------------------------------------

def test_elastic_props_registered():
    for key, typ in [
        ("ignis.elastic.enabled", "bool"),
        ("ignis.elastic.min.executors", "int"),
        ("ignis.elastic.max.executors", "int"),
        ("ignis.elastic.step", "int"),
        ("ignis.elastic.queue.per.executor", "int"),
        ("ignis.elastic.cooldown.polls", "int"),
    ]:
        assert key in REGISTRY and REGISTRY[key].type == typ
    p = IProperties()
    assert p.get_bool("ignis.elastic.enabled", False) is False
    p["ignis.elastic.step"] = "3"
    assert p.get_int("ignis.elastic.step") == 3


def test_fail_elastic_reshard_sugar():
    plan = FaultPlan().fail_elastic_reshard(op="map", block=2)
    with faults.inject(plan):
        faults.check("elastic.reshard", op="sort", block=2)  # op mismatch
        faults.check("elastic.reshard", op="map", block=1)   # block mismatch
        with pytest.raises(faults.FaultInjected):
            faults.check("elastic.reshard", op="map", block=2)
        faults.check("elastic.reshard", op="map", block=2)   # times=1 spent
    assert plan.injections("elastic.reshard") == 1


# ---------------------------------------------------------------------------
# ElasticPolicy on a single-device world: decisions, clamps, disabled mode
# ---------------------------------------------------------------------------

def _props(**kv):
    return IProperties({f"ignis.elastic.{k.replace('_', '.')}": str(v)
                        for k, v in kv.items()})


def test_policy_disabled_records_denied():
    w = _worker()
    pol = ElasticPolicy(w, props=_props(enabled="false", max_executors=8))
    assert pol.poll(queue_depth=10_000) == 0
    assert w.executors == 1
    assert pol.stats["denied"] == 1
    assert pol.on_admit(8) == 0 and pol.stats["denied"] == 2


def test_policy_desired_clamps():
    w = _worker()
    pol = ElasticPolicy(w, props=_props(
        enabled="true", min_executors=2, max_executors=6,
        queue_per_executor=4))
    assert pol.desired(0) == 2        # floor
    assert pol.desired(12) == 3       # ceil(12/4)
    assert pol.desired(10_000) == 6   # ceiling
    assert pol.desired(-5) == 2       # negative depth clamps to floor


def test_policy_reads_scheduler_queue_depth():
    w = _worker()
    df = w.parallelize(np.arange(8, dtype=np.int32))
    assert df.count() == 8  # settled work: depth back to zero
    pol = ElasticPolicy(w, props=_props(enabled="false"))
    assert pol.scheduler().queue_depth() == 0
    assert pol.poll() == 0  # holds steady at desired == min == current


def test_policy_restore_single_device(tmp_path):
    from repro.checkpoint import save
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("olmo-1b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    save(str(tmp_path), 1, {"params": params})
    w = _worker()
    pol = ElasticPolicy(w, props=_props(enabled="false"))
    out = pol.restore(str(tmp_path), 1, cfg, {"params": params})
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out["params"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_restore_elastic_rejects_shape_mismatch(tmp_path):
    from repro.checkpoint import save
    from repro.configs import get_config
    from repro.launch.mesh import make_local_mesh
    from repro.models import build_model

    cfg = get_config("olmo-1b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    save(str(tmp_path), 1, {"params": params})
    bad = jax.tree.map(lambda x: x[..., : max(1, x.shape[-1] // 2)], params)
    with pytest.raises(ValueError, match="checkpoint"):
        restore_elastic(str(tmp_path), 1, cfg, make_local_mesh(1, 1),
                        {"params": bad})


# ---------------------------------------------------------------------------
# hypothesis: the policy state machine and the reshard planner vs oracles
# ---------------------------------------------------------------------------

try:
    import hypothesis  # noqa: F401
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover - dev-only dependency
    HAVE_HYP = False

if HAVE_HYP:
    _settings = settings(max_examples=25, deadline=None,
                         suppress_health_check=list(HealthCheck))

    class _FakeWorker:
        """A mesh-free stand-in: ElasticPolicy only reads ``executors`` and
        calls ``grow``/``shrink`` — the state machine is what's under test."""

        def __init__(self, p):
            self.executors = p

        def grow(self, n):
            self.executors += n
            return self.executors

        def shrink(self, n):
            self.executors -= n
            return self.executors

    @given(st.integers(1, 4), st.integers(1, 3), st.integers(1, 3),
           st.integers(1, 8),
           st.lists(st.integers(0, 200), min_size=1, max_size=30))
    @_settings
    def test_policy_matches_pure_oracle(p0, step, cooldown, queue_per, depths):
        lo, hi = 1, 8
        props = _props(enabled="true", min_executors=lo, max_executors=hi,
                       step=step, cooldown_polls=cooldown,
                       queue_per_executor=queue_per)
        fw = _FakeWorker(p0)
        pol = ElasticPolicy(fw, props=props)
        # the oracle: the documented state machine, written independently
        p, direction, streak = p0, 0, 0
        for depth in depths:
            want = max(lo, min(hi, -(-max(0, depth) // queue_per)))
            d = (want > p) - (want < p)
            if d != direction:
                direction, streak = d, 0
            streak += 1
            expect = 0
            if d != 0 and streak >= cooldown:
                streak = 0
                expect = max(-step, min(step, want - p))
                p += expect
            got = pol.poll(queue_depth=depth)
            assert got == expect
            assert fw.executors == p
            assert lo <= fw.executors <= hi
            assert abs(got) <= step

    @given(st.integers(1, 6), st.integers(1, 8), st.integers(1, 8))
    @_settings
    def test_policy_on_admit_matches_oracle(p0, tenants, mx):
        lo = 1
        hi = max(mx, lo)
        fw = _FakeWorker(p0)
        pol = ElasticPolicy(fw, props=_props(
            enabled="true", min_executors=lo, max_executors=hi))
        target = max(lo, min(hi, tenants))
        expect = max(0, target - p0)
        assert pol.on_admit(tenants) == expect
        assert fw.executors == max(p0, target)

    _devsets = st.sets(st.integers(0, 9), max_size=8).map(frozenset)

    @given(_devsets, _devsets,
           st.one_of(st.none(), _devsets.filter(lambda s: s)))
    @_settings
    def test_plan_reshard_invariants(old_world, new_world, devs):
        plan = plan_reshard(devs, old_world, new_world)
        assert plan in ("move", "keep")
        if plan == "keep":
            # a kept block is committed, inside the surviving world, off
            # every retired device, and not bound to the full old world
            assert devs is not None
            assert devs <= new_world
            assert not (devs & (old_world - new_world))
            assert devs != old_world
        if devs is None or devs == old_world or not (devs <= new_world):
            assert plan == "move"
