"""p=8 chaos matrix (docs/fault_tolerance.md) — run in a subprocess with 8
host devices (tests/test_faults.py drives this; the XLA flag must precede
jax import and must NOT leak into the main pytest process).

Mirrors the p=1 matrix in tests/test_faults.py over a real 8-executor mesh:
every task kind (narrow / fused / wide / native / reshard / action) killed
at representative kill-points, plus the overflow-retry path, the
checkpoint-truncated repair, speculative gang stragglers, inter-group
reshard kills and executor kill/blacklist. Every scenario must converge to
its no-fault oracle with EXACT retry counters.
"""
import os
import tempfile

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import ICluster, IProperties, IWorker  # noqa: E402
from repro.core import faults  # noqa: E402
from repro.core.dag import DagEngine  # noqa: E402
from repro.core.faults import FaultPlan  # noqa: E402
from repro.core.job import IJob, default_scheduler  # noqa: E402
from repro.core.native import ignis_export  # noqa: E402


def check(name, ok):
    print(f"{name}: {'OK' if ok else 'FAIL'}")
    if not ok:
        raise SystemExit(1)


def retries():
    return default_scheduler().stats["task_retries"]


def recovers(name, build, collect, plan, expect_retries=1):
    """No-fault oracle, then a fresh lineage under ``plan``: result must
    match with exactly the expected scheduler retries, all faults fired."""
    oracle = collect(build())
    r0 = retries()
    with faults.inject(plan):
        got = collect(build())
    check(name, got == oracle
          and retries() - r0 == expect_retries
          and plan.injections() == expect_retries)


def main():
    assert len(jax.devices()) == 8, jax.devices()
    props = IProperties({"ignis.executor.instances": "8"})
    w = IWorker(ICluster(props), "python")
    assert w.executors == 8

    rng = np.random.default_rng(0)
    vals = rng.integers(0, 100_000, 2048).astype(np.int32)

    # ---- narrow (unfused single op), kill-points at both edges ----------
    for blk in (0, 3):
        recovers(
            f"p8_narrow_block{blk}",
            lambda: w.parallelize(vals, blocks=4).map(lambda x: x * 2),
            lambda df: sorted(int(x) for x in df.collect()),
            FaultPlan().kill_block(op="map", block=blk))

    # ---- fused stage ----------------------------------------------------
    def fused():
        df = (w.parallelize(vals, blocks=4)
              .map(lambda x: x * 2)
              .filter(lambda x: x % 3 == 0)
              .map(lambda x: x + 1))
        assert w.engine.plan(df.node), "chain must fuse"
        return df

    for blk in (1, 2):
        recovers(f"p8_fused_block{blk}", fused,
                 lambda df: sorted(int(x) for x in df.collect()),
                 FaultPlan().kill_block(op="map", block=blk))

    # ---- wide: every shuffle kind, collective killed once ---------------
    wide_cases = [
        ("sort", lambda: w.parallelize(vals).sort()),
        ("distinct", lambda: w.parallelize(vals).map(lambda x: x % 17).distinct()),
        ("reduceByKey", lambda: w.parallelize(vals)
            .map(lambda x: {"key": x % 13, "value": jnp.int32(1)})
            .reduce_by_key(lambda a, b: a + b, 0)),
        ("groupByKey", lambda: w.parallelize(vals[:256])
            .map(lambda x: {"key": x % 7, "value": x}).group_by_key()),
        ("partitionBy", lambda: w.parallelize(vals[:512])
            .map(lambda x: {"key": x % 5, "value": x}).partition_by()),
    ]
    for kind, build in wide_cases:
        recovers(f"p8_wide_{kind}", build,
                 lambda df: sorted(map(repr, df.collect())),
                 FaultPlan().fail_collective(kind))

    def join_build():
        l = w.parallelize(np.arange(256, dtype=np.int32)).map(
            lambda x: {"key": x % 8, "value": x})
        r = w.parallelize(np.arange(64, dtype=np.int32)).map(
            lambda x: {"key": x % 8, "value": x * 2})
        return l.join(r)

    recovers("p8_wide_join", join_build,
             lambda df: sorted(
                 (int(np.asarray(x["key"])), int(np.asarray(x["value"][0])),
                  int(np.asarray(x["value"][1]))) for x in df.collect()),
             FaultPlan().fail_collective("join"))

    # ---- overflow path: fault during the capacity retry ------------------
    wt = IWorker(
        ICluster(IProperties({"ignis.executor.instances": "8",
                              "ignis.shuffle.capacity.factor": "0.05"})),
        "python")
    vals_t = rng.integers(0, 1000, 1024).astype(np.int32)
    oracle_t = sorted(int(v) for v in vals_t)
    plan_ovf = FaultPlan().fail("shuffle.overflow", kind="capacity")
    r0 = retries()
    with faults.inject(plan_ovf):
        got_t = [int(x) for x in wt.parallelize(vals_t).sort().collect()]
    check("p8_overflow_retry_fault",
          got_t == oracle_t and retries() - r0 == 1
          and plan_ovf.injections() == 1
          and wt.shuffle_stats()["overflow_retries"] >= 2)

    # ---- native ----------------------------------------------------------
    runs = []

    @ignis_export("p8_scale")
    def p8_scale(ctx, data=None, valid=None):
        runs.append(1)
        return data * jnp.int32(3), valid

    recovers("p8_native",
             lambda: w.call("p8_scale", w.parallelize(np.arange(64, dtype=np.int32))),
             lambda df: sorted(int(x) for x in df.collect()),
             FaultPlan().fail_node(op="call:p8_scale"))
    check("p8_native_reran_once", len(runs) == 2)

    # ---- reshard (importData between two workers on the mesh) ------------
    w2 = IWorker(w.cluster, "python", name="dst8")
    recovers("p8_reshard",
             lambda: w2.import_data(
                 w.parallelize(np.arange(128, dtype=np.int32)).map(lambda x: x + 1)),
             lambda df: sorted(int(x) for x in df.collect()),
             FaultPlan().fail_reshard(kind="importData"))

    # ---- action -----------------------------------------------------------
    recovers("p8_action",
             lambda: w.parallelize(vals, blocks=4).map(lambda x: x + 3),
             lambda df: df.count(),
             FaultPlan().fail_task(name="count(*"))

    # ---- checkpoint-truncated repair at p=8 -------------------------------
    with tempfile.TemporaryDirectory() as td:
        src = w.parallelize(vals, blocks=4)
        ck = src.map(lambda x: x + 1).checkpoint(td)
        tail = ck.map(lambda x: x * 2)
        oracle_ck = sorted(int(x) for x in tail.collect())
        src_cc = src.node.compute_count
        base = dict(w.engine.stats)
        DagEngine.kill_block(ck.node, 2)
        got_ck = sorted(int(x) for x in tail.collect())
        check("p8_checkpoint_repair",
              got_ck == oracle_ck
              and w.engine.stats["block_restores"] - base["block_restores"] == 1
              and src.node.compute_count == src_cc
              and ck.node.parents == [])

    # ---- speculative straggler on a gang task -----------------------------
    ws = IWorker(
        ICluster(IProperties({"ignis.executor.instances": "8",
                              "ignis.task.speculative": "true",
                              "ignis.task.speculative.timeout": "0.5"})),
        "python")
    g0, g1 = ws.groups(2)
    df_s = ws.parallelize(vals, blocks=2).map(lambda x: x + 5)
    oracle_s = sorted(int(x) for x in df_s.collect())
    df_s2 = ws.parallelize(vals, blocks=2).map(lambda x: x + 5)
    plan_s = FaultPlan().delay_block(op="map", block=0, seconds=3.0)
    with faults.inject(plan_s):
        fut = df_s2.collect_async(job=IJob("spec8", group=g0))
        got_s = sorted(int(x) for x in fut.result(120))
    check("p8_speculative_gang",
          got_s == oracle_s and ws.engine.stats["speculative_retries"] == 1)

    # speculative attempt threads must re-bind the gang communicator: the
    # app's execution-time context is the 4-rank group, not the world mesh
    widths = []

    @ignis_export("p8_width_probe")
    def p8_width_probe(ctx_, data=None, valid=None):
        widths.append(int(ctx_.executors))
        return data, valid

    futp = ws.call(
        "p8_width_probe", ws.parallelize(np.arange(32, dtype=np.int32))
    ).collect_async(job=IJob("specw", group=g0))
    got_p = sorted(int(x) for x in futp.result(120))
    check("p8_speculative_gang_keeps_group_mesh",
          got_p == list(range(32)) and bool(widths) and set(widths) == {4})

    # ---- inter-group reshard edge killed ----------------------------------
    @ignis_export("p8_ident")
    def p8_ident(ctx, data=None, valid=None):
        return data, valid

    def gang_build():
        job = IJob("edge", scheduler=default_scheduler())
        shared = ws.call("p8_ident", ws.parallelize(np.arange(64, dtype=np.int32)))
        f1 = shared.count_async(job=job, group=g0)
        f2 = shared.map(lambda x: x + 1).collect_async(job=job, group=g1)
        return f1, f2

    f1, f2 = gang_build()
    oracle_e = (f1.result(120), sorted(int(x) for x in f2.result(120)))
    r0 = retries()
    plan_e = FaultPlan().fail_reshard(kind="group")
    with faults.inject(plan_e):
        f1, f2 = gang_build()
        got_e = (f1.result(120), sorted(int(x) for x in f2.result(120)))
    check("p8_group_reshard_fault",
          got_e == oracle_e and retries() - r0 == 1 and plan_e.injections() == 1)

    # ---- executor kill + blacklist over the real mesh ---------------------
    gs_cached = w.groups(4)  # cached BEFORE the kill: must not bypass it
    dfp = w.parallelize(vals, blocks=8).map(lambda x: x * 7).persist()
    oracle_k = sorted(int(x) for x in dfp.collect())
    base = w.engine.stats["block_recomputes"]
    lost = w.kill_executor(5)
    check("p8_executor_kill_lost_blocks", lost >= 1)
    check("p8_executor_kill_repaired",
          sorted(int(x) for x in dfp.collect()) == oracle_k
          and w.engine.stats["block_recomputes"] - base >= 1)
    try:
        w.context.group([4, 5])
        check("p8_blacklist_guard", False)
    except ValueError as e:
        check("p8_blacklist_guard", "blacklisted" in str(e))
    try:
        w.groups(4)
        check("p8_blacklist_covers_cached_groups", False)
    except ValueError as e:
        check("p8_blacklist_covers_cached_groups", "blacklisted" in str(e))
    w.restore_executor(5)
    check("p8_blacklist_restore", w.context.group([4, 5]).executors == 2)
    check("p8_blacklist_restore_groups", w.groups(4) is gs_cached)

    # ---- nonblocking collective handles over the real mesh ----------------
    from repro.core import comm  # noqa: E402

    recovers("p8_kill_pending_handle",
             lambda: w.parallelize(vals).map(lambda x: x + 1),
             lambda df: df.count(),
             FaultPlan().kill_handle(coll="action.count", attempt=0))

    ctx8 = w.context
    x8 = comm.shard_rows(ctx8, jnp.arange(16, dtype=jnp.float32))
    with faults.inject(FaultPlan().kill_handle(coll="allreduce",
                                               attempt=0)) as p_dw:
        h8 = comm.iallreduce(ctx8, x8)
        try:
            h8.wait()
            check("p8_handle_kill_fires", False)
        except faults.FaultInjected:
            check("p8_handle_kill_fires", True)
        check("p8_double_wait_reposts", float(h8.wait()) == 120.0
              and float(h8.wait()) == 120.0 and p_dw.injections() == 1)

    @ignis_export("p8_leaky_app")
    def p8_leaky_app(ctx_, data=None, valid=None):
        comm.iallreduce(ctx_, comm.shard_rows(
            ctx_, jnp.arange(8, dtype=jnp.float32)))  # never awaited
        return data, valid

    sched8 = default_scheduler()
    f0 = sched8.stats["coll_flushed"]
    check("p8_leaked_handle_flushed",
          w.call("p8_leaky_app", w.parallelize(vals)).count() == len(vals)
          and sched8.stats["coll_flushed"] >= f0 + 1)
    recovers("p8_kill_flush_of_leaked_handle",
             lambda: w.call("p8_leaky_app", w.parallelize(vals)),
             lambda df: df.count(),
             FaultPlan().kill_handle(coll="allreduce", phase="flush",
                                     attempt=0))

    # ---- kernel tier chaos over the real mesh (docs/kernels.md) -----------
    wk = IWorker(ICluster(IProperties({
        "ignis.executor.instances": "8", "ignis.kernels": "interpret"})),
        "python")

    def kernel_build():
        return (wk.parallelize(np.arange(128, dtype=np.int32))
                .map(lambda x: {"key": x % 7, "value": x})
                .reduce_by_key(lambda a, b: a + b, 0))

    recovers("p8_kernel_stage_kill", kernel_build,
             lambda df: sorted(map(repr, df.collect())),
             FaultPlan().fail_kernel_stage("reduceByKey"))
    check("p8_kernel_stage_was_kernel_backed",
          wk.shuffle_stats()["kernel_hits"] >= 1)

    f0k = wk.shuffle_stats()["kernel_fallbacks"]
    r0k = retries()
    with faults.inject(FaultPlan().fail_kernel_capability()):
        rows_k = sorted(map(repr, kernel_build().collect()))
    check("p8_kernel_capability_degrades",
          rows_k == sorted(map(repr, kernel_build().collect()))
          and wk.shuffle_stats()["kernel_fallbacks"] > f0k
          and retries() == r0k)

    # ---- streaming chaos over gang groups (docs/streaming.md) -------------
    # 4 tenants on groups(4); one tenant's micro-batch is killed mid-stream.
    # Lineage replays it, every tenant's folded state stays bit-identical,
    # and the counters are EXACT (1 retry, 1 injection, 1 counted replay).
    from repro.streaming import (  # noqa: E402
        StreamContext, TenantFrontEnd, TenantRequestSource)

    ws = IWorker(ICluster(IProperties({
        "ignis.executor.instances": "8",
        "ignis.stream.batch.rows": "16"})), "python")

    def zeros():
        return np.zeros((2,), np.int64)

    def fe_run(tag):
        fe = TenantFrontEnd(ws, n_groups=4, name=f"stream-{tag}")
        for i in range(4):
            fe.admit(f"t{i}", TenantRequestSource(i, seed=31, limit=96),
                     init_state=zeros())
        return fe, fe.run()

    _, st_oracle = fe_run("oracle")
    r0s = retries()
    plan_s = FaultPlan().fail_stream_batch(tenant="t2", batch=3)
    with faults.inject(plan_s):
        fe_f, st_got = fe_run("chaos")
    check("p8_stream_batch_kill_bit_identical",
          all(bool((st_got[t] == st_oracle[t]).all()) for t in st_oracle))
    check("p8_stream_batch_kill_exact_counters",
          retries() - r0s == 1 and plan_s.injections("stream.batch") == 1
          and fe_f.stream("t2").batches_replayed == 1
          and fe_f.job.stats()["stream"]["batches_replayed"] == 1)

    # a kill that exhausts the retry budget aborts the pump; a NEW pump
    # restores the last quiesced offset checkpoint and reconverges to the
    # bit-identical oracle — the exactly-once restart path at p=8
    ws.cluster.props["ignis.stream.checkpoint.interval"] = "2"
    ck_dir = tempfile.mkdtemp(prefix="stream-ck-")
    grp = ws.groups(4)[1]

    def ck_stream(tenant, ckpt=True):
        return StreamContext(
            ws, TenantRequestSource(5, seed=31, limit=96), tenant=tenant,
            group=grp, init_state=zeros(),
            ckpt_dir=ck_dir if ckpt else None)

    ck_oracle = ck_stream("ck-oracle", ckpt=False).run()
    r0c = retries()
    plan_c = FaultPlan().fail_stream_batch(tenant="ck", batch=4, attempt=None)
    died = False
    with faults.inject(plan_c):
        try:
            ck_stream("ck").run()
        except faults.FaultInjected:
            died = True
    sc2 = ck_stream("ck")
    st2 = sc2.run()
    # restored_from is EXACTLY the aborted pump's committed count: the
    # crash checkpoint cut on the drain-abort path pins it even when the
    # doomed batch was pipelined behind the checkpoint trigger (this was
    # a race — restored_from could be None — before _drain_then_checkpoint)
    check("p8_stream_ckpt_restart_bit_identical",
          died and sc2.restored_from == 4
          and bool((st2 == ck_oracle).all())
          and sc2.committed == 6 and sc2.offset == 96)
    check("p8_stream_ckpt_restart_exact_counters",
          retries() - r0c == 1 and plan_c.injections("stream.batch") == 2
          and sc2.batches_replayed == 0)

    # ---- elastic-mesh chaos (docs/elasticity.md) ---------------------------
    # a rank's block lost mid-reshard degrades to a lineage hole repaired
    # block-wise on the next action: EXACT counter split — 1 reshard
    # recompute, 1 engine block recompute, everything else moved intact
    we = IWorker(ICluster(IProperties({"ignis.executor.instances": "4"})),
                 "python")
    vals_e = rng.integers(0, 50_000, 1024).astype(np.int32)
    dfe = we.parallelize(vals_e, blocks=4).map(lambda x: x * 5).persist()
    oracle_el = sorted(int(x) for x in dfe.collect())
    base_el = we.engine.stats["block_recomputes"]
    r0e = retries()
    plan_el = FaultPlan().fail_elastic_reshard(op="map", block=2)
    with faults.inject(plan_el):
        we.grow(2)
    st_el = we.metrics("elastic")
    check("p8_elastic_reshard_fault_counters",
          plan_el.injections("elastic.reshard") == 1
          and st_el["reshard_recomputes"] == 1
          and st_el["reshard_moves"] == 7  # 8 blocks, 1 lost, 0 kept
          and dfe.node.result[2] is None)
    check("p8_elastic_reshard_fault_repaired",
          sorted(int(x) for x in dfe.collect()) == oracle_el
          and we.engine.stats["block_recomputes"] - base_el == 1
          and retries() - r0e == 0)  # repair is lineage, not task retry

    # a shrink issued while a gang task is mid-flight must BLOCK on the
    # pinned group lock until the task drains on the old communicator —
    # the result is bit-identical, no retries, the world resized after
    import threading
    import time

    wg = IWorker(ICluster(IProperties({"ignis.executor.instances": "8"})),
                 "python")
    gg0, _gg1 = wg.groups(2)
    dfg = wg.parallelize(vals_e, blocks=2).map(lambda x: x + 9)
    oracle_g = sorted(int(x) for x in dfg.collect())
    dfg2 = wg.parallelize(vals_e, blocks=2).map(lambda x: x + 9)
    r0g = retries()
    with faults.inject(FaultPlan().delay_block(op="map", block=0,
                                               seconds=1.5)):
        futg = dfg2.collect_async(job=IJob("gang-shrink", group=gg0))
        time.sleep(0.3)  # let the straggler take the group lock
        t0 = time.monotonic()
        wg.shrink(2)     # drains the in-flight gang task first
        drained = time.monotonic() - t0
        got_g = sorted(int(x) for x in futg.result(120))
    check("p8_elastic_shrink_mid_gang_task",
          got_g == oracle_g and wg.executors == 6
          and drained >= 0.5 and retries() - r0g == 0)

    # ranks join AND leave mid-streaming-pump, with one micro-batch killed
    # while the mesh is in motion: folded states bit-identical to the
    # static solo oracle, EXACT retry/replay counters (1 retry, 1 replay)
    wp = IWorker(ICluster(IProperties({
        "ignis.executor.instances": "6",
        "ignis.stream.batch.rows": "16"})), "python")

    def pump_run(tag, resize=False, plan=None):
        fe = TenantFrontEnd(wp, n_groups=2, name=f"elastic-{tag}")
        for i in range(2):
            fe.admit(f"e{i}", TenantRequestSource(i, seed=13, limit=96),
                     init_state=zeros())
        stop = threading.Thread()
        sizes = []
        if resize:
            def resizer():
                while fe.job.metrics("stream")["completed"] < 3:
                    time.sleep(0.005)
                sizes.append(wp.grow(2))
                while fe.job.metrics("stream")["completed"] < 7:
                    time.sleep(0.005)
                sizes.append(wp.shrink(2))
            stop = threading.Thread(target=resizer, daemon=True)
            stop.start()
        if plan is not None:
            with faults.inject(plan):
                out = fe.run()
        else:
            out = fe.run()
        if stop.ident is not None:
            stop.join(60)
        return fe, out, sizes

    _, pump_oracle, _ = pump_run("oracle")
    r0p = retries()
    plan_p = FaultPlan().fail_stream_batch(tenant="e1", batch=2)
    fe_p, pump_got, sizes = pump_run("chaos", resize=True, plan=plan_p)
    check("p8_elastic_resize_mid_pump_bit_identical",
          all(bool((pump_got[t] == pump_oracle[t]).all())
              for t in pump_oracle)
          and sizes == [8, 6] and wp.executors == 6)
    check("p8_elastic_resize_mid_pump_exact_counters",
          retries() - r0p == 1 and plan_p.injections("stream.batch") == 1
          and fe_p.stream("e1").batches_replayed == 1
          and wp.metrics("elastic")["reshard_recomputes"] == 0)

    print("ALL_FAULTS_OK")


if __name__ == "__main__":
    main()
