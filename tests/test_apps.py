"""Paper workloads: SHA-256 vs hashlib, K-Means parity, PageRank/TC vs
host references, CG convergence."""
import hashlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.graph import (
    make_graph,
    pagerank,
    pagerank_reference,
    tc_reference,
    transitive_closure,
)
from repro.apps.kmeans import kmeans_driver_eval, kmeans_on_device, make_points
from repro.apps.minebench import make_blocks, merkle_root, mine
from repro.apps.sha256 import pack_bytes, sha256_bytes_len
from repro.core import ICluster, IProperties, IWorker


def test_sha256_bit_exact():
    for msg in [b"", b"abc", b"a" * 55, b"ignishpc-jax \xf0\x9f\x9a\x80"[:20]]:
        buf = np.zeros(64, np.uint8)
        buf[: len(msg)] = np.frombuffer(msg, np.uint8)
        d = np.asarray(sha256_bytes_len(jnp.asarray(pack_bytes(buf[None])), len(msg)))[0]
        got = b"".join(int(x).to_bytes(4, "big") for x in d).hex()
        assert got == hashlib.sha256(msg).hexdigest()


def test_minebench_mining_finds_nonce():
    blocks = make_blocks(2, 4)
    root = merkle_root(jnp.asarray(blocks[0]))
    nonce, found = mine(root, iters=4096, difficulty_bits=4)
    assert bool(found)  # P(miss) = (1 - 2^-4)^4096 ≈ 0


def test_kmeans_fused_equals_driver_eval():
    pts, _ = make_points(512, 8, 4, 3)
    init = jnp.asarray(pts[:4])
    a = kmeans_on_device(jnp.asarray(pts), init, 5)
    b = kmeans_driver_eval(jnp.asarray(pts), init, 5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_pagerank_matches_reference():
    w = IWorker(ICluster(IProperties()), "python")
    edges = make_graph(20, 50, seed=1)
    pr = pagerank(w, edges, iters=3)
    ref = pagerank_reference(edges, iters=3)
    assert max(abs(pr[v] - ref[v]) for v in ref) < 1e-3


def test_transitive_closure_matches_reference():
    w = IWorker(ICluster(IProperties()), "python")
    edges = make_graph(10, 16, seed=2)
    tc = transitive_closure(w, edges, max_rounds=8)
    got = {(int(np.asarray(a)), int(np.asarray(b))) for a, b in tc.collect()}
    assert got == tc_reference(edges)
