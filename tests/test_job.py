"""The lazy job layer (core/job.py, docs/driver.md): eager actions as
future facades, cross-worker job DAGs (dataflow + native + importData),
async overlap of independent branches, native nodes as lineage citizens,
call_partitions lineage repair, and early-exit take."""
import time

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import ICluster, IProperties, IWorker
from repro.core.dag import DagEngine
from repro.core.job import IJob, default_scheduler
from repro.core.native import ignis_export


@pytest.fixture
def cluster():
    return ICluster(IProperties())


@pytest.fixture
def worker(cluster):
    return IWorker(cluster, "python")


# ---------------------------------------------------------------------------
# eager actions are facades over the future API
# ---------------------------------------------------------------------------


def test_eager_actions_are_future_facades(worker):
    df = worker.parallelize(np.arange(20, dtype=np.int32)).map(lambda x: x + 1)
    s0 = default_scheduler().stats["tasks_submitted"]
    assert df.count() == df.count_async().result() == 20
    assert int(df.reduce(lambda a, b: a + b)) == int(
        df.reduce_async(lambda a, b: a + b).result()
    )
    assert [int(x) for x in df.collect()] == [
        int(x) for x in df.collect_async().result()
    ]
    assert int(df.max()) == int(df.max_async().result()) == 20
    assert int(df.min()) == int(df.min_async().result()) == 1
    assert [int(x) for x in df.take(3)] == [int(x) for x in df.take_async(3).result()]
    # the eager calls above really routed through the scheduler
    assert default_scheduler().stats["tasks_submitted"] >= s0 + 12


def test_future_protocol(worker):
    df = worker.parallelize(np.arange(8, dtype=np.int32))
    fut = df.count_async()
    assert fut.result(10) == 8
    assert fut.done() and fut.exception() is None
    seen = []
    fut.add_done_callback(lambda t: seen.append(t.state))  # already resolved
    assert seen == ["done"]


# ---------------------------------------------------------------------------
# scheduling: out-of-order resolution and overlap
# ---------------------------------------------------------------------------


def test_futures_resolve_out_of_submission_order(cluster):
    @ignis_export("slow_identity")
    def slow_identity(ctx, data=None, valid=None):
        time.sleep(0.4)
        return data, valid

    w1 = IWorker(cluster, "spmd", name="slow-w")
    w2 = IWorker(cluster, "python", name="fast-w")
    order = []
    job = IJob("ooo")
    fa = w1.call(
        "slow_identity", w1.parallelize(np.arange(8, dtype=np.int32))
    ).count_async(job=job)
    fb = w2.parallelize(np.arange(8, dtype=np.int32)).count_async(job=job)
    fa.add_done_callback(lambda t: order.append("first-submitted"))
    fb.add_done_callback(lambda t: order.append("second-submitted"))
    assert fb.result(10) == 8
    assert fa.result(10) == 8
    assert order[0] == "second-submitted"  # resolved before the slow branch


def test_independent_jobs_on_different_workers_overlap(cluster):
    @ignis_export("sleepy_identity")
    def sleepy_identity(ctx, data=None, valid=None):
        time.sleep(0.3)
        return data, valid

    w1, w2 = IWorker(cluster, "spmd"), IWorker(cluster, "spmd")
    d1 = w1.call("sleepy_identity", w1.parallelize(np.arange(4, dtype=np.int32)))
    d2 = w2.call("sleepy_identity", w2.parallelize(np.arange(4, dtype=np.int32)))
    # warm both pipelines (jit compiles) so the timed window isolates overlap
    t0 = time.perf_counter()
    assert d1.count() == 4
    t1 = time.perf_counter()
    assert d2.count() == 4
    eager_sum = time.perf_counter() - t0
    assert min(t1 - t0, eager_sum - (t1 - t0)) >= 0.3  # each stage sleeps
    t0 = time.perf_counter()
    f1 = d1.count_async(job=IJob("left"))
    f2 = d2.count_async(job=IJob("right"))
    assert f1.result(10) == 4 and f2.result(10) == 4
    wall = time.perf_counter() - t0
    # the two 0.3 s native stages on different workers must overlap
    assert wall < 0.8 * eager_sum, f"no overlap: {wall:.3f}s vs eager {eager_sum:.3f}s"
    assert default_scheduler().stats["max_concurrent"] >= 2


def test_fanout_below_shared_dep_overlaps(cluster):
    """Dependents released by a finishing task must go back to the pool:
    two independent branches hanging off ONE shared upstream stage task
    overlap instead of serializing on the finisher's thread."""

    @ignis_export("nap_identity")
    def nap_identity(ctx, data=None, valid=None):
        time.sleep(0.4)
        return data, valid

    wd = IWorker(cluster, "python")
    w1, w2 = IWorker(cluster, "spmd"), IWorker(cluster, "spmd")
    shared = wd.parallelize(np.arange(8, dtype=np.int32)).map(lambda x: x + 1)
    b1 = w1.call("nap_identity", w1.import_data(shared))
    b2 = w2.call("nap_identity", w2.import_data(shared))
    assert b1.count() == 8 and b2.count() == 8  # warm compiles
    t0 = time.perf_counter()
    job = IJob("fanout")
    f1, f2 = b1.count_async(job=job), b2.count_async(job=job)
    assert f1.result(10) == 8 and f2.result(10) == 8
    wall = time.perf_counter() - t0
    assert wall < 0.7, f"fan-out serialized: wall={wall:.3f}s"
    # the shared upstream stage was scheduled once for both branches
    assert job.stats()["stage"] == 1


# ---------------------------------------------------------------------------
# hybrid job: dataflow + native + importData in ONE scheduled DAG
# ---------------------------------------------------------------------------


def test_hybrid_job_is_one_dag_and_matches_eager(cluster):
    @ignis_export("double_native")
    def double_native(ctx, data=None, valid=None):
        return data * jnp.int32(2), valid

    wd = IWorker(cluster, "python")
    ws = IWorker(cluster, "spmd")
    base = wd.parallelize(np.arange(32, dtype=np.int32)).map(lambda x: x + 1)
    moved = ws.import_data(base)  # cross-worker reshard
    doubled = ws.call("double_native", moved)  # native SPMD stage
    back = wd.import_data(doubled).map(lambda x: x - 1)

    exp = sorted(2 * (x + 1) - 1 for x in range(32))
    job = IJob("hybrid")
    got = sorted(int(x) for x in back.collect_async(job=job).result(60))
    assert got == exp
    # ONE scheduled job: dataflow stage + native + both reshards + action
    st = job.stats()
    assert st["tasks"] >= 5 and st["failed"] == 0
    assert st["native"] == 1 and st["reshard"] == 2 and st["actions"] == 1
    assert len(st["workers"]) == 2
    txt = job.explain()
    assert "call:double_native" in txt and "importData" in txt
    # the native node is visible in the frame's physical plan too
    assert "call:double_native" in back.explain()
    # eager run of the same lineage agrees (facade path)
    assert sorted(int(x) for x in back.collect()) == exp


def test_shared_memo_evaluates_upstream_once(cluster):
    wd = IWorker(cluster, "python")
    ws = IWorker(cluster, "spmd")
    base = wd.parallelize(np.arange(16, dtype=np.int32)).map(lambda x: x * 3)
    imported = ws.import_data(base)
    job = IJob("memo")
    f1 = imported.count_async(job=job)
    f2 = imported.reduce_async(lambda a, b: a + b, job=job)
    assert f1.result(30) == 16
    assert int(f2.result(30)) == sum(3 * x for x in range(16))
    # the reshard and the upstream stage were scheduled once, not per action
    st = job.stats()
    assert st["reshard"] == 1 and st["stage"] == 1 and st["actions"] == 2


def test_nested_eager_action_inside_native_app(cluster):
    """A native app may invoke eager actions mid-flight: same-worker
    actions re-enter this thread's lock inline; another worker's actions
    go through the pool (no lock-order deadlock)."""

    @ignis_export("nested_actions")
    def nested_actions(ctx, data=None, valid=None):
        w = ctx.worker
        inner_same = w.parallelize(np.arange(5, dtype=np.int32)).count()
        inner_other = ctx.var("other").parallelize(
            np.arange(7, dtype=np.int32)
        ).count()
        return data + jnp.int32(inner_same + inner_other), valid

    wa, wb = IWorker(cluster, "python"), IWorker(cluster, "python")
    df = wa.call(
        "nested_actions",
        wa.parallelize(np.arange(4, dtype=np.int32)),
        other=wb,
    )
    assert sorted(int(x) for x in df.collect()) == [x + 12 for x in range(4)]
    assert default_scheduler().stats["inline_runs"] >= 1


def test_nested_cross_worker_lineage_does_not_deadlock(cluster):
    """The hard nesting case: a native app on worker A waits on a nested
    action whose lineage depends on worker B. The A-holding thread must
    cooperatively run the A-owned continuation tasks instead of parking
    (a pool thread can never take A's lock while the app holds it)."""

    @ignis_export("nested_cross")
    def nested_cross(ctx, data=None, valid=None):
        wa, wb = ctx.worker, ctx.var("other")
        inner = wa.import_data(
            wb.parallelize(np.arange(6, dtype=np.int32)).map(lambda x: x + 1)
        )
        return data + jnp.int32(inner.count()), valid

    wa, wb = IWorker(cluster, "python"), IWorker(cluster, "python")
    df = wa.call(
        "nested_cross", wa.parallelize(np.arange(4, dtype=np.int32)), other=wb
    )
    fut = df.collect_async()
    got = sorted(int(x) for x in fut.result(60))  # deadlock ⇒ TimeoutError
    assert got == [x + 6 for x in range(4)]
    assert default_scheduler().stats["helped_runs"] >= 1


def test_backed_off_frame_releases_its_own_acquire():
    """Regression (PR 6 review): ``task.lock_dropped`` describes the
    CLAIMING frame — the one whose ``_run_locked`` ran the task body and
    dropped the lock in ``_settle``. A pool thread that parked on acquire,
    won the lock only after that drop, and backed off on state != PENDING
    must still release its own acquisition: an RLock can never be released
    from another thread, so skipping here would leak the worker lock and
    block every subsequent task on that worker forever."""
    import threading

    from repro.core.job import DONE, JobScheduler, JobTask

    class W:
        pass

    w = W()
    w._job_lock = threading.RLock()
    sched = JobScheduler()
    stale = JobTask("stale", "action", w, lambda: 1, [])
    # simulate the helper frame having claimed + run the task and dropped
    # the lock in _settle while this frame was parked on acquire
    stale.state = DONE
    stale.lock_dropped = True
    sched._run(stale)  # this frame: acquire → back off → MUST release
    # symptom-level check: a follow-up task on the same worker lock runs
    follow = JobTask("follow", "action", w, lambda: 42, [])
    sched.submit(follow)
    assert follow.event.wait(10), "worker lock leaked: follow-up never ran"
    assert follow.result == 42 and follow.error is None


def test_job_wait_returns_in_submission_order(worker):
    job = IJob("waitall")
    a = worker.parallelize(np.arange(6, dtype=np.int32))
    a.count_async(job=job)
    a.reduce_async(lambda x, y: x + y, job=job)
    got = job.wait(30)
    assert got[0] == 6 and int(got[1]) == sum(range(6))


def test_full_take_feeds_the_job_memo(worker):
    """A fully-consumed lazy iterator materialises into the job's shared
    memo: a later action in the same job reuses the blocks."""
    df = worker.parallelize(np.arange(30, dtype=np.int32), blocks=3).map(
        lambda x: x + 1
    )
    job = IJob("take-then-collect")
    assert len(df.take_async(100, job=job).result(30)) == 30  # full consumption
    before = worker.engine.stats["node_computes"]
    assert len(df.collect_async(job=job).result(30)) == 30
    assert worker.engine.stats["node_computes"] == before  # memo hit, no redo


def test_future_failure_propagates(worker):
    @ignis_export("boom_app")
    def boom_app(ctx, data=None, valid=None):
        raise RuntimeError("kaboom")

    df = worker.call("boom_app", worker.parallelize(np.arange(4, dtype=np.int32)))
    fut = df.count_async()
    with pytest.raises(RuntimeError, match="kaboom"):
        fut.result(10)
    assert fut.done() and isinstance(fut.exception(), RuntimeError)


# ---------------------------------------------------------------------------
# native apps as first-class lineage citizens
# ---------------------------------------------------------------------------


def test_void_call_routes_through_dag(worker):
    hits = []

    @ignis_export("probe_void")
    def probe_void(ctx, data=None, valid=None):
        hits.append(int(ctx.var("x")))

    assert worker.void_call("probe_void", x=7) is None  # eager facade
    assert hits == [7]
    fut = worker.void_call_async("probe_void", x=9)
    assert fut.result(10) is None
    assert hits == [7, 9]
    assert fut.task.kind == "action"
    # the app itself ran as a native task in the job DAG, not eagerly outside
    s = default_scheduler().stats
    assert s["tasks_completed"] >= 2


def test_void_call_receives_dataframe(worker):
    sums = []

    @ignis_export("sum_void")
    def sum_void(ctx, data=None, valid=None):
        sums.append(int(jnp.where(valid, data, 0).sum()))

    df = worker.parallelize(np.arange(10, dtype=np.int32)).map(lambda x: x * 2)
    worker.void_call("sum_void", df)
    assert sums == [2 * sum(range(10))]


def test_native_ctx_binds_at_execution_time(worker):
    seen = {}

    @ignis_export("read_knob")
    def read_knob(ctx, data=None, valid=None):
        seen["v"] = ctx.var("knob")
        return data, valid

    df = worker.call("read_knob", worker.parallelize(np.arange(4, dtype=np.int32)))
    worker.context.set_var("knob", 123)  # after definition, before execution
    df.count()
    assert seen["v"] == 123  # stale eager-bound ctx would have seen None


def test_native_params_digest_in_sig(worker):
    @ignis_export("sig_app")
    def sig_app(ctx, data=None, valid=None):
        return data, valid

    df = worker.parallelize(np.arange(4, dtype=np.int32))
    a = worker.call("sig_app", df, knob=1)
    b = worker.call("sig_app", df, knob=2)
    c = worker.call("sig_app", df, knob=1)
    assert a.node.sig != b.node.sig  # params are part of the signature
    assert a.node.sig == c.node.sig  # re-built identical call keys the same


def test_call_partitions_preserves_blocks_and_repairs(worker):
    calls = []

    @ignis_export("scale_blocks")
    def scale_blocks(ctx, data=None, valid=None):
        calls.append(1)
        return data * jnp.int32(int(ctx.var("k", 2))), valid

    df = worker.parallelize(np.arange(40, dtype=np.int32), blocks=4)
    out = worker.call_partitions("scale_blocks", df, k=5).persist()
    assert sorted(int(x) for x in out.collect()) == [x * 5 for x in range(40)]
    assert len(out.node.result) == 4  # partition-preserving: no _merged collapse
    assert len(calls) == 4  # app ran once per block
    base = worker.engine.stats["block_recomputes"]
    DagEngine.kill_block(out.node, 2)
    assert sorted(int(x) for x in out.collect()) == [x * 5 for x in range(40)]
    assert worker.engine.stats["block_recomputes"] - base == 1  # lost block only
    assert len(calls) == 5  # the app re-ran for exactly one block


def test_planning_stops_at_materialised_nodes(worker):
    """A persisted node shields its ancestors: scheduling an action above it
    must not re-execute an upstream native app (side effects run once)."""
    calls = []

    @ignis_export("count_calls")
    def count_calls(ctx, data=None, valid=None):
        calls.append(1)
        return data, valid

    src = worker.parallelize(np.arange(12, dtype=np.int32))
    cached = worker.call("count_calls", src).map(lambda x: x + 1).persist()
    assert cached.count() == 12 and len(calls) == 1
    job = IJob("above-cache")
    assert cached.filter(lambda x: x > 0).count_async(job=job).result(30) == 12
    assert len(calls) == 1  # the native app did NOT re-run
    assert job.stats()["native"] == 0  # and was never scheduled


def test_boundary_with_killed_block_repairs_on_owner(cluster):
    """A cached native node that lost a block is NOT materialised: its
    owner's engine repairs it as a scheduled task under the owner's lock."""

    @ignis_export("ident_blocks")
    def ident_blocks(ctx, data=None, valid=None):
        return data, valid

    wa, wb = IWorker(cluster, "python"), IWorker(cluster, "python")
    df = wa.parallelize(np.arange(20, dtype=np.int32), blocks=2)
    sc = wa.call_partitions("ident_blocks", df).persist()
    assert sc.count() == 20
    DagEngine.kill_block(sc.node, 1)
    job = IJob("repair")
    assert wb.import_data(sc).count_async(job=job).result(30) == 20
    owners = [t.worker for t in job.tasks if t.kind == "native"]
    assert owners == [wa]  # repair task ran on the owning worker


def test_void_call_param_named_job_reaches_app(worker):
    """Eager void_call keeps the unrestricted param namespace: a param
    literally named "job" must reach the app's context, not be swallowed
    by the async path's job= keyword."""
    seen = {}

    @ignis_export("job_param_app")
    def job_param_app(ctx, data=None, valid=None):
        seen["job"] = ctx.var("job")

    worker.void_call("job_param_app", job="nightly")
    assert seen["job"] == "nightly"


def test_call_partitions_composes_with_downstream_ops(worker):
    @ignis_export("inc_blocks")
    def inc_blocks(ctx, data=None, valid=None):
        return data + jnp.int32(1), valid

    df = worker.parallelize(np.arange(20, dtype=np.int32), blocks=2)
    out = worker.call_partitions("inc_blocks", df).map(lambda x: x * 10)
    assert sorted(int(x) for x in out.collect()) == [(x + 1) * 10 for x in range(20)]
    assert "callPartitions:inc_blocks" in out.explain()


# ---------------------------------------------------------------------------
# early-exit take
# ---------------------------------------------------------------------------


def test_take_early_exits(worker):
    df = worker.parallelize(np.arange(40, dtype=np.int32), blocks=4).map(
        lambda x: x * 2
    )
    assert [int(x) for x in df.take(5)] == [0, 2, 4, 6, 8]
    # only the first of 4 blocks materialised through the lazy iterator
    assert worker.engine.stats["iter_block_computes"] == 1
    assert df.take(100) == df.collect()  # over-ask degrades to collect


def test_take_keeps_stage_fusion(worker):
    """The lazy iterator routes fusable chains through the same compiled
    stage kernels (and plan cache) as full evaluation — early exit does not
    degrade a fused chain to per-op Python dispatch."""
    df = (
        worker.parallelize(np.arange(40, dtype=np.int32), blocks=4)
        .map(lambda x: x * 2)
        .filter(lambda x: x % 3 == 0)
        .map(lambda x: x + 1)
    )
    m0 = worker.engine.stats["plan_cache_misses"]
    got = [int(x) for x in df.take(5)]
    assert got == [2 * x + 1 for x in range(40) if (2 * x) % 3 == 0][:5]
    st = worker.engine.stats
    assert st["plan_cache_misses"] == m0 + 1  # the fused kernel compiled once
    # 5 rows need 2 of the 4 blocks (filter keeps 4 rows/block): 2 dispatches
    assert st["iter_block_computes"] == 2
    fs0 = st["fused_stages"]
    assert [int(x) for x in df.take(100)] == [
        2 * x + 1 for x in range(40) if (2 * x) % 3 == 0
    ]
    assert worker.engine.stats["fused_stages"] == fs0 + 1  # full pass, fused


def test_take_on_wide_lineage_falls_back_to_full_eval(worker):
    vals = np.array([5, 3, 9, 1, 7, 2], np.int32)
    got = [int(x) for x in worker.parallelize(vals).sort().take(3)]
    assert got == [1, 2, 3]


def test_take_respects_cached_nodes(worker):
    df = worker.parallelize(np.arange(30, dtype=np.int32), blocks=3)
    mid = df.map(lambda x: x + 1).persist()
    assert [int(x) for x in mid.take(4)] == [1, 2, 3, 4]
    assert mid.node.result is not None  # cache still populated (full eval)
