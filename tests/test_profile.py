"""The introspection API (docs/profiling.md, DESIGN.md §13): trace-schema
validation, replay determinism, facade equivalence over the unified metrics
tree, the typed property registry, and the two cost-model decisions
(cost-aware fusion boundaries, auto speculative timeouts)."""
import json
import warnings

import numpy as np
import pytest

from repro.core import ICluster, IProperties, IWorker
from repro.core.job import IJob, task_history_key
from repro.core.metrics import Counters, MetricsTree
from repro.profile import (
    CostModel,
    Hypothesis,
    JobTracer,
    Span,
    TaskRecord,
    Trace,
    capture,
    predicted_vs_measured,
    simulate,
    to_chrome,
    validate,
)


@pytest.fixture
def cluster():
    return ICluster(IProperties())


@pytest.fixture
def worker(cluster):
    return IWorker(cluster, "python")


def _traced_run(worker, n_actions=3):
    """Run a few actions under an attached tracer; return (job, tracer)."""
    tracer = JobTracer()
    tracer.attach_worker(worker)
    job = IJob("traced")
    tracer.attach(job)
    df = worker.parallelize(np.arange(64, dtype=np.int32)).map(lambda x: x + 1)
    futs = [df.count_async(job=job) for _ in range(n_actions)]
    for f in futs:
        assert f.result() == 64
    return job, tracer


# ---------------------------------------------------------------------------
# trace schema
# ---------------------------------------------------------------------------


def test_chrome_trace_validates_clean(worker, tmp_path):
    job, tracer = _traced_run(worker)
    trace = tracer.to_chrome()
    assert validate(trace) == []
    # spans exist and carry lane labels in args (tid is the thread)
    task_events = [e for e in trace["traceEvents"] if e.get("ph") == "X"]
    assert task_events
    assert all("lane" in e["args"] for e in task_events
               if e.get("cat") in ("task", "sched"))
    # round-trips through JSON on disk
    path = tmp_path / "trace.json"
    tracer.save(str(path))
    assert validate(json.loads(path.read_text())) == []
    tracer.detach()


def test_validate_flags_negative_duration():
    bad = to_chrome([Span("t", "task", 2.0, 1.0, 1, {"lane": "w"})])
    # the exporter clamps dur, so corrupt the event directly
    bad["traceEvents"][-1]["dur"] = -5.0
    assert any("negative dur" in p for p in validate(bad))


def test_validate_flags_non_nesting_overlap():
    spans = [
        Span("a", "task", 0.0, 1.0, 7, {}),
        Span("b", "task", 0.5, 1.5, 7, {}),  # overlaps a on the same tid
    ]
    assert any("overlaps" in p for p in validate(to_chrome(spans)))
    # same spans on different tids are fine
    ok = [Span("a", "task", 0.0, 1.0, 7, {}),
          Span("b", "task", 0.5, 1.5, 8, {})]
    assert validate(to_chrome(ok)) == []


def test_validate_rejects_malformed_container():
    assert validate({}) == ["traceEvents missing or not a list"]


def test_trace_lanes_match_explain_groups(cluster):
    """Gang-task spans carry the gang group's label — the same string
    job.explain() prints as group=."""
    w = IWorker(cluster, "python")
    g = w.groups(1)[0]
    tracer = JobTracer()
    job = IJob("gang", group=g)
    tracer.attach(job)
    df = w.parallelize(np.arange(32, dtype=np.int32))
    assert df.count_async(job=job).result() == 32
    lanes = {s.args.get("lane") for s in tracer.spans() if s.cat == "task"}
    assert g.label() in lanes


def test_tracer_summary_and_profile_mount(worker):
    job, tracer = _traced_run(worker)
    summ = tracer.summary()
    assert summ["tasks"] >= 3
    assert summ["makespan_ms"] > 0
    assert summ["cost"]["tasks_observed"] >= 3
    # attach_worker mounted the profile/ namespace on the worker tree,
    # and attach() mounts it on the job tree
    assert worker.metrics("profile")["tasks"] == summ["tasks"]
    assert job.metrics("profile")["tasks"] == summ["tasks"]
    tracer.detach()


# ---------------------------------------------------------------------------
# replay: determinism + semantics
# ---------------------------------------------------------------------------


def _diamond():
    # a -> (b, c) -> d, b and c on different lanes
    return Trace(tasks=(
        TaskRecord(0, "a", "stage", "w0", 1.0),
        TaskRecord(1, "b", "stage", "w0", 2.0, deps=(0,)),
        TaskRecord(2, "c", "stage", "w1", 3.0, deps=(0,)),
        TaskRecord(3, "d", "action", "w0", 1.0, deps=(1, 2)),
    ), wall_s=5.0)


def test_replay_is_deterministic():
    tr = _diamond()
    s1 = simulate(tr, Hypothesis(lanes=2))
    s2 = simulate(tr, Hypothesis(lanes=2))
    assert s1 == s2
    assert s1.order == s2.order and s1.task_times == s2.task_times


def test_replay_diamond_semantics():
    s = simulate(_diamond())
    # b and c overlap on separate lanes; d waits for the slower branch
    assert s.makespan_s == pytest.approx(1.0 + 3.0 + 1.0)
    assert s.task_times[3][0] == pytest.approx(4.0)
    assert s.order == (0, 1, 2, 3)


def test_replay_single_lane_serialises():
    s = simulate(_diamond(), Hypothesis(lanes=1))
    assert s.makespan_s == pytest.approx(1.0 + 2.0 + 3.0 + 1.0)
    assert s.lanes == ("lane0",)


def test_replay_settle_frees_lane_but_blocks_dependents():
    # a's settle tail overlaps b (same lane), but c depends on a so it
    # waits for the settle to finish — the live one-way lock drop.
    tr = Trace(tasks=(
        TaskRecord(0, "a", "stage", "w0", 1.0, settle_s=2.0),
        TaskRecord(1, "b", "stage", "w0", 1.0),
        TaskRecord(2, "c", "stage", "w1", 0.5, deps=(0,)),
    ))
    s = simulate(tr)
    assert s.task_times[1][0] == pytest.approx(1.0)   # lane free after body
    assert s.task_times[2][0] == pytest.approx(3.0)   # dep waits for settle


def test_replay_speculative_timeout_caps_straggler():
    tr = Trace(tasks=(
        TaskRecord(0, "a", "stage", "w0", 1.0),
        TaskRecord(1, "b", "stage", "w1", 50.0),  # straggler
        TaskRecord(2, "c", "stage", "w0", 1.0),
    ))
    base = simulate(tr).makespan_s
    cut = simulate(tr, Hypothesis(speculative_timeout_s=2.0)).makespan_s
    # duplicate finishes in typical(stage)=1s once the 2s deadline passes
    assert base == pytest.approx(50.0)
    assert cut == pytest.approx(3.0)


def test_replay_scale_and_price_override():
    tr = _diamond()
    assert simulate(tr, Hypothesis(scale=2.0)).makespan_s == pytest.approx(
        2 * simulate(tr).makespan_s)
    flat = simulate(tr, price=lambda t: 1.0)
    assert flat.makespan_s == pytest.approx(3.0)  # a -> max(b,c) -> d, 1s each


def test_replay_cycle_raises():
    tr = Trace(tasks=(
        TaskRecord(0, "a", "stage", "w0", 1.0, deps=(1,)),
        TaskRecord(1, "b", "stage", "w0", 1.0, deps=(0,)),
    ))
    with pytest.raises(ValueError, match="cycle"):
        simulate(tr)


def test_capture_and_identity_replay_accuracy(worker):
    job, tracer = _traced_run(worker, n_actions=4)
    tr = capture(job)
    assert len(tr.tasks) >= 4 and tr.wall_s > 0
    r = predicted_vs_measured(job)
    assert r["tasks"] == len(tr.tasks)
    # identity replay of a serial single-worker capture tracks the wall
    assert 0.0 < r["accuracy"] <= 1.0
    tracer.detach()


# ---------------------------------------------------------------------------
# metrics tree + facade equivalence
# ---------------------------------------------------------------------------


def test_counters_are_plain_dicts():
    c = Counters("demo", {"hits": 0}, docs={"hits": "cache hits"})
    c["hits"] += 2
    c["grown"] = 1  # unknown-key writes allowed
    assert dict(c) == {"hits": 2, "grown": 1}
    assert c.describe() == {"hits": "cache hits"}
    assert c.snapshot() == dict(c) and c.snapshot() is not c


def test_metrics_tree_paths_and_unknown_key():
    live = Counters("x", {"n": 1})
    tree = MetricsTree(x=live, thunk=lambda: {"v": 7})
    tree.mount("a/b", {"deep": True})
    live["n"] += 1  # mounts are live, not copies
    snap = tree.snapshot()
    assert snap["x"] == {"n": 2}
    assert snap["thunk"] == {"v": 7}
    assert tree.snapshot("a/b") == {"deep": True}
    with pytest.raises(KeyError, match="have:"):
        tree.snapshot("typo")


def test_worker_facades_equal_metrics_tree(worker):
    df = worker.parallelize(np.arange(48, dtype=np.int32))
    assert df.map(lambda x: x * 2).map(lambda x: x + 1).count() == 48
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        assert worker.stage_stats() == worker.metrics("stages")
        merged = {**worker.metrics("shuffle"), **worker.metrics("kernels"),
                  **worker.metrics("coll")}
        assert worker.shuffle_stats() == merged
    assert {"coll", "kernels", "shuffle", "stages"} <= worker.metrics().keys()


def test_job_stats_facade_equals_metrics(worker):
    job = IJob("facade")
    df = worker.parallelize(np.arange(16, dtype=np.int32))
    assert df.count_async(job=job).result() == 16
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        old = job.stats()
    tree = job.metrics()
    assert old["coll"] == tree["coll"]
    for k in ("tasks", "done", "failed", "wall_ms"):
        assert k in old and k in tree["tasks"]
    # facades are marked deprecated (once per process — may have fired
    # already in this run, so only check the category when present)
    assert all(issubclass(w.category, DeprecationWarning) for w in rec)


def test_old_accessors_emit_deprecation_once(worker):
    from repro.core import metrics as m
    m._warned.discard("IWorker.stage_stats()->IWorker.metrics(\"stages\")")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        worker.stage_stats()
        worker.stage_stats()
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1 and "metrics" in str(dep[0].message)


# ---------------------------------------------------------------------------
# typed property registry
# ---------------------------------------------------------------------------


def test_unknown_ignis_key_warns_once_but_stores():
    from repro.core import properties as P
    P._warned_keys.discard("ignis.totally.unknown")  # props: ignore
    props = IProperties()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        props["ignis.totally.unknown"] = "1"  # props: ignore
        props["ignis.totally.unknown"] = "2"  # props: ignore
        props["app.private.key"] = "ok"  # non-ignis prefix: silent
    assert len([w for w in rec if "unknown property" in str(w.message)]) == 1
    assert props["ignis.totally.unknown"] == "2"  # props: ignore
    assert "unknown property 'ignis.totally.unknown'" in str(  # props: ignore
        props.validate())


def test_invalid_value_warns_but_stores():
    from repro.core import properties as P
    P._warned_keys.discard("ignis.task.attempts=lots")
    props = IProperties()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        props["ignis.task.attempts"] = "lots"
    assert any("expected an integer" in str(w.message) for w in rec)
    assert props["ignis.task.attempts"] == "lots"  # stored anyway
    assert props.get_int("ignis.task.attempts", 2) == 2  # getter absorbs
    assert any("expected an integer" in p for p in props.validate())


def test_speculative_timeout_auto_validator():
    props = IProperties()
    spec = props.describe("ignis.task.speculative.timeout")
    assert spec is not None and spec.type == "str"
    assert spec.check("auto") is None
    assert spec.check("2.5") is None
    assert spec.check("fast") is not None


def test_registry_defaults_are_valid():
    assert IProperties().validate() == []


def test_choices_enforced_in_validate():
    props = IProperties()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        props["ignis.fusion.mode"] = "greedy"
    assert any("ignis.fusion.mode" in p for p in props.validate())


# ---------------------------------------------------------------------------
# decision 1: cost-aware fusion boundaries
# ---------------------------------------------------------------------------


def test_cost_fusion_defers_then_fuses():
    f1, f2 = (lambda x: x * 2), (lambda x: x + 1)

    def build(w):
        return w.parallelize(np.arange(64, dtype=np.int32)).map(f1).map(f2)

    cl = ICluster(IProperties({"ignis.fusion.mode": "cost"}))
    w = IWorker(cl, "python")
    assert w.engine.fusion_mode == "cost" and w.engine.cost_model is not None

    assert build(w).count() == 64  # first sighting: compile unamortised
    assert w.engine.stats["fusion_deferred"] == 1
    assert w.engine.stats["fused_stages"] == 0

    assert build(w).count() == 64  # second sighting: amortised, fuse
    assert w.engine.stats["fused_stages"] == 1
    cost = w.engine.cost_model.snapshot()
    assert cost["fuse_decisions"] >= 2 and cost["fuse_deferrals"] >= 1


def test_explain_does_not_consume_sightings():
    f1, f2 = (lambda x: x * 2), (lambda x: x - 3)
    cl = ICluster(IProperties({"ignis.fusion.mode": "cost"}))
    w = IWorker(cl, "python")
    df = w.parallelize(np.arange(32, dtype=np.int32)).map(f1).map(f2)
    before = w.engine.cost_model.snapshot()["stage_signatures"]
    w.engine.explain(df.node)
    assert w.engine.cost_model.snapshot()["stage_signatures"] == before


def test_should_fuse_first_sighting_math():
    m = CostModel()
    p = m.params
    # enough blocks that one run's dispatch savings beat the compile
    big = int(2 * p.compile_s_per_op / p.dispatch_s) + 1
    assert m.should_fuse("sigA", n_ops=2, nblocks=big) is True
    assert m.should_fuse("sigB", n_ops=2, nblocks=1) is False
    assert m.should_fuse("sigB", n_ops=2, nblocks=1) is True  # 2nd sighting
    assert m.peek_fuse("sigC") is False  # peek records nothing
    assert m.should_fuse("sigC", n_ops=3, nblocks=1) is False


def test_static_mode_fuses_unconditionally(worker):
    # default mode: no deferral ever, cost model untouched by the planner
    df = worker.parallelize(np.arange(32, dtype=np.int32))
    assert df.map(lambda x: x * 2).map(lambda x: x + 1).count() == 32
    assert worker.engine.stats["fusion_deferred"] == 0
    assert worker.engine.stats["fused_stages"] >= 1


# ---------------------------------------------------------------------------
# decision 2: auto speculative timeouts
# ---------------------------------------------------------------------------


def test_auto_timeout_derives_from_history():
    m = CostModel()
    key = ("stage", "sig")
    assert m.speculative_timeout_s(key, default_s=30.0) == 30.0  # no history
    for d in (1.0, 2.0, 9.0):
        m.observe_task(key, d)
    assert m.typical_s(key) == 2.0  # median
    assert m.speculative_timeout_s(key, factor=3.0) == pytest.approx(6.0)
    # microsecond tasks: floored so jitter can't spawn duplicates
    fast = ("stage", "fast")
    m.observe_task(fast, 1e-5)
    assert m.speculative_timeout_s(fast, factor=3.0) == pytest.approx(0.05)


def test_scheduler_observes_into_engine_cost_model(worker):
    df = worker.parallelize(np.arange(32, dtype=np.int32)).map(lambda x: x + 1)
    before = worker.engine.cost_model.snapshot()["tasks_observed"]
    assert df.count() == 32
    after = worker.engine.cost_model.snapshot()["tasks_observed"]
    assert after > before


def test_task_history_key_is_structural(worker):
    job = IJob("keys")
    df = worker.parallelize(np.arange(8, dtype=np.int32)).map(lambda x: x + 1)
    assert df.count_async(job=job).result() == 8
    keys = {task_history_key(t) for t in job.tasks}
    assert keys and all(isinstance(k, tuple) and len(k) == 2 for k in keys)


def test_auto_timeout_used_by_gang_scheduler(cluster):
    """End to end: timeout=auto routes deadline computation through the
    worker engine's cost model (auto_timeouts counter moves)."""
    cluster.props["ignis.task.speculative"] = "true"
    cluster.props["ignis.task.speculative.timeout"] = "auto"
    w = IWorker(cluster, "python")
    g = w.groups(1)[0]
    before = w.engine.cost_model.snapshot()["auto_timeouts"]
    job = IJob("auto", group=g)
    df = w.parallelize(np.arange(16, dtype=np.int32))
    assert df.count_async(job=job).result() == 16
    assert w.engine.cost_model.snapshot()["auto_timeouts"] > before
