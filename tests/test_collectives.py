"""Collective conformance suite (docs/collectives.md): every collective ×
dtype × communicator, each call shape — blocking facade, nonblocking
``i*`` handle, persistent plan — must be BIT-identical to a NumPy oracle.
p=1 makes most wire patterns the identity, which is exactly what makes
the oracle exact; the 8-way shapes live in tests/_distributed_main.py.

The dtype axis exists because of history: PR 2 fixed reduction identities
that were silently wrong for ints (an all-negative max must not return
the f32 identity 0), so max/min run against all-negative / all-positive
int operands here, per call shape, forever.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import ICluster, IProperties, IWorker, comm


@pytest.fixture(scope="module")
def worker():
    return IWorker(ICluster(IProperties()), "python")


def _ctx(worker, kind):
    # "world" is the flat base communicator; "group" is MPI_Comm_create on
    # rank {0} — at p=1 the same span, but a DISTINCT context/mesh-keyed
    # plan, so group-portability of every call shape is exercised
    return worker.context if kind == "world" else worker.context.group([0])


_DTYPES = {
    "f32": np.array([2.5, -1.25, 0.5, 3.0], np.float32),
    "i32": np.array([7, -3, 11, 0], np.int32),
    "bool": np.array([True, False, True, True], np.bool_),
}

# (collective, op) → NumPy oracle at p=1. bool skips sum (MPI has no
# well-defined MAX/MIN/SUM promotion for logicals beyond lor/land — we
# map them onto max/min).
_REDUCE_OPS = {
    "sum": np.sum,
    "max": np.max,
    "min": np.min,
}


def _assert_bits(got, exp):
    got = np.asarray(got)
    exp = np.asarray(exp)
    assert got.dtype == exp.dtype, (got.dtype, exp.dtype)
    assert got.shape == exp.shape, (got.shape, exp.shape)
    assert np.array_equal(got, exp), (got, exp)


# bool × sum is not generated: bool reduces via max/min (lor/land), there
# is no MPI_SUM for logicals to conform to
_ALLREDUCE_CASES = [(d, o) for d in sorted(_DTYPES) for o in sorted(_REDUCE_OPS)
                    if (d, o) != ("bool", "sum")]


@pytest.mark.parametrize("kind", ["world", "group"])
@pytest.mark.parametrize("dtype,op", _ALLREDUCE_CASES)
def test_allreduce_conformance(worker, kind, dtype, op):
    ctx = _ctx(worker, kind)
    x = comm.shard_rows(ctx, _DTYPES[dtype])
    exp = np.asarray(_REDUCE_OPS[op](_DTYPES[dtype]), _DTYPES[dtype].dtype)
    _assert_bits(comm.allreduce(ctx, x, op), exp)          # blocking
    _assert_bits(comm.iallreduce(ctx, x, op).wait(), exp)  # nonblocking
    plan = comm.persistent(ctx, "allreduce", x, op=op)     # persistent
    _assert_bits(plan(x), exp)
    _assert_bits(plan.start(x).wait(), exp)
    _assert_bits(comm.reduce(ctx, x, op), exp)             # root variant


@pytest.mark.parametrize("kind", ["world", "group"])
@pytest.mark.parametrize("dtype", sorted(_DTYPES))
@pytest.mark.parametrize("coll", ["bcast", "scatter", "gather", "alltoall",
                                  "ppermute"])
def test_data_movement_conformance(worker, kind, dtype, coll):
    """At p=1 every movement pattern is the identity permutation — any
    other output means rows went to the wrong peer."""
    ctx = _ctx(worker, kind)
    arr = _DTYPES[dtype]
    x = comm.shard_rows(ctx, arr) if coll != "bcast" else arr
    blocking = getattr(comm, coll)
    nonblocking = getattr(comm, "i" + coll)
    _assert_bits(blocking(ctx, x), arr)
    _assert_bits(nonblocking(ctx, x).wait(), arr)
    if coll in ("bcast", "scatter"):  # placement-only plans
        plan = comm.persistent(ctx, coll)
    else:
        plan = comm.persistent(ctx, coll, x)
    _assert_bits(plan(x), arr)


@pytest.mark.parametrize("kind", ["world", "group"])
@pytest.mark.parametrize("dtype", ["f32", "i32"])
def test_exscan_conformance(worker, kind, dtype):
    ctx = _ctx(worker, kind)
    one = _DTYPES[dtype][:1]  # (p,) = (1,) per-rank scalar
    x = comm.shard_rows(ctx, one)
    exp = np.zeros(1, one.dtype)  # rank 0's exclusive prefix is empty
    _assert_bits(comm.exscan(ctx, x), exp)
    _assert_bits(comm.iexscan(ctx, x).wait(), exp)
    _assert_bits(comm.persistent(ctx, "exscan", x)(x), exp)


@pytest.mark.parametrize("kind", ["world", "group"])
def test_barrier_conformance(worker, kind):
    ctx = _ctx(worker, kind)
    assert comm.barrier(ctx) is None
    h = comm.ibarrier(ctx)
    assert h.wait() is None and h.done()
    assert comm.persistent(ctx, "barrier")() is None


@pytest.mark.parametrize("op", ["max", "min"])
def test_int_identity_edge_cases(worker, op):
    """PR 2's bug class: the f32 identity (0 / ±inf cast) leaking into an
    int reduction. An all-negative max and an all-positive min have no
    zero in their range, so a wrong identity changes the answer."""
    ctx = worker.context
    arr = (np.array([-5, -3, -9], np.int32) if op == "max"
           else np.array([7, 3, 9], np.int32))
    exp = np.asarray(_REDUCE_OPS[op](arr), arr.dtype)
    x = comm.shard_rows(ctx, arr)
    _assert_bits(comm.allreduce(ctx, x, op), exp)
    _assert_bits(comm.iallreduce(ctx, x, op).wait(), exp)
    _assert_bits(comm.persistent(ctx, "allreduce", x, op=op)(x), exp)


class _FakeCtx:
    executors = 4
    axis = "data"


def test_ialltoall_rejects_indivisible_rows_at_dispatch():
    """The i* variant must raise at DISPATCH (handle creation), not at
    wait: an invalid exchange never enters flight."""
    with pytest.raises(ValueError, match="divisible"):
        comm.ialltoall(_FakeCtx(), jnp.arange(6, dtype=jnp.int32))
    with pytest.raises(ValueError, match="divisible"):
        comm.persistent(_FakeCtx(), "alltoall", jnp.arange(8, dtype=jnp.int32))


def test_unknown_ops_rejected(worker):
    ctx = worker.context
    x = comm.shard_rows(ctx, np.arange(4, dtype=np.int32))
    with pytest.raises(ValueError, match="allreduce op"):
        comm.allreduce(ctx, x, op="prod")
    with pytest.raises(ValueError, match="exscan"):
        comm.iexscan(ctx, x, op="max")
    with pytest.raises(ValueError, match="unknown collective"):
        comm.persistent(ctx, "alltoallv", x)
    with pytest.raises(ValueError, match="prototype"):
        comm.persistent(ctx, "allreduce")


# ---------------------------------------------------------------------------
# handle semantics (MPI_Test / MPI_Wait contract)
# ---------------------------------------------------------------------------


def test_handle_wait_is_idempotent(worker):
    ctx = worker.context
    x = comm.shard_rows(ctx, np.arange(8, dtype=np.float32))
    h = comm.iallreduce(ctx, x)
    v1 = h.wait()
    v2 = h.wait()  # double-wait: same completed value, no re-dispatch
    assert v1 is v2 and h.done()
    ok, v3 = h.test()
    assert ok and v3 is v1


def test_handle_test_and_chain(worker):
    ctx = worker.context
    x = comm.shard_rows(ctx, np.arange(8, dtype=np.float32))
    h = comm.igather(ctx, x).chain(lambda v: np.asarray(v) + 1)
    _assert_bits(h.wait(), np.arange(8, dtype=np.float32) + 1)
    # chaining a completed handle applies immediately
    h2 = comm.igather(ctx, x)
    h2.wait()
    _assert_bits(h2.chain(lambda v: np.asarray(v) * 2).wait(),
                 np.arange(8, dtype=np.float32) * 2)


def test_wait_all_and_out_of_order(worker):
    ctx = worker.context
    xs = [comm.shard_rows(ctx, np.full(4, i, np.float32)) for i in range(6)]
    handles = [comm.iallreduce(ctx, x) for x in xs]
    # await in reverse — completion order must not affect values
    for i in reversed(range(6)):
        _assert_bits(handles[i].wait(), np.float32(4 * i))
    handles = [comm.iallreduce(ctx, x) for x in xs]
    got = comm.wait_all(handles)
    for i, v in enumerate(got):
        _assert_bits(v, np.float32(4 * i))


def test_plan_cache_hits_and_identical_results(worker):
    """Init-once/invoke-many: the second persistent() for the same
    (coll, aval, mesh) is a cache HIT and must return identical bits."""
    ctx = worker.context
    x = comm.shard_rows(ctx, np.arange(16, dtype=np.float32))
    before = comm.comm_stats()
    a = comm.persistent(ctx, "allreduce", x)(x)
    after_first = comm.comm_stats()
    b = comm.persistent(ctx, "allreduce", x)(x)
    after = comm.comm_stats()
    _assert_bits(a, b)
    assert after["coll_plan_hits"] > after_first["coll_plan_hits"]
    assert after["coll_plan_misses"] == after_first["coll_plan_misses"]
    assert after["coll_calls"] >= before["coll_calls"] + 2


def test_group_plans_keyed_separately(worker):
    """A group communicator must never reuse the flat world's compiled
    plan: the key includes the (sub)mesh."""
    ctx = worker.context
    g = ctx.group([0])
    x = np.arange(4, dtype=np.float32)
    base = comm.comm_stats()["coll_plan_misses"]
    comm.allreduce(ctx, comm.shard_rows(ctx, x))
    mid = comm.comm_stats()["coll_plan_misses"]
    comm.allreduce(g, comm.shard_rows(g, x))
    assert comm.comm_stats()["coll_plan_misses"] >= mid
    # …but repeating on the same group hits
    h0 = comm.comm_stats()["coll_plan_hits"]
    comm.allreduce(g, comm.shard_rows(g, x))
    assert comm.comm_stats()["coll_plan_hits"] > h0
    assert comm.comm_stats()["coll_plan_misses"] >= base


# ---------------------------------------------------------------------------
# thread-safety (handles are group-portable ACROSS THREADS — PR 6 review)
# ---------------------------------------------------------------------------


def test_concurrent_waits_finalize_exactly_once(worker):
    """Racing ``wait()``/``test()`` from many threads must apply the
    handle's transform exactly once and hand every thread the same value —
    the double-transform race the per-handle lock closes. ``handles_awaited``
    must also count the handle once, not per waiter."""
    import threading

    ctx = worker.context
    x = comm.shard_rows(ctx, np.arange(8, dtype=np.float32))
    for _ in range(10):
        calls = []
        h = comm.igather(ctx, x).chain(
            lambda v: (calls.append(1), np.asarray(v) + 1)[1])
        awaited0 = comm.comm_stats()["handles_awaited"]
        n = 8
        barrier = threading.Barrier(n)
        got = [None] * n

        def waiter(i):
            barrier.wait()
            if i % 2:
                ok, v = h.test()
                got[i] = v if ok else h.wait()
            else:
                got[i] = h.wait()

        threads = [threading.Thread(target=waiter, args=(i,)) for i in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(calls) == 1, f"transform applied {len(calls)} times"
        assert comm.comm_stats()["handles_awaited"] == awaited0 + 1
        for v in got:
            _assert_bits(v, np.arange(8, dtype=np.float32) + 1)


def test_plan_build_race_compiles_once(worker):
    """Threads missing the same plan key concurrently must cost ONE
    trace+jit total (late arrivals park on the in-flight build), so
    ``coll_plan_misses`` counts distinct init-once events — the
    ``recompiles=0`` gate in bench_collectives depends on this."""
    import threading

    ctx = worker.context
    x = comm.shard_rows(ctx, np.arange(32, dtype=np.float32))
    comm.engine().clear()  # force the next allreduce for this aval to miss
    before = comm.comm_stats()["coll_plan_misses"]
    n = 6
    barrier = threading.Barrier(n)
    outs = [None] * n

    def go(i):
        barrier.wait()
        outs[i] = comm.allreduce(ctx, x)

    threads = [threading.Thread(target=go, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert comm.comm_stats()["coll_plan_misses"] == before + 1
    for v in outs:
        _assert_bits(v, np.float32(np.arange(32, dtype=np.float32).sum()))
