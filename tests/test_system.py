"""End-to-end system behaviour: the unified runtime (paper's contribution)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import ICluster, IProperties, IWorker, Ignis
from repro.core.dag import DagEngine


@pytest.fixture
def worker():
    Ignis.start()
    return IWorker(ICluster(IProperties()), "python")


def test_map_filter_count_collect(worker):
    df = worker.parallelize(np.arange(100, dtype=np.int32))
    d2 = df.map(lambda x: x * 2).filter(lambda x: x % 3 == 0)
    assert d2.count() == sum(1 for x in range(100) if (2 * x) % 3 == 0)
    got = sorted(int(x) for x in d2.collect())
    assert got == sorted(2 * x for x in range(100) if (2 * x) % 3 == 0)


def test_reduce_and_aggregate(worker):
    df = worker.parallelize(np.arange(1, 51, dtype=np.int32))
    assert int(df.reduce(lambda a, b: a + b)) == sum(range(1, 51))
    assert int(df.fold(0, lambda a, b: a + b)) == sum(range(1, 51))


def test_reduce_by_key(worker):
    df = worker.parallelize(np.arange(60, dtype=np.int32))
    kv = df.map(lambda x: {"key": x % 7, "value": x})
    got = {int(np.asarray(r["key"])): int(np.asarray(r["value"]))
           for r in kv.reduce_by_key(lambda a, b: a + b).collect()}
    exp = {k: sum(x for x in range(60) if x % 7 == k) for k in range(7)}
    assert got == exp


def test_join_inner(worker):
    l = worker.parallelize(np.arange(12, dtype=np.int32)).map(
        lambda x: {"key": x % 4, "value": x})
    r = worker.parallelize(np.arange(8, dtype=np.int32)).map(
        lambda x: {"key": x % 4, "value": x * 10})
    rows = l.join(r).collect()
    got = sorted((int(np.asarray(x["key"])), int(np.asarray(x["value"][0])),
                  int(np.asarray(x["value"][1]))) for x in rows)
    exp = sorted((a % 4, a, b * 10) for a in range(12) for b in range(8)
                 if a % 4 == b % 4)
    assert got == exp


def test_sort_distinct_union(worker):
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 500, 80).astype(np.int32)
    s = [int(x) for x in worker.parallelize(vals).sort().collect()]
    assert s == sorted(int(v) for v in vals)
    d = worker.parallelize(np.array([5, 5, 1, 1, 1, 9], np.int32)).distinct()
    assert sorted(int(x) for x in d.collect()) == [1, 5, 9]
    u = worker.parallelize(np.array([1, 2], np.int32)).union(
        worker.parallelize(np.array([3], np.int32)))
    assert sorted(int(x) for x in u.collect()) == [1, 2, 3]


def test_lazy_evaluation_and_cache(worker):
    df = worker.parallelize(np.arange(10, dtype=np.int32))
    m = df.map(lambda x: x + 1)
    assert m.node.compute_count == 0  # nothing ran yet (lazy, paper §4.1)
    m.cache()
    m.count()
    m.count()
    assert m.node.compute_count == 1  # cached: computed once


def test_lineage_recovery(worker):
    df = worker.parallelize(np.arange(40, dtype=np.int32), blocks=4)
    m1 = df.map(lambda x: x + 1).persist()
    m2 = m1.map(lambda x: x * 2).persist()
    assert m2.count() == 40
    c1 = m1.node.compute_count
    DagEngine.kill_block(m2.node, 2)  # lose one executor's cached block
    assert m2.count() == 40
    assert m1.node.compute_count == c1  # cached ancestor untouched
    assert worker.engine.stats["block_recomputes"] == 1  # only the lost block


def test_import_data_between_workers(worker):
    cluster = worker.cluster
    w2 = IWorker(cluster, "cpp")
    df = worker.parallelize(np.arange(16, dtype=np.int32)).map(lambda x: x * 3)
    imported = w2.import_data(df)
    assert sorted(int(x) for x in imported.collect()) == [3 * x for x in range(16)]


def test_spark_mode_parity(worker):
    """spark mode must be numerically identical — only slower (the pipe)."""
    ws = IWorker(ICluster(IProperties({"ignis.mode": "spark"})), "python")
    data = np.arange(50, dtype=np.int32)
    for w in (worker, ws):
        kv = w.parallelize(data).map(lambda x: {"key": x % 5, "value": x})
        out = {int(np.asarray(r["key"])): int(np.asarray(r["value"]))
               for r in kv.reduce_by_key(lambda a, b: a + b).collect()}
        assert out == {k: sum(x for x in range(50) if x % 5 == k) for k in range(5)}


def test_group_by_key(worker):
    df = worker.parallelize(np.arange(20, dtype=np.int32))
    g = df.map(lambda x: {"key": x % 3, "value": x}).group_by_key(group_capacity=8)
    rows = g.collect()
    assert len(rows) == 3
    for r in rows:
        k = int(np.asarray(r["key"]))
        members = sorted(int(v) for v, m in
                         zip(np.asarray(r["value"]["items"]),
                             np.asarray(r["value"]["mask"])) if m)
        assert members == [x for x in range(20) if x % 3 == k]


def test_count_by_value_and_sample(worker):
    cbv = worker.parallelize(np.array([1, 1, 2, 5, 5, 5], np.int32)).count_by_value()
    assert cbv == {1: 2, 2: 1, 5: 3}
    s = worker.parallelize(np.arange(1000, dtype=np.int32)).sample(0.3, seed=1)
    assert 200 < s.count() < 400


def test_properties_system():
    p = IProperties({"ignis.executor.memory": "2GB"})
    assert p.get_bytes("ignis.executor.memory") == 2 * 2**30
    assert p.get_int("ignis.executor.instances") == 1
    assert "ignis.mode" in p
    v = p.view("ignis.executor.")
    assert "ignis.executor.memory" in v


def test_speculative_evaluation(worker):
    """Straggler mitigation: deadline-based duplicate execution."""
    df = worker.parallelize(np.arange(20, dtype=np.int32)).map(lambda x: x + 1)
    blocks = worker.engine.evaluate_speculative(df.node, timeout_s=30.0)
    assert len(blocks) == 1
    # force the speculative path with an immediate deadline
    df2 = worker.parallelize(np.arange(20, dtype=np.int32)).map(lambda x: x * 2)
    blocks2 = worker.engine.evaluate_speculative(df2.node, timeout_s=0.0)
    assert len(blocks2) == 1
    assert worker.engine.stats.get("speculative_retries", 0) >= 1


def test_sample_by_key_and_take_sample(worker):
    kv = worker.parallelize(np.arange(400, dtype=np.int32)).map(
        lambda x: {"key": x % 2, "value": x})
    s = kv.sample_by_key({0: 1.0, 1: 0.0}, seed=3)
    rows = s.collect()
    assert all(int(np.asarray(r["key"])) == 0 for r in rows)
    assert len(rows) == 200
    ts = kv.take_sample(10, seed=1)
    assert len(ts) == 10


def test_foreach(worker):
    seen = []
    worker.parallelize(np.arange(5, dtype=np.int32)).foreach(
        lambda r: seen.append(int(np.asarray(r))))
    assert sorted(seen) == [0, 1, 2, 3, 4]
