"""Elasticity conformance tier at p=8 (docs/elasticity.md) — run in a
subprocess with 8 host devices (tests/test_elastic.py drives this; the XLA
flag must precede the jax import and must NOT leak into the main pytest
process).

The matrix: every action kind (narrow / fused / wide across all shuffle
kinds / native / action) evaluated across a grow(2) and a shrink(2) must be
bit-identical to the static-mesh oracle, with EXACT ``reshard_moves``
counters and zero recomputes on unaffected cached partitions. Plus: live
jobs spanning a resize, groups-cache revalidation, shuffle capacity memory
across world sizes, seeded random join/leave sequences against a pure-numpy
oracle, and shape-changing ``restore_elastic``.
"""
import os
import tempfile

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import ICluster, IProperties, IWorker  # noqa: E402
from repro.core.job import IJob  # noqa: E402
from repro.core.partition import block_devices  # noqa: E402
from repro.distributed.elastic import ElasticPolicy, restore_elastic  # noqa: E402


def check(name, ok):
    print(f"{name}: {'OK' if ok else 'FAIL'}")
    if not ok:
        raise SystemExit(1)


def canon(df):
    return sorted(map(repr, df.collect()))


def native_scale(ctx, data, valid):
    return data * jnp.int32(2), valid


def build_frames(w, vals):
    fr = {
        "src": w.parallelize(vals),
        "kv_l": w.parallelize(np.arange(256, dtype=np.int32)),
        "kv_r": w.parallelize(np.arange(64, dtype=np.int32)),
    }
    fr["mapped"] = fr["src"].map(lambda x: x * np.int32(3) ^ np.int32(5)).persist()
    fr["mapped"].count()  # materialise the persisted cache pre-resize
    return fr


def run_matrix(w, fr):
    """One result per action kind, canonicalized mesh-independently."""
    out = {}
    out["narrow"] = canon(fr["src"].map(lambda x: x + np.int32(9)))
    out["fused"] = canon(
        fr["src"].map(lambda x: x * np.int32(2))
        .map(lambda x: x - np.int32(3)).filter(lambda x: x % 3 == 0))
    out["wide_sort"] = [int(x) for x in fr["mapped"].sort().collect()]
    out["wide_distinct"] = canon(fr["src"].map(lambda x: x % 17).distinct())
    out["wide_reduceByKey"] = canon(
        fr["src"].map(lambda x: {"key": x % 13, "value": jnp.int32(1)})
        .reduce_by_key(lambda a, b: a + b, 0))
    gk = fr["kv_l"].map(lambda x: {"key": x % 7, "value": x}).group_by_key(
        group_capacity=64)
    out["wide_groupByKey"] = sorted(
        (int(np.asarray(r["key"])),
         tuple(sorted(int(v) for v, m in
                      zip(np.asarray(r["value"]["items"]),
                          np.asarray(r["value"]["mask"])) if m)))
        for r in gk.collect())
    out["wide_partitionBy"] = sorted(
        int(np.asarray(r["value"])) for r in
        fr["kv_l"].map(lambda x: {"key": x % 5, "value": x})
        .partition_by().collect())
    out["wide_join"] = sorted(
        (int(np.asarray(x["key"])), int(np.asarray(x["value"][0])),
         int(np.asarray(x["value"][1])))
        for x in fr["kv_l"].map(lambda x: {"key": x % 8, "value": x})
        .join(fr["kv_r"].map(lambda x: {"key": x % 8, "value": x * 2}))
        .collect())
    out["native"] = [int(x) for x in w.call(native_scale, fr["mapped"]).collect()]
    out["action_count"] = fr["mapped"].count()
    out["action_take"] = [int(x) for x in fr["src"].take(5)]
    return out


def main():
    assert len(jax.devices()) == 8, jax.devices()
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 100000, 4096).astype(np.int32)

    # ---- conformance matrix across grow(2) + shrink(2) ---------------------
    w = IWorker(ICluster(IProperties({"ignis.executor.instances": "4"})), "python")
    fr = build_frames(w, vals)
    # one group-pinned cached partition: the "unaffected" case — resident
    # wholly on surviving sub-group devices, it must never move
    gvals = np.arange(128, dtype=np.int32)
    with w.use_group(w.groups(2)[0]):
        gframe = w.parallelize(gvals)
        g_oracle = canon(gframe.map(lambda x: x * np.int32(7)))
    gs4 = w.groups(2)
    g_devs0 = block_devices(gframe.node.result[0])

    oracle = run_matrix(w, fr)  # static world-4 oracle
    world_blocks = 4  # src + mapped + kv_l + kv_r (one block each)
    eng0 = w.metrics("stages")["block_recomputes"]
    mapped_cc = fr["mapped"].node.compute_count

    check("p8_grow_returns_world", w.grow(2) == 6 and w.executors == 6)
    st = w.metrics("elastic")
    check("p8_grow_exact_counters",
          st["grows"] == 1 and st["world_size"] == 6
          and st["reshard_moves"] == world_blocks
          and st["reshard_unchanged"] == 1
          and st["reshard_recomputes"] == 0)
    check("p8_grow_unaffected_partition_not_moved",
          block_devices(gframe.node.result[0]) == g_devs0)

    post_grow = run_matrix(w, fr)
    for kind in oracle:
        check(f"p8_grow_bit_identical_{kind}", post_grow[kind] == oracle[kind])
    check("p8_grow_zero_recomputes",
          w.metrics("stages")["block_recomputes"] == eng0
          and fr["mapped"].node.compute_count == mapped_cc)

    # new submissions bind the resized mesh
    src6 = w.parallelize(vals[:512])
    check("p8_new_submission_binds_grown_mesh",
          block_devices(src6.node.result[0])
          == frozenset(w.context.mesh.devices.flat)
          and len(block_devices(src6.node.result[0])) == 6)

    # groups-cache revalidation: the cached split must rebuild for the new
    # world instead of handing out stale 4-rank sub-meshes
    gs6 = w.groups(2)
    check("p8_groups_revalidate_after_grow",
          gs6[0] is not gs4[0]
          and [g.group_ranks for g in gs6] == [(0, 1, 2), (3, 4, 5)])

    check("p8_shrink_returns_world", w.shrink(2) == 4 and w.executors == 4)
    st = w.metrics("elastic")
    check("p8_shrink_exact_counters",
          st["shrinks"] == 1 and st["world_size"] == 4
          and st["reshard_moves"] == 2 * world_blocks + 1  # + src6's block
          and st["reshard_unchanged"] == 2
          and st["reshard_recomputes"] == 0)

    post_shrink = run_matrix(w, fr)
    for kind in oracle:
        check(f"p8_shrink_bit_identical_{kind}", post_shrink[kind] == oracle[kind])
    check("p8_shrink_zero_recomputes",
          w.metrics("stages")["block_recomputes"] == eng0)

    # the group-pinned partition still evaluates identically under the
    # re-split world
    with w.use_group(w.groups(2)[0]):
        check("p8_group_frame_survives_resizes",
              canon(gframe.map(lambda x: x * np.int32(7))) == g_oracle)

    # ---- a live job spans grow(2) then shrink(2) ---------------------------
    job = IJob("elastic-live")
    f1 = fr["mapped"].count_async(job=job)
    check("p8_live_job_grow", w.grow(2) == 6)   # drains f1 on the old comm
    f2 = fr["mapped"].count_async(job=job)
    f3 = fr["mapped"].sort().count_async(job=job)
    check("p8_live_job_shrink", w.shrink(2) == 4)
    f4 = fr["mapped"].count_async(job=job)
    check("p8_live_job_results_bit_identical",
          f1.result() == oracle["action_count"]
          and f2.result() == oracle["action_count"]
          and f3.result() == oracle["action_count"]
          and f4.result() == oracle["action_count"])
    check("p8_live_job_no_failed_tasks", job.metrics("tasks")["failed"] == 0)

    # ---- shuffle capacity memory is keyed per communicator size ------------
    fr["mapped"].sort().count()  # warm the memo for the post-resize capacity
    sh0 = w.metrics("shuffle")
    fr["mapped"].sort().count()  # same world, same capacity: pure memo hit
    sh1 = w.metrics("shuffle")
    check("p8_capacity_memo_hit_same_world",
          sh1["capacity_memory_hits"] > sh0["capacity_memory_hits"]
          and sh1["capacity_memory_misses"] == sh0["capacity_memory_misses"])
    w.grow(1)  # world 5: same lineage, NEW capacity key at p=5
    fr["mapped"].sort().count()
    sh2 = w.metrics("shuffle")
    check("p8_capacity_memo_miss_new_world",
          sh2["capacity_memory_misses"] > sh1["capacity_memory_misses"])
    fr["mapped"].sort().count()
    sh3 = w.metrics("shuffle")
    check("p8_capacity_memo_hit_after_resize",
          sh3["capacity_memory_hits"] > sh2["capacity_memory_hits"]
          and sh3["capacity_memory_misses"] == sh2["capacity_memory_misses"]
          and sh3["overflow_retries"] == sh0["overflow_retries"])
    w.shrink(1)

    # ---- ElasticPolicy: queue-driven autoscaling on a live worker ----------
    w.cluster.props["ignis.elastic.enabled"] = "true"
    w.cluster.props["ignis.elastic.step"] = "2"
    w.cluster.props["ignis.elastic.cooldown.polls"] = "2"
    w.cluster.props["ignis.elastic.queue.per.executor"] = "4"
    pol = ElasticPolicy(w)
    check("p8_policy_cooldown_holds", pol.poll(queue_depth=32) == 0)
    check("p8_policy_grow_step_clamped",
          pol.poll(queue_depth=32) == 2 and w.executors == 6)
    check("p8_policy_idle_shrink",
          pol.poll(queue_depth=0) == 0 and pol.poll(queue_depth=0) == -2
          and w.executors == 4)
    check("p8_policy_results_still_identical",
          fr["mapped"].sort().count() == oracle["action_count"])

    # ---- seeded random join/leave sequences vs pure-numpy oracle -----------
    narrow_ops = [
        (lambda df: df.map(lambda x: x * np.int32(3)),
         lambda a: a * 3),
        (lambda df: df.map(lambda x: x + np.int32(11)),
         lambda a: a + 11),
        (lambda df: df.map(lambda x: x ^ np.int32(0x55)),
         lambda a: a ^ 0x55),
        (lambda df: df.filter(lambda x: x % 2 == 0),
         lambda a: a[a % 2 == 0]),
    ]
    for seed in (0, 1, 2):
        w2 = IWorker(ICluster(IProperties({"ignis.executor.instances": "4"})),
                     "python")
        base = np.random.default_rng(100 + seed).integers(
            0, 5000, 1536).astype(np.int32)
        src2 = w2.parallelize(base)
        r2 = np.random.default_rng(seed)
        ok = True
        for _step in range(6):
            frame, arr = src2, base.copy()
            for _ in range(int(r2.integers(1, 5))):  # 1–4-op chain
                k = int(r2.integers(0, len(narrow_ops)))
                frame = narrow_ops[k][0](frame)
                arr = narrow_ops[k][1](arr)
            if r2.integers(0, 2):  # wide terminal half the time
                ok = ok and [int(x) for x in frame.sort().collect()] \
                    == sorted(int(v) for v in arr)
            else:
                ok = ok and frame.count() == len(arr)
            p = w2.executors
            if p <= 2:
                w2.grow(int(r2.integers(1, 3)))
            elif p >= 7:
                w2.shrink(int(r2.integers(1, 3)))
            elif r2.integers(0, 2):
                w2.grow(int(r2.integers(1, min(3, 8 - p + 1))))
            else:
                w2.shrink(int(r2.integers(1, min(3, p))))
        st2 = w2.metrics("elastic")
        check(f"p8_random_join_leave_seed{seed}",
              ok and st2["reshard_recomputes"] == 0
              and st2["grows"] + st2["shrinks"] == 6
              and w2.metrics("stages")["block_recomputes"] == 0)

    # ---- restore_elastic: shape-changing restores (8→4, 4→8, rejection) ----
    from repro.checkpoint import save
    from repro.configs import get_config
    from repro.launch.mesh import make_local_mesh
    from repro.models import build_model

    cfg = get_config("olmo-1b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(1))
    flat = jax.tree.leaves(params)

    def same(tree):
        return all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(flat, jax.tree.leaves(tree)))

    with tempfile.TemporaryDirectory() as td:
        mesh8 = make_local_mesh(8, 1)
        save(td, 1, {"params": jax.device_put(params)})
        out4 = restore_elastic(td, 1, cfg, make_local_mesh(4, 1),
                               {"params": params})
        check("p8_restore_elastic_8to4", same(out4["params"]))
        save(td, 2, {"params": out4["params"]})  # saved from the 4-way world
        out8 = restore_elastic(td, 2, cfg, mesh8, {"params": params})
        check("p8_restore_elastic_4to8", same(out8["params"]))
        # uneven divisibility: specs degrade to replication, values exact
        out5 = restore_elastic(td, 2, cfg, make_local_mesh(5, 1),
                               {"params": params})
        check("p8_restore_elastic_uneven_world", same(out5["params"]))
        # rejection: a target whose shapes disagree with the manifest
        bad = jax.tree.map(lambda x: x[..., : max(1, x.shape[-1] // 2)], params)
        try:
            restore_elastic(td, 2, cfg, mesh8, {"params": bad})
            check("p8_restore_elastic_shape_rejected", False)
        except ValueError:
            check("p8_restore_elastic_shape_rejected", True)
        # policy-wired restore places onto the worker's CURRENT mesh
        out_w = pol.restore(td, 2, cfg, {"params": params})
        check("p8_policy_restore_on_live_mesh",
              same(out_w["params"])
              and all(frozenset(leaf.sharding.device_set)
                      <= frozenset(w.context.mesh.devices.flat)
                      for leaf in jax.tree.leaves(out_w["params"])))

    print("ALL_ELASTIC_OK")


if __name__ == "__main__":
    main()
