"""Kernel conformance + differential tier (docs/kernels.md, DESIGN.md §11).

Three layers, each against an always-available oracle:

  * **kernel conformance** — every shuffle-tier kernel (prefix_scan,
    segment_totals, bucket_route) × op (sum/max/min) × dtype
    (f32/i32/bool) × edge shape (ragged / empty / single-segment /
    all-invalid) is BIT-identical to its ref.py / core/shuffle oracle in
    interpret mode. f32 sums use integer-valued data (< 2^24) so the
    association order cannot show: bit-identity is the contract, not a
    tolerance (ISSUE 7).
  * **registry semantics** — mode resolution, capability-probe failure
    degrading to the fallback, builtin-op recognition, and the autotune
    memo's LRU + single-builder discipline (comm.py plan-cache pattern).
  * **wide-stage equivalence** — every shuffle kind run with the kernel
    tier forced ON (interpret) and OFF must produce identical collected
    rows AND identical overflow-retry counters, with the kernel actually
    engaged (kernel_hits > 0) on the eligible kinds. The p=8 twin of
    this block lives in tests/_distributed_main.py.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ICluster, IProperties, IWorker
from repro.core import faults
from repro.core.faults import FaultPlan
from repro.core.shuffle import segmented_reduce
from repro.kernels import registry as reg
from repro.kernels.moe_route import bucket_route, bucket_route_ref
from repro.kernels.registry import KernelRegistry, builtin_reduce_op
from repro.kernels.segment_reduce import segment_totals
from repro.kernels.ssd_scan import prefix_scan, prefix_scan_ref

KEY = jax.random.PRNGKey(11)

OPS = ("sum", "max", "min")
_FNS = {"sum": lambda a, b: a + b, "max": jnp.maximum, "min": jnp.minimum}
_IDENT = {"sum": 0, "max": -(2**31 - 1), "min": 2**31 - 1}


def bits_equal(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return a.dtype == b.dtype and a.shape == b.shape and np.array_equal(a, b)


def _data(n, dtype, seed=0):
    """Integer-valued samples: every op is associative-exact, so kernel
    vs oracle must agree to the bit even for float32."""
    r = np.random.default_rng(seed).integers(-1000, 1000, n)
    if dtype == "bool":
        return jnp.asarray(r % 2 == 0)
    return jnp.asarray(r.astype(dtype))


# ---------------------------------------------------------------------------
# prefix_scan — op × dtype × size (ragged/empty/single) × direction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("dtype", ["float32", "int32", "bool"])
@pytest.mark.parametrize("n", [0, 1, 5, 64, 200, 513])
def test_prefix_scan_matches_ref(op, dtype, n):
    x = _data(n, dtype, seed=n)
    for reverse in (False, True):
        got = prefix_scan(x, op=op, block=64, interpret=True, reverse=reverse)
        assert bits_equal(got, prefix_scan_ref(x, op=op, reverse=reverse))


def test_prefix_scan_block_size_is_invisible():
    x = _data(300, "int32")
    ref = prefix_scan_ref(x)
    for block in (1, 7, 128, 512):
        assert bits_equal(prefix_scan(x, block=block, interpret=True), ref)


# ---------------------------------------------------------------------------
# segment_totals — the reduceByKey stage ABI vs core/shuffle.segmented_reduce
# ---------------------------------------------------------------------------


def _segments(n, n_keys, valid_frac, dtype, d=None, seed=3):
    ks = np.random.default_rng(seed)
    keys = jnp.sort(jnp.asarray(ks.integers(0, n_keys, n).astype(np.int32)))
    valid = jnp.asarray(ks.random(n) < valid_frac)
    shape = n if d is None else (n, d)
    vals = jnp.asarray(ks.integers(-50, 50, shape).astype(dtype))
    return keys, valid, vals


@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("dtype", ["float32", "int32"])
@pytest.mark.parametrize("n,n_keys,valid_frac,d", [
    (256, 17, 0.8, None),     # ragged runs, scattered invalids
    (300, 17, 0.8, 4),        # non-multiple of block, row values
    (200, 1, 1.0, None),      # single segment spanning blocks
    (64, 40, 0.0, None),      # all-invalid: every row its own boundary
    (1, 1, 1.0, None),        # single row
])
def test_segment_totals_matches_oracle(op, dtype, n, n_keys, valid_frac, d):
    keys, valid, vals = _segments(n, n_keys, valid_frac, dtype, d)
    ident = jnp.asarray(_IDENT[op], dtype)
    h1, t1 = segment_totals(keys, valid, vals, op, ident, block=64,
                            interpret=True)
    h2, t2 = segmented_reduce(keys, valid, vals, _FNS[op], ident)
    assert bits_equal(h1, h2)
    assert bits_equal(t1, t2)


def test_segment_totals_empty_input():
    z = jnp.zeros(0, jnp.int32)
    h, t = segment_totals(z, jnp.zeros(0, bool), z, "sum", jnp.int32(0),
                          interpret=True)
    assert h.shape == (0,) and t.shape == (0,)


@pytest.mark.parametrize("op", ["max", "min"])
def test_segment_totals_bool_values(op):
    # bool rides as i32; max/min are OR/AND — exact either way
    keys, valid, _ = _segments(128, 9, 0.9, "int32")
    vals = _data(128, "bool", seed=5)
    ident = jnp.asarray(op == "min", bool)
    h1, t1 = segment_totals(keys, valid, vals, op, ident, block=32,
                            interpret=True)
    h2, t2 = segmented_reduce(keys, valid, vals, _FNS[op], ident)
    assert bits_equal(h1, h2) and bits_equal(t1, t2)


def test_segment_totals_nonzero_identity_at_invalid_rows():
    # the user identity never enters a combine, but it IS the output at
    # invalid rows (they are their own segments) — the oracle's contract
    keys, valid, vals = _segments(96, 7, 0.5, "int32", seed=9)
    ident = jnp.int32(41)
    _, t1 = segment_totals(keys, valid, vals, "sum", ident, block=32,
                           interpret=True)
    _, t2 = segmented_reduce(keys, valid, vals, _FNS["sum"], ident)
    assert bits_equal(t1, t2)
    assert bool((t1[~valid] == 41).all())


# ---------------------------------------------------------------------------
# bucket_route — exchange ordinals vs the stable-argsort oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,p,capacity", [
    (0, 4, 2),          # empty
    (1, 2, 1),          # single row
    (100, 8, 20),       # roomy
    (100, 8, 5),        # tight: overflow rows dropped by keep
    (600, 2, 400),      # multi-block
    (257, 5, 1),        # capacity 1, ragged tail
])
def test_bucket_route_matches_ref(n, p, capacity):
    dest = jnp.asarray(
        np.random.default_rng(n + p).integers(0, p, n).astype(np.int32))
    got = bucket_route(dest, p, capacity, block=64, interpret=True)
    ref = bucket_route_ref(dest, p, capacity)
    for g, r in zip(got, ref):
        assert bits_equal(g, r)


def test_bucket_route_all_one_destination():
    dest = jnp.zeros(90, jnp.int32)
    pos, keep, counts = bucket_route(dest, 4, 100, block=32, interpret=True)
    assert bits_equal(pos, jnp.arange(90, dtype=jnp.int32))
    assert bool(keep.all()) and counts[0] == 90 and int(counts.sum()) == 90


# ---------------------------------------------------------------------------
# registry: mode resolution + capability fallback
# ---------------------------------------------------------------------------


def test_registry_rejects_unknown_mode():
    with pytest.raises(ValueError, match="ignis.kernels"):
        KernelRegistry(mode="sometimes")


def test_mode_off_always_falls_back():
    r = KernelRegistry(mode="off")
    assert r.select("segment_reduce") is None
    assert r.stats == {"kernel_hits": 0, "kernel_fallbacks": 1,
                       "autotune_runs": 0, "autotune_evictions": 0}


def test_mode_auto_never_interprets_off_tpu():
    r = KernelRegistry(mode="auto")
    sel = r.select("segment_reduce")
    if reg.compiled_backend():
        assert sel is not None and not sel.interpret
    else:  # interpreted Pallas is strictly slower than the jnp oracle
        assert sel is None and r.stats["kernel_fallbacks"] == 1


def test_mode_interpret_selects_interpreted_kernel():
    r = KernelRegistry(mode="interpret")
    sel = r.select("bucket_route")
    assert sel is not None and sel.interpret
    assert sel.describe() == "bucket_route[interpret]"
    assert r.stats["kernel_hits"] == 1


def test_mode_on_uses_interpret_where_not_compiled():
    r = KernelRegistry(mode="on")
    sel = r.select("prefix_scan")
    assert sel is not None
    assert sel.interpret == (not reg.compiled_backend())


def test_probe_failure_degrades_to_fallback(monkeypatch):
    def boom(interpret):
        raise RuntimeError("no such kernel on this backend")

    monkeypatch.setitem(reg._PROBES, "segment_reduce", boom)
    r = KernelRegistry(mode="interpret")
    assert r.select("segment_reduce") is None
    assert r.stats["kernel_fallbacks"] == 1
    # the probe result is cached: a second select does not re-probe
    monkeypatch.setitem(reg._PROBES, "segment_reduce",
                        lambda interpret: None)
    assert r.select("segment_reduce") is None


def test_capability_fault_degrades_without_error():
    r = KernelRegistry(mode="interpret")
    plan = FaultPlan().fail_kernel_capability("segment_reduce", times=1)
    with faults.inject(plan):
        assert r.select("segment_reduce") is None      # degraded
        assert r.select("segment_reduce") is not None  # times=1: recovered
    assert r.stats["kernel_fallbacks"] == 1 and r.stats["kernel_hits"] == 1


def test_demote_rebooks_hit_as_fallback():
    r = KernelRegistry(mode="interpret")
    assert r.select("prefix_scan") is not None
    r.demote()
    assert r.stats == {"kernel_hits": 0, "kernel_fallbacks": 1,
                       "autotune_runs": 0, "autotune_evictions": 0}


# ---------------------------------------------------------------------------
# registry: builtin-op recognition (what reduceByKey may hand the kernel)
# ---------------------------------------------------------------------------


def test_builtin_reduce_op_recognizes_builtins():
    v, i = jnp.zeros(4, jnp.int32), jnp.int32(0)
    assert builtin_reduce_op(lambda a, b: a + b, i, v) == "sum"
    assert builtin_reduce_op(jnp.maximum, i, v) == "max"
    assert builtin_reduce_op(jnp.minimum, i, v) == "min"
    assert builtin_reduce_op(lambda a, b: a + b, jnp.float32(0),
                             jnp.zeros((4, 2), jnp.float32)) == "sum"


@pytest.mark.parametrize("fn", [
    lambda a, b: a + b + 1,     # extra eqn
    lambda a, b: a + 3,         # constant operand
    lambda a, b: a + a,         # ignores one argument
    lambda a, b: a * b,         # unsupported primitive
    lambda a, b: (a + b) / 2,   # dtype-changing chain
])
def test_builtin_reduce_op_rejects_non_builtins(fn):
    assert builtin_reduce_op(fn, jnp.int32(0), jnp.zeros(4, jnp.int32)) is None


def test_builtin_reduce_op_rejects_unsupported_values():
    add = lambda a, b: a + b  # noqa: E731
    assert builtin_reduce_op(add, np.float64(0),
                             jnp.zeros(4, jnp.float16)) is None
    assert builtin_reduce_op(  # pytree value: not a single leaf
        add, jnp.int32(0),
        {"a": jnp.zeros(4, jnp.int32), "b": jnp.zeros(4, jnp.int32)}) is None
    assert builtin_reduce_op(  # non-scalar identity
        add, jnp.zeros(2, jnp.int32), jnp.zeros(4, jnp.int32)) is None
    assert builtin_reduce_op(  # ndim > 2
        add, jnp.int32(0), jnp.zeros((4, 2, 2), jnp.int32)) is None


# ---------------------------------------------------------------------------
# registry: autotune memo (LRU + single-builder — ISSUE 7 satellite 4)
# ---------------------------------------------------------------------------


def test_tune_memoises_per_key():
    r = KernelRegistry(mode="interpret")
    calls = []
    best = r.tune(("k", 1), (128, 256), lambda b: calls.append(b) or b * 1e-6)
    assert best == 128 and calls == [128, 256]
    assert r.tune(("k", 1), (128, 256), lambda b: 1 / 0) == 128  # memo hit
    assert r.stats["autotune_runs"] == 1


def test_tune_keys_distinguish_ops_and_avals():
    r = KernelRegistry(mode="interpret")
    timer = lambda b: float(b)  # noqa: E731
    for key in (("segment_reduce", "sum", "int32", 256),
                ("segment_reduce", "max", "int32", 256),
                ("segment_reduce", "sum", "int32", 512)):
        r.tune(key, (64, 128), timer)
    assert r.stats["autotune_runs"] == 3


def test_tune_single_candidate_skips_timing():
    r = KernelRegistry(mode="interpret")
    assert r.tune(("k",), (256,), lambda b: 1 / 0) == 256
    assert r.stats["autotune_runs"] == 1  # still counted as a sweep


def test_tune_eviction_retunes_exactly_once():
    r = KernelRegistry(mode="interpret", tune_cache_size=1)
    timer = lambda b: float(b)  # noqa: E731
    for key in (("A",), ("B",), ("A",)):  # B evicts A; A re-tunes
        r.tune(key, (64, 128), timer)
    assert r.stats["autotune_runs"] == 3
    assert r.stats["autotune_evictions"] == 2
    assert r.tune(("A",), (64, 128), timer) == 64  # now memoised again
    assert r.stats["autotune_runs"] == 3


def test_concurrent_misses_on_one_key_cost_one_sweep():
    r = KernelRegistry(mode="interpret")
    calls, gate = [], threading.Event()

    def timer(b):
        calls.append(b)
        gate.wait(5)  # park the builder so every thread reaches tune()
        return float(b)

    threads = [threading.Thread(target=r.tune,
                                args=(("hot",), (64, 128), timer))
               for _ in range(6)]
    for t in threads:
        t.start()
    while not calls:  # one builder is inside the sweep
        pass
    gate.set()
    for t in threads:
        t.join()
    assert r.stats["autotune_runs"] == 1
    assert sorted(calls) == [64, 128]


def test_failed_sweep_unparks_waiters():
    r = KernelRegistry(mode="interpret")
    with pytest.raises(ZeroDivisionError):
        r.tune(("bad",), (64, 128), lambda b: 1 / 0)
    # the key is not poisoned: the next caller re-tunes
    assert r.tune(("bad",), (64, 128), lambda b: float(b)) == 64
    assert r.stats["autotune_runs"] == 1


# ---------------------------------------------------------------------------
# wide-stage equivalence: kernel tier ON vs OFF, every shuffle kind
# ---------------------------------------------------------------------------


def _worker(mode, **props):
    return IWorker(ICluster(IProperties({"ignis.kernels": mode, **props})),
                   "python")


_VALS = np.random.default_rng(2).integers(0, 10_000, 512).astype(np.int32)

# kind → (pipeline, kernel-eligible at p=1?) — partitionBy/join consult the
# router only when there is an exchange (p > 1): see _distributed_main.py
_KINDS = {
    "sort": (lambda df: df.sort(), False),
    "distinct": (lambda df: df.map(lambda x: x % 17).distinct(), False),
    "reduceByKey": (lambda df: df.map(lambda x: {"key": x % 13, "value": x})
                    .reduce_by_key(lambda a, b: a + b, 0), True),
    "groupByKey": (lambda df: df.map(lambda x: {"key": x % 13, "value": x})
                   .group_by_key(), False),
    "partitionBy": (lambda df: df.map(lambda x: {"key": x % 13, "value": x})
                    .partition_by(), False),
    "join": (lambda df: df.map(lambda x: {"key": x % 5, "value": x})
             .join(df.map(lambda x: {"key": x % 5, "value": x * 2}),
                   max_matches=4), False),
}


@pytest.mark.parametrize("kind", sorted(_KINDS))
def test_wide_stage_kernel_on_off_equivalence(kind):
    pipeline, eligible = _KINDS[kind]
    rows, counters = {}, {}
    for mode in ("interpret", "off"):
        w = _worker(mode)
        df = pipeline(w.parallelize(_VALS[:256]))
        rows[mode] = sorted(map(repr, df.collect()))
        s = w.shuffle_stats()
        counters[mode] = (s["overflow_retries"], s["fanout_retries"])
        if mode == "interpret" and eligible:
            assert s["kernel_hits"] >= 1, s
        if mode == "off":
            assert s["kernel_hits"] == 0
    assert rows["interpret"] == rows["off"]
    # the adaptive engine must take the SAME overflow/fan-out trajectory
    # on both tiers (bit-identical routing ⇒ identical retry decisions)
    assert counters["interpret"] == counters["off"]


@pytest.mark.parametrize("op,fn,ident", [
    ("sum", lambda a, b: a + b, 0),
    ("max", jnp.maximum, 0),
    ("min", jnp.minimum, 2**31 - 1),
])
def test_reduce_by_key_kernel_matches_python_oracle(op, fn, ident):
    w = _worker("interpret")
    df = (w.parallelize(_VALS).map(lambda x: {"key": x % 11, "value": x})
          .reduce_by_key(fn, ident))
    got = {int(np.asarray(r["key"])): int(np.asarray(r["value"]))
           for r in df.collect()}
    exp = {}
    red = {"sum": lambda a, b: a + b, "max": max, "min": min}[op]
    for v in _VALS:
        k = int(v) % 11
        exp[k] = red(exp[k], int(v)) if k in exp else int(v)
    assert got == exp
    assert w.shuffle_stats()["kernel_hits"] >= 1


def test_aggregate_by_key_rides_the_kernel_tier():
    w = _worker("interpret")
    df = (w.parallelize(_VALS[:256]).map(lambda x: {"key": x % 7, "value": x})
          .aggregate_by_key(0, lambda z, v: z + v % 3, lambda a, b: a + b))
    got = {int(np.asarray(r["key"])): int(np.asarray(r["value"]))
           for r in df.collect()}
    exp = {}
    for v in _VALS[:256]:
        exp[int(v) % 7] = exp.get(int(v) % 7, 0) + int(v) % 3
    assert got == exp
    assert w.shuffle_stats()["kernel_hits"] >= 1


def test_non_builtin_fn_falls_back_with_identical_results():
    w_on, w_off = _worker("interpret"), _worker("off")
    rows = {}
    for name, w in (("on", w_on), ("off", w_off)):
        df = (w.parallelize(_VALS[:128])
              .map(lambda x: {"key": x % 5, "value": x})
              .reduce_by_key(lambda a, b: a + b + 1, 0))  # not a builtin
        rows[name] = sorted(map(repr, df.collect()))
    assert rows["on"] == rows["off"]
    # the eligible node consulted the registry and was REJECTED before
    # selection (op recognition) — no hit either way
    assert w_on.shuffle_stats()["kernel_hits"] == 0


def test_float_values_stay_exact_for_integer_data():
    # f32 sums of integer-valued data are associative-exact: the kernel
    # path must match the oracle path to the bit
    fvals = _VALS[:256].astype(np.float32)
    rows = {}
    for mode in ("interpret", "off"):
        df = (_worker(mode).parallelize(fvals)
              .map(lambda x: {"key": x % 9, "value": x})
              .reduce_by_key(lambda a, b: a + b, 0.0))
        rows[mode] = [(int(np.asarray(r["key"])),
                       np.asarray(r["value"]).tobytes())
                      for r in sorted(df.collect(),
                                      key=lambda r: int(np.asarray(r["key"])))]
    assert rows["interpret"] == rows["off"]


# ---------------------------------------------------------------------------
# telemetry: stats surface, explain annotation, repeat-run flatness
# ---------------------------------------------------------------------------


def test_kernel_stats_surface_in_shuffle_stats():
    w = _worker("interpret")
    s = w.shuffle_stats()
    for k in ("kernel_hits", "kernel_fallbacks", "autotune_runs",
              "autotune_evictions"):
        assert k in s, sorted(s)


def test_explain_shows_kernel_annotation_and_tuned_block():
    w = _worker("interpret")
    df = (w.parallelize(_VALS[:256]).map(lambda x: {"key": x % 13, "value": x})
          .reduce_by_key(lambda a, b: a + b, 0))
    df.collect()
    text = df.explain()
    assert "kernel=segment_reduce[interpret]" in text
    assert "op=sum" in text and "block=" in text
    assert "kernels: mode=interpret" in text


def test_repeat_lineage_is_tune_and_compile_flat():
    w = _worker("interpret")

    def run():
        return (w.parallelize(_VALS[:256])
                .map(lambda x: {"key": x % 13, "value": x})
                .reduce_by_key(lambda a, b: a + b, 0).collect())

    first = sorted(map(repr, run()))
    s1 = w.shuffle_stats()
    assert s1["autotune_runs"] >= 1
    for _ in range(2):
        assert sorted(map(repr, run())) == first
    s2 = w.shuffle_stats()
    assert s2["autotune_runs"] == s1["autotune_runs"]
    assert s2["wide_plan_misses"] == s1["wide_plan_misses"]


def test_tuned_block_feeds_the_plan_key():
    # different tuned blocks must not collide in the wide-plan cache:
    # force two registries to tune differently by restricting candidates
    wa = _worker("interpret", **{"ignis.kernels.blocks": "64"})
    wb = _worker("interpret", **{"ignis.kernels.blocks": "128"})
    rows, plans = [], []
    for w in (wa, wb):
        df = (w.parallelize(_VALS[:256])
              .map(lambda x: {"key": x % 13, "value": x})
              .reduce_by_key(lambda a, b: a + b, 0))
        rows.append(sorted(map(repr, df.collect())))
        assert w.shuffle_stats()["kernel_hits"] >= 1
        plans.append(df.explain())
    assert rows[0] == rows[1]
    assert "block=64" in plans[0]
    assert "block=128" in plans[1]
