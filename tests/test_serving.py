"""Serve engine: continuous batching correctness vs single-request greedy."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


def _greedy_reference(bundle, params, prompt, n_new):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = bundle.prefill(params, tokens=toks,
                                   cache_len=len(prompt) + n_new + 1)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        t = jnp.asarray([[out[-1]]], jnp.int32)
        logits, cache = bundle.decode_step(params, cache, t)
        out.append(int(jnp.argmax(logits[0])))
    return out


def test_engine_matches_single_request_greedy():
    cfg = get_config("ignis-tiny")
    bundle = build_model(cfg)
    params = bundle.init(KEY)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, int(rng.integers(3, 9)),
                            dtype=np.int32) for _ in range(5)]
    n_new = 6
    eng = ServeEngine(bundle, params, slots=2, cache_len=64)
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=n_new))
    done = eng.run_to_completion()
    assert len(done) == len(prompts)
    by_id = {r.rid: r.tokens for r in done}
    for i, p in enumerate(prompts):
        assert by_id[i] == _greedy_reference(bundle, params, p, n_new), i


def test_engine_slot_reuse_and_truncation():
    cfg = get_config("ignis-tiny")
    bundle = build_model(cfg)
    params = bundle.init(KEY)
    eng = ServeEngine(bundle, params, slots=1, cache_len=32)
    for i in range(3):
        eng.submit(Request(i, np.asarray([1, 2, 3], np.int32), max_new_tokens=4))
    done = eng.run_to_completion()
    assert len(done) == 3  # one slot served all three sequentially
    assert all(len(r.tokens) == 4 for r in done)


def test_engine_single_tick_request_not_lost():
    """Regression: a request admitted AND finished within one tick must be
    reported. The old run_to_completion diffed a before/after snapshot taken
    AFTER _admit had already run, so a max_new_tokens=1 request (done at
    prefill) never appeared in the output."""
    cfg = get_config("ignis-tiny")
    bundle = build_model(cfg)
    params = bundle.init(KEY)
    eng = ServeEngine(bundle, params, slots=2, cache_len=32)
    prompt = np.asarray([1, 2, 3], np.int32)
    for i in range(4):
        eng.submit(Request(i, prompt, max_new_tokens=1))
    done = eng.run_to_completion()
    assert sorted(r.rid for r in done) == [0, 1, 2, 3]
    # budget honored exactly: prefill's token is the first AND last
    assert all(len(r.tokens) == 1 and r.done for r in done)
    # and the single token matches the greedy reference
    ref = _greedy_reference(bundle, params, prompt, 1)
    assert all(r.tokens == ref for r in done)


def test_engine_queue_is_deque_fifo():
    """Admission order is FIFO and the queue supports O(1) head pops."""
    from collections import deque

    cfg = get_config("ignis-tiny")
    bundle = build_model(cfg)
    params = bundle.init(KEY)
    eng = ServeEngine(bundle, params, slots=1, cache_len=32)
    assert isinstance(eng.queue, deque)
    for i in range(5):
        eng.submit(Request(i, np.asarray([7, i], np.int32), max_new_tokens=2))
    done = eng.run_to_completion()
    assert [r.rid for r in done] == [0, 1, 2, 3, 4]


def test_engine_eos_at_prefill_frees_slot():
    """A request whose very first (prefill) token hits eos retires without
    ever occupying a decode slot, so the waiter behind it is admitted in
    the same tick."""
    cfg = get_config("ignis-tiny")
    bundle = build_model(cfg)
    params = bundle.init(KEY)
    prompt = np.asarray([1, 2, 3], np.int32)
    first = _greedy_reference(bundle, params, prompt, 1)[0]
    eng = ServeEngine(bundle, params, slots=1, cache_len=32)
    eng.submit(Request(0, prompt, max_new_tokens=8, eos_id=first))
    eng.submit(Request(1, prompt, max_new_tokens=2))
    eng._admit()
    assert [r.rid for r in eng.retired] == [0]
    assert eng.live[0] is not None and eng.live[0].rid == 1
    done = eng.run_to_completion()
    assert sorted(r.rid for r in done) == [0, 1]
    assert done[0].tokens == [first] and not done[0].truncated


def test_engine_with_ssm_family():
    """Continuous batching over an O(1)-state SSM (no KV slab growth)."""
    from repro.configs import get_config as _gc

    cfg = _gc("mamba2-780m").reduced().with_overrides(param_dtype="float32")
    bundle = build_model(cfg)
    params = bundle.init(KEY)
    eng = ServeEngine(bundle, params, slots=2, cache_len=32)
    rng = np.random.default_rng(1)
    for i in range(3):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 6, dtype=np.int32),
                           max_new_tokens=5))
    done = eng.run_to_completion()
    assert len(done) == 3
    ref = _greedy_reference(bundle, params, done[0].prompt
                            if hasattr(done[0], "prompt") else None, 5) if False else None
    assert all(len(r.tokens) == 5 for r in done)
