"""Property-based kernel differential tests (hypothesis): random shapes,
ops and dtypes against the pure-jnp oracles, plus the end-to-end
reduceByKey path with the kernel tier forced on vs a Python oracle
(docs/kernels.md — bit-identity is the contract for associative-exact
data, so every comparison here is exact, never a tolerance)."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="dev-only dependency (requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import ICluster, IProperties, IWorker
from repro.core.shuffle import segmented_reduce
from repro.kernels.moe_route import bucket_route, bucket_route_ref
from repro.kernels.segment_reduce import segment_totals
from repro.kernels.ssd_scan import prefix_scan, prefix_scan_ref

_settings = settings(max_examples=12, deadline=None,
                     suppress_health_check=list(HealthCheck))

_FNS = {"sum": lambda a, b: a + b, "max": jnp.maximum, "min": jnp.minimum}
ops = st.sampled_from(["sum", "max", "min"])
ints = st.lists(st.integers(-1000, 1000), min_size=1, max_size=300)


def bits_equal(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return a.dtype == b.dtype and a.shape == b.shape and np.array_equal(a, b)


_worker = None


def worker():
    global _worker
    if _worker is None:
        _worker = IWorker(ICluster(IProperties({"ignis.kernels": "interpret"})),
                          "python")
    return _worker


@given(ints, ops, st.sampled_from([7, 64, 256]), st.booleans())
@_settings
def test_prefix_scan_random(xs, op, block, reverse):
    x = jnp.asarray(xs, jnp.int32)
    got = prefix_scan(x, op=op, block=block, interpret=True, reverse=reverse)
    assert bits_equal(got, prefix_scan_ref(x, op=op, reverse=reverse))


@given(st.lists(st.tuples(st.integers(0, 9), st.integers(-100, 100),
                          st.booleans()),
                min_size=1, max_size=200),
       ops, st.sampled_from(["int32", "float32"]))
@_settings
def test_segment_totals_random(rows, op, dtype):
    rows = sorted(rows)  # segmented_reduce requires sorted keys
    keys = jnp.asarray([k for k, _, _ in rows], jnp.int32)
    vals = jnp.asarray(np.asarray([v for _, v, _ in rows], dtype))
    valid = jnp.asarray([m for _, _, m in rows])
    ident = jnp.asarray({"sum": 0, "max": -(2**31 - 1),
                         "min": 2**31 - 1}[op], dtype)
    h1, t1 = segment_totals(keys, valid, vals, op, ident, block=64,
                            interpret=True)
    h2, t2 = segmented_reduce(keys, valid, vals, _FNS[op], ident)
    assert bits_equal(h1, h2) and bits_equal(t1, t2)


@given(st.integers(1, 8), st.integers(1, 64),
       st.lists(st.integers(0, 7), min_size=1, max_size=300))
@_settings
def test_bucket_route_random(p, capacity, dest):
    d = jnp.asarray([x % p for x in dest], jnp.int32)
    got = bucket_route(d, p, capacity, block=64, interpret=True)
    ref = bucket_route_ref(d, p, capacity)
    assert all(bits_equal(g, r) for g, r in zip(got, ref))


@given(st.lists(st.integers(0, 2**15 - 1), min_size=1, max_size=60),
       st.integers(1, 7), ops)
@_settings
def test_reduce_by_key_kernel_tier_matches_python(xs, k, op):
    df = (worker().parallelize(np.asarray(xs, np.int32))
          .map(lambda x: {"key": x % k, "value": x})
          .reduce_by_key(_FNS[op], {"sum": 0, "max": 0, "min": 2**31 - 1}[op]))
    got = {int(np.asarray(r["key"])): int(np.asarray(r["value"]))
           for r in df.collect()}
    red = {"sum": lambda a, b: a + b, "max": max, "min": min}[op]
    exp = {}
    for x in xs:
        exp[x % k] = red(exp[x % k], x) if x % k in exp else x
    assert got == exp
    assert worker().shuffle_stats()["kernel_hits"] >= 1
