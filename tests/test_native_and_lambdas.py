"""Native SPMD apps (paper §5) + text lambdas (paper §4.2) + submit."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import ICluster, IProperties, ISource, IWorker
from repro.core.textlambda import text_lambda
from repro.apps.stencil import cg_native, laplacian_matvec_ref, stencil_native


@pytest.fixture
def worker():
    w = IWorker(ICluster(IProperties()), "cpp")
    w.load_library("repro.apps.stencil")
    return w


def test_text_lambda_forms():
    f = text_lambda("lambda x: x + 1")
    assert int(f(jnp.int32(3))) == 4
    g = text_lambda("def fn(x):\n    return jnp.square(x)")
    assert int(g(jnp.int32(5))) == 25


def test_text_lambda_in_dataframe(worker):
    df = worker.parallelize(np.arange(10, dtype=np.int32))
    assert int(df.map("lambda x: x * 3").reduce("lambda a, b: a + b")) == 3 * 45


def test_isource_params(worker):
    b = np.random.default_rng(0).normal(size=64).astype(np.float32)
    src = ISource("cg_app").add_param("iters", 150)
    x_df = worker.call(src, worker.parallelize(b))
    x = jnp.asarray([np.asarray(r) for r in x_df.collect()])
    assert float(jnp.abs(laplacian_matvec_ref(x) - jnp.asarray(b)).max()) < 1e-2


def test_native_app_matches_direct_execution(worker):
    """worker.call == running the collective program natively (paper §6.3)."""
    mesh, axis = worker.context.comm()
    g = np.random.default_rng(0).normal(size=(16, 8)).astype(np.float32)
    got = worker.call("stencil_app", worker.parallelize(g), iters=6)
    got = np.stack([np.asarray(r) for r in got.collect()])
    native = np.asarray(stencil_native(mesh, axis, jnp.asarray(g), 6))
    np.testing.assert_allclose(got, native, atol=1e-6)


def test_void_call(worker):
    from repro.core.native import ignis_export

    hits = []

    @ignis_export("probe")
    def probe(ctx, data=None, valid=None):
        hits.append(int(ctx.var("x")))

    worker.void_call("probe", x=42)
    assert hits == [42]


def test_unknown_app_raises(worker):
    with pytest.raises(KeyError, match="not loaded"):
        worker.call("no_such_app")


def test_submit_writes_jobspec(tmp_path):
    import json
    import os
    from repro.launch.submit import main as submit_main

    driver = tmp_path / "driver.py"
    driver.write_text("print('hi from driver')\n")
    rc = submit_main([
        "--name", "t1", "--properties", "ignis.driver.memory=1GB",
        "--jobs-dir", str(tmp_path), "--attach", "ignishpc/jax", str(driver),
    ])
    assert rc == 0
    spec = json.load(open(tmp_path / "t1" / "job.json"))
    assert spec["properties"]["ignis.driver.memory"] == "1GB"
