"""Communicator groups (docs/collectives.md): ``IContext.split``/``group``
edge cases, the gang-scheduled job path, and the ``comm.alltoall``
validation fix — everything that is testable at p=1 (the 8-way isolation
and concurrency checks live in tests/_distributed_main.py)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import ICluster, IProperties, IWorker
from repro.core import comm
from repro.core.job import IJob
from repro.core.native import ignis_export


@pytest.fixture
def worker():
    return IWorker(ICluster(IProperties()), "python")


# ---------------------------------------------------------------------------
# split / group construction
# ---------------------------------------------------------------------------


def test_split_p1(worker):
    """split(1) on a single-executor world is the degenerate but legal
    MPI_Comm_split: one group spanning the whole mesh."""
    ctx = worker.context
    (g,) = ctx.split(1)
    assert g.is_group and g.parent is ctx
    assert g.executors == 1 and g.group_ranks == (0,)
    assert g.axis == ctx.axis


def test_split_uneven_rejected(worker):
    ctx = worker.context
    with pytest.raises(ValueError, match="does not divide"):
        ctx.split(2)  # p=1 cannot split 2 ways
    with pytest.raises(ValueError, match="n_groups"):
        ctx.split(0)


def test_group_rank_validation(worker):
    ctx = worker.context
    with pytest.raises(ValueError, match="at least one"):
        ctx.group([])
    with pytest.raises(ValueError, match="distinct"):
        ctx.group([0, 0])
    with pytest.raises(ValueError, match="out of range"):
        ctx.group([0, 1])  # p=1 has no rank 1


def test_nested_split(worker):
    ctx = worker.context
    (g,) = ctx.split(1)
    (gg,) = g.split(1)
    assert gg.parent is g and g.parent is ctx
    assert gg.executors == 1
    assert gg.label() == "data[0:1][0:1]"


def test_group_inherits_vars(worker):
    ctx = worker.context
    ctx.set_var("alpha", 3)
    (g,) = ctx.split(1)
    assert g.var("alpha") == 3
    # snapshot, not a live view
    ctx.set_var("alpha", 4)
    assert g.var("alpha") == 3
    # bind() keeps group identity
    b = g.bind({"beta": 1})
    assert b.is_group and b.group_ranks == (0,)


def test_worker_groups_cached_and_locked(worker):
    gs1 = worker.groups(1)
    gs2 = worker.groups(1)
    assert gs1 is gs2
    assert worker.group_lock(gs1[0]) is worker.group_lock(gs1[0])


def test_use_group_thread_local_binding(worker):
    base = worker.context
    (g,) = worker.groups(1)
    with worker.use_group(g):
        assert worker.context is g
        with worker.use_group(None):  # nested rebind to the base mesh
            assert worker.context is base
        assert worker.context is g
    assert worker.context is base


# ---------------------------------------------------------------------------
# comm fixes: alltoall validation + dead helper removal
# ---------------------------------------------------------------------------


class _FakeCtx:
    """Shape-validation happens before any mesh work, so a bare stand-in
    exercises the error path without needing multiple devices."""

    executors = 4
    axis = "data"


def test_alltoall_rejects_indivisible_rows():
    with pytest.raises(ValueError, match="divisible"):
        comm.alltoall(_FakeCtx(), jnp.arange(6, dtype=jnp.int32))
    with pytest.raises(ValueError, match="divisible"):
        # total divides p but the local count does not (8/4 = 2, 2 % 4 != 0)
        comm.alltoall(_FakeCtx(), jnp.arange(8, dtype=jnp.int32))


def test_alltoall_p1_roundtrip(worker):
    x = comm.shard_rows(worker.context, jnp.arange(5, dtype=jnp.int32))
    assert np.array_equal(np.asarray(comm.alltoall(worker.context, x)),
                          np.arange(5))


def test_dead_cached_jit_removed():
    assert not hasattr(comm, "_cached_jit")


# ---------------------------------------------------------------------------
# gang-scheduled jobs (p=1 degenerate groups; concurrency is p=8-only)
# ---------------------------------------------------------------------------


def test_gang_job_results_match_eager(worker):
    vals = np.random.default_rng(0).integers(0, 100, 64).astype(np.int32)
    df = worker.parallelize(vals).map(lambda x: x + 1)
    job = IJob("gang1", gang=1)
    f1 = df.count_async(job=job)
    f2 = worker.parallelize(vals).sort().collect_async(job=job)
    assert f1.result(30) == 64
    assert [int(x) for x in f2.result(30)] == sorted(int(v) + 0 for v in vals)
    st = job.stats()
    assert st["gang"] == st["tasks"] and st["failed"] == 0
    assert st["groups"] == ["data[0:1]"]
    assert "group=data[0:1]" in job.explain()


def test_explicit_group_submission(worker):
    g = worker.context.group([0])
    vals = np.arange(32, dtype=np.int32)
    fut = worker.parallelize(vals).map(lambda x: x * 2).collect_async(group=g)
    assert [int(x) for x in fut.result(30)] == [2 * v for v in range(32)]


def test_gang_scheduler_stats(worker):
    sched = IJob("probe").scheduler
    g0 = sched.stats["gang_tasks"]
    job = IJob("gang-stats", gang=1)
    worker.parallelize(np.arange(8, dtype=np.int32)).count_async(job=job).result(30)
    assert sched.stats["gang_tasks"] > g0


def test_group_job_failure_cascade(worker):
    """A native task failing on a group fails its dependents with the same
    error, without running them (the group-scheduled cascade)."""

    @ignis_export("groups_boom")
    def groups_boom(ctx, data=None, valid=None):
        raise RuntimeError("groups_boom")

    job = IJob("gang-fail", gang=1)
    bad = worker.call("groups_boom", worker.parallelize(np.arange(4, dtype=np.int32)))
    f1 = bad.count_async(job=job)
    f2 = bad.map(lambda x: x).collect_async(job=job)
    with pytest.raises(RuntimeError, match="groups_boom"):
        f1.result(30)
    with pytest.raises(RuntimeError, match="groups_boom"):
        f2.result(30)
    st = job.stats()
    assert st["failed"] >= 2 and st["done"] == 0


def test_wide_ops_under_group_binding(worker):
    """Wide stages consult the ACTIVE communicator: under a group binding
    the shuffle manager keys its capacity memory and plans per-group."""
    vals = np.random.default_rng(1).integers(0, 50, 64).astype(np.int32)
    (g,) = worker.groups(1)
    with worker.use_group(g):
        got = worker.parallelize(vals).map(
            lambda x: {"key": x % 5, "value": jnp.int32(1)}
        ).reduce_by_key(lambda a, b: a + b, 0).collect()
    exp = {}
    for v in vals:
        exp[int(v) % 5] = exp.get(int(v) % 5, 0) + 1
    assert {int(np.asarray(r["key"])): int(np.asarray(r["value"])) for r in got} == exp


def test_driver_binding_propagates_to_submissions(worker):
    """An action submitted inside ``with worker.use_group(g):`` must run
    ON ``g`` even though it executes on a pool thread: the submission
    inherits the driver thread's binding as its task group."""
    (g,) = worker.groups(1)
    df = worker.parallelize(np.arange(16, dtype=np.int32))
    with worker.use_group(g):
        fut = df.count_async()
    assert fut.task.group is g
    assert fut.result(30) == 16
    # outside the binding, submissions are ungrouped again
    assert df.count_async().task.group is None
    # explicit group= still wins over the ambient binding
    other = worker.context.group([0])
    with worker.use_group(g):
        fut2 = df.count_async(group=other)
    assert fut2.task.group is other and fut2.result(30) == 16
