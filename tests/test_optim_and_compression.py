"""Optimizer + gradient compression + schedules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import compressed_grads, init_ef_state
from repro.optim.adamw import adamw_update, init_opt_state
from repro.optim.schedule import warmup_cosine


def test_adamw_matches_reference():
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    opt = init_opt_state(p)
    p2, opt2 = adamw_update(g, opt, p, lr=1e-2, b1=0.9, b2=0.999, eps=1e-8,
                            weight_decay=0.0)
    # hand-computed first Adam step: update = lr * sign-ish(m̂/√v̂)
    m = 0.1 * np.asarray([0.1, 0.2, -0.3])
    v = 0.001 * np.asarray([0.1, 0.2, -0.3]) ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.999)
    exp = np.asarray([1.0, -2.0, 3.0]) - 1e-2 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"]), exp, rtol=1e-5)
    assert int(opt2["step"]) == 1


def test_adamw_moment_dtype():
    p = {"w": jnp.zeros((4,), jnp.bfloat16)}
    opt = init_opt_state(p, jnp.bfloat16)
    assert opt["m"]["w"].dtype == jnp.bfloat16


def test_warmup_cosine():
    assert float(warmup_cosine(jnp.int32(0), 1.0, 10, 100)) == 0.0
    assert abs(float(warmup_cosine(jnp.int32(10), 1.0, 10, 100)) - 1.0) < 1e-6
    end = float(warmup_cosine(jnp.int32(100), 1.0, 10, 100))
    assert 0.09 < end < 0.11  # floor = 0.1 × peak


def test_int8_compression_error_feedback():
    """Quantization noise must be re-injected (EF) so the SUM over steps is
    preserved — the convergence-preserving property."""
    g = {"w": jnp.asarray(np.linspace(-1, 1, 64), jnp.float32)}
    ef = init_ef_state(g)
    total_c = np.zeros(64)
    for _ in range(50):
        gc, ef = compressed_grads(g, ef, "int8")
        total_c += np.asarray(gc["w"])
    total_true = np.asarray(g["w"]) * 50
    # relative error of accumulated compressed grads is tiny with EF
    assert np.abs(total_c - total_true).max() < 0.02


def test_topk_compression_sparsity():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=100), jnp.float32)}
    ef = init_ef_state(g)
    gc, ef2 = compressed_grads(g, ef, "topk", topk_frac=0.1)
    nz = int((np.asarray(gc["w"]) != 0).sum())
    assert nz <= 12
    # residual carried in EF
    assert float(jnp.abs(ef2["w"]).sum()) > 0


def test_compressed_training_converges():
    """Quadratic descent with int8-compressed grads still converges."""
    w = {"w": jnp.asarray([5.0, -3.0])}
    ef = init_ef_state(w)
    opt = init_opt_state(w)
    for _ in range(200):
        g = {"w": 2 * w["w"]}  # ∇‖w‖²
        gc, ef = compressed_grads(g, ef, "int8")
        w, opt = adamw_update(gc, opt, w, lr=5e-2, weight_decay=0.0)
    assert float(jnp.abs(w["w"]).max()) < 0.1
