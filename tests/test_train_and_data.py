"""Train loop: loss decreases, checkpoint resume continues, data pipeline."""
import numpy as np

from repro.data.pipeline import (
    BOS,
    EOS,
    byte_tokenize,
    pack_sequences,
    batches_from_rows,
)
from repro.launch.train import train


def test_byte_tokenizer_roundtrip():
    t = byte_tokenize("hello")
    assert t.tolist() == list(b"hello")


def test_pack_sequences_shapes():
    docs = [byte_tokenize("aaa"), byte_tokenize("bbbb")]
    rows = pack_sequences(docs, seq_len=8)
    assert rows.shape[1] == 9
    flat = rows.reshape(-1).tolist()
    assert BOS in flat and EOS in flat


def test_batches_cycle():
    rows = np.arange(40, dtype=np.int32).reshape(8, 5)
    it = batches_from_rows(rows, batch=4, epochs=2)
    batches = list(it)
    assert len(batches) == 4  # 2 per epoch × 2 epochs
    assert batches[0]["tokens"].shape == (4, 4)


def test_train_decreases_loss_and_resumes(tmp_path):
    _, _, losses = train(arch="ignis-tiny", steps=16, batch=4, seq_len=64,
                         ckpt_dir=str(tmp_path), ckpt_every=8, log_every=4)
    assert losses[-1][1] < losses[0][1] + 0.5  # moving in the right direction
    # resume continues from step 16 (no error, steps advance)
    _, _, losses2 = train(arch="ignis-tiny", steps=24, batch=4, seq_len=64,
                          ckpt_dir=str(tmp_path), ckpt_every=8, log_every=4)
    assert losses2[-1][0] == 24


def test_train_with_compression(tmp_path):
    _, _, losses = train(arch="ignis-tiny", steps=10, batch=4, seq_len=64,
                         compression="int8", log_every=5)
    assert np.isfinite(losses[-1][1])
