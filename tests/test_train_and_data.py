"""Train loop: loss decreases, checkpoint resume continues, data pipeline."""
import time

import numpy as np

from repro.data.pipeline import (
    BOS,
    EOS,
    PAD,
    TrainPipeline,
    byte_tokenize,
    loss_mask_for,
    pack_sequences,
    batches_from_rows,
)
from repro.launch.train import train


def test_byte_tokenizer_roundtrip():
    t = byte_tokenize("hello")
    assert t.tolist() == list(b"hello")


def test_pack_sequences_shapes():
    docs = [byte_tokenize("aaa"), byte_tokenize("bbbb")]
    rows = pack_sequences(docs, seq_len=8)
    assert rows.shape[1] == 9
    flat = rows.reshape(-1).tolist()
    assert BOS in flat and EOS in flat


def test_batches_cycle():
    rows = np.arange(40, dtype=np.int32).reshape(8, 5)
    it = batches_from_rows(rows, batch=4, epochs=2)
    batches = list(it)
    assert len(batches) == 4  # 2 per epoch × 2 epochs
    assert batches[0]["tokens"].shape == (4, 4)


def test_pack_sequences_reports_dropped_tail():
    # 2 docs * (1 BOS + 3 toks + 1 EOS) = 10 stream tokens; L = 9 -> 1 row,
    # 1 token dropped off the tail
    docs = [byte_tokenize("aaa"), byte_tokenize("bbb")]
    stats = {}
    rows = pack_sequences(docs, seq_len=8, stats=stats)
    assert rows.shape == (1, 9)
    assert stats["stream_tokens"] == 10
    assert stats["packed_rows"] == 1
    assert stats["dropped_tail_tokens"] == 1
    # exact alignment: no tail dropped
    stats2 = {}
    pack_sequences([byte_tokenize("a" * 7)], seq_len=8, stats=stats2)
    assert stats2["dropped_tail_tokens"] == 0


def test_batches_emit_loss_mask_and_negative_pad_labels():
    # short doc -> the single packed row is mostly PAD filler
    rows = pack_sequences([byte_tokenize("ab")], seq_len=8)
    (b,) = list(batches_from_rows(rows, batch=1, epochs=1))
    labels_raw = rows[:, 1:]
    expect_mask = labels_raw != PAD
    assert b["loss_mask"].dtype == np.bool_
    assert (b["loss_mask"] == expect_mask).all()
    assert (expect_mask == loss_mask_for(labels_raw)).all()
    # PAD positions train on label -1 (the CE layer masks negatives);
    # real positions keep their token ids
    assert (b["labels"][~b["loss_mask"]] == -1).all()
    assert (b["labels"][b["loss_mask"]] == labels_raw[expect_mask]).all()
    assert (b["tokens"] == rows[:, :-1]).all()


def test_batches_report_dropped_partial_rows():
    rows = np.arange(50, dtype=np.int32).reshape(10, 5)
    stats = {}
    out = list(batches_from_rows(rows, batch=4, epochs=2, stats=stats))
    assert len(out) == 4  # 2 full batches per epoch, 2 rows dropped each
    assert stats["dropped_partial_rows"] == 4
    assert stats["epochs_done"] == 2


def test_pipeline_close_returns_with_full_queue():
    """Regression: the producer used a blocking Queue.put, so once the
    bounded queue filled and the consumer stopped, close() could never
    join the wedged thread."""

    def endless():
        i = 0
        while True:
            yield {"tokens": np.full((2, 2), i, np.int32)}
            i += 1

    pipe = TrainPipeline(endless(), depth=2)
    next(pipe)  # consume one, then walk away with the queue full
    deadline = time.monotonic() + 2.0
    while not pipe._q.full() and time.monotonic() < deadline:
        time.sleep(0.01)
    assert pipe._q.full()
    t0 = time.monotonic()
    pipe.close()
    assert time.monotonic() - t0 < 5.0
    assert not pipe._thread.is_alive()


def test_pipeline_drains_finite_iterator():
    rows = np.arange(40, dtype=np.int32).reshape(8, 5)
    pipe = TrainPipeline(batches_from_rows(rows, batch=4, epochs=1), depth=2)
    got = list(pipe)
    assert len(got) == 2
    pipe.close()
    assert not pipe._thread.is_alive()


def test_train_decreases_loss_and_resumes(tmp_path):
    _, _, losses = train(arch="ignis-tiny", steps=16, batch=4, seq_len=64,
                         ckpt_dir=str(tmp_path), ckpt_every=8, log_every=4)
    assert losses[-1][1] < losses[0][1] + 0.5  # moving in the right direction
    # resume continues from step 16 (no error, steps advance)
    _, _, losses2 = train(arch="ignis-tiny", steps=24, batch=4, seq_len=64,
                          ckpt_dir=str(tmp_path), ckpt_every=8, log_every=4)
    assert losses2[-1][0] == 24


def test_train_with_compression(tmp_path):
    _, _, losses = train(arch="ignis-tiny", steps=10, batch=4, seq_len=64,
                         compression="int8", log_every=5)
    assert np.isfinite(losses[-1][1])
