"""Adaptive shuffle engine (DESIGN.md §6): capacity memory, fused wide
stages, deferred overflow checks, join fan-out retry/memory, telemetry —
plus the max/min argselect regression (ISSUE 2 satellite).

Exchange-capacity overflow needs p > 1 and is covered in the 8-device
subprocess suite (tests/_distributed_main.py); here we cover everything
observable at p = 1, including join fan-out overflow (which is p-independent).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import ICluster, IProperties, IWorker


@pytest.fixture
def worker():
    return IWorker(ICluster(IProperties()), "python")


# ---------------------------------------------------------------------------
# capacity memory + wide-plan cache
# ---------------------------------------------------------------------------


def test_second_action_hits_memory_and_never_recompiles(worker):
    vals = np.random.default_rng(0).integers(0, 500, 96).astype(np.int32)
    srt = worker.parallelize(vals).sort()
    assert [int(x) for x in srt.collect()] == sorted(int(v) for v in vals)
    s1 = worker.shuffle_stats()
    assert s1["capacity_memory_misses"] >= 1
    assert s1["wide_plan_misses"] >= 1
    assert [int(x) for x in srt.collect()] == sorted(int(v) for v in vals)
    s2 = worker.shuffle_stats()
    assert s2["capacity_memory_hits"] > s1["capacity_memory_hits"]
    assert s2["wide_plan_misses"] == s1["wide_plan_misses"]  # zero recompiles
    assert s2["wide_plan_hits"] > s1["wide_plan_hits"]
    assert s2["overflow_retries"] == 0


def test_capacity_memory_survives_lineage_rebuild(worker):
    """Structural signatures: re-building an identical pipeline (fresh lambda
    objects, same code) maps to the same capacity-memory slot and compiled
    wide plan — the benchmark-loop / iterative-driver case."""

    def run():
        return (
            worker.parallelize(np.arange(64, dtype=np.int32))
            .map(lambda x: x % 7)
            .sort()
            .count()
        )

    assert run() == 64
    s1 = worker.shuffle_stats()
    assert run() == 64
    s2 = worker.shuffle_stats()
    assert s2["capacity_memory_hits"] > s1["capacity_memory_hits"]
    assert s2["wide_plan_misses"] == s1["wide_plan_misses"]


def test_fused_wide_stage_reduce_by_key_reuses_plan(worker):
    kv = worker.parallelize(np.arange(60, dtype=np.int32)).map(
        lambda x: {"key": x % 7, "value": x})
    red = kv.reduce_by_key(lambda a, b: a + b)
    exp = {k: sum(x for x in range(60) if x % 7 == k) for k in range(7)}
    got = {int(np.asarray(r["key"])): int(np.asarray(r["value"]))
           for r in red.collect()}
    assert got == exp
    m1 = worker.shuffle_stats()["wide_plan_misses"]
    got2 = {int(np.asarray(r["key"])): int(np.asarray(r["value"]))
            for r in red.collect()}
    assert got2 == exp
    s = worker.shuffle_stats()
    assert s["wide_plan_misses"] == m1
    assert s["wide_plan_hits"] >= 1


def test_partition_by_preserves_rows(worker):
    kv = worker.parallelize(np.arange(32, dtype=np.int32)).map(
        lambda x: {"key": x % 4, "value": x})
    for pb in (kv.partition_by(), kv.partition_by(lambda r: r["key"])):
        vals = sorted(int(np.asarray(r["value"])) for r in pb.collect())
        assert vals == list(range(32))


# ---------------------------------------------------------------------------
# join fan-out overflow: retry + fan-out memory (p-independent)
# ---------------------------------------------------------------------------


def test_join_fanout_overflow_retries_then_remembers(worker):
    # one hot key with 8 matches per row against max_matches=1: the fan-out
    # bound must double 1→2→4→8 (3 retries), results exactly the oracle
    L = worker.parallelize(np.arange(8, dtype=np.int32)).map(
        lambda x: {"key": x * 0, "value": x})
    R = worker.parallelize(np.arange(8, dtype=np.int32)).map(
        lambda x: {"key": x * 0, "value": x + 100})
    j = L.join(R, max_matches=1)
    got = sorted((int(np.asarray(r["value"][0])), int(np.asarray(r["value"][1])))
                 for r in j.collect())
    assert got == sorted((a, b + 100) for a in range(8) for b in range(8))
    s1 = worker.shuffle_stats()
    assert s1["fanout_retries"] >= 3
    # second run: fan-out memory starts at the fitted bound — no new retries
    assert len(j.collect()) == 64
    s2 = worker.shuffle_stats()
    assert s2["fanout_retries"] == s1["fanout_retries"]
    assert s2["wide_plan_misses"] == s1["wide_plan_misses"]


# ---------------------------------------------------------------------------
# telemetry surfaces
# ---------------------------------------------------------------------------


def test_shuffle_stats_keys_and_explain_annotations(worker):
    srt = worker.parallelize(np.arange(16, dtype=np.int32)).map(
        lambda x: x * 3).sort_by(lambda x: x)
    srt.count()
    stats = worker.shuffle_stats()
    for k in ("exchanges", "overflow_retries", "fanout_retries",
              "overflow_checks", "capacity_memory_hits",
              "capacity_memory_misses", "wide_plan_hits", "wide_plan_misses",
              "bytes_moved"):
        assert k in stats, k
    out = srt.explain()
    assert "== shuffle ==" in out
    assert "capacity_factor=" in out and "(memory)" in out
    assert "capacity_memory:" in out and "wide plans:" in out
    assert worker.explain(srt) == out


def test_cold_wide_node_annotated_cold(worker):
    srt = worker.parallelize(np.arange(8, dtype=np.int32)).sort()
    assert "(cold)" in srt.explain()  # never evaluated → no memory entry


# ---------------------------------------------------------------------------
# max/min with key_fn (ISSUE 2 satellite regression)
# ---------------------------------------------------------------------------


def test_max_min_without_key_fn_elementwise(worker):
    df = worker.parallelize(np.array([3, 9, 1, 7], np.int32))
    assert int(df.max()) == 9
    assert int(df.min()) == 1
    dff = worker.parallelize(np.array([3.5, -2.25, 7.75], np.float32))
    assert float(dff.max()) == 7.75
    assert float(dff.min()) == -2.25


def test_max_min_key_fn_returns_arg_row(worker):
    df = worker.parallelize(np.array([3, 9, 1, 7], np.int32))
    # key_fn no longer ignored: negated key flips the winner
    assert int(df.max(lambda x: -x)) == 1
    assert int(df.min(lambda x: -x)) == 9
    kv = worker.parallelize(np.arange(60, dtype=np.int32)).map(
        lambda x: {"key": x % 7, "value": x})
    top = kv.max(lambda r: r["value"])
    assert (int(top["key"]), int(top["value"])) == (59 % 7, 59)
    bot = kv.min(lambda r: r["value"])
    assert (int(bot["key"]), int(bot["value"])) == (0, 0)


def test_max_min_key_fn_respects_validity_mask(worker):
    df = worker.parallelize(np.arange(10, dtype=np.int32)).filter(
        lambda x: x < 5)
    assert int(df.max(lambda x: x)) == 4  # masked rows 5..9 never win
    assert int(df.min(lambda x: -x)) == 4


def test_max_min_key_fn_empty_raises(worker):
    empty = worker.parallelize(np.arange(4, dtype=np.int32)).filter(
        lambda x: x < 0)
    with pytest.raises(ValueError):
        empty.max(lambda x: x)
    with pytest.raises(ValueError):
        empty.min(lambda x: x)


def test_fn_tokens_do_not_collide_across_instances_or_dtypes(worker):
    """Bound methods carry behavior in __self__, and 1 == 1.0 == True in
    Python but not in XLA: neither may share a compiled wide plan."""
    from repro.core.shuffle_plan import fn_token

    class Scaler:
        def __init__(self, k):
            self.k = k

        def key(self, r):
            return r * self.k

    assert fn_token(Scaler(1).key) != fn_token(Scaler(-1).key)

    def mk(a):
        return lambda x: x * a

    assert fn_token(mk(1)) != fn_token(mk(1.0))
    assert fn_token(mk(1)) != fn_token(mk(True))
    assert fn_token(mk(2)) == fn_token(mk(2))  # rebuilds still match

    vals = np.array([3, 9, 1, 7], np.int32)
    up = [int(x) for x in worker.parallelize(vals).sort_by(Scaler(1).key).collect()]
    dn = [int(x) for x in worker.parallelize(vals).sort_by(Scaler(-1).key).collect()]
    assert up == [1, 3, 7, 9]
    assert dn == [9, 7, 3, 1]


def test_fn_token_tracks_referenced_globals(worker):
    """A rebuilt lambda whose referenced module global changed must NOT
    reuse the plan compiled against the old value."""
    import sys
    import types

    from repro.core.shuffle_plan import fn_token

    mod = types.ModuleType("shuffle_token_probe")
    sys.modules["shuffle_token_probe"] = mod
    exec("SCALE = 3\ndef make():\n    return lambda x: x * SCALE\n", mod.__dict__)
    t1 = fn_token(mod.make())
    mod.SCALE = 5
    assert fn_token(mod.make()) != t1
    mod.SCALE = 3
    assert fn_token(mod.make()) == t1  # restored value matches again

    # end to end: second build after the global changed computes fresh
    d = np.arange(6, dtype=np.int32)
    mod.SCALE = 3
    out1 = sorted(int(x) for x in
                  worker.parallelize(d).map(mod.make()).map(lambda x: x + 0).collect())
    assert out1 == [0, 3, 6, 9, 12, 15]
    mod.SCALE = 5
    out2 = sorted(int(x) for x in
                  worker.parallelize(d).map(mod.make()).map(lambda x: x + 0).collect())
    assert out2 == [0, 5, 10, 15, 20, 25]
    del sys.modules["shuffle_token_probe"]


def test_static_token_fingerprints_large_arrays():
    """repr() truncates big arrays; identity tokens must hash the bytes."""
    from repro.core.shuffle_plan import _static_token

    a = np.zeros(2000)
    b = np.zeros(2000)
    b[1000] = 7.0
    assert _static_token(a) != _static_token(b)
    assert _static_token(np.zeros(2000)) == _static_token(np.zeros(2000))


def test_join_unresolvable_fanout_raises_not_truncates(worker):
    """A key too skewed for MAX_ATTEMPTS doublings must raise — overflow is
    detected, never silently dropped (DESIGN.md §1)."""
    L = worker.parallelize(np.arange(1, dtype=np.int32)).map(
        lambda x: {"key": x * 0, "value": x})
    R = worker.parallelize(np.arange(600, dtype=np.int32)).map(
        lambda x: {"key": x * 0, "value": x})
    with pytest.raises(RuntimeError, match="max_matches"):
        L.join(R, max_matches=2).collect()
    assert len(L.join(R, max_matches=600).collect()) == 600


def test_spark_mode_shuffle_parity(worker):
    """The manager runs identically under the spark pipe — only slower."""
    ws = IWorker(ICluster(IProperties({"ignis.mode": "spark"})), "python")
    data = np.random.default_rng(3).integers(0, 99, 40).astype(np.int32)
    outs = []
    for w in (worker, ws):
        outs.append([int(x) for x in w.parallelize(data).sort().collect()])
    assert outs[0] == outs[1] == sorted(int(v) for v in data)
