"""Streaming subsystem tier-1 suite (docs/streaming.md): replayable
sources, admission control, driver-side backpressure, tenant isolation vs
solo oracles, offset checkpoint/restore, and the serve front door. The
chaos matrix (kill/replay with exact counters) lives in tests/test_faults.py;
the gang-group runs at p=8 live in tests/_distributed_main.py and
tests/_faults_main.py."""
import threading
import time

import numpy as np
import pytest

from repro.core import ICluster, IProperties, IWorker
from repro.data.pipeline import byte_tokenize, pack_sequences
from repro.streaming import (
    AdmissionController,
    ArraySource,
    IteratorSource,
    ServeFrontDoor,
    StreamContext,
    StreamTelemetry,
    TenantFrontEnd,
    TenantRequestSource,
)


@pytest.fixture
def worker():
    w = IWorker(ICluster(IProperties()), "python")
    w.cluster.props["ignis.stream.batch.rows"] = "8"
    return w


def _zeros():
    return np.zeros((2,), np.int64)


# ---------------------------------------------------------------------------
# sources: poll(offset) must be a pure function of its arguments
# ---------------------------------------------------------------------------


def test_tenant_source_poll_is_replayable():
    src = TenantRequestSource(3, seed=11, limit=100)
    a, off_a = src.poll(0, 16)
    b, off_b = src.poll(0, 16)
    assert off_a == off_b == 16 and (a == b).all()
    # any split of the offset range concatenates to the same rows
    c1, o1 = src.poll(0, 7)
    c2, o2 = src.poll(o1, 9)
    assert o2 == 16 and (np.concatenate([c1, c2]) == a).all()
    # distinct tenants see distinct payloads from the same offsets
    other, _ = TenantRequestSource(4, seed=11, limit=100).poll(0, 16)
    assert not (other[:, 1] == a[:, 1]).all()
    # the limit bounds the stream
    tail, off_t = src.poll(96, 16)
    assert len(tail) == 4 and off_t == 100
    assert src.poll(100, 16) == (None, 100)


def test_array_source_bounds():
    src = ArraySource(np.arange(10, dtype=np.int32))
    rows, off = src.poll(6, 8)
    assert rows.tolist() == [6, 7, 8, 9] and off == 10
    assert src.poll(10, 8) == (None, 10)


def test_iterator_source_replays_by_reconstruction():
    calls = []

    def factory():
        calls.append(1)
        return (np.arange(i * 5, i * 5 + 5, dtype=np.int32).reshape(5, 1)
                for i in range(4))

    src = IteratorSource(factory)
    a, off = src.poll(0, 7)  # straddles two iterator items
    assert a[:, 0].tolist() == [0, 1, 2, 3, 4, 5, 6] and off == 7
    b, off2 = src.poll(7, 7)
    assert b[:, 0].tolist() == [7, 8, 9, 10, 11, 12, 13] and off2 == 14
    # a replay BEHIND the cursor rebuilds the iterator and returns the
    # exact rows the first poll saw
    a2, _ = src.poll(0, 7)
    assert (a2 == a).all() and len(calls) == 2
    tail, off3 = src.poll(14, 100)
    assert tail[:, 0].tolist() == list(range(14, 20)) and off3 == 20
    assert src.poll(20, 4) == (None, 20)


def test_iterator_source_over_seed_pipeline_rows():
    """The seed data pipeline is a valid stream source: packed rows flow
    through IteratorSource with deterministic replay."""
    docs = [byte_tokenize(f"document-{i}" * 3) for i in range(6)]
    factory = lambda: iter([pack_sequences([d], seq_len=8) for d in docs])
    src = IteratorSource(factory)
    first, off = src.poll(0, 5)
    assert first.shape[1] == 9
    again, _ = src.poll(0, 5)
    assert (again == first).all()


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def test_admission_quota_and_global_bound():
    c = AdmissionController(max_inflight=3, tenant_quota=2, queue_depth=4,
                            policy="block")
    assert c.try_admit("a") == "admit"
    assert c.try_admit("a") == "admit"
    assert c.try_admit("a") == "wait"  # per-tenant quota
    assert c.try_admit("b") == "admit"
    assert c.try_admit("b") == "wait"  # global bound (3 in flight)
    c.release("a")
    assert c.try_admit("b") == "admit"
    assert c.inflight == 3 and c.tenant_inflight("a") == 1


def test_admission_shed_policy_and_queue_depth():
    c = AdmissionController(max_inflight=1, tenant_quota=1, queue_depth=4,
                            policy="shed")
    assert c.try_admit("a") == "admit"
    assert c.try_admit("b") == "shed"  # over the bound, policy shed
    # queue depth 0 turns "wait" into "shed" even under policy block
    c0 = AdmissionController(max_inflight=1, tenant_quota=1, queue_depth=0,
                             policy="block")
    assert c0.try_admit("a") == "admit"
    assert c0.try_admit("b") == "shed"
    with pytest.raises(ValueError):
        AdmissionController(policy="bogus")


def test_admission_props_defaults(worker):
    c = AdmissionController(worker.cluster.props)
    assert (c.max_inflight, c.tenant_quota, c.queue_depth, c.policy) == \
        (8, 4, 16, "block")


# ---------------------------------------------------------------------------
# StreamContext: pump, backpressure, telemetry
# ---------------------------------------------------------------------------


def test_stream_runs_to_exhaustion_with_exact_offsets(worker):
    src = TenantRequestSource(0, seed=1, limit=50)
    sc = StreamContext(worker, src, tenant="a", init_state=_zeros())
    state = sc.run()
    # oracle: exact int64 column sums over the whole stream
    rows, _ = TenantRequestSource(0, seed=1, limit=50).poll(0, 50)
    assert (state == rows.astype(np.int64).sum(axis=0)).all()
    st = sc.stats()
    assert st["committed"] == 7 and st["offset"] == 50  # ceil(50/8)
    assert st["batches_replayed"] == 0 and st["inflight"] == 0
    snap = sc.job.stats()["stream"]
    assert snap["tenants"]["a"]["completed"] == 7
    assert snap["inflight"] == 0  # every admission slot released
    assert snap["tenants"]["a"]["latency_p99_ms"] >= \
        snap["tenants"]["a"]["latency_p50_ms"] > 0


def test_stream_backpressure_bounds_inflight(worker):
    """The pump may never hold more submitted-uncommitted batches than the
    admission bound — and the bound must actually engage (wait decisions)."""
    worker.cluster.props["ignis.stream.max.inflight"] = "2"
    peak = {"v": 0}
    waits = {"v": 0}

    class Probe(AdmissionController):
        def try_admit(self, tenant):
            d = super().try_admit(tenant)
            with self._cond:
                peak["v"] = max(peak["v"], sum(self._inflight.values()))
            if d == "wait":
                waits["v"] += 1
            return d

    def slow_batch(rows):
        time.sleep(0.005)
        return rows.astype(np.int64).sum(axis=0)

    sc = StreamContext(worker, TenantRequestSource(0, seed=2, limit=80),
                       tenant="a", init_state=_zeros(),
                       admission=Probe(worker.cluster.props),
                       batch_fn=slow_batch)
    sc.run()
    assert sc.committed == 10
    assert peak["v"] <= 2
    assert waits["v"] >= 1  # backpressure engaged at least once


def test_stream_commits_strictly_in_order(worker):
    def batch_fn(rows):
        return rows.astype(np.int64).sum(axis=0)

    folded = []

    def fold(state, result):
        folded.append(int(result[0]))
        return state + result

    sc = StreamContext(worker, TenantRequestSource(0, seed=3, limit=64),
                       tenant="a", init_state=_zeros(),
                       batch_fn=batch_fn, fold_fn=fold)
    sc.run()
    # first-column sums are strictly increasing per batch index for this
    # source (payload col varies, index col grows), so commit order is
    # observable: it must equal submission order
    assert folded == sorted(folded)
    assert len(folded) == 8


def test_tenant_isolation_matches_solo_oracle(worker):
    fe = TenantFrontEnd(worker, n_groups=1)
    for i in range(3):
        fe.admit(f"t{i}", TenantRequestSource(i, seed=7, limit=40),
                 init_state=_zeros())
    res = fe.run()
    for i in range(3):
        solo = StreamContext(worker, TenantRequestSource(i, seed=7, limit=40),
                             tenant=f"solo{i}", init_state=_zeros()).run()
        assert (res[f"t{i}"] == solo).all(), i
    snap = fe.telemetry.snapshot(fe.admission)
    assert snap["completed"] == 15 and snap["shed"] == 0
    assert snap["inflight"] == 0
    assert "3 tenants" in fe.summary()
    assert fe.job.stats()["stream"]["completed"] == 15


def test_tenant_double_admit_rejected(worker):
    fe = TenantFrontEnd(worker)
    fe.admit("a", TenantRequestSource(0, limit=8), init_state=_zeros())
    with pytest.raises(ValueError):
        fe.admit("a", TenantRequestSource(0, limit=8), init_state=_zeros())


# ---------------------------------------------------------------------------
# offset checkpoint / restore (exactly-once restart)
# ---------------------------------------------------------------------------


def test_stream_checkpoint_restart_is_bit_identical(worker, tmp_path):
    oracle = StreamContext(worker, TenantRequestSource(0, seed=5, limit=48),
                           tenant="o", init_state=_zeros()).run()
    worker.cluster.props["ignis.stream.checkpoint.interval"] = "2"
    d = str(tmp_path / "ck")
    sc1 = StreamContext(worker, TenantRequestSource(0, seed=5, limit=48),
                        tenant="a", init_state=_zeros(), ckpt_dir=d)
    sc1.run(max_batches=3)
    assert sc1.committed == 3 and sc1.offset == 24
    # a NEW pump restores the latest quiesced checkpoint (the final drain
    # of run() cuts one at commit 3, on top of the interval cut at 2)
    sc2 = StreamContext(worker, TenantRequestSource(0, seed=5, limit=48),
                        tenant="a", init_state=_zeros(), ckpt_dir=d)
    assert sc2.restored_from == sc2.committed and sc2.committed >= 2
    state = sc2.run()
    assert (state == oracle).all()
    assert sc2.offset == 48


def test_stream_ckpt_requires_init_state(worker, tmp_path):
    with pytest.raises(ValueError):
        StreamContext(worker, TenantRequestSource(0, limit=8),
                      ckpt_dir=str(tmp_path))


def test_stream_restart_skips_nothing_and_replays_nothing(worker, tmp_path):
    """Offsets move only at commit: restoring must resume at exactly the
    checkpointed row, observable through the rows each batch actually saw."""
    seen: list[int] = []
    lock = threading.Lock()

    def spy_batch(rows):
        with lock:
            seen.extend(int(r) for r in rows[:, 0])
        return rows.astype(np.int64).sum(axis=0)

    worker.cluster.props["ignis.stream.checkpoint.interval"] = "3"
    d = str(tmp_path / "ck")
    sc1 = StreamContext(worker, TenantRequestSource(0, seed=9, limit=64),
                        tenant="a", init_state=_zeros(), ckpt_dir=d,
                        batch_fn=spy_batch)
    sc1.run(max_batches=3)  # commits 0..2, checkpoint at 3rd commit
    first_half = sorted(seen)
    seen.clear()
    sc2 = StreamContext(worker, TenantRequestSource(0, seed=9, limit=64),
                        tenant="a", init_state=_zeros(), ckpt_dir=d,
                        batch_fn=spy_batch)
    sc2.run()
    # the union covers every row exactly once — nothing skipped, nothing
    # double-committed
    assert first_half + sorted(seen) == list(range(64))


# ---------------------------------------------------------------------------
# serve front door
# ---------------------------------------------------------------------------


def _toy_engine(slots=2):
    """A deterministic stand-in for ServeEngine exposing the same surface
    the front door drives (queue/live/retired/submit/step). Token i+1
    follows token i; requests retire on budget."""
    from collections import deque

    class Toy:
        def __init__(self):
            self.queue = deque()
            self.live = [None] * slots
            self.retired = []

        def submit(self, req):
            self.queue.append(req)

        def step(self):
            for s in range(slots):
                if self.live[s] is None and self.queue:
                    req = self.queue.popleft()
                    req.tokens.append(int(req.prompt[-1]) + 1)
                    if len(req.tokens) >= req.max_new_tokens:
                        req.done = True
                        self.retired.append(req)
                    else:
                        self.live[s] = req
            for s, req in enumerate(self.live):
                if req is None:
                    continue
                req.tokens.append(req.tokens[-1] + 1)
                if len(req.tokens) >= req.max_new_tokens:
                    req.done = True
                    self.retired.append(req)
                    self.live[s] = None
            return sum(r is not None for r in self.live)

    return Toy()


def test_serve_front_door_completes_requests(worker):
    from repro.core.job import IJob

    job = IJob("serve-test")
    fd = ServeFrontDoor(_toy_engine(), worker, job=job)
    tix = [fd.submit(np.asarray([i], np.int32), max_new_tokens=3,
                     tenant=f"t{i % 2}") for i in range(5)]
    done = fd.run_until_drained()
    assert len(done) == 5
    for i, t in enumerate(tix):
        req = t.result(5.0)
        assert req.tokens == [i + 1, i + 2, i + 3]
        assert t.latency_ms > 0
    st = fd.stats()
    assert st["completed"] == 5 and st["waiting"] == 0 and st["live"] == 0
    # tick tasks are first-class job tasks (kind "serve") in the job DAG
    assert job.stats()["serve"] >= 1
    assert "serve.tick#0" in job.explain()


def test_serve_front_door_sheds_beyond_queue_depth(worker):
    worker.cluster.props["ignis.serve.queue.depth"] = "2"
    fd = ServeFrontDoor(_toy_engine(), worker)
    tix = [fd.submit(np.asarray([0], np.int32), max_new_tokens=2)
           for _ in range(5)]
    shed = [t for t in tix if t.shed]
    assert len(shed) == 3
    for t in shed:  # a shed ticket resolves immediately to None
        assert t.done() and t.result() is None
    fd.run_until_drained()
    assert all(t.done() for t in tix)
    snap = fd.telemetry.snapshot()
    assert snap["shed"] == 3 and snap["completed"] == 2


def test_serve_single_tick_request_resolves(worker):
    """A request admitted and finished within one tick resolves its ticket
    on that same tick (front-door twin of the engine regression)."""
    fd = ServeFrontDoor(_toy_engine(), worker)
    t = fd.submit(np.asarray([7], np.int32), max_new_tokens=1)
    fd.tick_async().result(5.0)
    assert t.done() and t.result().tokens == [8]


def test_stream_and_serve_share_one_scheduler(worker):
    """Ingestion pump + serve ticks drain concurrently through the same
    JobScheduler — the hybrid pattern at serving time."""
    from repro.core.job import default_scheduler

    tel = StreamTelemetry()
    fd = ServeFrontDoor(_toy_engine(), worker, telemetry=tel)
    for i in range(4):
        fd.submit(np.asarray([i], np.int32), max_new_tokens=4, tenant="serve")
    sc = StreamContext(worker, TenantRequestSource(0, seed=4, limit=40),
                       tenant="ingest", init_state=_zeros(), telemetry=tel)
    done = {}
    th = threading.Thread(target=lambda: done.update(
        serve=fd.run_until_drained()), daemon=True)
    th.start()
    state = sc.run()
    th.join(30)
    assert not th.is_alive()
    assert len(done["serve"]) == 4 and state is not None
    snap = tel.snapshot()
    assert snap["tenants"]["serve"]["completed"] == 4
    assert snap["tenants"]["ingest"]["completed"] == 5
    assert default_scheduler().stats["tasks_completed"] > 0
