"""Stage compilation (DESIGN.md §5): planner boundaries, the compiled-plan
cache, and lineage repair through fused stages."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import ICluster, IProperties, IWorker
from repro.core.dag import DagEngine


@pytest.fixture
def worker():
    return IWorker(ICluster(IProperties()), "python")


def _chain(df):
    return (
        df.map(lambda x: x * 2)
        .filter(lambda x: x % 3 == 0)
        .map(lambda x: x + 1)
    )


# ---------------------------------------------------------------------------
# planner shape
# ---------------------------------------------------------------------------


def test_maximal_chain_fuses(worker):
    df = _chain(worker.parallelize(np.arange(30, dtype=np.int32)))
    plans = worker.engine.plan(df.node)
    assert df.node in plans
    stage = plans[df.node]
    assert [n.op for n in stage.nodes] == ["map", "filter", "map"]


def test_single_op_does_not_fuse(worker):
    df = worker.parallelize(np.arange(10, dtype=np.int32)).map(lambda x: x + 1)
    assert worker.engine.plan(df.node) == {}


def test_cached_node_is_a_stage_boundary(worker):
    df = worker.parallelize(np.arange(30, dtype=np.int32))
    mid = df.map(lambda x: x * 2).filter(lambda x: x % 3 == 0).cache()
    tail = mid.map(lambda x: x + 1).map(lambda x: x - 5)
    plans = worker.engine.plan(tail.node)
    # chain below the cached node and chain above it are separate stages
    assert [n.op for n in plans[tail.node].nodes] == ["map", "map"]
    assert [n.op for n in plans[mid.node].nodes] == ["map", "filter"]
    # the cached boundary really materialises
    tail.count()
    assert mid.node.result is not None


def test_wide_op_is_a_stage_boundary(worker):
    df = worker.parallelize(np.arange(30, dtype=np.int32))
    tail = (
        df.map(lambda x: x % 7)
        .distinct()
        .map(lambda x: x + 1)
        .map(lambda x: x * 3)
    )
    plans = worker.engine.plan(tail.node)
    assert [n.op for n in plans[tail.node].nodes] == ["map", "map"]
    # the map below distinct has nothing narrow to pair with → unfused
    assert len(plans) == 1


def test_shared_node_is_a_stage_boundary(worker):
    df = worker.parallelize(np.arange(20, dtype=np.int32))
    a = df.map(lambda x: x + 1).map(lambda x: x * 2)
    b = a.map(lambda x: x - 1)
    c = a.map(lambda x: x + 10)
    u = b.union(c)
    plans = worker.engine.plan(u.node)
    # a's tail has two consumers: neither b nor c may absorb it
    assert [n.op for n in plans[a.node].nodes] == ["map", "map"]
    assert b.node not in plans and c.node not in plans  # single ops
    rows = sorted(int(x) for x in u.collect())
    exp = sorted(
        [2 * (x + 1) - 1 for x in range(20)] + [2 * (x + 1) + 10 for x in range(20)]
    )
    assert rows == exp


def test_spark_mode_pipe_disables_fusion():
    ws = IWorker(ICluster(IProperties({"ignis.mode": "spark"})), "python")
    df = _chain(ws.parallelize(np.arange(30, dtype=np.int32)))
    assert ws.engine.plan(df.node) == {}
    got = sorted(int(x) for x in df.collect())
    assert got == sorted(2 * x + 1 for x in range(30) if (2 * x) % 3 == 0)


def test_map_partitions_is_opaque_to_fusion(worker):
    df = (
        worker.parallelize(np.arange(12, dtype=np.int32))
        .map(lambda x: x + 1)
        .map_partitions(lambda d: d * 2)
        .map(lambda x: x - 1)
    )
    plans = worker.engine.plan(df.node)
    assert plans == {}  # both maps are length-1 chains around the opaque op
    got = sorted(int(x) for x in df.collect())
    assert got == sorted(2 * (x + 1) - 1 for x in range(12))


def test_fusion_disabled_by_property():
    w = IWorker(ICluster(IProperties({"ignis.fusion.enabled": "false"})), "python")
    df = _chain(w.parallelize(np.arange(30, dtype=np.int32)))
    assert w.engine.plan(df.node) == {}
    df.count()
    assert w.engine.stats["fused_stages"] == 0


# ---------------------------------------------------------------------------
# correctness: fused == unfused
# ---------------------------------------------------------------------------


def test_fused_matches_unfused_results():
    wf = IWorker(ICluster(IProperties()), "python")
    wu = IWorker(ICluster(IProperties({"ignis.fusion.enabled": "false"})), "python")
    data = np.arange(100, dtype=np.int32)
    outs = []
    for w in (wf, wu):
        kv = (
            w.parallelize(data, blocks=4)
            .map(lambda x: x * 3)
            .filter(lambda x: x % 2 == 0)
            .map(lambda x: {"key": x % 5, "value": x})
            .map_values(lambda v: v + 1)
        )
        outs.append(
            sorted(
                (int(np.asarray(r["key"])), int(np.asarray(r["value"])))
                for r in kv.collect()
            )
        )
    assert outs[0] == outs[1]
    assert wf.engine.stats["fused_stages"] > 0
    assert wu.engine.stats["fused_stages"] == 0


def test_flatmap_and_sample_fuse(worker):
    df = worker.parallelize(np.arange(16, dtype=np.int32))

    def fan(x):
        return jnp.stack([x, x + 100]), jnp.ones((2,), bool)

    out = df.map(lambda x: x + 1).flatmap(fan, 2).filter(lambda x: x % 2 == 0)
    plans = worker.engine.plan(out.node)
    assert [n.op for n in plans[out.node].nodes] == ["map", "flatmap", "filter"]
    got = sorted(int(x) for x in out.collect())
    exp = sorted(
        v for x in range(16) for v in (x + 1, x + 101) if v % 2 == 0
    )
    assert got == exp


# ---------------------------------------------------------------------------
# compiled-plan cache
# ---------------------------------------------------------------------------


def test_plan_cache_hits_across_blocks_and_actions(worker):
    df = _chain(worker.parallelize(np.arange(40, dtype=np.int32), blocks=4))
    df.count()
    s1 = worker.stage_stats()
    assert s1["plan_cache_misses"] == 1  # one compile for 4 same-shape blocks
    assert s1["plan_cache_hits"] == 3
    df.count()  # second action over the same lineage
    s2 = worker.stage_stats()
    assert s2["plan_cache_misses"] == 1  # no recompile
    assert s2["plan_cache_hits"] == 7


def test_plan_cache_eviction():
    w = IWorker(
        ICluster(IProperties({"ignis.fusion.plan.cache.size": "1"})), "python"
    )
    a = _chain(w.parallelize(np.arange(8, dtype=np.int32)))
    b = _chain(w.parallelize(np.arange(8, dtype=np.int32)).map(lambda x: x))
    a.count()
    b.count()
    assert w.engine.stats["plan_cache_evictions"] >= 1
    assert len(w.engine._plan_cache) == 1


def test_explain_mentions_fused_stage(worker):
    df = _chain(worker.parallelize(np.arange(10, dtype=np.int32)))
    plan = df.explain()
    assert "FusedStage[map -> filter -> map]" in plan
    assert "parallelize" in plan
    assert worker.explain(df) == plan


# ---------------------------------------------------------------------------
# lineage repair through a fused stage
# ---------------------------------------------------------------------------


def test_kill_block_recomputes_only_lost_block_through_fused_stage(worker):
    df = worker.parallelize(np.arange(40, dtype=np.int32), blocks=4)
    tail = _chain(df).persist()
    assert tail.count() == sum(1 for x in range(40) if (2 * x) % 3 == 0)
    base = worker.engine.stats["block_recomputes"]
    DagEngine.kill_block(tail.node, 2)
    assert tail.count() == sum(1 for x in range(40) if (2 * x) % 3 == 0)
    # repair walks the 3-op chain for block 2 only: interior recomputes are
    # per-op but confined to the lost block
    recomputes = worker.engine.stats["block_recomputes"] - base
    assert 1 <= recomputes <= 3
    got = sorted(int(x) for x in tail.collect())
    assert got == sorted(2 * x + 1 for x in range(40) if (2 * x) % 3 == 0)


def test_kill_block_with_cached_ancestor_inside_lineage(worker):
    df = worker.parallelize(np.arange(40, dtype=np.int32), blocks=4)
    m1 = df.map(lambda x: x + 1).persist()
    tail = m1.map(lambda x: x * 2).map(lambda x: x - 1).persist()
    assert tail.count() == 40
    c1 = m1.node.compute_count
    base = worker.engine.stats["block_recomputes"]
    DagEngine.kill_block(tail.node, 1)
    assert tail.count() == 40
    assert m1.node.compute_count == c1  # cached ancestor untouched
    assert worker.engine.stats["block_recomputes"] - base == 2  # 2 fused ops, 1 block
