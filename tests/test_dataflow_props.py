"""Property-based tests (hypothesis): dataflow semantics vs Python oracles,
including random wide-op chains evaluated with and without one injected
block kill (docs/fault_tolerance.md — recovery must be semantically
invisible)."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="dev-only dependency (requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import ICluster, IProperties, IWorker
from repro.core import faults
from repro.core.dag import DagEngine
from repro.core.faults import FaultPlan

_worker = None


def worker():
    global _worker
    if _worker is None:
        _worker = IWorker(ICluster(IProperties()), "python")
    return _worker


ints = st.lists(st.integers(0, 2**15 - 1), min_size=1, max_size=60)
_settings = settings(max_examples=12, deadline=None,
                     suppress_health_check=list(HealthCheck))


@given(ints)
@_settings
def test_count_matches(xs):
    df = worker().parallelize(np.asarray(xs, np.int32))
    assert df.count() == len(xs)


@given(ints, st.integers(1, 7))
@_settings
def test_filter_matches(xs, m):
    df = worker().parallelize(np.asarray(xs, np.int32))
    got = sorted(int(v) for v in df.filter(lambda x: x % m == 0).collect())
    assert got == sorted(x for x in xs if x % m == 0)


@given(ints)
@_settings
def test_sort_matches(xs):
    df = worker().parallelize(np.asarray(xs, np.int32))
    assert [int(v) for v in df.sort().collect()] == sorted(xs)


@given(ints)
@_settings
def test_reduce_sum_matches(xs):
    df = worker().parallelize(np.asarray(xs, np.int32))
    assert int(df.reduce(lambda a, b: a + b)) == sum(xs)


@given(ints, st.integers(1, 5))
@_settings
def test_reduce_by_key_matches(xs, k):
    df = worker().parallelize(np.asarray(xs, np.int32))
    kv = df.map(lambda x: {"key": x % k, "value": x})
    got = {int(np.asarray(r["key"])): int(np.asarray(r["value"]))
           for r in kv.reduce_by_key(lambda a, b: a + b).collect()}
    exp = {}
    for x in xs:
        exp[x % k] = exp.get(x % k, 0) + x
    assert got == exp


@given(ints)
@_settings
def test_distinct_matches(xs):
    df = worker().parallelize(np.asarray(xs, np.int32))
    assert sorted(int(v) for v in df.distinct().collect()) == sorted(set(xs))


@given(st.lists(st.tuples(st.integers(0, 15), st.integers(0, 100)),
                min_size=1, max_size=30),
       st.lists(st.tuples(st.integers(0, 15), st.integers(0, 100)),
                min_size=1, max_size=30))
@_settings
def test_join_matches(ls, rs):
    w = worker()
    l = w.parallelize(np.asarray(ls, np.int32)).map(
        lambda r: {"key": r[0], "value": r[1]})
    r = w.parallelize(np.asarray(rs, np.int32)).map(
        lambda r: {"key": r[0], "value": r[1]})
    rows = l.join(r, max_matches=max(len(rs), 1)).collect()
    got = sorted((int(np.asarray(x["key"])), int(np.asarray(x["value"][0])),
                  int(np.asarray(x["value"][1]))) for x in rows)
    exp = sorted((ka, va, vb) for ka, va in ls for kb, vb in rs if ka == kb)
    assert got == exp


@given(ints, st.integers(1, 4))
@_settings
def test_flatmap_matches(xs, f):
    df = worker().parallelize(np.asarray(xs, np.int32))

    def fn(x):
        reps = jnp.stack([x + i for i in range(f)])
        return reps, jnp.ones((f,), bool)

    got = sorted(int(v) for v in df.flatmap(fn, f).collect())
    assert got == sorted(x + i for x in xs for i in range(f))


# ---------------------------------------------------------------------------
# single-op algebra vs oracles: union / distinct(key_fn) / aggregate_by_key /
# sample_by_key
# ---------------------------------------------------------------------------

kvs = st.lists(st.tuples(st.integers(0, 7), st.integers(0, 255)),
               min_size=1, max_size=30)


def _kv_frame(pairs, blocks=1):
    df = worker().parallelize(np.asarray(pairs, np.int32), blocks=blocks)
    return df.map(lambda r: {"key": r[0], "value": r[1]})


def _kv_rows(df):
    return sorted((int(np.asarray(r["key"])), int(np.asarray(r["value"])))
                  for r in df.collect())


@given(ints, ints)
@_settings
def test_union_matches(xs, ys):
    w = worker()
    u = w.parallelize(np.asarray(xs, np.int32)).union(
        w.parallelize(np.asarray(ys, np.int32)))
    assert sorted(int(v) for v in u.collect()) == sorted(xs + ys)


@given(kvs)
@_settings
def test_distinct_keyfn_matches(pairs):
    # injective key_fn over (key, value) → oracle is the set of pairs
    df = _kv_frame(pairs).distinct(
        key_fn=lambda r: (r["key"] << 18) | r["value"])
    assert _kv_rows(df) == sorted(set(pairs))


@given(kvs)
@_settings
def test_aggregate_by_key_matches(pairs):
    df = _kv_frame(pairs).aggregate_by_key(
        0, lambda z, v: z + v, lambda a, b: a + b)
    oracle = sorted((k, sum(v for kk, v in pairs if kk == k))
                    for k in {k for k, _ in pairs})
    assert _kv_rows(df) == oracle


@given(kvs, st.dictionaries(st.integers(0, 7), st.sampled_from([0.0, 1.0]),
                            min_size=1, max_size=8))
@_settings
def test_sample_by_key_zero_one_fractions(pairs, fractions):
    # {0,1}-valued fractions make stratified sampling deterministic
    df = _kv_frame(pairs).sample_by_key(fractions)
    oracle = sorted((k, v) for k, v in pairs if fractions.get(k, 0.0) >= 1.0)
    assert _kv_rows(df) == oracle


# ---------------------------------------------------------------------------
# random op chains vs a pure-Python oracle, with and without one injected
# block kill (the chaos property: recovery is semantically invisible)
# ---------------------------------------------------------------------------

# each op: (name, frame_transform, oracle_transform over [(k, v)])
_CHAIN_OPS = {
    "map_values": (
        lambda df: df.map_values(lambda v: v + 3),
        lambda rows: [(k, v + 3) for k, v in rows]),
    "filter": (
        lambda df: df.filter(lambda r: r["value"] % 2 == 0),
        lambda rows: [(k, v) for k, v in rows if v % 2 == 0]),
    "distinct": (
        lambda df: df.distinct(key_fn=lambda r: (r["key"] << 18) | r["value"]),
        lambda rows: sorted(set(rows))),
    "aggregate_by_key": (
        lambda df: df.aggregate_by_key(0, lambda z, v: z + v, lambda a, b: a + b),
        lambda rows: sorted(
            (k, sum(v for kk, v in rows if kk == k))
            for k in {k for k, _ in rows})),
    "sample_by_key": (
        lambda df: df.sample_by_key({k: 1.0 for k in range(0, 8, 2)}),
        lambda rows: [(k, v) for k, v in rows if k % 2 == 0]),
}
_CHAIN_NAMES = sorted(_CHAIN_OPS)

chain_st = st.lists(st.sampled_from(_CHAIN_NAMES), min_size=1, max_size=4)


def _run_chain(pairs, chain, blocks):
    df, rows = _kv_frame(pairs, blocks=blocks), list(pairs)
    for name in chain:
        op, oracle = _CHAIN_OPS[name]
        df, rows = op(df), oracle(rows)
    return df, sorted(rows)


@given(kvs, chain_st, st.integers(1, 3))
@_settings
def test_random_chain_matches_oracle(pairs, chain, blocks):
    df, oracle = _run_chain(pairs, chain, blocks)
    assert _kv_rows(df) == oracle


@given(kvs, chain_st, st.integers(1, 3), st.integers(0, 10**6))
@_settings
def test_random_chain_with_injected_block_kill(pairs, chain, blocks, seed):
    """One evaluation-time block kill at a seeded kill-point: the scheduler
    retry must converge to the oracle, and the number of retries must equal
    the number of faults that actually fired (0 if the sampled kill-point
    is not on this chain's path)."""
    df, oracle = _run_chain(pairs, chain, blocks)
    plan = FaultPlan(seed=seed)
    op = plan.choice(["map", "mapValues", "filter"])
    plan.kill_block(op=op, block=plan.randint(0, blocks - 1))
    from repro.core.job import default_scheduler

    r0 = default_scheduler().stats["task_retries"]
    with faults.inject(plan):
        got = _kv_rows(df)
    assert got == oracle
    assert default_scheduler().stats["task_retries"] - r0 == plan.injections()
    assert plan.injections() <= 1


@given(kvs, chain_st, st.integers(1, 3), st.integers(0, 10**6))
@_settings
def test_random_chain_with_cached_block_kill(pairs, chain, blocks, seed):
    """Post-materialisation loss of one cached block: lineage repair must
    reproduce the oracle exactly."""
    df, oracle = _run_chain(pairs, chain, blocks)
    df.persist()
    assert _kv_rows(df) == oracle
    if df.node.result:
        plan = FaultPlan(seed=seed)
        DagEngine.kill_block(df.node, plan.randint(0, len(df.node.result) - 1))
    assert _kv_rows(df) == oracle


@given(st.lists(st.tuples(st.integers(0, 7), st.integers(0, 63)),
                min_size=1, max_size=20),
       st.lists(st.tuples(st.integers(0, 7), st.integers(0, 63)),
                min_size=1, max_size=20),
       st.integers(0, 10**6))
@_settings
def test_join_terminal_with_injected_kill(ls, rs, seed):
    """join(max_matches) as the chain terminal, with one injected collective
    kill: retry must converge to the oracle join."""
    l, r = _kv_frame(ls), _kv_frame(rs)
    j = l.join(r, max_matches=max(len(rs), 1))
    plan = FaultPlan(seed=seed).fail_collective("join")
    with faults.inject(plan):
        rows = j.collect()
    got = sorted((int(np.asarray(x["key"])), int(np.asarray(x["value"][0])),
                  int(np.asarray(x["value"][1]))) for x in rows)
    exp = sorted((ka, va, vb) for ka, va in ls for kb, vb in rs if ka == kb)
    assert got == exp and plan.injections() == 1


# ---------------------------------------------------------------------------
# nonblocking collectives (docs/collectives.md): random await interleavings
# and persistent-plan reuse must be invisible — always the in-order
# blocking oracle's bits
# ---------------------------------------------------------------------------

_COLL_OPS = ["allreduce_sum", "allreduce_max", "allreduce_min", "gather",
             "ppermute", "alltoall"]


def _coll_dispatch(ctx, name, arr):
    from repro.core import comm

    x = comm.shard_rows(ctx, arr)
    if name.startswith("allreduce"):
        return comm.iallreduce(ctx, x, op=name.split("_")[1])
    if name == "gather":
        return comm.igather(ctx, x)
    if name == "ppermute":
        return comm.ippermute(ctx, x, shift=1)
    return comm.ialltoall(ctx, x)


def _coll_oracle(name, arr):
    if name == "allreduce_sum":
        return np.asarray(arr.sum(), arr.dtype)
    if name == "allreduce_max":
        return np.asarray(arr.max(), arr.dtype)
    if name == "allreduce_min":
        return np.asarray(arr.min(), arr.dtype)
    return arr  # p=1: every movement pattern is the identity


@given(st.lists(st.tuples(st.sampled_from(_COLL_OPS),
                          st.integers(0, 1),  # which communicator
                          st.lists(st.integers(-2**15, 2**15 - 1),
                                   min_size=1, max_size=16)),
                min_size=1, max_size=8),
       st.integers(0, 10**6))
@_settings
def test_interleaved_nonblocking_collectives_match_blocking_oracle(seq, seed):
    """A random sequence of nonblocking collectives, split across the flat
    world and a group communicator, ALL dispatched before ANY is awaited,
    then drained in a seeded random order — every value must equal the
    in-order blocking oracle for its own operands."""
    w = worker()
    ctxs = (w.context, w.context.group([0]))
    inflight = []
    for name, which, xs in seq:
        arr = np.asarray(xs, np.int32)
        inflight.append((_coll_dispatch(ctxs[which], name, arr),
                         _coll_oracle(name, arr)))
    order = list(range(len(inflight)))
    FaultPlan(seed=seed).rng.shuffle(order)
    for i in order:
        h, exp = inflight[i]
        got = np.asarray(h.wait())
        assert got.dtype == exp.dtype and np.array_equal(got, exp), (got, exp)


@given(st.lists(st.integers(-2**15, 2**15 - 1), min_size=1, max_size=32),
       st.integers(2, 5))
@_settings
def test_persistent_plan_reuse_never_changes_results(xs, reps):
    """Init-once/invoke-many: repeated invocations of one persistent plan
    (pure cache hits after the first) return identical bits, and the miss
    counter stays flat across the repeats."""
    from repro.core import comm

    ctx = worker().context
    arr = np.asarray(xs, np.int32)
    x = comm.shard_rows(ctx, arr)
    plan = comm.persistent(ctx, "allreduce", x)
    first = np.asarray(plan(x))
    m0 = comm.comm_stats()["coll_plan_misses"]
    for _ in range(reps):
        again = np.asarray(comm.persistent(ctx, "allreduce", x)(x))
        assert np.array_equal(again, first)
    assert comm.comm_stats()["coll_plan_misses"] == m0
