"""Property-based tests (hypothesis): dataflow semantics vs Python oracles."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="dev-only dependency (requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import ICluster, IProperties, IWorker

_worker = None


def worker():
    global _worker
    if _worker is None:
        _worker = IWorker(ICluster(IProperties()), "python")
    return _worker


ints = st.lists(st.integers(0, 2**15 - 1), min_size=1, max_size=60)
_settings = settings(max_examples=12, deadline=None,
                     suppress_health_check=list(HealthCheck))


@given(ints)
@_settings
def test_count_matches(xs):
    df = worker().parallelize(np.asarray(xs, np.int32))
    assert df.count() == len(xs)


@given(ints, st.integers(1, 7))
@_settings
def test_filter_matches(xs, m):
    df = worker().parallelize(np.asarray(xs, np.int32))
    got = sorted(int(v) for v in df.filter(lambda x: x % m == 0).collect())
    assert got == sorted(x for x in xs if x % m == 0)


@given(ints)
@_settings
def test_sort_matches(xs):
    df = worker().parallelize(np.asarray(xs, np.int32))
    assert [int(v) for v in df.sort().collect()] == sorted(xs)


@given(ints)
@_settings
def test_reduce_sum_matches(xs):
    df = worker().parallelize(np.asarray(xs, np.int32))
    assert int(df.reduce(lambda a, b: a + b)) == sum(xs)


@given(ints, st.integers(1, 5))
@_settings
def test_reduce_by_key_matches(xs, k):
    df = worker().parallelize(np.asarray(xs, np.int32))
    kv = df.map(lambda x: {"key": x % k, "value": x})
    got = {int(np.asarray(r["key"])): int(np.asarray(r["value"]))
           for r in kv.reduce_by_key(lambda a, b: a + b).collect()}
    exp = {}
    for x in xs:
        exp[x % k] = exp.get(x % k, 0) + x
    assert got == exp


@given(ints)
@_settings
def test_distinct_matches(xs):
    df = worker().parallelize(np.asarray(xs, np.int32))
    assert sorted(int(v) for v in df.distinct().collect()) == sorted(set(xs))


@given(st.lists(st.tuples(st.integers(0, 15), st.integers(0, 100)),
                min_size=1, max_size=30),
       st.lists(st.tuples(st.integers(0, 15), st.integers(0, 100)),
                min_size=1, max_size=30))
@_settings
def test_join_matches(ls, rs):
    w = worker()
    l = w.parallelize(np.asarray(ls, np.int32)).map(
        lambda r: {"key": r[0], "value": r[1]})
    r = w.parallelize(np.asarray(rs, np.int32)).map(
        lambda r: {"key": r[0], "value": r[1]})
    rows = l.join(r, max_matches=max(len(rs), 1)).collect()
    got = sorted((int(np.asarray(x["key"])), int(np.asarray(x["value"][0])),
                  int(np.asarray(x["value"][1]))) for x in rows)
    exp = sorted((ka, va, vb) for ka, va in ls for kb, vb in rs if ka == kb)
    assert got == exp


@given(ints, st.integers(1, 4))
@_settings
def test_flatmap_matches(xs, f):
    df = worker().parallelize(np.asarray(xs, np.int32))

    def fn(x):
        reps = jnp.stack([x + i for i in range(f)])
        return reps, jnp.ones((f,), bool)

    got = sorted(int(v) for v in df.flatmap(fn, f).collect())
    assert got == sorted(x + i for x in xs for i in range(f))
