"""Multi-device collective paths (PSRS over 8 shards, alltoall, comm layer,
PP schedule, elastic reshard) — executed in a subprocess so the 8-device
host-platform flag never leaks into this process (dry-run ground rule)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.timeout(900)
def test_distributed_suite():
    here = os.path.dirname(__file__)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "..", "src"), env.get("PYTHONPATH", "")]
    )
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(here, "_distributed_main.py")],
        env=env, capture_output=True, text=True, timeout=880,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "ALL_DISTRIBUTED_OK" in r.stdout
