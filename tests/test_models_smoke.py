"""Per-architecture smoke tests: reduced same-family configs, one forward /
train / prefill / decode step on CPU — shapes + finiteness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    b = {
        "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(KEY, (B, 32, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(KEY, (B, cfg.num_patches, 1024), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_finite(arch):
    cfg = get_config(arch).reduced()
    bundle = build_model(cfg, max_dec=64)
    params = bundle.init(KEY)
    opt = bundle.init_opt(params)
    batch = _batch(cfg)
    p2, opt2, loss = bundle.train_step(params, opt, batch)
    assert np.isfinite(float(loss)), arch
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0, arch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode_shapes(arch):
    cfg = get_config(arch).reduced()
    bundle = build_model(cfg, max_dec=64)
    params = bundle.init(KEY)
    B, S = 2, 16
    inp = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        inp["frames"] = jax.random.normal(KEY, (B, 32, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        inp["patches"] = jax.random.normal(KEY, (B, cfg.num_patches, 1024), jnp.bfloat16)
    logits, cache = bundle.prefill(params, **inp)
    assert logits.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    l2, cache2 = bundle.decode_step(params, cache, tok)
    assert l2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(l2, np.float32)).all(), arch
    # positions advanced
    assert int(cache2["pos"][0]) == int(cache["pos"][0]) + 1


@pytest.mark.parametrize("arch", ASSIGNED)
def test_input_specs_cover_cells(arch):
    cfg = get_config(arch)
    bundle = build_model(cfg)
    for cell in cfg.shape_cells():
        specs = bundle.input_specs(cell)
        assert specs, (arch, cell.name)
        fn, args = bundle.step_for_cell(cell)
        assert callable(fn) and len(args) >= 2


def test_decode_matches_prefill_continuation():
    """Decoding token-by-token must equal prefilling the longer prompt
    (f32 params: the equivalence is exact up to roundoff)."""
    cfg = get_config("olmo-1b").reduced().with_overrides(param_dtype="float32")
    bundle = build_model(cfg)
    params = bundle.init(KEY)
    B, S = 1, 12
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    # path A: prefill S (with headroom), then decode the next token
    logits_a, cache = bundle.prefill(params, tokens=toks[:, :S], cache_len=S + 4)
    la, _ = bundle.decode_step(params, cache, toks[:, S:S + 1])
    # path B: prefill S+1 directly
    lb, _ = bundle.prefill(params, tokens=toks)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=1e-4, atol=1e-4)


def test_long_500k_applicability_flags():
    """The assignment's sub-quadratic rule is encoded in the configs."""
    ok = {a for a in ASSIGNED if get_config(a).long_context_ok}
    assert ok == {"mamba2-780m", "jamba-1.5-large-398b", "gemma3-4b", "mixtral-8x7b"}


def test_flash_impl_matches_chunked():
    """cfg.attn_impl="flash" (Pallas, interpret on CPU) == chunked jnp path."""
    base = get_config("yi-9b").reduced().with_overrides(
        param_dtype="float32", num_layers=2)
    b1 = build_model(base)
    b2 = build_model(base.with_overrides(attn_impl="flash"))
    params = b1.init(KEY)
    batch = {
        "tokens": jax.random.randint(KEY, (2, 64), 0, base.vocab_size),
        "labels": jax.random.randint(KEY, (2, 64), 0, base.vocab_size),
    }
    l1 = b1.train_loss(params, batch)
    l2 = b2.train_loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_flash_impl_matches_chunked_windowed():
    """Flash dispatch with a static sliding window (mixtral-style)."""
    base = get_config("mixtral-8x7b").reduced().with_overrides(
        param_dtype="float32", num_layers=2, sliding_window=16)
    b1 = build_model(base)
    b2 = build_model(base.with_overrides(attn_impl="flash"))
    params = b1.init(KEY)
    batch = {
        "tokens": jax.random.randint(KEY, (1, 48), 0, base.vocab_size),
        "labels": jax.random.randint(KEY, (1, 48), 0, base.vocab_size),
    }
    np.testing.assert_allclose(float(b1.train_loss(params, batch)),
                               float(b2.train_loss(params, batch)), rtol=1e-5)
