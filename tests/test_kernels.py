"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.moe_route import moe_route, moe_route_ref
from repro.kernels.segment_reduce import segment_reduce, segment_reduce_ref
from repro.kernels.ssd_scan import ssd_ref, ssd_scan

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("B,H,K,Sq,Skv,hd,causal,window,cap", [
    (2, 4, 2, 128, 128, 64, True, None, 0.0),
    (1, 8, 4, 256, 256, 128, True, None, 50.0),
    (2, 4, 4, 64, 192, 64, True, 64, 0.0),
    (1, 2, 1, 1, 128, 64, True, None, 0.0),       # decode-style
    (1, 4, 2, 96, 96, 32, False, None, 0.0),      # bidirectional (encoder)
])
def test_flash_attention(B, H, K, Sq, Skv, hd, causal, window, cap):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, K, Skv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, K, Skv, hd), jnp.float32)
    off = (Skv - Sq) if causal else 0
    o = flash_attention(q, k, v, causal, window, cap, off, 128, 128, True)
    r = attention_ref(q, k, v, causal=causal, window=window, softcap=cap, q_offset=off)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 2, 128, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 2, 128, 64)).astype(dtype)
    o = flash_attention(q, k, v, True, None, 0.0, 0, 64, 64, True)
    r = attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(r, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_grad_matches_ref():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 64, 32))
    k = jax.random.normal(ks[1], (1, 2, 64, 32))
    v = jax.random.normal(ks[2], (1, 2, 64, 32))
    g1 = jax.grad(lambda q: flash_attention(q, k, v, True, None, 0.0, 0, 64, 64, True).sum())(q)
    g2 = jax.grad(lambda q: attention_ref(q, k, v).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)


@pytest.mark.parametrize("B,S,H,P,G,N,q", [
    (2, 128, 4, 16, 1, 32, 32),
    (1, 256, 8, 64, 1, 128, 64),
    (2, 64, 4, 16, 2, 16, 16),  # multi-group
])
def test_ssd_scan(B, S, H, P, G, N, q):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A_log = jax.random.normal(ks[2], (H,)) * 0.5
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    y, st = ssd_scan(x, dt, A_log, Bm, Cm, q, True)
    yr, sr = ssd_ref(x, dt, A_log, Bm, Cm, q)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr), atol=2e-4, rtol=1e-3)


@pytest.mark.parametrize("N,D,op,block", [
    (512, 1, "sum", 128), (300, 4, "sum", 128),
    (512, 1, "max", 256), (256, 8, "min", 64),
])
def test_segment_reduce(N, D, op, block):
    ks = jax.random.split(KEY, 3)
    keys = jnp.sort(jax.random.randint(ks[0], (N,), 0, 40))
    valid = jax.random.bernoulli(ks[1], 0.85, (N,))
    vals = jax.random.normal(ks[2], (N,) if D == 1 else (N, D))
    h1, s1 = segment_reduce(keys, valid, vals, op, block, True)
    h2, s2 = segment_reduce_ref(keys, valid, vals, op)
    assert bool((h1 == h2).all())
    mask = np.isfinite(np.asarray(s2))
    np.testing.assert_allclose(np.asarray(s1)[mask], np.asarray(s2)[mask], atol=1e-4)


@pytest.mark.parametrize("T,E,k,C,bt", [
    (512, 8, 2, 64, 128), (300, 16, 2, 30, 256), (128, 4, 1, 40, 128),
])
def test_moe_route(T, E, k, C, bt):
    logits = jax.random.normal(KEY, (T, E))
    w1, i1, p1, k1 = moe_route(logits, k, C, bt, True)
    w2, i2, p2, k2 = moe_route_ref(logits, k, C)
    assert bool((i1 == i2).all() and (p1 == p2).all() and (k1 == k2).all())
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=1e-5)


def test_moe_route_matches_moe_ffn_positions():
    """Kernel ordinals must agree with models/moe.moe_ffn's argsort path."""
    from repro.configs import get_config
    cfg = get_config("mixtral-8x7b").reduced()
    T, E, k = 64, cfg.num_experts, cfg.experts_per_token
    x = jax.random.normal(KEY, (T, cfg.d_model))
    router = jax.random.normal(KEY, (cfg.d_model, E))
    logits = x @ router
    C = 16
    _, idx, pos, keep = moe_route(logits, k, C, 64, True)
    # recompute via the argsort path used in moe_ffn
    e_flat = np.asarray(idx).reshape(-1)
    order = np.argsort(e_flat, kind="stable")
    counts = np.bincount(e_flat, minlength=E)
    starts = np.cumsum(counts) - counts
    pos_ref = np.empty_like(e_flat)
    pos_ref[order] = np.arange(len(e_flat)) - starts[e_flat[order]]
    np.testing.assert_array_equal(np.asarray(pos).reshape(-1), pos_ref)
