"""Checkpoint/restart: roundtrip, async overlap, integrity, GC, elastic."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.configs import get_config
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def _tree():
    return {
        "params": {"w": jax.random.normal(KEY, (8, 8)),
                   "b": jnp.zeros((8,), jnp.float32)},
        "opt": {"m": jnp.ones((3,)), "step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 5, t)
    assert latest_step(str(tmp_path)) == 5
    out = restore(str(tmp_path), 5, jax.tree.map(lambda x: x, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_corruption_detected(tmp_path):
    t = _tree()
    sdir = save(str(tmp_path), 1, t)
    victim = [f for f in os.listdir(sdir) if f.endswith(".npy")][0]
    with open(os.path.join(sdir, victim), "r+b") as f:
        f.seek(100)
        f.write(b"\xde\xad")
    with pytest.raises(IOError, match="corruption"):
        restore(str(tmp_path), 1, t)


def test_gc_keeps_latest(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), s, t, keep=2)
    steps = sorted(os.listdir(str(tmp_path)))
    assert steps == ["step_00000004", "step_00000005"]


def test_async_checkpointer(tmp_path):
    t = _tree()
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    ck.save(10, t)
    ck.wait()
    assert latest_step(str(tmp_path)) == 10
    out = restore(str(tmp_path), 10, t)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(t["params"]["w"]))


def test_elastic_restore_single_device(tmp_path):
    """Elastic re-placement API on a 1-device mesh (multi-device path is
    exercised in test_distributed via subprocess)."""
    from repro.distributed.elastic import restore_elastic
    from repro.launch.mesh import make_local_mesh

    cfg = get_config("olmo-1b").reduced()
    bundle = build_model(cfg)
    params = bundle.init(KEY)
    opt = bundle.init_opt(params)
    save(str(tmp_path), 3, {"params": params, "opt": opt})
    mesh = make_local_mesh(1, 1)
    out = restore_elastic(str(tmp_path), 3, cfg, mesh,
                          {"params": params, "opt": opt})
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shape_mismatch_rejected(tmp_path):
    t = {"w": jnp.zeros((4, 4))}
    save(str(tmp_path), 1, t)
    with pytest.raises(ValueError, match="checkpoint"):
        restore(str(tmp_path), 1, {"w": jnp.zeros((5, 4))})
