"""Chaos suite (docs/fault_tolerance.md): deterministic fault injection at
every kill-point × task kind, asserting convergence to the no-fault oracle
with EXACT retry/repair counters.

Covers, at p=1 (p=8 runs the same matrix in tests/_faults_main.py):

  * the FaultPlan rule machinery itself (matching, attempts, times, log)
  * scheduler retry via lineage for all six task kinds — narrow, fused,
    wide (every shuffle kind), native, reshard, action
  * retry-budget exhaustion and non-recoverable cascade
  * checkpoint-truncated repair (never re-reads the source)
  * speculative re-execution of straggling gang tasks
  * executor kill / blacklist / restore
  * unpersist() eviction regressions
"""
import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import ICluster, IProperties, IWorker
from repro.core import faults
from repro.core.dag import DagEngine
from repro.core.faults import FaultInjected, FaultPlan
from repro.core.job import IJob, default_scheduler
from repro.core.native import ignis_export


@pytest.fixture
def worker():
    return IWorker(ICluster(IProperties()), "python")


def _retries():
    return default_scheduler().stats["task_retries"]


def _ints(n=32):
    return np.arange(n, dtype=np.int32)


# ---------------------------------------------------------------------------
# FaultPlan rule machinery
# ---------------------------------------------------------------------------


def test_rule_fires_on_exact_attempt():
    plan = FaultPlan().kill_block(op="map", block=1, attempt=1)
    plan.check("dag.block", op="map", block=1)  # attempt 0: no fire
    with pytest.raises(FaultInjected):
        plan.check("dag.block", op="map", block=1)  # attempt 1: fire
    plan.check("dag.block", op="map", block=1)  # attempt 2: no fire
    assert plan.injections() == 1 and plan.injections("dag.block") == 1


def test_rule_match_is_exact_not_substring():
    plan = FaultPlan().kill_block(op="map", block=0)
    plan.check("dag.block", op="mapValues", block=0)  # must not match
    with pytest.raises(FaultInjected):
        plan.check("dag.block", op="map", block=0)


def test_rule_glob_and_times():
    plan = FaultPlan().fail("job.task", name="collect(*", attempt=None, times=2)
    for _ in range(2):
        with pytest.raises(FaultInjected):
            plan.check("job.task", name="collect(map#3)", kind="action", attempt=0)
    plan.check("job.task", name="collect(map#3)", kind="action", attempt=0)
    assert plan.injections() == 2


def test_delay_rule_sleeps_and_logs():
    import time

    plan = FaultPlan().delay("dag.node", 0.05, op="sortBy")
    t0 = time.perf_counter()
    plan.check("dag.node", op="sortBy")
    assert time.perf_counter() - t0 >= 0.05
    assert plan.log[0][0:2] == ("dag.node", "delay")


def test_inject_nesting_restores_previous_plan():
    a, b = FaultPlan(), FaultPlan()
    assert faults.active() is None
    with faults.inject(a):
        with faults.inject(b):
            assert faults.active() is b
        assert faults.active() is a
    assert faults.active() is None


def test_seeded_sampling_is_deterministic():
    picks = [FaultPlan(seed=7).choice(range(100)) for _ in range(3)]
    assert len(set(picks)) == 1


# ---------------------------------------------------------------------------
# chaos matrix, p=1: every task kind recovers to the no-fault oracle with
# exactly the expected retry count
# ---------------------------------------------------------------------------


def _assert_recovers(build, collect, plan, expect_retries=1):
    """Oracle run without faults, then a fresh lineage under ``plan``:
    result must match, scheduler retries must be EXACTLY ``expect_retries``
    and every planned fault must actually have fired."""
    oracle = collect(build())
    r0 = _retries()
    with faults.inject(plan):
        got = collect(build())
    assert got == oracle
    assert _retries() - r0 == expect_retries
    assert plan.injections() == expect_retries
    return oracle


@pytest.mark.parametrize("block", [0, 1, 2, 3])
def test_narrow_task_block_kill(worker, block):
    # a single map cannot fuse → the unfused block_fn path
    def build():
        return worker.parallelize(_ints(40), blocks=4).map(lambda x: x * 2)

    _assert_recovers(build, lambda df: sorted(int(x) for x in df.collect()),
                     FaultPlan().kill_block(op="map", block=block))


@pytest.mark.parametrize("block", [0, 1, 2, 3])
def test_fused_stage_block_kill(worker, block):
    def build():
        return (worker.parallelize(_ints(40), blocks=4)
                .map(lambda x: x * 2)
                .filter(lambda x: x % 3 == 0)
                .map(lambda x: x + 1))

    def collect(df):
        assert worker.engine.plan(df.node), "chain must fuse"
        return sorted(int(x) for x in df.collect())

    _assert_recovers(build, collect, FaultPlan().kill_block(op="map", block=block))


@pytest.mark.parametrize("kind,pipeline", [
    ("sort", lambda df: df.sort()),
    ("distinct", lambda df: df.map(lambda x: x % 7).distinct()),
    ("reduceByKey", lambda df: df.map(lambda x: {"key": x % 5, "value": x})
        .reduce_by_key(lambda a, b: a + b, 0)),
    ("groupByKey", lambda df: df.map(lambda x: {"key": x % 5, "value": x})
        .group_by_key()),
    ("partitionBy", lambda df: df.map(lambda x: {"key": x % 5, "value": x})
        .partition_by()),
])
def test_wide_task_collective_kill(worker, kind, pipeline):
    def build():
        return pipeline(worker.parallelize(_ints(30)))

    def collect(df):
        return sorted(map(repr, df.collect()))

    _assert_recovers(build, collect, FaultPlan().fail_collective(kind))


def test_wide_join_collective_kill(worker):
    def build():
        l = worker.parallelize(_ints(16)).map(lambda x: {"key": x % 4, "value": x})
        r = worker.parallelize(_ints(8)).map(lambda x: {"key": x % 4, "value": x * 2})
        return l.join(r, max_matches=4)

    def collect(df):
        return sorted(
            (int(np.asarray(x["key"])), int(np.asarray(x["value"][0])),
             int(np.asarray(x["value"][1]))) for x in df.collect())

    _assert_recovers(build, collect, FaultPlan().fail_collective("join"))


def test_native_task_kill(worker):
    runs = []

    @ignis_export("faulty_scale")
    def faulty_scale(ctx, data=None, valid=None):
        runs.append(1)
        return data * jnp.int32(3), valid

    def build():
        return worker.call("faulty_scale", worker.parallelize(_ints(12)))

    runs.clear()
    _assert_recovers(build, lambda df: sorted(int(x) for x in df.collect()),
                     FaultPlan().fail_node(op="call:faulty_scale"))
    # oracle run once + faulted attempt killed BEFORE the app + retry run
    assert len(runs) == 2


def test_reshard_task_kill():
    cluster = ICluster(IProperties())
    w1 = IWorker(cluster, "python", name="src-w")
    w2 = IWorker(cluster, "python", name="dst-w")

    def build():
        return w2.import_data(w1.parallelize(_ints(20)).map(lambda x: x + 1))

    _assert_recovers(build, lambda df: sorted(int(x) for x in df.collect()),
                     FaultPlan().fail_reshard(kind="importData"))


def test_action_task_kill(worker):
    def build():
        return worker.parallelize(_ints(24), blocks=2).map(lambda x: x + 3)

    _assert_recovers(build, lambda df: df.count(),
                     FaultPlan().fail_task(name="count(*"))


def test_take_action_iter_path_kill(worker):
    """Early-exit take evaluates through the lazy block iterator — its
    injection sites retry like any other action."""
    def build():
        return worker.parallelize(_ints(40), blocks=4).map(lambda x: x + 1)

    _assert_recovers(build, lambda df: [int(x) for x in df.take(5)],
                     FaultPlan().kill_block(op="map", block=0))


# ---------------------------------------------------------------------------
# retry budget semantics
# ---------------------------------------------------------------------------


def test_kill_on_retry_attempt_needs_bigger_budget():
    w = IWorker(ICluster(IProperties({"ignis.task.attempts": "3"})), "python")

    def build():
        return w.parallelize(_ints(16), blocks=2).map(lambda x: x * 5)

    plan = (FaultPlan()
            .kill_block(op="map", block=1, attempt=0)
            .kill_block(op="map", block=1, attempt=1))
    _assert_recovers(build, lambda df: sorted(int(x) for x in df.collect()),
                     plan, expect_retries=2)


def test_budget_exhaustion_surfaces_the_fault(worker):
    df = worker.parallelize(_ints(8)).map(lambda x: x)
    plan = FaultPlan().fail("dag.block", op="map", block=0, attempt=None)
    r0 = _retries()
    with faults.inject(plan):
        with pytest.raises(FaultInjected):
            df.collect()
    # default budget ignis.task.attempts=2 → exactly one retry then cascade
    assert _retries() - r0 == 1


def test_non_recoverable_error_never_retries(worker):
    @ignis_export("det_boom")
    def det_boom(ctx, data=None, valid=None):
        raise ValueError("deterministic app bug")

    fut = worker.call("det_boom", worker.parallelize(_ints(4))).count_async()
    r0 = _retries()
    with pytest.raises(ValueError, match="deterministic"):
        fut.result(30)
    assert _retries() == r0
    # the native boundary task failed; the action cascaded without running
    assert fut.task.attempt == 0 and fut.task.state == "failed"


def test_retries_disabled_by_property():
    w = IWorker(ICluster(IProperties({"ignis.task.attempts": "1"})), "python")
    df = w.parallelize(_ints(8)).map(lambda x: x)
    r0 = _retries()
    with faults.inject(FaultPlan().kill_block(op="map", block=0)):
        with pytest.raises(FaultInjected):
            df.collect()
    assert _retries() == r0


def test_failure_cascade_after_exhaustion(worker):
    """Dependents of an unrecoverable task still fail with its error."""
    job = IJob("cascade")
    df = worker.parallelize(_ints(8)).map(lambda x: x)
    plan = FaultPlan().fail("job.task", name="count(*", attempt=None)
    with faults.inject(plan):
        f1 = df.count_async(job=job)
        with pytest.raises(FaultInjected):
            f1.result(30)


# ---------------------------------------------------------------------------
# checkpoint-aware lineage recovery
# ---------------------------------------------------------------------------


def test_checkpoint_truncates_lineage(worker, tmp_path):
    src = worker.parallelize(_ints(40), blocks=4)
    ck = src.map(lambda x: x + 1).map(lambda x: x * 3).checkpoint(str(tmp_path))
    assert ck.node.parents == []
    assert ck.node.op.startswith("checkpoint(")
    assert sorted(int(x) for x in ck.collect()) == sorted(
        (x + 1) * 3 for x in range(40))


def test_checkpoint_repair_restores_block_not_source(worker, tmp_path):
    src = worker.parallelize(_ints(40), blocks=4)
    ck = src.map(lambda x: x + 1).checkpoint(str(tmp_path))
    tail = ck.map(lambda x: x * 2)
    oracle = sorted(int(x) for x in tail.collect())
    src_cc = src.node.compute_count
    base = dict(worker.engine.stats)
    DagEngine.kill_block(ck.node, 2)
    assert sorted(int(x) for x in tail.collect()) == oracle
    assert worker.engine.stats["block_restores"] - base["block_restores"] == 1
    assert worker.engine.stats["block_recomputes"] == base["block_recomputes"]
    assert src.node.compute_count == src_cc  # source never re-read


def test_checkpoint_full_loss_restores_everything(worker, tmp_path):
    ck = worker.parallelize(_ints(24), blocks=3).map(lambda x: x * 7).checkpoint(
        str(tmp_path))
    oracle = sorted(int(x) for x in ck.collect())
    ck.node.result = None  # total cache loss — reload all blocks from disk
    assert sorted(int(x) for x in ck.collect()) == oracle


def test_checkpoint_restore_verifies_integrity(worker, tmp_path):
    ck = worker.parallelize(_ints(16), blocks=2).map(lambda x: x + 9).checkpoint(
        str(tmp_path))
    sdir = [d for d in os.listdir(tmp_path) if d.startswith("step_")][0]
    victim = sorted(f for f in os.listdir(tmp_path / sdir) if f.endswith(".npy"))[0]
    with open(tmp_path / sdir / victim, "r+b") as f:
        f.seek(90)
        f.write(b"\xde\xad")
    DagEngine.kill_block(ck.node, 0)
    with pytest.raises(IOError, match="corruption"):
        ck.collect()


def test_kill_during_post_checkpoint_map_retries_from_checkpoint(worker, tmp_path):
    src = worker.parallelize(_ints(32), blocks=4)
    ck = src.map(lambda x: x + 1).checkpoint(str(tmp_path))
    src_cc = src.node.compute_count

    def build():
        return ck.map(lambda x: x - 1)

    _assert_recovers(build, lambda df: sorted(int(x) for x in df.collect()),
                     FaultPlan().kill_block(op="map", block=1))
    assert src.node.compute_count == src_cc


# ---------------------------------------------------------------------------
# speculative re-execution (straggler policy for gang tasks)
# ---------------------------------------------------------------------------


def _spec_worker(timeout: float = 0.25):
    return IWorker(ICluster(IProperties({
        "ignis.task.speculative": "true",
        "ignis.task.speculative.timeout": str(timeout),
    })), "python")


def test_straggling_gang_task_is_speculatively_duplicated():
    w = _spec_worker()
    g = w.groups(1)[0]
    oracle = sorted(
        int(x) for x in
        w.parallelize(_ints(16), blocks=2).map(lambda x: x + 5).collect())
    df = w.parallelize(_ints(16), blocks=2).map(lambda x: x + 5)
    plan = FaultPlan().delay_block(op="map", block=0, seconds=1.5)
    with faults.inject(plan):
        fut = df.collect_async(job=IJob("spec", group=g))
        got = sorted(int(x) for x in fut.result(60))
    assert got == oracle
    assert w.engine.stats["speculative_retries"] == 1
    assert plan.injections() == 1


def test_fast_gang_task_launches_no_duplicate():
    # generous deadline: this asserts the ABSENCE of a duplicate, so the
    # deadline must sit far above suite-load jitter (~0.1 s evaluations)
    w = _spec_worker(timeout=5.0)
    g = w.groups(1)[0]
    df = w.parallelize(_ints(16), blocks=2).map(lambda x: x + 5)
    assert df.collect_async(job=IJob("fast", group=g)).result(60)
    assert w.engine.stats["speculative_retries"] == 0


def test_speculative_policy_off_for_ungrouped_tasks():
    w = _spec_worker()
    df = w.parallelize(_ints(16), blocks=2).map(lambda x: x + 5)
    plan = FaultPlan().delay_block(op="map", block=0, seconds=0.6)
    with faults.inject(plan):
        assert df.count() == 16  # no group → no deadline, just slow
    assert w.engine.stats["speculative_retries"] == 0


# ---------------------------------------------------------------------------
# executor kill + blacklist
# ---------------------------------------------------------------------------


def test_kill_executor_repairs_cached_blocks(worker):
    df = worker.parallelize(_ints(24), blocks=3).map(lambda x: x * 7).persist()
    oracle = sorted(int(x) for x in df.collect())
    base = worker.engine.stats["block_recomputes"]
    assert worker.kill_executor(1, blacklist=False) >= 1
    assert sorted(int(x) for x in df.collect()) == oracle
    assert worker.engine.stats["block_recomputes"] - base == 1


def test_blacklisted_rank_refused_by_group_until_restored(worker):
    worker.kill_executor(0)
    with pytest.raises(ValueError, match="blacklisted"):
        worker.context.group([0])
    worker.restore_executor(0)
    assert worker.context.group([0]).executors == 1


def test_blacklist_covers_cached_groups(worker):
    """A split cached by groups(n) BEFORE a kill must not keep handing out
    sub-clusters over the lost rank."""
    gs = worker.groups(1)
    worker.kill_executor(0)
    with pytest.raises(ValueError, match="blacklisted"):
        worker.groups(1)
    worker.restore_executor(0)
    assert worker.groups(1) is gs  # same communicators (and locks) return


# ---------------------------------------------------------------------------
# unpersist(): eviction regressions
# ---------------------------------------------------------------------------


def test_unpersist_drops_blocks_and_recomputes(worker):
    df = worker.parallelize(_ints(20), blocks=2).map(lambda x: x + 1).persist()
    assert df.count() == 20
    assert df.node.result is not None
    cc = df.node.compute_count
    df.unpersist()
    assert df.node.result is None and not df.node.cached
    assert df.count() == 20
    assert df.node.compute_count > cc  # really recomputed
    assert df.node.result is None  # and not silently re-cached


def test_unpersist_restores_fusability(worker):
    mid = (worker.parallelize(_ints(20)).map(lambda x: x * 2)
           .filter(lambda x: x % 2 == 0).persist())
    tail = mid.map(lambda x: x + 1)
    tail.count()
    assert mid.node not in worker.engine.plan(tail.node)  # cached boundary
    mid.unpersist()
    plans = worker.engine.plan(tail.node)
    assert any(mid.node in stage.nodes for stage in plans.values())


def test_unpersist_with_holes_is_safe(worker):
    df = worker.parallelize(_ints(30), blocks=3).map(lambda x: x - 1).persist()
    oracle = sorted(int(x) for x in df.collect())
    DagEngine.kill_block(df.node, 1)
    df.unpersist()
    assert sorted(int(x) for x in df.collect()) == oracle


def test_unpersist_node_dropped_by_executor_kill_accounting(worker):
    """An unpersisted node no longer holds blocks, so an executor kill
    after unpersist must not count it as a lost block."""
    df = worker.parallelize(_ints(16), blocks=2).map(lambda x: x).persist()
    df.count()
    df.unpersist()
    killed_before = worker.kill_executor(1, blacklist=False)
    # only the parallelize source (still cached) can lose its block
    assert all(n.op == "parallelize" or n.result is None
               for n in list(worker._cached_nodes))
    assert killed_before <= 1


def test_job_memo_reuse_is_scoped_to_the_job(worker):
    """Within one explicit IJob the shared memo intentionally reuses an
    unpersisted node's blocks (docs/fault_tolerance.md); release() is that
    layer's eviction point and the NEXT job recomputes."""
    df = worker.parallelize(_ints(12), blocks=2).map(lambda x: x + 2).persist()
    job = IJob("memo-scope")
    assert df.count_async(job=job).result(30) == 12
    df.unpersist()
    cc = df.node.compute_count
    job.release()
    assert df.count() == 12
    assert df.node.compute_count > cc


# ---------------------------------------------------------------------------
# nonblocking collective handles (the comm.handle site — docs/collectives.md)
# ---------------------------------------------------------------------------


def test_kill_pending_handle_retries_task(worker):
    """A handle-valued action result killed mid-await re-enters the task's
    retry loop: the fn re-runs, re-issues its collective, and the job
    converges with EXACTLY one retry."""

    def build():
        return worker.parallelize(_ints(48)).map(lambda x: x + 1)

    _assert_recovers(build, lambda df: df.count(),
                     FaultPlan().kill_handle(coll="action.count", attempt=0))


def test_double_wait_after_fault_and_idempotency(worker):
    """MPI_Wait semantics under chaos: a kill leaves the handle PENDING (the
    transfer was lost, not completed), so wait may be re-posted; once it
    completes, further waits return the same value WITHOUT re-checking the
    fault site (idempotent completion)."""
    from repro.core import comm

    ctx = worker.context
    x = comm.shard_rows(ctx, jnp.arange(8, dtype=jnp.float32))
    with faults.inject(FaultPlan().kill_handle(coll="allreduce",
                                               attempt=0)) as plan:
        h = comm.iallreduce(ctx, x)
        with pytest.raises(FaultInjected):
            h.wait()
        # the kill left the handle un-awaited (done() may still report
        # device readiness — MPI_Test on the wire — but completion state
        # is what re-arms the fault site)
        assert "pending" in repr(h)
        assert float(h.wait()) == 28.0  # re-posted wait completes
        assert float(h.wait()) == 28.0  # idempotent: site not re-checked
    assert plan.injections() == 1


def test_never_awaited_handle_flushed_at_task_end(worker):
    """An in-flight collective must not outlive its task: a handle the fn
    issued but never awaited is drained by the scheduler at task end and
    counted in coll_flushed."""
    from repro.core import comm

    @ignis_export("leaky_app")
    def leaky_app(ctx, data=None, valid=None):
        comm.iallreduce(ctx, comm.shard_rows(
            ctx, jnp.arange(4, dtype=jnp.float32)))  # never awaited
        return data, valid

    sched = default_scheduler()
    f0 = sched.stats["coll_flushed"]
    assert worker.call("leaky_app", worker.parallelize(_ints(16))).count() == 16
    assert sched.stats["coll_flushed"] >= f0 + 1


def test_kill_flush_of_leaked_handle_retries(worker):
    """The end-of-task flush is a kill-point like any other: a fault there
    re-runs the whole task fn (which re-issues the leaked collective)."""
    from repro.core import comm

    @ignis_export("leaky_app_chaos")
    def leaky_app_chaos(ctx, data=None, valid=None):
        comm.iallreduce(ctx, comm.shard_rows(
            ctx, jnp.arange(4, dtype=jnp.float32)))
        return data, valid

    def build():
        return worker.call("leaky_app_chaos", worker.parallelize(_ints(16)))

    _assert_recovers(
        build, lambda df: df.count(),
        FaultPlan().kill_handle(coll="allreduce", phase="flush", attempt=0))


def test_kill_handle_budget_exhaustion_surfaces(worker):
    """Killing EVERY await of the action's handle exhausts the retry budget
    and the fault surfaces through the future, like any task failure."""
    def build():
        return worker.parallelize(_ints(8))

    with faults.inject(FaultPlan().fail("comm.handle", coll="action.count",
                                        attempt=None)):
        with pytest.raises(FaultInjected):
            build().count()


# ---------------------------------------------------------------------------
# kernel tier chaos (docs/kernels.md): kernel.stage is a task fault the
# scheduler retries via lineage; kernel.capability only degrades the node
# ---------------------------------------------------------------------------


def _kernel_worker(mode="interpret"):
    return IWorker(ICluster(IProperties({"ignis.kernels": mode})), "python")


def _rbk_build(w):
    def build():
        return (w.parallelize(_ints(64))
                .map(lambda x: {"key": x % 5, "value": x})
                .reduce_by_key(lambda a, b: a + b, 0))

    return build


def test_kernel_stage_kill_retries_via_lineage():
    """A kill INSIDE a kernel-backed wide stage is a task fault: lineage
    retry must converge to the oracle with exactly one retry."""
    w = _kernel_worker()
    _assert_recovers(_rbk_build(w), lambda df: sorted(map(repr, df.collect())),
                     FaultPlan().fail_kernel_stage("reduceByKey"))
    assert w.shuffle_stats()["kernel_hits"] >= 1


def test_kernel_stage_site_never_fires_on_fallback_tier():
    """With the kernel tier off the stage runs the jnp oracle, so the
    kernel.stage site must not exist on the path — the plan stays silent."""
    w = _kernel_worker("off")
    plan = FaultPlan().fail_kernel_stage()
    with faults.inject(plan):
        assert len(_rbk_build(w)().collect()) == 5
    assert plan.injections() == 0


def test_kernel_capability_fault_degrades_mid_job_without_error():
    """Capability loss mid-job is NOT a task fault: the node silently runs
    the plain-JAX fallback, results match, no scheduler retries."""
    w = _kernel_worker()
    build = _rbk_build(w)
    oracle = sorted(map(repr, build().collect()))
    f0 = w.shuffle_stats()["kernel_fallbacks"]
    r0 = _retries()
    plan = FaultPlan().fail_kernel_capability()  # unbounded: every check
    with faults.inject(plan):
        assert sorted(map(repr, build().collect())) == oracle
    assert plan.injections() >= 1
    assert _retries() == r0
    assert w.shuffle_stats()["kernel_fallbacks"] > f0


def test_kernel_stage_budget_exhaustion_surfaces():
    w = _kernel_worker()
    plan = FaultPlan().fail("kernel.stage", kind="reduceByKey",
                            attempt=None)  # unbounded: exhaust the budget
    with faults.inject(plan):
        with pytest.raises(FaultInjected):
            _rbk_build(w)().collect()


# ---------------------------------------------------------------------------
# streaming pumps (docs/streaming.md): stream.batch is a task fault
# (lineage replay, bit-identical commit), stream.admit is a policy fault
# (forced shed, no retry), and a checkpointed pump survives a hard kill
# ---------------------------------------------------------------------------


def _stream_worker(worker):
    worker.cluster.props["ignis.stream.batch.rows"] = "8"
    return worker


def _stream_run(worker, tenant, **kw):
    from repro.streaming import StreamContext, TenantRequestSource

    sc = StreamContext(worker, TenantRequestSource(0, seed=13, limit=50),
                       tenant=tenant, init_state=np.zeros((2,), np.int64), **kw)
    return sc, sc.run()


def test_stream_batch_kill_replays_bit_identical(worker):
    """Killing one micro-batch task mid-stream: the scheduler replays it via
    lineage, the commit order holds, and the folded state is bit-identical —
    with EXACTLY one retry, one injection, one counted replay."""
    w = _stream_worker(worker)
    _, oracle = _stream_run(w, "oracle")
    r0 = _retries()
    plan = FaultPlan().fail_stream_batch(tenant="a", batch=3)
    with faults.inject(plan):
        sc, state = _stream_run(w, "a")
    assert (state == oracle).all()
    assert _retries() - r0 == 1
    assert plan.injections("stream.batch") == 1
    assert sc.batches_replayed == 1
    assert sc.job.stats()["stream"]["tenants"]["a"]["batches_replayed"] == 1


def test_stream_batch_budget_exhaustion_surfaces(worker):
    """An unbounded kill on one batch exhausts ``ignis.task.attempts`` and
    the fault surfaces through the pump's in-order commit."""
    w = _stream_worker(worker)
    r0 = _retries()
    plan = FaultPlan().fail_stream_batch(tenant="a", batch=2, attempt=None)
    with faults.inject(plan):
        with pytest.raises(FaultInjected):
            _stream_run(w, "a")
    assert _retries() - r0 == 1  # one retry, then the budget is spent
    assert plan.injections("stream.batch") == 2


def test_stream_admit_fault_sheds_without_retry(worker):
    """stream.admit is NOT a task fault: each injection forces one shed
    decision — counted in telemetry, never retried, offset still advances
    past the shed batches so the stream completes."""
    w = _stream_worker(worker)
    r0 = _retries()
    plan = FaultPlan().fail_stream_admit(tenant="a", times=2)
    with faults.inject(plan):
        sc, _ = _stream_run(w, "a")
    assert sc.shed_batches == 2
    assert sc.committed == 5  # 7 polled batches, 2 shed
    assert sc.offset == 50  # the cursor still reaches the end of the stream
    assert _retries() == r0
    assert plan.injections("stream.admit") == 2
    snap = sc.job.stats()["stream"]["tenants"]["a"]
    assert snap["shed"] == 2 and snap["completed"] == 5


def test_stream_kill_then_restart_resumes_from_checkpoint(worker, tmp_path):
    """The acceptance scenario: a micro-batch kill that exhausts its retry
    budget aborts the pump; a NEW pump restores the last quiesced offset
    checkpoint and reconverges to the bit-identical oracle state."""
    w = _stream_worker(worker)
    _, oracle = _stream_run(w, "oracle")
    w.cluster.props["ignis.stream.checkpoint.interval"] = "2"
    d = str(tmp_path / "ck")
    r0 = _retries()
    plan = FaultPlan().fail_stream_batch(tenant="a", batch=5, attempt=None)
    with faults.inject(plan):
        with pytest.raises(FaultInjected):
            _stream_run(w, "a", ckpt_dir=d)
    assert _retries() - r0 == 1
    assert plan.injections("stream.batch") == 2
    # restart without the fault: resume from the last quiesced checkpoint
    # (the interval cut drains in-flight batches first, so the exact step
    # depends on how far the pump ran ahead — the bit-identity does not)
    sc2, state = _stream_run(w, "a", ckpt_dir=d)
    assert sc2.restored_from is not None and 2 <= sc2.restored_from <= 5
    assert (state == oracle).all()
    assert sc2.offset == 50 and sc2.committed == 7
    assert sc2.batches_replayed == 0  # replay-by-restart, not re-commit


# ---------------------------------------------------------------------------
# the p=8 chaos matrix (subprocess: the 8-device flag must not leak here)
# ---------------------------------------------------------------------------


@pytest.mark.timeout(900)
def test_faults_suite_p8():
    here = os.path.dirname(__file__)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "..", "src"), env.get("PYTHONPATH", "")]
    )
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(here, "_faults_main.py")],
        env=env, capture_output=True, text=True, timeout=880,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "ALL_FAULTS_OK" in r.stdout
