"""Multi-device collective checks — run in a subprocess with 8 host devices
(tests/test_distributed.py drives this; the flag must precede jax import and
must NOT leak into the main pytest process per the dry-run ground rules).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import ICluster, IProperties, IWorker  # noqa: E402
from repro.core import comm  # noqa: E402
from repro.core import compat  # noqa: E402
from repro.distributed.pipeline import pipeline_apply, reference_apply  # noqa: E402
from repro.launch.mesh import make_local_mesh, make_pp_mesh  # noqa: E402


def check(name, ok):
    print(f"{name}: {'OK' if ok else 'FAIL'}")
    if not ok:
        raise SystemExit(1)


def main():
    assert len(jax.devices()) == 8, jax.devices()

    # ---- dataflow over 8 executors ----------------------------------------
    props = IProperties({"ignis.executor.instances": "8"})
    w = IWorker(ICluster(props), "python")
    assert w.executors == 8

    rng = np.random.default_rng(0)
    vals = rng.integers(0, 100000, 4096).astype(np.int32)
    got = [int(x) for x in w.parallelize(vals).sort().collect()]
    check("psrs_sort_8shards", got == sorted(int(v) for v in vals))

    kv = w.parallelize(vals).map(lambda x: {"key": x % 13, "value": jnp.int32(1)})
    counts = {int(np.asarray(r["key"])): int(np.asarray(r["value"]))
              for r in kv.reduce_by_key(lambda a, b: a + b, 0).collect()}
    exp = {}
    for v in vals:
        exp[int(v) % 13] = exp.get(int(v) % 13, 0) + 1
    check("reduce_by_key_hash_exchange", counts == exp)

    l = w.parallelize(np.arange(64, dtype=np.int32)).map(
        lambda x: {"key": x % 8, "value": x})
    r = w.parallelize(np.arange(32, dtype=np.int32)).map(
        lambda x: {"key": x % 8, "value": x * 2})
    rows = l.join(r).collect()
    got_j = sorted((int(np.asarray(x["key"])), int(np.asarray(x["value"][0])),
                    int(np.asarray(x["value"][1]))) for x in rows)
    exp_j = sorted((a % 8, a, b * 2) for a in range(64) for b in range(32)
                   if a % 8 == b % 8)
    check("distributed_join", got_j == exp_j)

    # ---- adaptive shuffle engine: overflow retry + capacity memory --------
    # (DESIGN.md §6) a deliberately tiny capacity factor forces every
    # exchange bucket to overflow; results must still match the oracle and
    # the capacity memory must remove the retry on the second run.
    wt = IWorker(
        ICluster(IProperties({"ignis.executor.instances": "8",
                              "ignis.shuffle.capacity.factor": "0.05"})),
        "python")
    vals_t = rng.integers(0, 1000, 1024).astype(np.int32)
    frame = wt.parallelize(vals_t).sort()
    got_t = [int(x) for x in frame.collect()]
    check("overflow_sort_correct", got_t == sorted(int(v) for v in vals_t))
    st1 = wt.shuffle_stats()
    check("overflow_sort_retried", st1["overflow_retries"] >= 1)
    got_t2 = [int(x) for x in frame.collect()]
    st2 = wt.shuffle_stats()
    check("overflow_sort_stable", got_t2 == got_t)
    check("capacity_memory_no_second_retry",
          st2["overflow_retries"] == st1["overflow_retries"]
          and st2["wide_plan_misses"] == st1["wide_plan_misses"]
          and st2["capacity_memory_hits"] > st1["capacity_memory_hits"])

    # hash-exchange overflow (partitionBy with 5-key skew at p=8, C≈1)
    pb = wt.parallelize(vals_t).map(
        lambda x: {"key": x % 5, "value": x}).partition_by()
    vals_back = sorted(int(np.asarray(r["value"])) for r in pb.collect())
    check("overflow_hash_rows_preserved",
          vals_back == sorted(int(v) for v in vals_t))
    st3 = wt.shuffle_stats()
    check("overflow_hash_retried", st3["overflow_retries"] > st2["overflow_retries"])

    # join under tiny capacity: exchange retry, then fan-out retry, oracle match
    lt = wt.parallelize(np.arange(256, dtype=np.int32)).map(
        lambda x: {"key": x % 4, "value": x})
    rt = wt.parallelize(np.arange(64, dtype=np.int32)).map(
        lambda x: {"key": x % 4, "value": x * 2})
    got_tj = sorted((int(np.asarray(x["key"])), int(np.asarray(x["value"][0])),
                     int(np.asarray(x["value"][1])))
                    for x in lt.join(rt, max_matches=2).collect())
    exp_tj = sorted((a % 4, a, b * 2) for a in range(256) for b in range(64)
                    if a % 4 == b % 4)
    check("overflow_join_correct", got_tj == exp_tj)
    check("overflow_join_fanout_retried", wt.shuffle_stats()["fanout_retries"] >= 1)
    check("bytes_moved_recorded", wt.shuffle_stats()["bytes_moved"] > 0)

    # ---- comm layer (MPI analogue) -----------------------------------------
    ctx = w.context
    x = comm.shard_rows(ctx, jnp.arange(16, dtype=jnp.float32))
    check("allreduce", float(comm.allreduce(ctx, x)) == float(np.arange(16).sum()))
    g = comm.gather(ctx, x)
    check("allgather", np.array_equal(np.asarray(g), np.arange(16, dtype=np.float32)))
    y = comm.ppermute(ctx, x, shift=1)
    check("ppermute_ring", np.array_equal(
        np.asarray(y).reshape(8, 2), np.roll(np.arange(16).reshape(8, 2), 1, axis=0)))
    a2a = comm.alltoall(ctx, comm.shard_rows(ctx, jnp.arange(64, dtype=jnp.int32)))
    check("alltoall_shape", np.asarray(a2a).shape == (64,))
    try:
        comm.alltoall(ctx, comm.shard_rows(ctx, jnp.arange(24, dtype=jnp.int32)))
        check("alltoall_indivisible_raises", False)
    except ValueError:
        check("alltoall_indivisible_raises", True)

    # ---- nonblocking + persistent conformance at p=8 ------------------------
    # (the p=1 matrix is tests/test_collectives.py; here the wire patterns
    # are real 8-way exchanges, checked against the same NumPy oracles)
    a2a_i = comm.ialltoall(
        ctx, comm.shard_rows(ctx, jnp.arange(64, dtype=jnp.int32))).wait()
    check("ialltoall_transpose_8way", np.array_equal(
        np.asarray(a2a_i), np.arange(64).reshape(8, 8).T.reshape(-1)))
    xi = comm.shard_rows(ctx, jnp.arange(16, dtype=jnp.int32) - 16)
    check("iallreduce_max_all_negative_int",
          int(comm.iallreduce(ctx, xi, op="max").wait()) == -1)
    ex = comm.exscan(ctx, comm.shard_rows(ctx, jnp.ones(8, jnp.int32)))
    check("exscan_rank_prefix", np.array_equal(np.asarray(ex), np.arange(8)))
    plan8 = comm.persistent(ctx, "allreduce", x)
    s0 = comm.comm_stats()
    # a HELD plan skips the cache entirely (init-once/invoke-many): no
    # misses, and no lookups either
    reps = [float(plan8(x)) for _ in range(3)]
    s1 = comm.comm_stats()
    check("persistent_invoke_many_stable",
          reps == [float(np.arange(16).sum())] * 3)
    # re-RESOLVING the same (coll, mesh, aval) key must be pure cache hits
    for _ in range(2):
        comm.persistent(ctx, "allreduce", x)
    s2 = comm.comm_stats()
    check("persistent_plan_cache_hit_8way",
          s2["coll_plan_misses"] == s0["coll_plan_misses"]
          and s2["coll_plan_hits"] >= s1["coll_plan_hits"] + 2)

    # ---- communicator groups (MPI_Comm_split over the mesh) ----------------
    g0, g1 = ctx.split(2)
    check("split_sizes", g0.executors == 4 and g1.executors == 4)
    check("split_disjoint_devices",
          not (set(g0.mesh.devices.flat) & set(g1.mesh.devices.flat)))
    # collectives inside a group must not leak across the boundary: each
    # group allreduces ITS residents only
    x0 = comm.shard_rows(g0, jnp.arange(8, dtype=jnp.float32))         # 0..7
    x1 = comm.shard_rows(g1, jnp.arange(8, 16, dtype=jnp.float32))     # 8..15
    check("group_allreduce_isolated",
          float(comm.allreduce(g0, x0)) == 28.0
          and float(comm.allreduce(g1, x1)) == 92.0)
    check("group_gather_local",
          np.array_equal(np.asarray(comm.gather(g1, x1)),
                         np.arange(8, 16, dtype=np.float32)))
    # world collectives are untouched by the existence of groups
    check("world_allreduce_after_split",
          float(comm.allreduce(ctx, comm.shard_rows(ctx, jnp.arange(16, dtype=jnp.float32))))
          == 120.0)
    # inter-group reshard edge: a group collective accepts blocks committed
    # to the OTHER group (device_put sub-mesh -> sub-mesh)
    check("intergroup_reshard_collective",
          float(comm.allreduce(g1, x0)) == 28.0)
    # nonblocking handles are group-portable and await out of ORDER: world
    # and both halves in flight together, drained newest-first
    h_w = comm.iallreduce(
        ctx, comm.shard_rows(ctx, jnp.arange(16, dtype=jnp.float32)))
    h_0 = comm.iallreduce(g0, x0)
    h_1 = comm.igather(g1, x1)
    check("out_of_order_group_awaits",
          np.array_equal(np.asarray(h_1.wait()),
                         np.arange(8, 16, dtype=np.float32))
          and float(h_0.wait()) == 28.0 and float(h_w.wait()) == 120.0)
    # nested split: a group is itself splittable
    n0, n1 = g0.split(2)
    check("nested_split", n0.executors == 2
          and float(comm.allreduce(n0, comm.shard_rows(n0, jnp.arange(4, dtype=jnp.float32)))) == 6.0)

    # ---- gang-scheduled concurrent jobs on disjoint groups -----------------
    from repro.core.job import IJob as _IJob

    wg = IWorker(w.cluster, "python")
    gg0, gg1 = wg.groups(2)
    vals_g = rng.integers(0, 10_000, 1024).astype(np.int32)
    jobA = _IJob("gangA", group=gg0)
    jobB = _IJob("gangB", group=gg1)
    fA = wg.parallelize(vals_g).sort().collect_async(job=jobA)
    kvg = wg.parallelize(vals_g).map(lambda x: {"key": x % 11, "value": jnp.int32(1)})
    fB = kvg.reduce_by_key(lambda a, b: a + b, 0).collect_async(job=jobB)
    check("gang_sort_on_group",
          [int(x) for x in fA.result(120)] == sorted(int(v) for v in vals_g))
    counts_g = {int(np.asarray(r["key"])): int(np.asarray(r["value"]))
                for r in fB.result(120)}
    exp_g = {}
    for v in vals_g:
        exp_g[int(v) % 11] = exp_g.get(int(v) % 11, 0) + 1
    check("gang_rbk_on_group", counts_g == exp_g)
    check("gang_jobs_tagged",
          jobA.stats()["groups"] == ["data[0:4]"]
          and jobB.stats()["groups"] == ["data[4:8]"])
    check("gang_group_reshards", wg.shuffle_stats()["group_reshards"] >= 2)
    check("gang_tasks_counted",
          jobA.scheduler.stats["gang_tasks"] >= 2)
    # a driver-thread use_group binding rides along into the submission
    with wg.use_group(gg0):
        fbind = wg.parallelize(vals_g).sort().collect_async()
    check("driver_binding_propagates",
          fbind.task.group is gg0
          and [int(x) for x in fbind.result(120)] == sorted(int(v) for v in vals_g))
    # native app on a subset of executors (paper Fig. 9): the bound context
    # inside the app IS the group communicator
    from repro.core.native import ignis_export

    wsg = IWorker(w.cluster, "spmd")
    h0, _h1 = wsg.groups(2)

    @ignis_export("mesh_probe")
    def mesh_probe(ctx_, data=None, valid=None):
        assert ctx_.executors == 4, ctx_.executors
        return data, valid

    probe = wsg.call("mesh_probe", wsg.parallelize(np.arange(32, dtype=np.int32)))
    got_probe = probe.collect_async(group=h0).result(120)
    check("native_on_subset", [int(x) for x in got_probe] == list(range(32)))

    # ---- native HPC apps at p=8 --------------------------------------------
    from repro.apps.stencil import cg_native, laplacian_matvec_ref

    b = np.random.default_rng(1).normal(size=256).astype(np.float32)
    xs = cg_native(ctx.mesh, ctx.axis, jnp.asarray(b), 400)
    res = float(jnp.abs(laplacian_matvec_ref(xs) - jnp.asarray(b)).max())
    check("cg_8way", res < 5e-2)

    # ---- job scheduler: hybrid native+dataflow job at p=8 ------------------
    from repro.core.job import IJob
    from repro.core.native import ignis_export
    from repro.apps.stencil import stencil_native

    ws8 = IWorker(w.cluster, "spmd")
    grid = np.random.default_rng(2).normal(size=(16, 8)).astype(np.float32)
    job = IJob("hybrid8")
    st_f = ws8.call(
        "stencil_app", ws8.parallelize(grid), iters=4
    ).collect_async(job=job)
    kv8 = w.parallelize(vals).map(lambda x: {"key": x % 7, "value": jnp.int32(1)})
    cnt_f = kv8.reduce_by_key(lambda a, b: a + b, 0).collect_async(job=job)
    got_st = np.stack([np.asarray(r) for r in st_f.result(120)])
    native8 = np.asarray(
        stencil_native(ws8.context.mesh, ws8.context.axis, jnp.asarray(grid), 4)
    )
    check("job_native_stage_p8", np.allclose(got_st, native8, atol=1e-6))
    counts8 = {int(np.asarray(r["key"])): int(np.asarray(r["value"]))
               for r in cnt_f.result(120)}
    exp8 = {}
    for v in vals:
        exp8[int(v) % 7] = exp8.get(int(v) % 7, 0) + 1
    check("job_hybrid_dataflow_p8", counts8 == exp8)
    st_job = job.stats()
    check("job_one_dag_p8",
          st_job["native"] >= 1 and st_job["actions"] == 2
          and st_job["failed"] == 0 and len(st_job["workers"]) == 2)

    # call_partitions at p=8: partition-preserving native + kill_block repair
    @ignis_export("scale8")
    def scale8(ctx, data=None, valid=None):
        return data * jnp.int32(int(ctx.var("k", 2))), valid

    dfp = w.parallelize(np.arange(64, dtype=np.int32), blocks=4)
    sc = w.call_partitions("scale8", dfp, k=3).persist()
    got_sc = sorted(int(x) for x in sc.collect())
    check("call_partitions_p8", got_sc == [x * 3 for x in range(64)])
    check("call_partitions_blocks_p8", len(sc.node.result) == 4)
    from repro.core.dag import DagEngine
    DagEngine.kill_block(sc.node, 1)
    check("call_partitions_repair_p8",
          sorted(int(x) for x in sc.collect()) == got_sc)

    # early-exit take at p=8: one block materialised out of four
    it0 = w.engine.stats["iter_block_computes"]
    tk = w.parallelize(np.arange(64, dtype=np.int32), blocks=4).map(
        lambda x: x + 1).take(3)
    check("take_early_exit_p8",
          [int(x) for x in tk] == [1, 2, 3]
          and w.engine.stats["iter_block_computes"] - it0 == 1)

    # ---- pipeline parallelism (4 stages × 8 microbatches) -------------------
    pmesh = make_pp_mesh(4, 1)
    S, M, mb, d = 4, 8, 2, 16
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (S, d, d)) * 0.3
    xm = jax.random.normal(key, (M, mb, d))

    def stage_fn(wmat, x):
        return jnp.tanh(x @ wmat)

    with compat.set_mesh(pmesh):
        got_pp = pipeline_apply(ws, xm, stage_fn, pmesh)
    ref_pp = reference_apply(ws, xm, stage_fn)
    check("pipeline_1f1b", bool(jnp.allclose(got_pp, ref_pp, atol=1e-5)))

    # ---- elastic: save at dp=8, restore at dp=4 ----------------------------
    import tempfile

    from repro.checkpoint import save
    from repro.configs import get_config
    from repro.distributed.elastic import restore_elastic
    from repro.models import build_model

    cfg = get_config("olmo-1b").reduced()
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(1))
    with tempfile.TemporaryDirectory() as td:
        mesh8 = make_local_mesh(8, 1)
        p8 = jax.device_put(params)  # pretend it lived on dp=8
        save(td, 1, {"params": p8})
        mesh4 = make_local_mesh(4, 2)
        out = restore_elastic(td, 1, cfg, mesh4, {"params": params})
        same = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out["params"]))
        )
        check("elastic_reshard_8to4x2", same)

    # ---- shard_map expert-parallel MoE == GSPMD reference ------------------
    from jax.sharding import NamedSharding, PartitionSpec as P2

    from repro.configs import get_config
    from repro.models.moe import make_moe_params, moe_ffn_bsd
    from repro.models.moe_ep import ep_applicable, moe_ffn_bsd_ep

    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced().with_overrides(
        num_experts=8, experts_per_token=2, d_model=32, d_ff=64, moe_ep=True,
        capacity_factor=8.0,  # no drops → exact parity
    )
    mesh2 = make_local_mesh(8, 1)
    pmoe = make_moe_params(jax.random.PRNGKey(3), cfg, jnp.float32)
    xin = jax.random.normal(jax.random.PRNGKey(4), (16, 4, 32))
    with compat.set_mesh(mesh2):
        xs2 = jax.device_put(xin, NamedSharding(mesh2, P2("data")))
        ps2 = jax.device_put(pmoe, NamedSharding(mesh2, P2()))

        def fmoe(x, p):
            assert ep_applicable(cfg)
            return moe_ffn_bsd_ep(x, p, cfg)

        y_ep, _aux = jax.jit(fmoe)(xs2, ps2)
    y_ref, _ = moe_ffn_bsd(xin, pmoe, cfg)
    check("moe_ep_parity", float(jnp.abs(y_ep - y_ref).max()) < 1e-4)

    # ---- kernel tier at p=8 (docs/kernels.md): interpret vs off must be
    # bit-identical with identical retry trajectories, with the kernels
    # actually engaged on the exchange paths (segment_reduce post on
    # reduceByKey, bucket_route on partitionBy/join)
    res8, ctr8 = {}, {}
    for mode in ("interpret", "off"):
        wk = IWorker(ICluster(IProperties({
            "ignis.executor.instances": "8", "ignis.kernels": mode})),
            "python")
        kvk = wk.parallelize(vals).map(
            lambda x: {"key": x % 13, "value": jnp.int32(1)})
        rbk = sorted((int(np.asarray(r["key"])), int(np.asarray(r["value"])))
                     for r in kvk.reduce_by_key(lambda a, b: a + b, 0).collect())
        pbk = sorted(int(np.asarray(r["value"]))
                     for r in wk.parallelize(vals[:512]).map(
                         lambda x: {"key": x % 5, "value": x})
                     .partition_by().collect())
        lk = wk.parallelize(np.arange(64, dtype=np.int32)).map(
            lambda x: {"key": x % 8, "value": x})
        rk = wk.parallelize(np.arange(32, dtype=np.int32)).map(
            lambda x: {"key": x % 8, "value": x * 2})
        jk = sorted((int(np.asarray(x["key"])), int(np.asarray(x["value"][0])),
                     int(np.asarray(x["value"][1])))
                    for x in lk.join(rk).collect())
        res8[mode] = (rbk, pbk, jk)
        sk = wk.shuffle_stats()
        ctr8[mode] = (sk["overflow_retries"], sk["fanout_retries"])
        if mode == "interpret":
            check("p8_kernel_hits", sk["kernel_hits"] >= 3)
        else:
            check("p8_kernel_off_no_hits", sk["kernel_hits"] == 0)
    check("p8_kernel_on_off_equal", res8["interpret"] == res8["off"])
    check("p8_kernel_retry_counters_equal", ctr8["interpret"] == ctr8["off"])

    # ---- streaming multi-tenant front end on gang groups + serve front
    # door in ONE job DAG (docs/streaming.md): 4 tenant pumps on groups(4)
    # run concurrently with continuous-batching decode ticks, all through
    # the shared JobScheduler — the paper's hybrid pattern at serving time
    import threading

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving.engine import ServeEngine
    from repro.streaming import (
        ServeFrontDoor, StreamContext, TenantFrontEnd, TenantRequestSource)

    ws = IWorker(ICluster(IProperties({
        "ignis.executor.instances": "8",
        "ignis.stream.batch.rows": "16"})), "python")
    fe = TenantFrontEnd(ws, n_groups=4)
    for i in range(4):
        fe.admit(f"t{i}", TenantRequestSource(i, seed=21, limit=160),
                 init_state=np.zeros((2,), np.int64))

    scfg = get_config("ignis-tiny")
    bundle = build_model(scfg)
    sparams = bundle.init(jax.random.PRNGKey(0))
    eng = ServeEngine(bundle, sparams, slots=2, cache_len=64)
    fd = ServeFrontDoor(eng, ws, group=fe.groups[0], job=fe.job,
                        telemetry=fe.telemetry)
    rng_s = np.random.default_rng(7)
    prompts = [rng_s.integers(0, scfg.vocab_size, 5, dtype=np.int32)
               for _ in range(6)]
    tix = [fd.submit(p, max_new_tokens=4, tenant="serve") for p in prompts]

    serve_n = {}
    th = threading.Thread(
        target=lambda: serve_n.update(n=len(fd.run_until_drained())),
        daemon=True)
    th.start()
    res_s = fe.run()
    th.join(300)
    check("p8_stream_serve_overlap_drained",
          not th.is_alive() and serve_n.get("n") == 6)

    ok_iso = True
    for i in range(4):
        solo = StreamContext(
            ws, TenantRequestSource(i, seed=21, limit=160),
            tenant=f"solo{i}", init_state=np.zeros((2,), np.int64)).run()
        ok_iso = ok_iso and bool((res_s[f"t{i}"] == solo).all())
    check("p8_stream_tenants_match_solo_oracles", ok_iso)

    # decode output is unchanged by the multi-tenant load: every ticket
    # matches the single-request greedy reference
    def greedy_ref(prompt, n_new):
        toks = jnp.asarray(prompt, jnp.int32)[None]
        logits, cache = bundle.prefill(sparams, tokens=toks,
                                       cache_len=len(prompt) + n_new + 1)
        out = [int(jnp.argmax(logits[0]))]
        for _ in range(n_new - 1):
            t = jnp.asarray([[out[-1]]], jnp.int32)
            logits, cache = bundle.decode_step(sparams, cache, t)
            out.append(int(jnp.argmax(logits[0])))
        return out

    check("p8_serve_greedy_parity_under_load",
          all(t.result(10.0).tokens == greedy_ref(p, 4)
              for t, p in zip(tix, prompts)))

    # one DAG: tick tasks and all 40 micro-batches are gang-pinned job
    # tasks; the shared telemetry splits per tenant
    js = fe.job.stats()
    check("p8_stream_serve_one_dag",
          js["serve"] >= 1 and js["gang"] == js["tasks"]
          and len(js["groups"]) == 4)
    check("p8_stream_telemetry_per_tenant",
          js["stream"]["tenants"]["serve"]["completed"] == 6
          and js["stream"]["completed"] == 46
          and js["stream"]["inflight"] == 0)

    # ---- elastic mesh: grow/shrink under cached partitions ----------------
    # compact cross-check of the dedicated tier (tests/_elastic_main.py,
    # DESIGN.md §14): a cached frame survives shrink(2)+grow(2) bit-identically
    # with zero lineage recomputes — resharding is pure data movement
    we = IWorker(ICluster(IProperties({"ignis.executor.instances": "8"})),
                 "python")
    dfe = we.parallelize(np.arange(4096, dtype=np.int32)).map(
        lambda x: x * 3 + 1).persist()
    oracle_e = [int(x) for x in dfe.collect()]
    we.shrink(2)
    mid = [int(x) for x in dfe.collect()]
    we.grow(2)
    es = we.metrics("elastic")
    check("p8_elastic_resize_bit_identical",
          mid == oracle_e and [int(x) for x in dfe.collect()] == oracle_e)
    check("p8_elastic_zero_recomputes",
          es["reshard_recomputes"] == 0 and es["reshard_moves"] > 0
          and es["grows"] == 1 and es["shrinks"] == 1
          and es["world_size"] == 8 and dfe.node.compute_count == 1)

    print("ALL_DISTRIBUTED_OK")


if __name__ == "__main__":
    main()
