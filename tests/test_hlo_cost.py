"""The HLO cost parser: trip-count multiplication, dot flops, collectives."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_cost


def test_scan_trip_count_multiplied():
    def body(x, _):
        return x @ x, None

    def f(x):
        return jax.lax.scan(body, x, None, length=10)[0]

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    r = hlo_cost.analyze(c.as_text())
    expect = 10 * 2 * 128**3
    assert 0.9 * expect < r["flops_per_device"] < 1.3 * expect
    assert r["unknown_trip_loops"] == 0


def test_dot_flops_exact():
    f = jax.jit(lambda a, b: a @ b)
    c = f.lower(jax.ShapeDtypeStruct((64, 32), jnp.float32),
                jax.ShapeDtypeStruct((32, 16), jnp.float32)).compile()
    r = hlo_cost.analyze(c.as_text())
    assert abs(r["flops_per_device"] - 2 * 64 * 32 * 16) < 2 * 64 * 16  # ±eltwise


def test_shape_parsing():
    assert hlo_cost.shape_bytes("f32[16,4]{1,0}") == 256
    assert hlo_cost.shape_bytes("(bf16[8], s32[2])") == 24
    assert hlo_cost.shape_elems("pred[3,3]") == 9


def test_dus_counted_in_place():
    def f(x, u):
        return jax.lax.dynamic_update_slice(x, u, (0, 0))

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((1024, 1024), jnp.float32),
                         jax.ShapeDtypeStruct((4, 4), jnp.float32)).compile()
    r = hlo_cost.analyze(c.as_text())
    # the dus itself counts as slice traffic; XLA inserts ONE defensive copy
    # of the unaliased input (read+write = 2 buffers). Naive operand+result
    # counting of the dus node alone would give ≥ 2 more buffers on top.
    buf = 1024 * 1024 * 4
    assert r["hbm_bytes_per_device"] < 2.2 * buf
