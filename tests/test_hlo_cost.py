"""The HLO cost parser: trip-count multiplication, dot flops, collectives."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_cost


def test_scan_trip_count_multiplied():
    def body(x, _):
        return x @ x, None

    def f(x):
        return jax.lax.scan(body, x, None, length=10)[0]

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    r = hlo_cost.analyze(c.as_text())
    expect = 10 * 2 * 128**3
    assert 0.9 * expect < r["flops_per_device"] < 1.3 * expect
    assert r["unknown_trip_loops"] == 0


def test_dot_flops_exact():
    f = jax.jit(lambda a, b: a @ b)
    c = f.lower(jax.ShapeDtypeStruct((64, 32), jnp.float32),
                jax.ShapeDtypeStruct((32, 16), jnp.float32)).compile()
    r = hlo_cost.analyze(c.as_text())
    assert abs(r["flops_per_device"] - 2 * 64 * 32 * 16) < 2 * 64 * 16  # ±eltwise


def test_shape_parsing():
    assert hlo_cost.shape_bytes("f32[16,4]{1,0}") == 256
    assert hlo_cost.shape_bytes("(bf16[8], s32[2])") == 24
    assert hlo_cost.shape_elems("pred[3,3]") == 9


def test_dus_counted_in_place():
    def f(x, u):
        return jax.lax.dynamic_update_slice(x, u, (0, 0))

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((1024, 1024), jnp.float32),
                         jax.ShapeDtypeStruct((4, 4), jnp.float32)).compile()
    r = hlo_cost.analyze(c.as_text())
    # the dus itself counts as slice traffic; XLA inserts ONE defensive copy
    # of the unaliased input (read+write = 2 buffers). Naive operand+result
    # counting of the dus node alone would give ≥ 2 more buffers on top.
    buf = 1024 * 1024 * 4
    assert r["hbm_bytes_per_device"] < 2.2 * buf


# ---------------------------------------------------------------------------
# cost-model integration (PR 9): the seed parser priced against jaxpr costs
# of known stages — the calibration cross-check docs/profiling.md describes
# ---------------------------------------------------------------------------

from repro.profile.cost import CostEstimate, CostModel, DeviceParams


def _hlo(f, *avals):
    return jax.jit(f).lower(*avals).compile().as_text()


def test_jaxpr_and_hlo_price_agree_on_dot():
    """Static jaxpr pricing and compiled-HLO pricing must agree on the
    dominant term of a matmul — the model the planner consults before
    execution and the parser's post-lowering truth cross-check."""
    m = CostModel()
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    f = lambda x, y: jnp.tanh(x @ y) + 1.0
    est_j = m.price_fn(f, a, b)
    est_h = m.price_hlo(_hlo(f, a, b))
    dot = 2 * 128 * 256 * 64
    assert 0.95 * dot < est_j.flops < 1.05 * dot
    assert 0.95 * dot < est_h.flops < 1.05 * dot
    assert abs(est_j.flops - est_h.flops) < 0.05 * dot


def test_narrow_chain_pricing_scales_with_blocks():
    m = CostModel()
    aval = jax.ShapeDtypeStruct((1024,), jnp.float32)
    chain = lambda x: (x * 2 + 1) * (x - 3)
    one = m.price_jaxpr(jax.make_jaxpr(chain)(aval), nblocks=1)
    four = m.price_jaxpr(jax.make_jaxpr(chain)(aval), nblocks=4)
    assert four.flops == 4 * one.flops
    assert four.hbm_bytes == 4 * one.hbm_bytes
    assert four.dispatches == 4 * one.dispatches
    # 4 arithmetic eqns (mul, add, sub, mul) on 1024 elems
    assert one.flops == 4 * 1024


def test_move_ops_price_bytes_not_flops():
    """Dtype-rot regression: a bf16 add lowers as convert→add→convert; the
    converts move bytes but must not bill flops (they used to)."""
    x = jax.ShapeDtypeStruct((32, 32), jnp.bfloat16)
    r = hlo_cost.analyze(_hlo(lambda v: v + v, x))
    assert r["flops_per_device"] == 32 * 32
    assert r["hbm_bytes_per_device"] >= 2 * 32 * 32 * 2  # in+out at 2B/elem


def test_fp8_and_subbyte_dtypes_price():
    assert hlo_cost.shape_bytes("f8e4m3[64]") == 64
    assert hlo_cost.shape_bytes("f8e5m2fnuz[64]") == 64
    assert hlo_cost.shape_bytes("u2[8]") == 8  # ceiling at byte granularity


def test_predict_seconds_monotone_in_work():
    m = CostModel(DeviceParams())
    small = CostEstimate(flops=1e6, hbm_bytes=1e5, dispatches=1)
    big = CostEstimate(flops=1e9, hbm_bytes=1e8, dispatches=1)
    assert m.predict_s(big) > m.predict_s(small) > 0


def test_fit_rescales_toward_observed():
    m = CostModel()
    est = CostEstimate(flops=1e9)
    before = m.predict_s(est)
    m.fit([(before, 2 * before), (before, 2 * before), (before, 2 * before)])
    assert abs(m.predict_s(est) - 2 * before) / (2 * before) < 1e-6


def test_wide_stage_collective_priced():
    """An 8-way psum prices wire bytes through the parser — the collective
    half of stage pricing (DESIGN.md §13)."""
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    if len(jax.devices()) < 2:
        import pytest

        pytest.skip("needs >1 device")
    n = len(jax.devices())
    mesh = Mesh(np.array(jax.devices()), ("data",))
    g = shard_map(lambda x: jax.lax.psum(x * 2.0, "data"),
                  mesh=mesh, in_specs=P("data"), out_specs=P())
    txt = jax.jit(g).lower(jnp.ones((n, 16), jnp.float32)).compile().as_text()
    r = hlo_cost.analyze(txt)
    assert r["comm_bytes_total_per_device"] > 0
    assert r["wire_bytes_per_device"] > 0
