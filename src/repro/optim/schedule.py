"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, peak_lr, warmup_steps, total_steps, floor=0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = peak_lr * step / max(warmup_steps, 1)
    frac = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup_steps, warm, cos)
