"""AdamW in pure JAX (no optax). Moments are stored in a configurable dtype
(fp32 default; bf16 for the 398B config to fit HBM) and updated in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_opt_state(params, moment_dtype=jnp.float32):
    zeros = lambda p: jnp.zeros(p.shape, dtype=moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    grads, opt_state, params, lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1
):
    """Returns (new_params, new_opt_state). lr may be a scalar or traced."""
    step = opt_state["step"] + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / c1
        vhat = vf / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (
            (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
            mf.astype(m.dtype),
            vf.astype(v.dtype),
        )

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, {"m": new_m, "v": new_v, "step": step}
