"""Kernel tier: capability checks, mode resolution, autotune memo
(docs/kernels.md, DESIGN.md §11).

The shuffle engine's wide stages (core/shuffle.py) each have a Pallas
kernel implementation (segment_reduce / ssd_scan's prefix pass /
moe_route's bucket router) and a plain-JAX oracle that is always
available. This module decides, per wide node, which one runs:

* **Mode** (``ignis.kernels``): ``auto`` uses compiled Pallas where the
  backend supports it and the plain-JAX fallback everywhere else (an
  interpreted kernel is strictly slower than the jnp oracle, so auto
  never interprets); ``on`` forces the kernel (compiled where available,
  ``interpret=True`` otherwise); ``interpret`` forces interpret mode
  (the CI conformance path); ``off`` forces the fallback.
* **Capability probe**: a tiny invocation per (kernel, interpret,
  backend), cached; any failure degrades that kernel to the fallback
  instead of erroring. The ``kernel.capability`` fault site fires on
  every selection so chaos tests can force mid-job degradation.
* **Autotune memo**: best block size per (kernel, aval, op) key, found
  by a timed sweep over ``ignis.kernels.blocks`` candidates. The memo
  is an LRU with single-builder discipline (per-key in-flight Event,
  same pattern as comm.py's collective plan cache): concurrent misses
  on one key cost exactly one sweep. Tuned blocks feed the wide-plan
  cache key, so a repeat lineage pays zero re-tunes and zero
  recompiles.

Selection results and tune counts surface as ``kernel_hits`` /
``kernel_fallbacks`` / ``autotune_runs`` / ``autotune_evictions`` in
``worker.shuffle_stats()`` and ``df.explain()``.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import Counters

#: dtypes the kernel tier computes natively (bool rides as i32)
SUPPORTED_DTYPES = ("float32", "int32")


def compiled_backend() -> bool:
    """True where pl.pallas_call lowers to a real Mosaic kernel."""
    return jax.default_backend() == "tpu"


@dataclass(frozen=True)
class Selection:
    """A resolved kernel choice: which kernel, interpreted or compiled."""

    kernel: str
    interpret: bool

    def describe(self) -> str:
        return f"{self.kernel}[{'interpret' if self.interpret else 'compiled'}]"


# ---------------------------------------------------------------------------
# capability probes: one tiny invocation per kernel
# ---------------------------------------------------------------------------


def _probe_segment_reduce(interpret: bool):
    from repro.kernels.segment_reduce.segment_reduce import segment_reduce_fwd

    v = jnp.zeros((8, 1), jnp.float32)
    hb = jnp.ones((8,), bool)
    jax.block_until_ready(
        segment_reduce_fwd(v, hb, op="sum", block=8, interpret=interpret))


def _probe_prefix_scan(interpret: bool):
    from repro.kernels.ssd_scan.prefix import prefix_scan_fwd

    x = jnp.zeros((8,), jnp.int32)
    jax.block_until_ready(prefix_scan_fwd(x, op="min", block=8, interpret=interpret))


def _probe_bucket_route(interpret: bool):
    from repro.kernels.moe_route.route import bucket_route_fwd

    d = jnp.zeros((8,), jnp.int32)
    jax.block_until_ready(
        bucket_route_fwd(d, p=2, capacity=4, block=8, interpret=interpret))


_PROBES: dict = {
    "segment_reduce": _probe_segment_reduce,
    "prefix_scan": _probe_prefix_scan,
    "bucket_route": _probe_bucket_route,
}


# ---------------------------------------------------------------------------
# builtin-op recognition: which reduce fns the kernel tier can take over
# ---------------------------------------------------------------------------

_PRIM_OPS = {"add": "sum", "max": "max", "min": "min"}


def builtin_reduce_op(fn, identity, value) -> Optional[str]:
    """Recognize a reduceByKey fn as a builtin sum/max/min the segment
    kernel implements, or None (→ jnp-oracle fallback).

    Eligibility (anything else falls back, never errors): the value is a
    single array leaf of a supported dtype with ndim ≤ 2, the identity is
    a single scalar leaf, and ``fn`` traces to exactly one add/max/min
    primitive applied to its two arguments with no dtype change. A
    recognized fn is numerically the same primitive the kernel applies,
    which is what makes the kernel path bit-identical for exact ops
    (docs/kernels.md).
    """
    leaves = jax.tree_util.tree_leaves(value)
    ileaves = jax.tree_util.tree_leaves(identity)
    if len(leaves) != 1 or len(ileaves) != 1 or np.ndim(ileaves[0]) != 0:
        return None
    leaf = leaves[0]
    dtype = getattr(leaf, "dtype", None)
    if dtype is None or str(dtype) not in SUPPORTED_DTYPES or leaf.ndim > 2:
        return None
    try:
        jaxpr = jax.make_jaxpr(fn)(jnp.zeros((), dtype), jnp.zeros((), dtype))
    except Exception:
        return None
    eqns = jaxpr.jaxpr.eqns
    if len(eqns) != 1:
        return None
    eqn = eqns[0]
    op = _PRIM_OPS.get(eqn.primitive.name)
    if op is None or len(eqn.invars) != 2:
        return None
    # both operands must be the fn's own arguments (rejects a+const, a+a)
    if {id(v) for v in eqn.invars} != {id(v) for v in jaxpr.jaxpr.invars}:
        return None
    out = jaxpr.jaxpr.outvars
    if len(out) != 1 or out[0].aval.dtype != dtype or out[0].aval.shape != ():
        return None
    return op


class KernelRegistry:
    """Per-worker kernel capability + autotune state (one per
    ShuffleManager; thread-safe — gang tasks share it)."""

    MODES = ("auto", "on", "off", "interpret")

    def __init__(self, mode: str = "auto", blocks="128,256,512",
                 tune_cache_size: int = 512):
        mode = str(mode).strip().lower()
        if mode not in self.MODES:
            raise ValueError(f"ignis.kernels={mode!r}: expected one of {self.MODES}")
        self.mode = mode
        if isinstance(blocks, str):
            blocks = [int(b) for b in blocks.replace(",", " ").split()]
        self.blocks = tuple(int(b) for b in blocks) or (256,)
        self.tune_cache_size = int(tune_cache_size)
        self._lock = threading.Lock()
        self._probe_cache: dict = {}
        self._tunes: "OrderedDict[tuple, int]" = OrderedDict()
        self._tuning: dict = {}  # key → Event while a sweep is in flight
        self.stats = Counters("kernels", {
            "kernel_hits": 0,        # wide nodes that ran kernel-backed
            "kernel_fallbacks": 0,   # kernel-eligible nodes on the jnp oracle
            "autotune_runs": 0,      # block-size sweeps performed
            "autotune_evictions": 0,
        })

    def _bump(self, key: str, n: int = 1):
        with self._lock:
            self.stats[key] += n

    # ------------------------------------------------------------------
    # selection
    # ------------------------------------------------------------------
    def _probe(self, kernel: str, interpret: bool) -> bool:
        key = (kernel, interpret, jax.default_backend())
        with self._lock:
            if key in self._probe_cache:
                return self._probe_cache[key]
        try:
            _PROBES[kernel](interpret)
            ok = True
        except Exception:
            ok = False
        with self._lock:
            self._probe_cache[key] = ok
        return ok

    def select(self, kernel: str) -> Optional[Selection]:
        """Resolve one kernel-eligible wide node. None → plain-JAX
        fallback (always available, bit-identical for exact ops).

        A ``kernel.capability`` fault or a failed probe degrades to the
        fallback rather than erroring — capability loss mid-job must not
        kill the job (unlike ``kernel.stage``, which is a task fault the
        scheduler retries via lineage).
        """
        # deferred import: repro.core.shuffle_plan imports this module at
        # class-definition time, so a module-level core import would cycle
        from repro.core import faults

        if self.mode == "off":
            return self._fallback()
        try:
            faults.check("kernel.capability", kernel=kernel)
        except faults.FaultInjected:
            return self._fallback()
        if self.mode == "auto":
            if not compiled_backend():
                # interpreted Pallas is strictly slower than the jnp
                # oracle — auto never interprets (docs/kernels.md)
                return self._fallback()
            interpret = False
        elif self.mode == "interpret":
            interpret = True
        else:  # "on": compiled where the backend supports it
            interpret = not compiled_backend()
        if not self._probe(kernel, interpret):
            return self._fallback()
        self._bump("kernel_hits")
        return Selection(kernel, interpret)

    def _fallback(self) -> None:
        self._bump("kernel_fallbacks")
        return None

    def demote(self):
        """Re-book the last counted hit as a fallback — a post-selection
        step (e.g. the autotune sweep) failed and the caller degraded to
        the plain-JAX path after all."""
        with self._lock:
            self.stats["kernel_hits"] -= 1
            self.stats["kernel_fallbacks"] += 1

    # ------------------------------------------------------------------
    # autotune memo (single-builder, LRU — comm.py plan-cache discipline)
    # ------------------------------------------------------------------
    def tune(self, key: tuple, candidates, timer: Callable[[int], float]) -> int:
        """Best block size for ``key``; memoised. ``timer(block)`` returns
        seconds for one representative invocation at that block size.
        Concurrent misses on one key cost exactly one sweep; a failed
        sweep unparks the waiters (one of them re-tunes)."""
        while True:
            with self._lock:
                b = self._tunes.get(key)
                if b is not None:
                    self._tunes.move_to_end(key)
                    return b
                building = self._tuning.get(key)
                if building is None:
                    building = self._tuning[key] = threading.Event()
                    break
            building.wait()
        try:
            cands = sorted({int(c) for c in candidates})
            if not cands:
                raise ValueError("autotune: empty candidate set")
            best, best_t = cands[0], float("inf")
            if len(cands) > 1:  # a single candidate needs no timing
                for c in cands:
                    t = timer(c)
                    if t < best_t:
                        best, best_t = c, t
            with self._lock:
                self.stats["autotune_runs"] += 1
                self._tunes[key] = best
                while len(self._tunes) > self.tune_cache_size:
                    self._tunes.popitem(last=False)
                    self.stats["autotune_evictions"] += 1
            return best
        finally:
            with self._lock:
                self._tuning.pop(key, None)
            building.set()

    def describe(self) -> str:
        s = self.stats
        return (f"mode={self.mode} hits={s['kernel_hits']} "
                f"fallbacks={s['kernel_fallbacks']} "
                f"autotune_runs={s['autotune_runs']} "
                f"autotune_evictions={s['autotune_evictions']} "
                f"tuned_keys={len(self._tunes)}")
