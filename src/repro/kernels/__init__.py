"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel package: <name>.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd public wrapper, CPU auto-interpret), ref.py (pure-jnp oracle).

  flash_attention — blockwise fused attention (causal/window/softcap/GQA);
                    kills the O(S²) HBM scores traffic the §Roofline table
                    shows dominating the jnp baseline
  ssd_scan        — Mamba-2 SSD chunk scan (intra-chunk attention-like +
                    carried inter-chunk state); also hosts prefix_scan, the
                    same carry pattern backing the shuffle prefix pass
  segment_reduce  — sorted segmented reduction (reduceByKey/groupBy hot path
                    of the dataflow layer — the paper's TeraSort/K-Means side)
                    + segment_totals, the shuffle-stage ABI entry
  moe_route       — fused softmax + top-k + capacity positions for MoE
                    dispatch (phi3.5 / mixtral / jamba) + bucket_route, the
                    same ordinal technique routing shuffle exchanges

registry.py is the capability/selection/autotune layer the shuffle engine
(core/shuffle_plan.py) consults per wide node — docs/kernels.md.
"""
