from repro.kernels.segment_reduce.ops import segment_reduce, segment_totals  # noqa: F401
from repro.kernels.segment_reduce.ref import segment_reduce_ref  # noqa: F401
