"""Public segment_reduce wrapper: masking, padding, CPU auto-interpret."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.segment_reduce.ref import OPS, heads_of
from repro.kernels.segment_reduce.segment_reduce import segment_reduce_fwd


def _should_interpret():
    return jax.default_backend() != "tpu"


def segment_reduce(keys, valid, values, op: str = "sum", block: int = 256,
                   interpret=None):
    """Inclusive segmented scan over sorted-key runs.

    keys: (N,) sorted; valid: (N,); values: (N,) or (N, D).
    Returns (heads (N,), scanned (N, …) f32) — same contract as the ref.
    """
    interpret = _should_interpret() if interpret is None else interpret
    _, ident = OPS[op]
    squeeze = values.ndim == 1
    v = values[:, None] if squeeze else values
    heads = heads_of(keys, valid)
    hb = heads | ~valid
    v = jnp.where(valid[:, None], v.astype(jnp.float32), jnp.float32(ident))

    N = v.shape[0]
    pad = (-N) % block if N > block else 0
    if pad:
        v = jnp.concatenate([v, jnp.full((pad, v.shape[1]), ident, v.dtype)])
        hb = jnp.concatenate([hb, jnp.ones((pad,), bool)])
    out = segment_reduce_fwd(v, hb, op=op, block=block, interpret=interpret)[:N]
    return heads, (out[:, 0] if squeeze else out)
