"""Public segment_reduce wrappers: masking, padding, CPU auto-interpret.

``segment_reduce`` is the standalone inclusive-scan entry (kernel tests);
``segment_totals`` is the shuffle-stage ABI (docs/kernels.md): the drop-in
kernel implementation of core/shuffle.segmented_reduce, combining the
segment scan with the ssd-carry prefix pass for the last-row gather.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.segment_reduce.ref import heads_of
from repro.kernels.segment_reduce.segment_reduce import segment_reduce_fwd
from repro.kernels.ssd_scan.ops import prefix_scan
from repro.kernels.ssd_scan.prefix import op_identity


def _should_interpret():
    return jax.default_backend() != "tpu"


def _compute_dtype(dtype):
    """f32 for floats, i32 for ints/bool — the kernel's native dtypes."""
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.float32
    return jnp.int32


def _scan(keys, valid, values, op, mask_value, block, interpret):
    """Shared core: mask invalid rows to ``mask_value``, pad to a block
    multiple with the op identity, run the segmented-scan kernel.
    Returns (heads, scanned (N, D) in the compute dtype, squeeze)."""
    squeeze = values.ndim == 1
    v = values[:, None] if squeeze else values
    ct = _compute_dtype(v.dtype)
    heads = heads_of(keys, valid)
    hb = heads | ~valid
    v = jnp.where(valid[:, None], v.astype(ct), jnp.asarray(mask_value, ct))

    N = v.shape[0]
    ident = op_identity(op, ct)
    pad = (-N) % block if N > block else 0
    if pad:
        v = jnp.concatenate([v, jnp.full((pad, v.shape[1]), ident, v.dtype)])
        hb = jnp.concatenate([hb, jnp.ones((pad,), bool)])
    out = segment_reduce_fwd(v, hb, op=op, block=block, interpret=interpret)[:N]
    return heads, out, squeeze


def segment_reduce(keys, valid, values, op: str = "sum", block: int = 256,
                   interpret=None):
    """Inclusive segmented scan over sorted-key runs.

    keys: (N,) sorted; valid: (N,); values: (N,) or (N, D).
    Returns (heads (N,), scanned (N, …)) — same contract as the ref;
    float inputs compute in f32, integer/bool inputs exactly in i32.
    """
    interpret = _should_interpret() if interpret is None else interpret
    ct = _compute_dtype(values.dtype)
    heads, out, squeeze = _scan(keys, valid, values, op,
                                op_identity(op, ct), block, interpret)
    return heads, (out[:, 0] if squeeze else out)


def segment_totals(keys, valid, values, op: str, identity, block: int = 256,
                   interpret=None):
    """Shuffle-stage ABI: per-segment totals broadcast to every row.

    Drop-in for core/shuffle.segmented_reduce with a builtin fn: invalid
    rows are masked to the *user* identity (the oracle's contract — the
    identity never enters a combine, invalid rows are their own
    boundaries), the segment scan runs in the kernel, and the last-row
    gather uses the prefix kernel's reverse cummin. Bit-identical to the
    oracle for associative-exact data (integers; max/min on any dtype).

    Returns (heads (N,) bool, totals (N, …) in values.dtype).
    """
    interpret = _should_interpret() if interpret is None else interpret
    n = keys.shape[0]
    if n == 0:
        return jnp.zeros(0, bool), values
    heads, scanned, squeeze = _scan(keys, valid, values, op, identity,
                                    block, interpret)
    hb = heads | ~valid
    # last row of each segment = (next boundary) - 1, via the suffix-min
    # prefix pass (core/shuffle.segmented_reduce's exact formula)
    idx = jnp.arange(n)
    head_pos = jnp.where(hb, idx, n).astype(jnp.int32)
    suff_min = prefix_scan(head_pos, op="min", block=block,
                           interpret=interpret, reverse=True)
    nxt = jnp.concatenate([suff_min[1:], jnp.full((1,), n, jnp.int32)])
    last_pos = jnp.clip(jnp.where(nxt >= n, n - 1, nxt - 1), 0, n - 1)
    out = scanned[last_pos].astype(values.dtype)
    return heads, (out[:, 0] if squeeze else out)
