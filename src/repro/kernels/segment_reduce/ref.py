"""Oracle: inclusive segmented scan over sorted-key runs (sum/max/min).

Matches core/shuffle.segmented_reduce semantics: invalid rows are their own
identity segments; output[i] = running reduction of row i's segment up to i.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

OPS = {
    "sum": (jnp.add, 0.0),
    "max": (jnp.maximum, -jnp.inf),
    "min": (jnp.minimum, jnp.inf),
}


def heads_of(keys, valid):
    prev = jnp.concatenate([keys[:1], keys[:-1]])
    first = jnp.arange(keys.shape[0]) == 0
    pv = jnp.concatenate([valid[:1], valid[:-1]])
    return valid & (first | (keys != prev) | ~pv)


def segment_reduce_ref(keys, valid, values, op: str = "sum"):
    """keys: (N,) sorted; valid: (N,) bool; values: (N,) or (N, D).
    Returns (heads (N,), scanned (N, …)) — inclusive segmented scan."""
    fn, ident = OPS[op]
    heads = heads_of(keys, valid)
    hb = heads | ~valid
    v = jnp.where(valid.reshape((-1,) + (1,) * (values.ndim - 1)), values,
                  jnp.asarray(ident, values.dtype))

    def comb(a, b):
        va, ha = a
        vb, hb_ = b
        bc = hb_.reshape((-1,) + (1,) * (va.ndim - 1))
        return (jnp.where(bc, vb, fn(va, vb)), ha | hb_)

    scanned, _ = jax.lax.associative_scan(comb, (v, hb))
    return heads, scanned
