"""Sorted segmented reduction — Pallas TPU kernel.

Grid (n_blocks,) sequential over row tiles; scratch carries the running
segment value across tiles. In-tile segmented inclusive scan is a
Hillis–Steele log-depth sweep (static python loop of shifted selects —
VPU-friendly, no HBM intermediates). Backs reduceByKey/groupBy of the
dataflow layer (paper's TeraSort/K-Means path).

Compute dtype follows the input (f32 floats, i32 ints — the ops wrapper
normalizes): integer reductions are associative-exact, which is what lets
the shuffle engine's differential gate demand bit-identity with the jnp
oracle on the counting hot path (docs/kernels.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ssd_scan.prefix import op_identity

_FNS = {"sum": jnp.add, "max": jnp.maximum, "min": jnp.minimum}


def _kernel(v_ref, h_ref, o_ref, carry, *, bq, n_blocks, op, ident):
    fn = _FNS[op]
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry[...] = jnp.full_like(carry, ident)

    v = v_ref[...]  # (bq, D)
    hb = h_ref[...]  # (bq,) bool: segment boundary (head-or-invalid)

    # Hillis–Steele segmented inclusive scan
    f = hb
    off = 1
    while off < bq:
        v_sh = jnp.concatenate([jnp.full((off, v.shape[1]), ident, v.dtype), v[:-off]])
        f_sh = jnp.concatenate([jnp.ones((off,), bool), f[:-off]])
        v = jnp.where(f[:, None], v, fn(v, v_sh))
        f = f | f_sh
        off *= 2

    # inject carry into the prefix that continues the previous tile's segment
    seen = jnp.cumsum(hb.astype(jnp.int32)) > 0
    v = jnp.where(seen[:, None], v, fn(v, carry[...]))
    o_ref[...] = v
    carry[...] = v[-1:]


def segment_reduce_fwd(values, boundaries, op: str = "sum", block: int = 256,
                       interpret: bool = False):
    """values: (N, D) pre-masked on invalid rows; boundaries: (N,) bool =
    head-or-invalid flags. N % block == 0 (ops.py pads with the op
    identity). Returns the inclusive segmented scan (N, D), values.dtype."""
    N, D = values.shape
    bq = min(block, N)
    n_blocks = N // bq
    ident = op_identity(op, values.dtype)
    kern = functools.partial(_kernel, bq=bq, n_blocks=n_blocks, op=op, ident=ident)
    return pl.pallas_call(
        kern,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((bq, D), lambda i: (i, 0)),
            pl.BlockSpec((bq,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bq, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, D), values.dtype),
        scratch_shapes=[pltpu.VMEM((1, D), values.dtype)],
        interpret=interpret,
    )(values, boundaries)
