"""Unsegmented prefix scan (cumsum/cummin/cummax) — Pallas TPU kernel.

The SSD chunk scan's inter-chunk recurrence pattern applied to the shuffle
engine's prefix pass: grid (n_blocks,) sequential over row tiles, a VMEM
scalar scratch carries the running reduction across tiles (exactly how
ssd_scan.py carries its (P, N) state), and the in-tile inclusive scan is a
Hillis–Steele log-depth sweep. Backs ``segment_totals``' last-row gather
(core/shuffle.segmented_reduce's ``suff_min`` pass) — docs/kernels.md.

Integer min/max/sum are associative-exact, so any association order —
this kernel's, or lax.cummin's — produces bit-identical results; that is
the property the wide-stage differential tests pin.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_FNS = {"sum": jnp.add, "max": jnp.maximum, "min": jnp.minimum}


def op_identity(op: str, dtype):
    """True identity of ``op`` on ``dtype`` (python scalar, static)."""
    if op == "sum":
        return 0
    if jnp.issubdtype(jnp.dtype(dtype), jnp.floating):
        return float("-inf") if op == "max" else float("inf")
    info = jnp.iinfo(jnp.dtype(dtype))
    return info.min if op == "max" else info.max


def _kernel(x_ref, o_ref, carry, *, bq, op, ident):
    fn = _FNS[op]
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry[...] = jnp.full_like(carry, ident)

    v = x_ref[...]  # (bq,)
    off = 1
    while off < bq:  # Hillis–Steele inclusive scan, log-depth
        v = fn(v, jnp.concatenate([jnp.full((off,), ident, v.dtype), v[:-off]]))
        off *= 2
    v = fn(v, carry[0])  # fold in the reduction of all previous tiles
    o_ref[...] = v
    carry[...] = v[-1:]


def prefix_scan_fwd(x, op: str = "sum", block: int = 512, interpret: bool = False):
    """x: (N,), N % block == 0 (the ops wrapper pads with the op identity).
    Returns the inclusive scan (N,), same dtype."""
    (N,) = x.shape
    bq = min(block, N)
    n_blocks = N // bq
    ident = op_identity(op, x.dtype)
    kern = functools.partial(_kernel, bq=bq, op=op, ident=ident)
    return pl.pallas_call(
        kern,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((bq,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bq,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), x.dtype),
        scratch_shapes=[pltpu.VMEM((1,), x.dtype)],
        interpret=interpret,
    )(x)
