"""Oracle for the SSD scan kernel = the model-side chunked SSD."""
from __future__ import annotations

from repro.models.mamba2 import ssd_chunked


def ssd_ref(x, dt, A_log, Bm, Cm, chunk):
    """x: (b, s, h, p); dt: (b, s, h) (softplus applied); A_log: (h,);
    Bm/Cm: (b, s, g, n). Returns (y, final_state)."""
    return ssd_chunked(x, dt, A_log, Bm, Cm, chunk)
