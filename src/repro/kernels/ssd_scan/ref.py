"""Oracles for the SSD scan kernel (= the model-side chunked SSD) and
the prefix-scan kernel (= the lax cumulative primitives)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.mamba2 import ssd_chunked


def ssd_ref(x, dt, A_log, Bm, Cm, chunk):
    """x: (b, s, h, p); dt: (b, s, h) (softplus applied); A_log: (h,);
    Bm/Cm: (b, s, g, n). Returns (y, final_state)."""
    return ssd_chunked(x, dt, A_log, Bm, Cm, chunk)


_CUM = {"sum": jnp.cumsum, "max": jax.lax.cummax, "min": jax.lax.cummin}


def prefix_scan_ref(x, op: str = "sum", reverse: bool = False):
    """Inclusive scan via the lax cumulative primitives; ``reverse=True``
    scans from the tail (suffix scan)."""
    v = x.astype(jnp.int32) if x.dtype == jnp.bool_ else x
    if reverse:
        v = v[::-1]
    out = _CUM[op](v)
    if reverse:
        out = out[::-1]
    return out.astype(bool) if x.dtype == jnp.bool_ else out
