"""Mamba-2 SSD chunk scan — Pallas TPU kernel.

Grid (B, H, n_chunks), chunks innermost: the (P, N) inter-chunk state lives
in VMEM scratch across chunk steps (the recurrence is sequential anyway —
the kernel makes that explicit instead of leaving a lax.scan to materialise
(q, q, H) decay tensors in HBM). Intra-chunk work is two MXU matmuls
(C·Bᵀ ⊙ decay) @ xΔ — identical math to the jnp oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, y_ref, st_ref, state, *, q, n_chunks):
    c_id = pl.program_id(2)

    @pl.when(c_id == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    x = x_ref[0, :, 0].astype(jnp.float32)  # (q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (q,)
    A = -jnp.exp(alog_ref[0].astype(jnp.float32))  # scalar
    Bm = b_ref[0, :, 0].astype(jnp.float32)  # (q, N)
    Cm = c_ref[0, :, 0].astype(jnp.float32)  # (q, N)

    a = A * dt  # (q,) ≤ 0
    ca = jnp.cumsum(a)
    xdt = x * dt[:, None]

    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (q, q)
    seg = ca[:, None] - ca[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.where(jj <= ii, jnp.exp(seg), 0.0)
    y_intra = jax.lax.dot_general(cb * decay, xdt, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (q, P)

    h_prev = state[...]  # (P, N)
    y_inter = jax.lax.dot_general(Cm, h_prev, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (q, P)
    y_inter = y_inter * jnp.exp(ca)[:, None]

    w_last = jnp.exp(ca[-1] - ca)  # (q,)
    upd = jax.lax.dot_general(xdt, Bm * w_last[:, None], (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    state[...] = jnp.exp(ca[-1]) * h_prev + upd

    y_ref[0, :, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    @pl.when(c_id == n_chunks - 1)
    def _fin():
        st_ref[0, 0] = state[...].astype(st_ref.dtype)


def ssd_scan_fwd(x, dt, A_log, Bm, Cm, chunk, *, interpret=False):
    """x: (B, S, H, P); dt: (B, S, H); A_log: (H,); Bm/Cm: (B, S, G, N).
    S % chunk == 0. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    q = chunk
    n_chunks = S // q
    hpg = H // G
    grid = (B, H, n_chunks)

    kern = functools.partial(_kernel, q=q, n_chunks=n_chunks)
    y, st = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, q, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, q, 1, N), lambda b, h, c: (b, c, h // hpg, 0)),
            pl.BlockSpec((1, q, 1, N), lambda b, h, c: (b, c, h // hpg, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A_log, Bm, Cm)
    return y, st
