"""Public SSD scan wrapper: CPU auto-interpret + ref-vjp backward.

Also hosts ``prefix_scan`` — the SSD carry pattern applied to the shuffle
engine's prefix pass (prefix.py, docs/kernels.md)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.prefix import op_identity, prefix_scan_fwd
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan_fwd


def _should_interpret():
    return jax.default_backend() != "tpu"


def prefix_scan(x, op: str = "sum", block: int = 512, interpret=None,
                reverse: bool = False):
    """Inclusive prefix scan (sum/max/min) over a 1-D array.

    ``reverse=True`` scans from the tail (the suffix-min pass of
    core/shuffle.segmented_reduce). Bool rides as i32 and is cast back.
    Bit-identical to ``prefix_scan_ref`` for integer dtypes (associative-
    exact ops — any association order agrees)."""
    interpret = _should_interpret() if interpret is None else interpret
    (N,) = x.shape
    if N == 0:
        return x
    squeeze_bool = x.dtype == jnp.bool_
    v = x.astype(jnp.int32) if squeeze_bool else x
    if reverse:
        v = v[::-1]
    ident = op_identity(op, v.dtype)
    pad = (-N) % block if N > block else 0
    if pad:
        v = jnp.concatenate([v, jnp.full((pad,), ident, v.dtype)])
    out = prefix_scan_fwd(v, op=op, block=block, interpret=interpret)[:N]
    if reverse:
        out = out[::-1]
    return out.astype(bool) if squeeze_bool else out


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def ssd_scan(x, dt, A_log, Bm, Cm, chunk, interpret=None):
    interpret = _should_interpret() if interpret is None else interpret
    return ssd_scan_fwd(x, dt, A_log, Bm, Cm, chunk, interpret=interpret)


def _fwd(x, dt, A_log, Bm, Cm, chunk, interpret):
    out = ssd_scan(x, dt, A_log, Bm, Cm, chunk, interpret)
    return out, (x, dt, A_log, Bm, Cm)


def _bwd(chunk, interpret, res, g):
    x, dt, A_log, Bm, Cm = res
    _, vjp = jax.vjp(lambda *a: ssd_ref(*a, chunk), x, dt, A_log, Bm, Cm)
    return vjp(g)


ssd_scan.defvjp(_fwd, _bwd)
