"""Public SSD scan wrapper: CPU auto-interpret + ref-vjp backward."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan_fwd


def _should_interpret():
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def ssd_scan(x, dt, A_log, Bm, Cm, chunk, interpret=None):
    interpret = _should_interpret() if interpret is None else interpret
    return ssd_scan_fwd(x, dt, A_log, Bm, Cm, chunk, interpret=interpret)


def _fwd(x, dt, A_log, Bm, Cm, chunk, interpret):
    out = ssd_scan(x, dt, A_log, Bm, Cm, chunk, interpret)
    return out, (x, dt, A_log, Bm, Cm)


def _bwd(chunk, interpret, res, g):
    x, dt, A_log, Bm, Cm = res
    _, vjp = jax.vjp(lambda *a: ssd_ref(*a, chunk), x, dt, A_log, Bm, Cm)
    return vjp(g)


ssd_scan.defvjp(_fwd, _bwd)
