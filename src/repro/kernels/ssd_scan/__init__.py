from repro.kernels.ssd_scan.ops import prefix_scan, ssd_scan  # noqa: F401
from repro.kernels.ssd_scan.ref import prefix_scan_ref, ssd_ref  # noqa: F401
