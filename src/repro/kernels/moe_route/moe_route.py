"""Fused MoE routing — Pallas TPU kernel.

Grid (T/bt,) sequential over token tiles; scratch carries per-expert running
counts so capacity ordinals are globally consistent without a host round or
a (T, E, C) dispatch tensor. Per tile: softmax (VPU), iterative top-k
(k ≤ 2 in all assigned configs), one-hot cumsum for in-tile ordinals.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, i_ref, p_ref, keep_ref, counts, *, k, E, bt, capacity):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        counts[...] = jnp.zeros_like(counts)

    logits = x_ref[...].astype(jnp.float32)  # (bt, E)
    m = logits.max(axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    probs = e / e.sum(axis=-1, keepdims=True)

    # iterative top-k (k is tiny: 1–2 in every assigned MoE config)
    remaining = probs
    ws, ids = [], []
    for _ in range(k):
        wi = remaining.max(axis=-1)
        ii = jnp.argmax(remaining, axis=-1).astype(jnp.int32)
        ws.append(wi)
        ids.append(ii)
        remaining = remaining - jax.nn.one_hot(ii, E, dtype=remaining.dtype) * wi[:, None]
    w = jnp.stack(ws, axis=1)  # (bt, k)
    idx = jnp.stack(ids, axis=1)  # (bt, k)
    w = w / jnp.maximum(w.sum(axis=1, keepdims=True), 1e-9)

    # ordinals within expert: carried counts + in-tile exclusive cumsum
    oh = jax.nn.one_hot(idx.reshape(-1), E, dtype=jnp.int32)  # (bt·k, E)
    csum = jnp.cumsum(oh, axis=0)
    local_pos = ((csum - oh) * oh).sum(-1)  # (bt·k,)
    base = jax.lax.dot_general(
        oh.astype(jnp.float32), counts[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    ).astype(jnp.int32)  # counts gathered per assignment
    pos = (base + local_pos).reshape(bt, k)

    w_ref[...] = w
    i_ref[...] = idx
    p_ref[...] = pos
    keep_ref[...] = pos < capacity
    counts[...] = counts[...] + csum[-1:].astype(counts.dtype)


def moe_route_fwd(logits, k: int, capacity: int, *, block_t: int = 256,
                  interpret: bool = False):
    """logits: (T, E), T % block_t == 0 (ops.py pads).
    Returns (weights, idx, pos, keep) each (T, k)."""
    T, E = logits.shape
    bt = min(block_t, T)
    grid = (T // bt,)
    kern = functools.partial(_kernel, k=k, E=E, bt=bt, capacity=capacity)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((bt, E), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, k), jnp.float32),
            jax.ShapeDtypeStruct((T, k), jnp.int32),
            jax.ShapeDtypeStruct((T, k), jnp.int32),
            jax.ShapeDtypeStruct((T, k), jnp.bool_),
        ],
        scratch_shapes=[pltpu.VMEM((1, E), jnp.int32)],
        interpret=interpret,
    )(logits)
