from repro.kernels.moe_route.ops import moe_route  # noqa: F401
from repro.kernels.moe_route.ref import moe_route_ref  # noqa: F401
