from repro.kernels.moe_route.ops import bucket_route, moe_route  # noqa: F401
from repro.kernels.moe_route.ref import bucket_route_ref, moe_route_ref  # noqa: F401
