"""Public MoE routing wrapper: padding + CPU auto-interpret."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.moe_route.moe_route import moe_route_fwd


def _should_interpret():
    return jax.default_backend() != "tpu"


def moe_route(logits, k: int, capacity: int, block_t: int = 256, interpret=None):
    interpret = _should_interpret() if interpret is None else interpret
    T = logits.shape[0]
    pad = (-T) % block_t if T > block_t else 0
    x = logits
    if pad:
        # padded tokens route somewhere but their ordinals come AFTER all real
        # tokens only if appended — they are appended, so real ordinals are
        # unaffected; padded outputs are sliced off.
        x = jnp.concatenate([x, jnp.full((pad, x.shape[1]), -1e9, x.dtype)])
    w, idx, pos, keep = moe_route_fwd(x, k, capacity, block_t=block_t,
                                      interpret=interpret)
    return w[:T], idx[:T], pos[:T], keep[:T]
