"""Public MoE routing wrapper: padding + CPU auto-interpret."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.moe_route.moe_route import moe_route_fwd


def _should_interpret():
    return jax.default_backend() != "tpu"


def bucket_route(dest, p: int, capacity: int, block: int = 512, interpret=None):
    """Shuffle-exchange routing (route.py): capacity ordinals in row order.

    dest: (N,) int32 in [0, p). Returns (pos (N,) i32, keep (N,) bool,
    counts (p,) i32) — bit-identical to the stable-argsort formulation in
    core/shuffle._pack_exchange (and to ``bucket_route_ref``)."""
    from repro.kernels.moe_route.route import bucket_route_fwd

    interpret = _should_interpret() if interpret is None else interpret
    (N,) = dest.shape
    if N == 0:
        return (jnp.zeros(0, jnp.int32), jnp.zeros(0, bool),
                jnp.zeros(p, jnp.int32))
    d = dest.astype(jnp.int32)
    pad = (-N) % block if N > block else 0
    if pad:
        # the sentinel p one-hots to an all-zero row: padding neither
        # claims ordinals nor inflates counts
        d = jnp.concatenate([d, jnp.full((pad,), p, jnp.int32)])
    pos, keep, counts = bucket_route_fwd(d, p=p, capacity=capacity,
                                         block=block, interpret=interpret)
    return pos[:N], keep[:N], counts


def moe_route(logits, k: int, capacity: int, block_t: int = 256, interpret=None):
    interpret = _should_interpret() if interpret is None else interpret
    T = logits.shape[0]
    pad = (-T) % block_t if T > block_t else 0
    x = logits
    if pad:
        # padded tokens route somewhere but their ordinals come AFTER all real
        # tokens only if appended — they are appended, so real ordinals are
        # unaffected; padded outputs are sliced off.
        x = jnp.concatenate([x, jnp.full((pad, x.shape[1]), -1e9, x.dtype)])
    w, idx, pos, keep = moe_route_fwd(x, k, capacity, block_t=block_t,
                                      interpret=interpret)
    return w[:T], idx[:T], pos[:T], keep[:T]
