"""Oracle: fused MoE routing = softmax → top-k → renorm → capacity ordinals.

Ordinal semantics match models/moe.moe_ffn: assignments are ranked within
their expert in flattened (token-major, slot-minor) order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bucket_route_ref(dest, p: int, capacity: int):
    """Oracle for route.bucket_route: the stable-argsort formulation of
    capacity ordinals (the exact code path of core/shuffle._pack_exchange,
    inverted back to row order)."""
    n = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    ds = dest[order]
    counts = jnp.bincount(ds, length=p)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(n) - starts[ds]
    pos = jnp.zeros(n, jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    return pos, pos < capacity, counts.astype(jnp.int32)


def moe_route_ref(logits, k: int, capacity: int):
    """logits: (T, E). Returns (weights (T,k) f32, idx (T,k) i32,
    pos (T,k) i32 ordinal-within-expert, keep (T,k) bool)."""
    T, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    oh = jax.nn.one_hot(idx.reshape(-1), E, dtype=jnp.int32)  # (T·k, E)
    csum = jnp.cumsum(oh, axis=0)
    pos = ((csum - oh) * oh).sum(-1).reshape(T, k)
    keep = pos < capacity
    return w, idx.astype(jnp.int32), pos.astype(jnp.int32), keep
