"""Shuffle bucket routing — Pallas TPU kernel (docs/kernels.md).

The MoE router's capacity-ordinal technique (moe_route.py) applied to the
shuffle engine's exchange: rows are "tokens", destination executors are
"experts", bucket capacity C is the expert capacity. Grid (n_blocks,)
sequential over row tiles; a VMEM (1, p) scratch carries per-destination
running counts, so ordinals are globally consistent in row order without
an argsort. Per tile: one-hot cumsum for in-tile ordinals, a carried-count
gather for the base.

Ordinals are exact integers — for row r with destination b, ``pos`` is the
number of earlier rows routed to b, which is precisely the rank a stable
argsort-by-destination assigns (core/shuffle._pack_exchange). That makes
the kernel-routed packed buffer bit-identical to the argsort path: kept
rows land in the same unique slots; only the sliced-off overflow scratch
slot can differ.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(d_ref, pos_ref, keep_ref, cnt_ref, counts, *, bt, p, capacity, n_blocks):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        counts[...] = jnp.zeros_like(counts)

    d = d_ref[...]  # (bt,) int32 in [0, p); == p marks padding rows
    oh = jax.nn.one_hot(d, p, dtype=jnp.int32)  # (bt, p); pad rows → all-zero
    csum = jnp.cumsum(oh, axis=0)
    local = ((csum - oh) * oh).sum(-1)  # exclusive in-tile ordinal
    base = (oh * counts[...]).sum(-1)  # carried counts gathered per row
    pos = base + local
    pos_ref[...] = pos
    keep_ref[...] = (pos < capacity) & (d < p)
    counts[...] = counts[...] + csum[-1:]

    @pl.when(t == n_blocks - 1)
    def _fin():
        cnt_ref[...] = counts[0]


def bucket_route_fwd(dest, p: int, capacity: int, block: int = 512,
                     interpret: bool = False):
    """dest: (N,) int32 in [0, p] (p = padding sentinel), N % block == 0
    (the ops wrapper pads). Returns (pos (N,) i32, keep (N,) bool,
    counts (p,) i32 — final per-destination demand)."""
    (N,) = dest.shape
    bt = min(block, N)
    n_blocks = N // bt
    kern = functools.partial(_kernel, bt=bt, p=p, capacity=capacity,
                             n_blocks=n_blocks)
    return pl.pallas_call(
        kern,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((bt,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((p,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N,), jnp.int32),
            jax.ShapeDtypeStruct((N,), jnp.bool_),
            jax.ShapeDtypeStruct((p,), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, p), jnp.int32)],
        interpret=interpret,
    )(dest)
