"""Public wrapper: padding, CPU auto-interpret, custom_vjp.

Backward pass recomputes through the jnp oracle (standard practice when only
the fwd kernel is hand-written): fwd = Pallas kernel, bwd = vjp of ref —
numerically consistent since both implement the same math in f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


def _should_interpret():
    return jax.default_backend() != "tpu"


def _pad_to(x, axis, mult):
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x, s
    w = [(0, 0)] * x.ndim
    w[axis] = (0, pad)
    return jnp.pad(x, w), s


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9)
)
def flash_attention(q, k, v, causal=True, window=None, softcap=0.0, q_offset=0,
                    block_q=128, block_k=128, interpret=None):
    return _fwd_impl(q, k, v, causal, window, softcap, q_offset, block_q, block_k,
                     interpret)


def _fwd_impl(q, k, v, causal, window, softcap, q_offset, block_q, block_k, interpret):
    interpret = _should_interpret() if interpret is None else interpret
    qp, Sq = _pad_to(q, 2, block_q)
    kp, Skv = _pad_to(k, 2, block_k)
    vp, _ = _pad_to(v, 2, block_k)
    # padded kv cols are masked via kv_len; padded q rows are discarded
    o = flash_attention_fwd(
        qp, kp, vp, causal=causal, window=window, softcap=softcap,
        q_offset=q_offset, block_q=block_q, block_k=block_k, interpret=interpret,
        kv_len=Skv,
    )
    return o[:, :, :Sq, :]


def _vjp_fwd(q, k, v, causal, window, softcap, q_offset, block_q, block_k, interpret):
    o = _fwd_impl(q, k, v, causal, window, softcap, q_offset, block_q, block_k,
                  interpret)
    return o, (q, k, v)


def _vjp_bwd(causal, window, softcap, q_offset, block_q, block_k, interpret, res, g):
    q, k, v = res

    def f(q, k, v):
        return attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap, q_offset=q_offset
        )

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)
