"""Blockwise fused attention (flash) — Pallas TPU kernel.

Grid (B, H, Sq/bq, Skv/bk); the kv dim is innermost (sequential on TPU), so
the (m, l, acc) online-softmax state lives in VMEM scratch across kv steps.
Scores never touch HBM — the exact traffic the §Roofline table shows
dominating the chunked-jnp baseline. GQA is free via the k/v index_map
(h → h // group); causal + sliding-window blocks are skipped with pl.when.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, scale, causal,
            window, softcap, bq, bk, n_kv, q_offset, kv_len):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    iq = pl.program_id(2)
    row0 = iq * bq + q_offset
    col0 = ik * bk
    # block-level skip: entirely-future (causal) or entirely-too-old (window)
    live = jnp.bool_(True)
    if causal:
        live &= col0 <= row0 + bq - 1
    if window is not None:
        live &= (row0 - (col0 + bk - 1)) < window

    @pl.when(live)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = col0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = cols < kv_len
        if causal:
            ok &= cols <= rows
        if window is not None:
            ok &= (rows - cols) < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _fin():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal=True, window=None, softcap=0.0,
                        q_offset=0, block_q=128, block_k=128, interpret=False,
                        kv_len=None):
    """q: (B, H, Sq, hd); k, v: (B, K, Skv, hd), H = K·G. Sq % block_q == 0,
    Skv % block_k == 0 (ops.py pads). kv_len masks padded key columns.
    Returns (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    K, Skv = k.shape[1], k.shape[2]
    G = H // K
    bq, bk = min(block_q, Sq), min(block_k, Skv)
    n_q, n_kv = Sq // bq, Skv // bk
    grid = (B, H, n_q, n_kv)

    kern = functools.partial(
        _kernel, scale=hd**-0.5, causal=causal, window=window, softcap=softcap,
        bq=bq, bk=bk, n_kv=n_kv, q_offset=q_offset,
        kv_len=Skv if kv_len is None else kv_len,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
