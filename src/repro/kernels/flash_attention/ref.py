"""Pure-jnp oracle for the flash attention kernel.

Layout: q (B, H, Sq, hd); k, v (B, K, Skv, hd) with H = K·G (GQA).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=None, softcap=0.0, q_offset=0):
    B, H, Sq, hd = q.shape
    K = k.shape[1]
    G = H // K
    Skv = k.shape[2]
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kk, preferred_element_type=jnp.float32)
    s = s * (hd**-0.5)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    iq = jnp.arange(Sq)[:, None] + q_offset
    ik = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= ik <= iq
    if window is not None:
        ok &= (iq - ik) < window
    s = jnp.where(ok[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), vv)
