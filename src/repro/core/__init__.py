"""IgnisHPC-JAX core: the paper's contribution.

One communication fabric (a jax Mesh + lax collectives) under two programming
models:

  * a Spark-inspired lazy dataflow API (``IDataFrame``) whose shuffles,
    sorts and reductions run as on-device collectives (no driver round-trips)
  * native SPMD "MPI" programs (``worker.call``) that receive the worker's
    communicator (mesh + axis) exactly like IgnisHPC hands MPI apps
    ``IGNIS_COMM_WORLD``

plus the lazy task-dependency graph with lineage-based fault tolerance,
the job-oriented driver layer (``IJob``/``IFuture``: every action submits
into a cross-worker job DAG; eager actions are facades — docs/driver.md),
communicator groups (``IContext.split``/``group`` = ``MPI_Comm_split``;
``IJob(group=...)`` gang-schedules jobs onto disjoint sub-meshes —
docs/collectives.md), the unified fault-tolerance subsystem (``faults``:
deterministic injection, scheduler retry, checkpoint-truncated repair,
speculative stragglers — docs/fault_tolerance.md), and the
driver-round-trip "spark mode" baseline the paper compares against.
"""
from repro.core.properties import IProperties  # noqa: F401
from repro.core.cluster import Ignis, ICluster, IWorker  # noqa: F401
from repro.core.dataframe import IDataFrame  # noqa: F401
from repro.core.context import IContext  # noqa: F401
from repro.core.textlambda import ISource, text_lambda  # noqa: F401
from repro.core.native import ignis_export  # noqa: F401
from repro.core.job import IFuture, IJob, JobScheduler  # noqa: F401
from repro.core.faults import FaultInjected, FaultPlan, Recoverable  # noqa: F401
