"""Lazy job-oriented driver layer: IJob / IFuture / JobScheduler.

The paper's job hierarchy (§3.2, Figs. 2–3) holds dataflow tasks, native
SPMD tasks and inter-worker transfers in ONE task DAG; this module is the
driver-side realisation. An ``IJob`` partitions a frame's lineage into
uniform *job tasks* at cross-worker boundaries:

  * a **stage** task materialises a subgraph on the worker that owns it,
  * a **native** task runs a ``worker.call`` / ``void_call`` app node,
  * a **reshard** task executes an ``importData`` node (the inter-worker
    communicator, paper Fig. 4),
  * an **action** task applies the driver-side action function to the
    materialised blocks.

Tasks execute on a shared thread pool under per-worker locks, so a worker's
engine is never entered concurrently while *independent branches on
different workers overlap* — the Pilot-style async-handle model (PAPERS.md:
Luckow et al. 2015) over IgnisHPC's hierarchy. Results flow between tasks
through the job's shared memo (the same memo ``DagEngine.evaluate`` uses),
so a downstream worker never re-evaluates an upstream worker's subgraph.

Every ``IDataFrame`` action has an ``*_async`` twin returning an
``IFuture``; the eager form is a facade — ``df.count()`` is literally
``df.count_async().result()`` (docs/driver.md).
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Any, Callable, Optional

from repro.core import comm, faults
from repro.core.dag import _OverlayMemo
from repro.core.metrics import Counters, MetricsTree, warn_deprecated

_task_ids = itertools.count()


def task_history_key(task) -> tuple:
    """The cost-model history key for a task — structural, so retries and
    re-submissions of the same logical work share one duration history
    (docs/profiling.md §auto). Node-backed tasks key on their node's
    signature; action tasks on the action name."""
    from repro.core.dag import node_sig

    node = getattr(task, "node", None)
    if node is not None:
        return (task.kind, node_sig(node))
    return (task.kind, task.name.split("(", 1)[0])


PENDING = "pending"
RUNNING = "running"
DONE = "done"
FAILED = "failed"


class JobTask:
    """One schedulable unit of a job DAG (uniform across task kinds)."""

    __slots__ = (
        "id", "name", "kind", "worker", "fn", "deps", "dependents",
        "remaining", "state", "result", "error", "event", "callbacks",
        "cb_lock", "scheduler", "t_submit", "t_start", "t_end",
        "group", "node", "lock", "attempt", "attempts", "lock_dropped",
        # profiling (docs/profiling.md): the thread that ran the body, the
        # serialisation-lock wait that preceded it, the compute→settle
        # phase boundary timestamps, and the job's tracer (if attached)
        "tid", "t_lock_wait", "t_compute_end", "t_settle_end", "tracer",
    )

    def __init__(self, name: str, kind: str, worker, fn: Callable[[], Any],
                 deps: list["JobTask"], group=None, node=None,
                 attempts: int | None = None):
        self.id = next(_task_ids)
        self.name = name
        self.kind = kind  # "action" | "native" | "reshard" | "stage"
        self.worker = worker
        self.fn = fn
        self.deps = list(deps)
        self.dependents: list[JobTask] = []
        self.remaining = 0
        self.state = PENDING
        self.result: Any = None
        self.error: Optional[BaseException] = None
        self.event = threading.Event()
        self.callbacks: list[Callable] = []
        self.cb_lock = threading.Lock()  # guards callbacks vs resolution
        self.scheduler = None  # set on submit; lets futures help-while-waiting
        self.t_submit = time.perf_counter()
        self.t_start = 0.0
        self.t_end = 0.0
        self.tid = 0
        self.t_lock_wait = 0.0
        self.t_compute_end = 0.0
        self.t_settle_end = 0.0
        self.tracer = None
        # gang scheduling (docs/collectives.md): the group communicator this
        # task executes on (None → the worker's base mesh), the TaskNode it
        # materialises (for inter-group reshard edges), and the serialisation
        # lock it must hold — the worker's job lock, or the GROUP's lock so
        # tasks on disjoint sub-meshes of one worker run concurrently.
        self.group = group
        self.node = node
        # fault tolerance (docs/fault_tolerance.md): total execution attempts
        # for this task. A task failing with a faults.Recoverable error is
        # re-run by the scheduler — through the job's shared memo, so only
        # the failed subgraph recomputes (lineage repair at task granularity)
        # — until it succeeds or exhausts the budget; non-recoverable errors
        # cascade immediately. ``None`` → read ``ignis.task.attempts`` from
        # the owning worker's properties (1 for worker-less tasks).
        if attempts is None:
            props = getattr(getattr(worker, "cluster", None), "props", None)
            attempts = props.get_int("ignis.task.attempts", 1) if props else 1
        self.attempt = 0
        self.attempts = max(1, int(attempts))
        # set by JobScheduler._settle when the runner hands the task's lock
        # off early (awaiting a nonblocking collective with no more
        # lock-protected work left); the acquiring frame then skips its
        # paired release
        self.lock_dropped = False
        if worker is None:
            self.lock = None
        elif group is not None and hasattr(worker, "group_lock"):
            self.lock = worker.group_lock(group)
        else:
            self.lock = getattr(worker, "_job_lock", None)

    @property
    def duration_ms(self) -> float:
        return (self.t_end - self.t_start) * 1e3 if self.t_end else 0.0


class IFuture:
    """Async handle for a submitted job task (the paper-adjacent
    Pilot-abstraction handle): ``result()`` blocks until the scheduler
    resolves the task, propagating any executor exception."""

    def __init__(self, task: JobTask):
        self._task = task

    @property
    def task(self) -> JobTask:
        return self._task

    def done(self) -> bool:
        return self._task.state in (DONE, FAILED)

    def running(self) -> bool:
        return self._task.state == RUNNING

    def _wait(self, timeout: float | None):
        task = self._task
        sched = task.scheduler
        held = () if sched is None else getattr(sched._local, "held_locks", ())
        if not held:
            if not task.event.wait(timeout):
                raise TimeoutError(f"task {task.name!r} still {task.state}")
            return
        # Called from inside a running task while holding job locks:
        # parking here could deadlock (a task that needs one of OUR locks
        # can never run on the pool). Cooperative wait instead — execute
        # claimable tasks guarded by locks this thread holds.
        deadline = None if timeout is None else time.perf_counter() + timeout
        delay = 0.002  # back off once the help queue is drained
        while not task.event.wait(delay):
            while sched._help(held) and not task.event.is_set():
                delay = 0.002
            delay = min(delay * 2, 0.05)
            if deadline is not None and time.perf_counter() >= deadline:
                raise TimeoutError(f"task {task.name!r} still {task.state}")

    def result(self, timeout: float | None = None):
        self._wait(timeout)
        if self._task.state == FAILED:
            raise self._task.error
        return self._task.result

    def exception(self, timeout: float | None = None) -> Optional[BaseException]:
        self._wait(timeout)
        return self._task.error

    def add_done_callback(self, fn: Callable[[JobTask], None]):
        """Run ``fn(task)`` when the task resolves (immediately if it has).
        Registration is synchronized with resolution (the event is set and
        the callback list drained under the task's cb_lock), so a callback
        can neither be lost nor fired twice."""
        task = self._task
        with task.cb_lock:
            if not task.event.is_set():
                task.callbacks.append(fn)
                return
        fn(task)


class JobScheduler:
    """Topological executor for job tasks across workers.

    Ready tasks (all deps resolved) run on a shared thread pool; each task
    acquires its serialisation lock — the owning worker's re-entrant job
    lock, or, for a gang-scheduled task, the lock of its GROUP communicator
    (docs/collectives.md) — so two tasks holding the SAME lock never run
    concurrently, while independent branches on different workers and on
    disjoint sub-meshes of the same worker overlap. The worker lock does
    not exclude group locks: an ungrouped (world-mesh) task may run
    alongside gang tasks of the same worker — correct (engine caches are
    locked, placement is re-established per stage) but oversubscribed, so
    keep a worker's concurrent jobs all-grouped for strict slice
    isolation. Failure is recovered before it cascades: a task failing
    with a ``faults.Recoverable`` error is re-run through the job's shared
    memo (lineage repair at task granularity) up to its
    ``ignis.task.attempts`` budget; only a non-recoverable error, or an
    exhausted budget, cascades — dependents then fail with the same error
    without running (docs/fault_tolerance.md).
    """

    def __init__(self, max_threads: int = 16):
        self.max_threads = max_threads
        self._pool = None
        self._lock = threading.Lock()
        self._local = threading.local()
        self._running = 0
        # ready tasks handed to the pool but not yet claimed — a blocked
        # lock-holder (cooperative wait in IFuture.result) may claim and run
        # one guarded by a lock it holds
        self._claimable: list[JobTask] = []
        self.stats = Counters("scheduler", {
            "jobs_submitted": 0,
            "tasks_submitted": 0,
            "tasks_completed": 0,
            "tasks_failed": 0,
            "inline_runs": 0,
            "helped_runs": 0,
            "max_concurrent": 0,
            "gang_tasks": 0,       # tasks run on a group communicator
            "group_reshards": 0,   # inter-group reshard edges executed
            "task_retries": 0,     # recoverable-failure re-runs (faults.py)
            "coll_awaits": 0,      # handle-valued task results awaited here
            "coll_flushed": 0,     # never-awaited handles drained at task end
        })

    # ------------------------------------------------------------------
    def queue_depth(self) -> int:
        """Submitted-but-unresolved task count — the autoscaling signal
        (``ElasticPolicy.poll``, docs/elasticity.md): sustained depth above
        ``ignis.elastic.queue.per.executor`` × world size asks for ranks."""
        with self._lock:
            return (self.stats["tasks_submitted"]
                    - self.stats["tasks_completed"]
                    - self.stats["tasks_failed"])

    # ------------------------------------------------------------------
    def _ensure_pool(self):
        with self._lock:
            if self._pool is None:
                from concurrent.futures import ThreadPoolExecutor

                self._pool = ThreadPoolExecutor(
                    max_workers=self.max_threads, thread_name_prefix="ignis-job"
                )
            return self._pool

    def submit(self, task: JobTask) -> JobTask:
        """Register a task; launches immediately when its deps are resolved."""
        launch = failed_dep = None
        task.scheduler = self
        with self._lock:
            self.stats["tasks_submitted"] += 1
            for d in task.deps:
                if d.state == FAILED:
                    failed_dep = d
                elif d.state != DONE:
                    d.dependents.append(task)
                    task.remaining += 1
            if failed_dep is None and task.remaining == 0:
                launch = task
        if failed_dep is not None:
            self._fail(task, failed_dep.error)
        elif launch is not None:
            self._launch(launch)
        return task

    def _launch(self, task: JobTask):
        # A nested submission from inside a running task (a native app
        # invoking an eager action) executes inline ONLY when this thread
        # already holds the task's serialisation lock — same-lock
        # reentrancy must stay on this thread, while a task guarded by a
        # foreign lock goes to the pool (acquiring a second job lock while
        # holding one is the AB/BA deadlock shape). Ready dependents of a
        # finished task also go to the pool: fan-out must not serialize on
        # the finishing thread.
        held = getattr(self._local, "held_locks", ())
        if task.lock is not None and any(task.lock is l for l in held):
            with self._lock:
                self.stats["inline_runs"] += 1
            self._run(task)
        else:
            with self._lock:
                self._claimable.append(task)
            self._ensure_pool().submit(self._run, task)

    def _help(self, held) -> bool:
        """Claim and run ONE ready task from a cooperative wait. Preference:
        a task guarded by a lock in ``held`` (this thread already holds it
        — re-entrant, always safe). Failing that, any ready task whose
        lock can be TRY-acquired: non-blocking acquisition adds no
        wait-for edge, so it cannot create a deadlock cycle, and it keeps
        the DAG draining even when every pool thread is parked (pool
        exhaustion under deeply nested cross-worker calls). Returns True if
        a task ran. A pool thread that also picked the task up blocks on
        the task lock, then finds it claimed (state != PENDING) and backs
        off — no double run, and the backed-off frame always releases its
        own acquire (see the per-frame release contract in _run)."""
        cand = foreign = None
        with self._lock:
            for t in self._claimable:
                if t.state != PENDING or t.lock is None:
                    continue
                if any(t.lock is l for l in held):
                    cand = t
                    break
                if foreign is None:
                    foreign = t
            if cand is not None:
                self.stats["helped_runs"] += 1
        if cand is not None:
            self._run(cand)  # held lock: re-entrant acquire, cannot block
            return True
        if foreign is not None:
            lock = foreign.lock
            if lock is None or lock.acquire(blocking=False):
                claimed: list = []
                try:
                    with self._lock:
                        self.stats["helped_runs"] += 1
                    self._run_locked(foreign, claimed)
                finally:
                    if lock is not None and not (claimed and foreign.lock_dropped):
                        lock.release()
                return True
        return False

    def _run(self, task: JobTask):
        # Acquire the task lock BEFORE claiming: a cooperative waiter that
        # already holds the lock can claim the task while a pool thread is
        # still parked on acquire; the late acquirer sees state != PENDING
        # and backs off. The release-skip is PER-FRAME, not per-task:
        # ``task.lock_dropped`` describes the one frame that claimed and ran
        # the task body (the only frame that can reach _settle's drop), so
        # the paired release is skipped only when THIS frame is that frame
        # (``claimed`` non-empty). A frame that parked on acquire, won the
        # lock after the claiming helper dropped it, and backed off on
        # state != PENDING must release its own acquisition — an RLock
        # cannot be released from any other thread, so skipping here would
        # leak the worker/group lock forever.
        lock = task.lock
        lock_wait = 0.0
        if lock is not None:
            t0 = time.perf_counter()
            lock.acquire()
            lock_wait = time.perf_counter() - t0
        claimed: list = []
        try:
            self._run_locked(task, claimed, lock_wait=lock_wait)
        finally:
            if lock is not None and not (claimed and task.lock_dropped):
                lock.release()

    def _unclaim_locked(self, task: JobTask):
        """Drop a task leaving PENDING from the claimable list (caller holds
        self._lock) — entries must not outlive their tasks, or the scheduler
        would pin every job's closures and results for the process lifetime."""
        for i, t in enumerate(self._claimable):
            if t is task:
                del self._claimable[i]
                return

    def _settle(self, task, result, pending, held):
        """Complete a task's nonblocking collectives: await a handle-valued
        result, then flush every handle the task created but never awaited
        (the never-awaited-at-job-end rule — docs/fault_tolerance.md).

        The award of the nonblocking design happens here: when this thread
        holds the task's serialisation lock only for THIS task (not
        re-entrantly from an outer frame), the lock is DROPPED for good
        before the await — the task's own mutations are complete, only
        in-flight device work remains — so the next task on the same
        worker/group starts its tracing and planning while this one's
        collectives drain. The drop is one-way: re-acquiring here could
        deadlock against a peer that took the lock and is now parked on
        THIS task's event (IFuture's cooperative wait holds its locks).
        ``task.lock_dropped`` tells the CLAIMING frame (_run/_help, the one
        whose ``_run_locked`` call ran the body — see ``claimed``) to skip
        its paired release; any other frame that acquired the lock and
        backed off still releases its own acquisition. A retry after a
        fault injected at the ``comm.handle`` site re-runs the fn
        unlocked — a group slice
        briefly oversubscribed is explicitly tolerated (cluster.group_lock),
        never corrupted, since every task binds its own communicator."""
        if not (comm.is_handle(result) or pending):
            return result
        lock = task.lock
        drop = (lock is not None and not task.lock_dropped
                and not any(lock is l for l in held))
        if drop:
            task.lock_dropped = True
            lock.release()
        if comm.is_handle(result):
            result = result.wait()
            with self._lock:
                self.stats["coll_awaits"] += 1
        flushed = 0
        while pending:
            pending[-1].wait(_phase="flush")  # deregisters from the scope
            flushed += 1
        if flushed:
            with self._lock:
                self.stats["coll_flushed"] += flushed
        return result

    def _run_locked(self, task: JobTask, claimed: Optional[list] = None,
                    lock_wait: float = 0.0):
        with self._lock:
            if task.state != PENDING:  # cascaded failure or claimed elsewhere
                return  # back-off: the caller's finally releases its acquire
            task.state = RUNNING
            if claimed is not None:
                # tell the calling frame it is the claiming frame — only then
                # may it honour task.lock_dropped and skip its release
                claimed.append(task)
            self._unclaim_locked(task)
            self._running += 1
            self.stats["max_concurrent"] = max(
                self.stats["max_concurrent"], self._running
            )
        task.t_start = time.perf_counter()
        task.t_lock_wait = lock_wait
        task.tid = threading.get_ident()
        held = getattr(self._local, "held_locks", ())
        error = None
        try:
            self._local.held_locks = held + (task.lock,)
            try:
                worker = task.worker
                if task.group is not None and worker is not None:
                    with self._lock:
                        self.stats["gang_tasks"] += 1
                # Retry loop (paper §3.5: "resubmits failed tasks using the
                # lineage DAG"): a recoverable failure re-runs the task fn.
                # Deps already materialised sit in the job's shared memo, so
                # the retry recomputes only this task's own subgraph; cached
                # nodes that lost blocks repair block-wise inside the engine.
                while True:
                    try:
                        faults.check("job.task", name=task.name, kind=task.kind,
                                     attempt=task.attempt)
                        # the runner (not the task fn) binds the communicator:
                        # a cooperative helper thread may carry another task's
                        # group binding, so every task re-binds its own
                        # (None → base mesh)
                        if worker is not None and hasattr(worker, "use_group"):
                            with worker.use_group(task.group):
                                with comm.track() as pending:
                                    task.result = task.fn()
                        else:
                            with comm.track() as pending:
                                task.result = task.fn()
                        # a task completes only when its collectives do:
                        # await a handle-valued result (MPI_Wait on the
                        # device; releases the GIL and — when safe — the
                        # task's own lock, so peer tasks keep running), then
                        # drain handles the task issued but never awaited —
                        # an in-flight collective must not outlive its task,
                        # and an injected fault on either re-enters THIS
                        # retry loop, re-running the task fn and re-issuing
                        # its collectives.
                        task.t_compute_end = time.perf_counter()
                        task.result = self._settle(task, task.result,
                                                   pending, held)
                        task.t_settle_end = time.perf_counter()
                        break
                    except BaseException as e:
                        task.attempt += 1
                        if task.attempt >= task.attempts or not faults.recoverable(e):
                            raise
                        if task.lock_dropped:
                            # the settle handed the lock off before faulting;
                            # the retry runs unlocked (see _settle), so stop
                            # advertising the lock to nested cooperative waits
                            self._local.held_locks = held
                        with self._lock:
                            self.stats["task_retries"] += 1
            finally:
                self._local.held_locks = held
        except BaseException as e:  # surfaced via IFuture.result()
            error = e
        task.t_end = time.perf_counter()
        with self._lock:
            self._running -= 1
            if error is None:
                task.state = DONE
                self.stats["tasks_completed"] += 1
            else:
                task.error = error
                task.state = FAILED
                self.stats["tasks_failed"] += 1
            task.fn = None  # never called again — release the closure (and
            # with it the job memo / blocks it pins) once the task resolves
            dependents = list(task.dependents)
        self._observe(task, error)
        self._resolve(task)
        for dep in dependents:
            self._dep_resolved(dep, task)

    def _observe(self, task: JobTask, error):
        """Feed the profiling surfaces as a task resolves: the attached
        tracer's span buffer (docs/profiling.md), and — for successful
        runs — the owning worker's cost-model task history, which is what
        ``ignis.task.speculative.timeout=auto`` derives deadlines from.
        Observation must never poison the DAG: failures are swallowed."""
        tracer = task.tracer
        if tracer is not None:
            try:
                tracer.task_done(task)
            except Exception:
                pass
        model = getattr(getattr(task.worker, "engine", None),
                        "cost_model", None)
        if (model is not None and error is None
                and (tracer is None or tracer.cost is not model)):
            try:
                model.observe_task(task_history_key(task),
                                   task.t_end - task.t_start)
            except Exception:
                pass

    def _resolve(self, task: JobTask):
        with task.cb_lock:
            task.event.set()
            callbacks, task.callbacks = task.callbacks, []
        for cb in callbacks:
            try:
                cb(task)
            except Exception:  # observer errors never poison the DAG
                pass

    def _fail(self, task: JobTask, error: BaseException):
        """Cascade an upstream failure through ``task`` and its dependents."""
        with self._lock:
            if task.state in (DONE, FAILED):
                return
            task.error = error
            task.state = FAILED
            self._unclaim_locked(task)
            task.fn = None
            self.stats["tasks_failed"] += 1
            dependents = list(task.dependents)
        self._resolve(task)
        for dep in dependents:
            self._fail(dep, error)

    def _dep_resolved(self, task: JobTask, dep: JobTask):
        if dep.state == FAILED:
            self._fail(task, dep.error)
            return
        launch = False
        with self._lock:
            task.remaining -= 1
            launch = task.remaining == 0 and task.state == PENDING
        if launch:
            self._launch(task)


class _TaskMemo(_OverlayMemo):
    """Task-local view of a job's shared evaluation memo: resharded copies
    of cross-group dep results live in this dict (reads prefer them, so the
    consumer's engine sees blocks on ITS communicator), while — unlike the
    read-only-base ``_OverlayMemo`` it extends — every new materialisation
    writes through to the shared memo for downstream reuse. The shared memo
    itself is never re-placed — see ``IJob._task_memo``."""

    __slots__ = ()

    def __init__(self, shared: dict, overlay: dict):
        super().__init__(shared)
        dict.update(self, overlay)  # seed locally, never write through

    def __setitem__(self, key, value):
        dict.__setitem__(self, key, value)
        self._base[key] = value


_default: Optional[JobScheduler] = None
_default_lock = threading.Lock()


def default_scheduler() -> JobScheduler:
    """The process-wide scheduler every implicit (eager-facade) job uses."""
    global _default
    with _default_lock:
        if _default is None:
            _default = JobScheduler()
    return _default


class IJob:
    """A named group of driver submissions scheduled as one DAG.

    ``submit_action`` walks the frame's lineage, cuts it at *task
    boundaries* — native app nodes, ``importData`` reshards, and any edge
    crossing worker ownership — and submits one job task per boundary node
    plus the action task itself. Tasks share ``self.memo`` (the DagEngine
    evaluation memo), so each subgraph is evaluated exactly once, by the
    worker that owns it, and downstream tasks pick results out of the memo.

    An ``IJob`` may span many frames, workers and actions; futures resolve
    independently (out of submission order when the DAG allows).

    Gang scheduling (docs/collectives.md): ``group=`` pins EVERY task of
    the job onto one communicator group (a per-job sub-cluster — two such
    jobs on disjoint groups run concurrently on different slices of the
    mesh); ``gang=n`` instead splits each owning worker's mesh ``n`` ways
    and deals successive submissions onto the groups round-robin. A task
    consuming blocks that a different group produced gets an inter-group
    reshard edge: the blocks are device_put sub-mesh → sub-mesh before the
    consumer runs.
    """

    def __init__(self, name: str = "job", scheduler: JobScheduler | None = None,
                 group=None, gang: int | None = None):
        self.name = name
        self.scheduler = scheduler or default_scheduler()
        self.group = group
        self.gang = gang
        self._rr = 0  # round-robin dealer for gang=n
        self.tasks: list[JobTask] = []
        self.futures: list[IFuture] = []
        self.memo: dict = {}  # TaskNode -> list[Block], shared across tasks
        self._node_tasks: dict = {}  # TaskNode -> JobTask
        # streaming telemetry hook (docs/streaming.md): StreamTelemetry
        # .attach(job) installs a snapshot thunk here; stats() surfaces it
        self.stream: Optional[Callable[[], dict]] = None
        # profiling hook (docs/profiling.md): JobTracer.attach(job) installs
        # itself here; metrics()["profile"] and trace export read it
        self.tracer = None
        self._t0 = time.perf_counter()
        with self.scheduler._lock:
            self.scheduler.stats["jobs_submitted"] += 1

    # ---- lineage → job-task planning ----------------------------------
    @staticmethod
    def _task_kind(node) -> str:
        if getattr(node, "task_kind", "dataflow") == "native":
            return "native"
        if node.op == "importData":
            return "reshard"
        return "stage"

    @staticmethod
    def _materialised(node) -> bool:
        """Hole-free result: evaluation will short-circuit here, so planning
        must neither schedule it nor descend past it. A cached node that
        lost blocks (``kill_block``) is NOT materialised — its owner must
        repair it under its own job lock."""
        return node.result is not None and not any(b is None for b in node.result)

    @classmethod
    def _is_boundary(cls, node, consumer) -> bool:
        """A parent node that must become its own job task."""
        if cls._materialised(node):
            return False
        if getattr(node, "task_kind", "dataflow") == "native":
            return True
        if node.op == "importData":
            return True
        po, co = getattr(node, "owner", None), getattr(consumer, "owner", None)
        return po is not None and co is not None and po is not co

    def _dep_tasks(self, root, group=None) -> list[JobTask]:
        """Job tasks for every boundary node reachable from ``root`` without
        crossing another boundary (those become the boundary task's deps).
        Traversal stops at materialised nodes: evaluation never descends
        below them, so ancestors (including native apps with side effects)
        must not be scheduled or re-executed. ``group`` is the submitting
        branch's communicator — threaded as a parameter, not instance
        state, so concurrent submissions into one job cannot mis-pin each
        other's boundary tasks."""
        deps, stack, seen = [], [root], {root}
        while stack:
            n = stack.pop()
            for p in n.parents:
                if p in seen:
                    continue
                seen.add(p)
                if self._materialised(p):
                    continue
                if self._is_boundary(p, n):
                    deps.append(self._node_task(p, group))
                else:
                    stack.append(p)
        return deps

    def _task_memo(self, task: JobTask) -> dict:
        """The evaluation memo for one task, with inter-group reshard edges
        applied: any dep that ran on a DIFFERENT communicator leaves its
        blocks committed to that sub-mesh; device_put copies onto this
        task's communicator (the worker's base mesh for ungrouped tasks)
        live in a task-LOCAL overlay, never the shared memo — two groups
        consuming one producer must not race each other's placements (each
        would otherwise read blocks mid-flight on the other's slice). New
        materialisations still write through to the shared memo.

        Caveat: a ``cache()``d dep short-circuits on ``node.result`` inside
        the engine BEFORE the memo, bypassing the overlay — its consumers
        read the blocks where they were cached (wide stages still re-place
        them via the shuffle manager's ingress; narrow stages follow the
        cached placement). Cross-group sharing of explicitly cached frames
        trades slice isolation for the cache hit."""
        worker = task.worker
        if worker is None or not hasattr(worker, "_base_context"):
            return self.memo
        from repro.core.partition import place_block

        tgt = task.group if task.group is not None else worker._base_context
        overlay: dict = {}
        moved = 0
        for d in task.deps:
            if d.node is None or d.group is task.group:
                continue
            blocks = self.memo.get(d.node)
            if not blocks:
                continue
            faults.check("reshard", kind="group", op=d.node.op)
            overlay[d.node] = [place_block(b, tgt.mesh, tgt.axis) for b in blocks]
            moved += len(blocks)
        if not overlay:
            return self.memo
        with self.scheduler._lock:
            self.scheduler.stats["group_reshards"] += moved
        return _TaskMemo(self.memo, overlay)

    @staticmethod
    def _evaluator(worker, task):
        """How a task materialises a node on its worker's engine: plain
        evaluation, or — for gang tasks when ``ignis.task.speculative`` is
        set — deadline-triggered speculative duplication, the straggler
        half of the paper's §3.5 recovery path (docs/fault_tolerance.md)."""
        props = getattr(getattr(worker, "cluster", None), "props", None)
        if (task.group is not None and props is not None
                and props.get_bool("ignis.task.speculative", False)):
            raw = str(props.get("ignis.task.speculative.timeout", "30")).strip()
            if raw.lower() == "auto":
                # cost-derived deadline (docs/profiling.md §auto): factor x
                # the typical observed duration of tasks with this task's
                # structural signature, read at run time so the history the
                # job has already accumulated informs its later tasks
                factor = props.get_float("ignis.task.speculative.factor", 3.0)

                def timeout_s(_t=task, _w=worker, _f=factor):
                    model = getattr(_w.engine, "cost_model", None)
                    if model is None:
                        return 30.0
                    return model.speculative_timeout_s(
                        task_history_key(_t), factor=_f, default_s=30.0)
            else:
                fixed = props.get_float("ignis.task.speculative.timeout", 30.0)
                timeout_s = lambda _fixed=fixed: _fixed
            # every speculative attempt runs on its own thread, so each must
            # re-bind the gang communicator (thread-locals don't cross spawns)
            return lambda node, memo: worker.engine.evaluate_speculative(
                node, timeout_s=timeout_s(), memo=memo,
                bind=lambda: worker.use_group(task.group))
        return lambda node, memo: worker.engine.evaluate(node, memo=memo)

    def _node_task(self, node, group=None) -> JobTask:
        """The (deduplicated) job task materialising ``node`` on its owner.
        A node shared by two branches keeps the group of whichever branch
        created its task first; later consumers in other groups get an
        inter-group reshard edge instead."""
        t = self._node_tasks.get(node)
        if t is not None:
            return t
        worker = getattr(node, "owner", None)
        deps = self._dep_tasks(node, group)
        t = JobTask(f"{node.op}#{node.id}", self._task_kind(node), worker, None,
                    deps, group=group, node=node)

        def fn(_node=node, _worker=worker, _t=t):
            return self._evaluator(_worker, _t)(_node, self._task_memo(_t))

        t.fn = fn
        t.tracer = self.tracer
        self._node_tasks[node] = t
        self.tasks.append(t)
        self.scheduler.submit(t)
        return t

    # ---- submission ----------------------------------------------------
    def _next_group(self, worker, group):
        """The communicator for this submission: explicit ``group=`` wins,
        then the job-wide group, then the gang round-robin dealer, then the
        DRIVER thread's own ``use_group`` binding — an action submitted
        inside ``with worker.use_group(g):`` must execute on ``g`` even
        though it runs on a pool thread, not the driver thread."""
        if group is not None:
            return group
        if self.group is not None:
            return self.group
        if self.gang and worker is not None and hasattr(worker, "groups"):
            gs = worker.groups(self.gang)
            g = gs[self._rr % len(gs)]
            self._rr += 1
            return g
        if worker is not None and hasattr(worker, "_ctx_local"):
            return getattr(worker._ctx_local, "ctx", None)
        return None

    def submit_action(self, frame, name: str, blocks_fn=None, task_fn=None,
                      group=None) -> IFuture:
        """Schedule an action over ``frame``'s lineage; returns its future.

        ``blocks_fn(blocks)`` maps the materialised root blocks to the
        action result; alternatively ``task_fn(memo)`` takes over the whole
        evaluation (early-exit actions like ``take``). ``group`` pins this
        submission (and the boundary tasks it creates) onto a communicator
        group."""
        node, worker = frame.node, frame.worker
        gsel = self._next_group(worker, group)
        if self._materialised(node):
            deps = []  # evaluation short-circuits at the root
        elif self._is_boundary(node, node):  # native/reshard root: own task
            deps = [self._node_task(node, gsel)]
        else:
            deps = self._dep_tasks(node, gsel)
        t = JobTask(f"{name}({node.op}#{node.id})", "action", worker, None, deps,
                    group=gsel)

        def fn(_t=t):
            memo = self._task_memo(_t)
            if task_fn is not None:
                return task_fn(memo)
            blocks = self._evaluator(worker, _t)(node, memo)
            return blocks_fn(blocks)

        t.fn = fn
        t.tracer = self.tracer
        self.tasks.append(t)
        self.scheduler.submit(t)
        fut = IFuture(t)
        self.futures.append(fut)
        return fut

    # ---- introspection -------------------------------------------------
    def wait(self, timeout: float | None = None) -> list:
        """Resolve every submitted future, in submission order. ``timeout``
        is an overall deadline for the whole job, not per future."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        out = []
        for f in self.futures:
            left = None if deadline is None else max(0.0, deadline - time.perf_counter())
            out.append(f.result(left))
        return out

    def release(self):
        """Drop the job's evaluation memo and planning state. The shared
        memo intentionally pins every evaluated subgraph's blocks for reuse
        *within* the job; a long-lived job object should release() once its
        futures are resolved to restore the eager path's memory lifetime.
        ``persist()``-cached nodes are unaffected (they live on TaskNodes)."""
        self.memo.clear()
        self._node_tasks.clear()

    def stats(self) -> dict:
        """Deprecated facade over ``metrics()`` (docs/profiling.md):
        the flat pre-PR-9 shape — task summary at the top level, the
        ``coll`` subtree inline, ``stream`` when attached. Key names and
        merged shapes are unchanged."""
        warn_deprecated("IJob.stats()", "IJob.metrics()")
        return {
            **self._task_summary(),
            # collective-engine telemetry (process-wide: persistent-plan
            # cache + handles; docs/collectives.md) and this scheduler's
            # handle settlement counters
            "coll": self.metrics("coll"),
            # per-tenant streaming/serving telemetry, when a StreamTelemetry
            # is attached to this job (docs/streaming.md)
            **({"stream": self.stream()} if self.stream is not None else {}),
        }

    def metrics(self, path: str | None = None) -> dict:
        """The job's namespaced metrics tree (docs/profiling.md §metrics):
        ``tasks/`` (this job's task-state summary), ``scheduler/`` (the
        owning scheduler's counters), ``coll/`` (process-wide collective
        engine + this scheduler's settlement counters — same shape as the
        ``stats()["coll"]`` facade), plus ``stream/`` and ``profile/`` when
        a StreamTelemetry or JobTracer is attached. ``path`` selects one
        subtree (``metrics("coll")``)."""
        tree = MetricsTree(
            tasks=self._task_summary,
            scheduler=self.scheduler.stats,
            coll=lambda: {**comm.comm_stats(),
                          "awaits": self.scheduler.stats["coll_awaits"],
                          "flushed": self.scheduler.stats["coll_flushed"]},
        )
        if self.stream is not None:
            tree.mount("stream", self.stream)
        if self.tracer is not None:
            tree.mount("profile", self.tracer.summary)
        return tree.snapshot(path)

    def _task_summary(self) -> dict:
        by_state: dict[str, int] = {}
        for t in self.tasks:
            by_state[t.state] = by_state.get(t.state, 0) + 1
        return {
            "tasks": len(self.tasks),
            "actions": sum(1 for t in self.tasks if t.kind == "action"),
            "serve": sum(1 for t in self.tasks if t.kind == "serve"),
            "native": sum(1 for t in self.tasks if t.kind == "native"),
            "reshard": sum(1 for t in self.tasks if t.kind == "reshard"),
            "stage": sum(1 for t in self.tasks if t.kind == "stage"),
            "gang": sum(1 for t in self.tasks if t.group is not None),
            "groups": sorted({t.group.label() for t in self.tasks
                              if t.group is not None}),
            "done": by_state.get(DONE, 0),
            "failed": by_state.get(FAILED, 0),
            "workers": sorted({t.worker.name for t in self.tasks if t.worker}),
            "wall_ms": (time.perf_counter() - self._t0) * 1e3,
        }

    def explain(self) -> str:
        """Render the job DAG: one line per task with kind, owning worker,
        communicator group, dependencies, state and duration — the
        cross-worker complement of ``df.explain()``'s per-lineage plan."""
        lines = [f"== job {self.name!r} ({len(self.tasks)} tasks) =="]
        for t in sorted(self.tasks, key=lambda t: t.id):
            deps = ",".join(f"t{d.id}" for d in t.deps) or "-"
            wname = t.worker.name if t.worker is not None else "?"
            gname = f"  group={t.group.label()}" if t.group is not None else ""
            dur = f"{t.duration_ms:.1f}ms" if t.t_end else ""
            lines.append(
                f"  t{t.id} {t.kind}:{t.name}  worker={wname}{gname}  "
                f"deps=[{deps}]  {t.state} {dur}".rstrip()
            )
        return "\n".join(lines)
