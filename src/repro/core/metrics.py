"""Unified counter registry — the single backend behind every telemetry
surface (docs/profiling.md, DESIGN.md §13).

Through PR 8 the framework grew five ad-hoc stats dicts: the engine's
``stage_stats()``, the shuffle manager's ``shuffle_stats()`` (which also
merged the kernel registry's and the collective engine's counters), the
scheduler's ``job.stats()["coll"]`` slice, and the streaming telemetry
attached per job. This module replaces the *plumbing* — not the counters —
with one mechanism:

* ``Counters`` is a named namespace of numeric counters. It IS a dict
  (``stats["x"] += 1`` and ``dict(stats)`` keep working verbatim at every
  existing call site), but it knows its namespace and registers per-key
  docstrings, so a metrics tree can be assembled and documented from the
  pieces.
* ``MetricsTree`` mounts namespaces (``Counters`` instances, snapshot
  callables, or nested trees) under path segments and snapshots them into
  one nested dict: ``worker.metrics()`` → ``{"stages": {...}, "shuffle":
  {...}, "coll": {...}, "kernels": {...}, "profile": {...}}``.

The pre-PR-9 accessors (``worker.stage_stats()``, ``worker.shuffle_stats()``,
``job.stats()["coll"]``) remain as thin facades over subtree snapshots — the
counter names and merged shapes are unchanged, so gated CI counters
(tools/check_bench.py) and existing tests keep their meaning. New code
should read the tree (docs/profiling.md has the old→new migration table).
"""
from __future__ import annotations

import warnings
from typing import Callable, Mapping, Optional, Union


class Counters(dict):
    """A namespace of numeric counters inside a metrics tree.

    A plain ``dict`` in every behavioural respect — subsystems mutate it
    under their own locks exactly as before — plus a namespace name and
    optional per-key documentation used by the metrics tree and the docs
    tooling. Unknown-key writes are allowed (streaming telemetry grows keys
    per tenant); ``describe()`` returns whatever docs were registered.
    """

    __slots__ = ("namespace", "_docs")

    def __init__(self, namespace: str, initial: Optional[Mapping] = None,
                 docs: Optional[Mapping[str, str]] = None):
        super().__init__(initial or {})
        self.namespace = namespace
        self._docs = dict(docs or {})

    def describe(self) -> dict:
        """{counter: docstring} for every documented counter."""
        return dict(self._docs)

    def snapshot(self) -> dict:
        return dict(self)

    def __repr__(self):
        return f"Counters({self.namespace!r}, {dict.__repr__(self)})"


Source = Union[Counters, Callable[[], Mapping], "MetricsTree", Mapping]


class MetricsTree:
    """A mounted tree of counter namespaces.

    Each mount point is a ``Counters`` instance (live — snapshots read the
    current values), a zero-arg callable returning a mapping (for
    process-wide or lazily-computed sources like ``comm.comm_stats``), a
    nested ``MetricsTree``, or a plain mapping. ``snapshot()`` renders the
    whole tree as nested plain dicts; ``snapshot(path)`` renders one
    subtree. Mount points can be replaced (a worker re-wiring a subsystem
    re-mounts the same path).
    """

    __slots__ = ("_mounts",)

    def __init__(self, **mounts: Source):
        self._mounts: dict[str, Source] = {}
        for name, src in mounts.items():
            self.mount(name, src)

    def mount(self, name: str, source: Source) -> "MetricsTree":
        if "/" in name:
            head, rest = name.split("/", 1)
            sub = self._mounts.get(head)
            if not isinstance(sub, MetricsTree):
                sub = MetricsTree()
                self._mounts[head] = sub
            sub.mount(rest, source)
            return self
        self._mounts[name] = source
        return self

    def unmount(self, name: str):
        self._mounts.pop(name, None)

    def names(self) -> list[str]:
        return sorted(self._mounts)

    @staticmethod
    def _render(src: Source) -> dict:
        if isinstance(src, MetricsTree):
            return src.snapshot()
        if isinstance(src, Counters):
            return src.snapshot()
        if callable(src):
            return dict(src())
        return dict(src)

    def snapshot(self, path: str | None = None) -> dict:
        """Nested plain-dict snapshot of the tree (or of one ``path``
        subtree, ``/``-separated). Unknown paths raise ``KeyError`` with
        the known mount names — a misspelt subsystem should fail loudly,
        not read as zero activity."""
        if path:
            head, _, rest = path.partition("/")
            if head not in self._mounts:
                raise KeyError(
                    f"no metrics namespace {head!r} (have: {self.names()})")
            src = self._mounts[head]
            if rest:
                if not isinstance(src, MetricsTree):
                    snap = self._render(src)
                    if rest in snap:
                        return snap[rest]
                    raise KeyError(f"no metrics path {path!r}")
                return src.snapshot(rest)
            return self._render(src)
        return {name: self._render(src) for name, src in self._mounts.items()}


# ---------------------------------------------------------------------------
# deprecation plumbing for the old accessors
# ---------------------------------------------------------------------------

_warned: set[str] = set()


def warn_deprecated(old: str, new: str):
    """One ``DeprecationWarning`` per (old, new) pair per process — the old
    accessors keep working (facades over the metrics tree) but new code
    should read ``metrics()`` (docs/profiling.md migration table)."""
    key = f"{old}->{new}"
    if key in _warned:
        return
    _warned.add(key)
    warnings.warn(
        f"{old} is a facade over the unified metrics tree; use {new} "
        f"(docs/profiling.md)", DeprecationWarning, stacklevel=3)
