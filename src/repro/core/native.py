"""Native SPMD app execution (paper §5, Figs. 9–11).

IgnisHPC runs MPI applications by (1) removing MPI_Init/Finalize — the
framework owns the environment — and (2) swapping MPI_COMM_WORLD for the
framework's communicator. The TPU analogue: a native app is a function
``fn(ctx, *arrays, **params)`` whose body uses ``ctx.comm()`` (mesh + axis)
with jax.lax collectives inside shard_map. ``ignis_export`` registers it in
a library; ``worker.load_library`` + ``worker.call`` execute it — the +17…75
SLOC integration the paper's Table 5 measures is exactly the export wrapper.
"""
from __future__ import annotations

import importlib
import importlib.util
import sys
from typing import Callable

_REGISTRY: dict[str, Callable] = {}


def ignis_export(name: str | None = None):
    """Decorator: register a native app under ``name`` (paper's
    ``ignis_export(Class, Name)`` / ``create_ignis_library``)."""

    def deco(fn):
        _REGISTRY[name or fn.__name__] = fn
        return fn

    if callable(name):  # bare @ignis_export
        fn, nm = name, name.__name__
        _REGISTRY[nm] = fn
        return fn
    return deco


def load_library(path_or_module: str) -> list[str]:
    """Import a library module, returning the names it exported."""
    before = set(_REGISTRY)
    if path_or_module.endswith(".py"):
        spec = importlib.util.spec_from_file_location(
            f"ignis_lib_{abs(hash(path_or_module))}", path_or_module
        )
        mod = importlib.util.module_from_spec(spec)
        sys.modules[spec.name] = mod
        spec.loader.exec_module(mod)
    else:
        importlib.import_module(path_or_module)
    return sorted(set(_REGISTRY) - before)


def get_app(name: str) -> Callable:
    if name not in _REGISTRY:
        raise KeyError(f"native app {name!r} not loaded; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]
