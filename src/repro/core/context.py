"""IContext — the executor context (paper §3.6).

The TPU analogue of IgnisHPC's MPI communicators (paper Fig. 4):

  base communicator    → the worker's (mesh, axis) pair: every executor
                         (device along the "data" axis) participates
  driver communicator  → host↔device transfers (device_put / device_get)
  inter-worker comm.   → resharding between two workers' meshes (importData)
  group communicator   → ``split``/``group`` (the ``MPI_Comm_split`` /
                         ``MPI_Comm_create`` analogues): a sub-mesh over a
                         subset of the executors with its own collective
                         axis — collectives inside the group never touch
                         devices outside it (docs/collectives.md)

Inside a native SPMD program the context is what ``MPI_COMM_WORLD`` is to an
MPI code: ``ctx.axis`` names the collective axis for jax.lax primitives, and
``ctx.var(...)`` carries driver variables to the executors (paper Fig. 10
parses LULESH's argv from exactly this mechanism).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import numpy as np

from repro.core import compat


class IContext:
    def __init__(self, mesh, axis: str = "data", props=None, worker=None):
        self.mesh = mesh
        self.axis = axis
        self.props = props
        self.worker = worker
        self._vars: dict[str, Any] = {}
        # communicator-group lineage (None / () for the base communicator)
        self.parent: "IContext | None" = None
        self.group_ranks: tuple[int, ...] = ()

    # ---- communicator surface (the MPI_COMM_WORLD analogue) ---------------
    def comm(self):
        """The base communicator: (mesh, collective axis name)."""
        return self.mesh, self.axis

    @property
    def executors(self) -> int:
        """World size along the collective axis."""
        return self.mesh.shape[self.axis]

    def rank(self):
        """Executor rank — only meaningful inside shard_map'd code."""
        return jax.lax.axis_index(self.axis)

    def place(self, x, spec=None):
        """Commit ``x`` to THIS communicator's mesh (no-op when already
        resident): row-sharded over the collective axis by default, or per
        ``spec``. A shard_map over a group mesh rejects operands committed
        to a different device set, so placing first is what makes
        collectives — and their nonblocking handles — group-portable: the
        device_put IS the inter-group reshard edge (docs/collectives.md)."""
        if spec is None:
            spec = jax.sharding.PartitionSpec(self.axis)
        return jax.device_put(x, jax.NamedSharding(self.mesh, spec))

    # ---- communicator groups (MPI_Comm_split / MPI_Comm_create) -----------
    @property
    def is_group(self) -> bool:
        return self.parent is not None

    def label(self) -> str:
        """Human-readable communicator name for explain()/locks."""
        if not self.is_group:
            return self.axis
        lo, hi = self.group_ranks[0], self.group_ranks[-1]
        return f"{self.parent.label()}[{lo}:{hi + 1}]"

    def group(self, ranks: Sequence[int]) -> "IContext":
        """``MPI_Comm_create``: a sub-communicator over ``ranks`` of THIS
        communicator's axis. The group gets its own mesh — a sub-mesh pinned
        to the ranks' devices — so every collective issued through it spans
        only those executors. Driver vars are inherited (snapshot)."""
        p = self.executors
        ranks = tuple(int(r) for r in ranks)
        if not ranks:
            raise ValueError("group() needs at least one rank")
        if len(set(ranks)) != len(ranks):
            raise ValueError(f"group() ranks must be distinct, got {ranks}")
        bad = [r for r in ranks if not 0 <= r < p]
        if bad:
            raise ValueError(
                f"group() ranks {bad} out of range for {p} executors")
        # executor blacklist (docs/fault_tolerance.md): a base-communicator
        # group must not be built over a lost container — the scheduler
        # routes new sub-clusters around blacklisted ranks until the worker
        # restore_executor()s them. Nested groups use parent-relative ranks,
        # so the guard applies at the base communicator only.
        if self.parent is None and self.worker is not None:
            lost = sorted(
                r for r in ranks
                if r in getattr(self.worker, "executor_blacklist", ()))
            if lost:
                raise ValueError(
                    f"group() ranks {lost} are blacklisted (lost executors); "
                    f"restore_executor() to re-admit them")
        dim = list(self.mesh.axis_names).index(self.axis)
        devs = np.take(np.asarray(self.mesh.devices), ranks, axis=dim)
        sub = IContext(
            compat.make_mesh_of(devs, self.mesh.axis_names),
            self.axis, self.props, self.worker,
        )
        sub._vars = dict(self._vars)
        sub.parent = self
        sub.group_ranks = ranks
        return sub

    def split(self, n_groups: int) -> "list[IContext]":
        """``MPI_Comm_split`` with ``color = rank // (p / n_groups)``: carve
        the communicator into ``n_groups`` contiguous equal sub-meshes.
        Rejects uneven splits — capacity padding and PSRS bucketing both
        assume every group member holds the same row count, so a ragged
        split would silently skew capacities (DESIGN.md §1)."""
        p = self.executors
        if n_groups < 1:
            raise ValueError(f"split() needs n_groups >= 1, got {n_groups}")
        if p % n_groups:
            raise ValueError(
                f"split({n_groups}) does not divide {p} executors evenly; "
                f"use group(ranks) for ragged sub-communicators")
        k = p // n_groups
        return [self.group(range(i * k, (i + 1) * k)) for i in range(n_groups)]

    # ---- driver↔executor variable exchange (ISource.addParam / context.var)
    def set_var(self, name: str, value):
        self._vars[name] = value

    def is_var(self, name: str) -> bool:
        return name in self._vars

    def var(self, name: str, default=None):
        return self._vars.get(name, default)

    def vars(self) -> dict:
        return dict(self._vars)

    def child(self, **extra_vars) -> "IContext":
        c = IContext(self.mesh, self.axis, self.props, self.worker)
        c._vars = {**self._vars, **extra_vars}
        c.parent = self.parent  # a child of a group stays in the group
        c.group_ranks = self.group_ranks
        return c

    def bind(self, params: dict) -> "IContext":
        """Execution-time context for a native task: a child communicator
        carrying the driver's *current* vars plus the call's params (paper
        Fig. 11 ``addParam``). Native call nodes invoke this when the task
        RUNS, not when it was defined, so ``set_var`` updates between
        definition and execution are visible (docs/driver.md)."""
        return self.child(**params)
