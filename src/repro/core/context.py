"""IContext — the executor context (paper §3.6).

The TPU analogue of IgnisHPC's MPI communicators (paper Fig. 4):

  base communicator    → the worker's (mesh, axis) pair: every executor
                         (device along the "data" axis) participates
  driver communicator  → host↔device transfers (device_put / device_get)
  inter-worker comm.   → resharding between two workers' meshes (importData)

Inside a native SPMD program the context is what ``MPI_COMM_WORLD`` is to an
MPI code: ``ctx.axis`` names the collective axis for jax.lax primitives, and
``ctx.var(...)`` carries driver variables to the executors (paper Fig. 10
parses LULESH's argv from exactly this mechanism).
"""
from __future__ import annotations

from typing import Any

import jax


class IContext:
    def __init__(self, mesh, axis: str = "data", props=None, worker=None):
        self.mesh = mesh
        self.axis = axis
        self.props = props
        self.worker = worker
        self._vars: dict[str, Any] = {}

    # ---- communicator surface (the MPI_COMM_WORLD analogue) ---------------
    def comm(self):
        """The base communicator: (mesh, collective axis name)."""
        return self.mesh, self.axis

    @property
    def executors(self) -> int:
        """World size along the collective axis."""
        return self.mesh.shape[self.axis]

    def rank(self):
        """Executor rank — only meaningful inside shard_map'd code."""
        return jax.lax.axis_index(self.axis)

    # ---- driver↔executor variable exchange (ISource.addParam / context.var)
    def set_var(self, name: str, value):
        self._vars[name] = value

    def is_var(self, name: str) -> bool:
        return name in self._vars

    def var(self, name: str, default=None):
        return self._vars.get(name, default)

    def vars(self) -> dict:
        return dict(self._vars)

    def child(self, **extra_vars) -> "IContext":
        c = IContext(self.mesh, self.axis, self.props, self.worker)
        c._vars = {**self._vars, **extra_vars}
        return c

    def bind(self, params: dict) -> "IContext":
        """Execution-time context for a native task: a child communicator
        carrying the driver's *current* vars plus the call's params (paper
        Fig. 11 ``addParam``). Native call nodes invoke this when the task
        RUNS, not when it was defined, so ``set_var`` updates between
        definition and execution are visible (docs/driver.md)."""
        return self.child(**params)
