"""Version-portable wrappers for jax APIs that moved between releases.

The runtime targets the newest jax surface (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``) but must also run on older
installs where ``shard_map`` still lives in ``jax.experimental`` (with
``check_rep`` instead of ``check_vma``) and meshes carry no axis types.
Everything in the repo goes through these two helpers instead of calling
jax directly, so the version split lives in exactly one file.
"""
from __future__ import annotations

import jax

_NEW_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where the install supports them."""
    if _HAS_AXIS_TYPE:
        kinds = (jax.sharding.AxisType.Auto,) * len(axis_names)
        return jax.make_mesh(axis_shapes, axis_names, axis_types=kinds)
    return jax.make_mesh(axis_shapes, axis_names)


def make_mesh_of(devices, axis_names):
    """A Mesh over an EXPLICIT device array — the communicator-group path
    (``IContext.split``/``group``): sub-meshes must pin their device subset,
    which ``jax.make_mesh`` (auto device selection) cannot express."""
    if _HAS_AXIS_TYPE:
        kinds = (jax.sharding.AxisType.Auto,) * len(axis_names)
        try:
            return jax.sharding.Mesh(devices, axis_names, axis_types=kinds)
        except TypeError:  # jax window with AxisType but no Mesh kwarg
            pass
    return jax.sharding.Mesh(devices, axis_names)


def get_ambient_mesh():
    """The mesh installed by ``set_mesh`` (or None): the abstract mesh on new
    jax, the thread-resources physical mesh on old."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src import mesh as _mesh_lib

    mesh = _mesh_lib.thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh. On older jax
    the Mesh object is itself the context manager (thread resources)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def shard_map(f, mesh=None, in_specs=None, out_specs=None):
    """``jax.shard_map`` with replication checking off (our collectives use
    unreduced intermediates that the checker rejects on every jax version)."""
    if _NEW_SHARD_MAP:
        kw = {} if mesh is None else {"mesh": mesh}
        try:
            return jax.shard_map(f, in_specs=in_specs, out_specs=out_specs,
                                 check_vma=False, **kw)
        except TypeError:  # jax window with top-level shard_map but check_rep
            return jax.shard_map(f, in_specs=in_specs, out_specs=out_specs,
                                 check_rep=False, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map

    if mesh is None:  # old jax cannot infer the mesh from context
        from jax._src import mesh as _mesh_lib

        mesh = _mesh_lib.thread_resources.env.physical_mesh
        if mesh.empty:
            raise RuntimeError(
                "shard_map without an explicit mesh needs jax>=0.5 or an "
                "enclosing `with mesh:` scope"
            )
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
