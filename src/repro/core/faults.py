"""Deterministic fault injection (docs/fault_tolerance.md, DESIGN.md §9).

The paper's recovery claims (§3.5, Fig. 3 — "the scheduler resubmits failed
tasks using the lineage DAG") are only testable if failures can be produced
*on demand, deterministically, at every task kind*. This module is that
layer: a ``FaultPlan`` is a replayable list of rules ("kill block 2 of the
map node on its first attempt", "fail the sort collective once", "delay
this task 1 s"), and the runtime calls ``faults.check(site, **info)`` at
every injection site. With no active plan the check is a single global
read — the production hot path pays one ``is None`` test.

Injection sites (threaded through the runtime):

  ==================  =====================================================
  site                where / info keys
  ==================  =====================================================
  ``dag.block``       per-block narrow/fused evaluation (``dag.py``):
                      ``op``, ``block``, ``fused``
  ``dag.node``        whole-node (wide / native) evaluation: ``op``
  ``dag.repair``      lineage repair of a lost cached block: ``op``,
                      ``block``
  ``shuffle.stage``   a wide collective stage (``shuffle_plan.py``):
                      ``kind`` (sort/distinct/reduceByKey/groupByKey/
                      partitionBy/join), ``p``
  ``shuffle.overflow``the capacity-overflow retry path: ``kind``
  ``kernel.stage``    a KERNEL-BACKED wide stage (``shuffle_plan.py``,
                      docs/kernels.md) — fires only when the stage runs on
                      the Pallas tier: ``kind``, ``kernel``
                      (segment_reduce/bucket_route), ``p``. A task fault:
                      the scheduler retries via lineage.
  ``kernel.capability``the kernel tier's per-node capability check
                      (``kernels/registry.py``): ``kernel``. NOT a task
                      fault — an injected failure degrades the node to the
                      plain-JAX fallback without erroring.
  ``job.task``        one scheduler attempt of a job task (``job.py``):
                      ``name``, ``kind``, ``attempt``
  ``reshard``         communicator edges (``cluster.py`` importData /
                      native args, ``job.py`` inter-group edges): ``kind``
  ``comm.handle``     awaiting a still-pending nonblocking collective
                      (``comm.py`` ``CollHandle.wait``, and the scheduler's
                      end-of-task drain of never-awaited handles): ``coll``
                      (allreduce/gather/alltoall/…), ``phase`` (``wait`` /
                      ``flush``)
  ``stream.batch``    one micro-batch task of a streaming pump
                      (``streaming/context.py``, docs/streaming.md):
                      ``tenant``, ``batch``. A task fault: the scheduler
                      retries via lineage and the pump counts the replay
                      (``batches_replayed``) — output stays bit-identical.
  ``stream.admit``    an admission decision (``streaming/admission.py``):
                      ``tenant``. NOT a task fault — an injected failure
                      forces a ``shed`` decision (counted, never retried).
  ``elastic.reshard`` one incremental block move during a mesh resize
                      (``distributed/elastic.py`` ``reshard_cached``,
                      docs/elasticity.md): ``op``, ``block``. NOT retried in
                      place — an injected failure models the block lost in
                      flight: it becomes a lineage hole (counted as an
                      elastic ``reshard_recompute``) and the next action
                      repairs it block-wise, exactly like an executor kill.
  ==================  =====================================================

Rules match a site plus a subset of the info keys; string values match via
``fnmatch`` (exact unless the pattern carries ``*``/``?``), everything else
by equality. Each rule keeps its own match counter, so ``attempt=k`` means
"the k-th time this exact site+match fires" — replayable across runs.
Every firing is appended to ``plan.log`` for post-hoc assertions.

``Recoverable`` is the error contract with the scheduler: a job task
failing with a ``Recoverable`` error (``FaultInjected``, or anything a
deployment maps onto it — executor loss, preempted containers) is retried
via lineage up to ``ignis.task.attempts``; any other exception is an
application error and cascades (core/job.py).
"""
from __future__ import annotations

import contextlib
import random
import threading
import time
from dataclasses import dataclass
from typing import Optional


class Recoverable(Exception):
    """Base class for errors the job scheduler may retry via lineage."""


class FaultInjected(Recoverable):
    """Raised by an injection site when a fail rule fires."""


def recoverable(error: BaseException) -> bool:
    """Scheduler retry policy: injected/infrastructure faults retry,
    deterministic application errors cascade."""
    return isinstance(error, Recoverable)


@dataclass
class _Rule:
    site: str
    match: dict
    action: str  # "fail" | "delay"
    attempt: Optional[int] = 0  # None → any attempt (bounded by times)
    times: Optional[int] = None  # None → unbounded firings
    seconds: float = 0.0
    count: int = 0  # matching check() calls seen
    fired: int = 0  # faults actually injected
    note: str = ""

    def matches(self, site: str, info: dict) -> bool:
        if site != self.site:
            return False
        from fnmatch import fnmatch

        for k, v in self.match.items():
            if k not in info:
                return False
            got = info[k]
            if isinstance(v, str):
                if not fnmatch(str(got), v):
                    return False
            elif got != v:
                return False
        return True


class FaultPlan:
    """A deterministic, seedable set of fault-injection rules.

    The ``seed`` drives ``choice``/``randint`` — used by chaos/property
    tests to *sample* kill-points reproducibly; rule firing itself is
    purely counter-based and independent of the seed.
    """

    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)
        self.rules: list[_Rule] = []
        self.log: list[tuple] = []  # (site, action, info) per firing
        self._lock = threading.Lock()

    # ---- registration --------------------------------------------------
    def fail(self, site: str, attempt: Optional[int] = 0,
             times: Optional[int] = None, note: str = "", **match) -> "FaultPlan":
        self.rules.append(_Rule(site, match, "fail", attempt, times, note=note))
        return self

    def delay(self, site: str, seconds: float, attempt: Optional[int] = 0,
              times: Optional[int] = None, note: str = "", **match) -> "FaultPlan":
        self.rules.append(
            _Rule(site, match, "delay", attempt, times, seconds=seconds, note=note))
        return self

    # sugar for the common kill-points ------------------------------------
    def kill_block(self, op: str, block: int, attempt: int = 0) -> "FaultPlan":
        """Fail evaluation of block ``block`` of node ``op`` on attempt k."""
        return self.fail("dag.block", op=op, block=block, attempt=attempt)

    def fail_node(self, op: str, attempt: int = 0) -> "FaultPlan":
        """Fail a whole-node (wide / native) evaluation on attempt k."""
        return self.fail("dag.node", op=op, attempt=attempt)

    def fail_collective(self, kind: str, times: int = 1) -> "FaultPlan":
        """Fail the next ``times`` runs of a shuffle collective stage."""
        return self.fail("shuffle.stage", kind=kind, attempt=None, times=times)

    def fail_task(self, name: str, attempt: int = 0) -> "FaultPlan":
        """Fail a job task by (fnmatch) name on scheduler attempt k."""
        return self.fail("job.task", name=name, attempt=attempt)

    def fail_kernel_stage(self, kind: str = "*", times: int = 1) -> "FaultPlan":
        """Kill the next ``times`` kernel-backed wide stages (lineage retry)."""
        return self.fail("kernel.stage", kind=kind, attempt=None, times=times)

    def fail_kernel_capability(self, kernel: str = "*",
                               times: Optional[int] = None) -> "FaultPlan":
        """Fail kernel capability checks: the node degrades to the
        plain-JAX fallback (no error, no retry — docs/kernels.md)."""
        return self.fail("kernel.capability", kernel=kernel, attempt=None,
                         times=times)

    def fail_stream_batch(self, tenant: str = "*", batch=None,
                          attempt: int = 0,
                          times: Optional[int] = None) -> "FaultPlan":
        """Kill a streaming micro-batch task on scheduler attempt k: the
        scheduler replays it via lineage; the pump's commit stays in order
        and counts the replay exactly (docs/streaming.md)."""
        match = {"tenant": tenant}
        if batch is not None:
            match["batch"] = batch
        return self.fail("stream.batch", attempt=attempt, times=times, **match)

    def fail_stream_admit(self, tenant: str = "*", times: int = 1) -> "FaultPlan":
        """Force the next ``times`` admission decisions for ``tenant`` to
        shed — overload as a policy outcome, not an error (no retry)."""
        return self.fail("stream.admit", tenant=tenant, attempt=None,
                         times=times)

    def fail_elastic_reshard(self, op: str = "*", block=None,
                             times: Optional[int] = 1) -> "FaultPlan":
        """Lose a cached block mid-move during a mesh resize: the resize
        completes, the block becomes a lineage hole, and the next action
        repairs it block-wise (docs/elasticity.md — no task retry here)."""
        match = {"op": op}
        if block is not None:
            match["block"] = block
        return self.fail("elastic.reshard", attempt=None, times=times, **match)

    def delay_task(self, name: str, seconds: float, attempt: int = 0) -> "FaultPlan":
        """Straggle a job task: sleep before its k-th scheduler attempt."""
        return self.delay("job.task", seconds, name=name, attempt=attempt)

    def delay_block(self, op: str, block: int, seconds: float,
                    attempt: int = 0) -> "FaultPlan":
        """Straggle one block evaluation (speculative-execution trigger)."""
        return self.delay("dag.block", seconds, op=op, block=block, attempt=attempt)

    def fail_reshard(self, kind: str = "*", attempt: int = 0) -> "FaultPlan":
        """Fail a communicator edge (importData / native / group)."""
        return self.fail("reshard", kind=kind, attempt=attempt)

    def kill_handle(self, coll: str = "*", attempt: int = 0,
                    phase: str = "*") -> "FaultPlan":
        """Kill a pending nonblocking collective as it is awaited: the k-th
        wait (or end-of-task ``flush``) of a matching in-flight handle fails
        as if the transfer was lost mid-flight."""
        return self.fail("comm.handle", coll=coll, phase=phase, attempt=attempt)

    # ---- deterministic sampling ----------------------------------------
    def choice(self, seq):
        return self.rng.choice(list(seq))

    def randint(self, a: int, b: int) -> int:
        return self.rng.randint(a, b)

    # ---- the runtime hook ----------------------------------------------
    def check(self, site: str, **info):
        fire = None
        with self._lock:
            # every matching rule counts this check (so "attempt k" always
            # means the k-th evaluation of the kill-point, even when another
            # rule fired earlier attempts); at most one rule fires per check
            for rule in self.rules:
                if not rule.matches(site, info):
                    continue
                n = rule.count
                rule.count += 1
                if fire is not None:
                    continue
                if rule.attempt is not None and n != rule.attempt:
                    continue
                if rule.times is not None and rule.fired >= rule.times:
                    continue
                rule.fired += 1
                self.log.append((site, rule.action, dict(info)))
                fire = rule
        if fire is None:
            return
        if fire.action == "delay":
            time.sleep(fire.seconds)
            return
        raise FaultInjected(f"injected fault at {site} ({info})")

    def injections(self, site: Optional[str] = None) -> int:
        """How many faults actually fired (optionally for one site)."""
        with self._lock:
            return sum(1 for s, _a, _i in self.log if site is None or s == site)


# ---------------------------------------------------------------------------
# active-plan plumbing: one process-wide plan, visible from every thread
# (job tasks run on pool threads; a thread-local would hide the plan from
# the scheduler). Chaos tests are serialized, so a single slot suffices.
# ---------------------------------------------------------------------------
_active: Optional[FaultPlan] = None


def active() -> Optional[FaultPlan]:
    return _active


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """Install ``plan`` as the process-wide fault plan for the block."""
    global _active
    prev = _active
    _active = plan
    try:
        yield plan
    finally:
        _active = prev


def check(site: str, **info):
    """Injection-site hook. No-op (one global read) without an active plan."""
    plan = _active
    if plan is not None:
        plan.check(site, **info)
