"""Executor module: block-level implementations of the dataflow operators
(paper §3.6). Narrow ops here; wide (shuffle-backed) ops in shuffle.py.

User functions are jnp-traceable row functions, vmapped over the block. A
negative/boolean mask carries filter results (fixed shapes — no dynamic
compaction on device).
"""
from __future__ import annotations

import weakref
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.partition import Block

# jit cache keyed on the user fn object: a dataframe op's fn is created once
# at graph-build time, so re-evaluating the same node hits the trace cache
# (compute-heavy row fns — e.g. Minebench's SHA-256 — would otherwise run
# eagerly op-by-op).
_VMAP_JIT: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _vmapped(fn: Callable) -> Callable:
    try:
        j = _VMAP_JIT.get(fn)
    except TypeError:  # unhashable/unweakrefable fn
        return jax.vmap(fn)
    if j is None:
        j = jax.jit(jax.vmap(fn))
        try:
            _VMAP_JIT[fn] = j
        except TypeError:
            pass
    return j


# ---------------------------------------------------------------------------
# narrow ops
# ---------------------------------------------------------------------------


def map_block(b: Block, fn: Callable) -> Block:
    return Block(_vmapped(fn)(b.data), b.valid)


def map_partitions_block(b: Block, fn: Callable) -> Block:
    """fn operates on the whole block data (arrays with leading dim)."""
    out = fn(b.data)
    return Block(out, b.valid)


def filter_block(b: Block, pred: Callable) -> Block:
    keep = _vmapped(pred)(b.data)
    return Block(b.data, b.valid & keep.astype(bool))


def flatmap_block(b: Block, fn: Callable, fanout: int) -> Block:
    """fn: row → (pytree with leading dim = fanout, valid_mask[fanout])."""

    def one(row):
        out, m = fn(row)
        return out, m

    outs, masks = _vmapped(one)(b.data)  # leaves (N, F, …), masks (N, F)
    n = b.valid.shape[0]

    def flat(x):
        return x.reshape(n * fanout, *x.shape[2:])

    data = jax.tree.map(flat, outs)
    valid = (masks & b.valid[:, None]).reshape(n * fanout)
    return Block(data, valid)


def key_by_block(b: Block, fn: Callable) -> Block:
    keys = _vmapped(fn)(b.data)
    return Block({"key": keys, "value": b.data}, b.valid)


def map_values_block(b: Block, fn: Callable) -> Block:
    return Block(
        {"key": b.data["key"], "value": _vmapped(fn)(b.data["value"])}, b.valid
    )


def keys_block(b: Block) -> Block:
    return Block(b.data["key"], b.valid)


def values_block(b: Block) -> Block:
    return Block(b.data["value"], b.valid)


def sample_block(b: Block, frac: float, seed: int) -> Block:
    u = jax.random.uniform(jax.random.PRNGKey(seed + 13 * b.capacity), (b.capacity,))
    return Block(b.data, b.valid & (u < frac))


# ---------------------------------------------------------------------------
# fusable kernels: Block → Block closures over one narrow op — the unit the
# DAG planner composes into FusedStages (DESIGN.md §5). Each is jit-safe:
# fixed shapes in → fixed shapes out, no host callbacks, so a chain of them
# traces into a single XLA computation. mapPartitions is deliberately absent —
# its user fn takes raw block data and may do host-side work.
# ---------------------------------------------------------------------------


def map_kernel(fn: Callable) -> Callable:
    return lambda b: map_block(b, fn)


def filter_kernel(pred: Callable) -> Callable:
    return lambda b: filter_block(b, pred)


def flatmap_kernel(fn: Callable, fanout: int) -> Callable:
    return lambda b: flatmap_block(b, fn, fanout)


def key_by_kernel(fn: Callable) -> Callable:
    return lambda b: key_by_block(b, fn)


def map_values_kernel(fn: Callable) -> Callable:
    return lambda b: map_values_block(b, fn)


def sample_kernel(frac: float, seed: int) -> Callable:
    return lambda b: sample_block(b, frac, seed)


# ---------------------------------------------------------------------------
# reductions (log-depth pairwise fold — TPU-friendly, general binary fn)
# ---------------------------------------------------------------------------


def pairwise_reduce(data, valid, fn, identity):
    """Reduce rows with an associative jnp-vectorizable binary fn in log
    depth. ``identity`` is a row pytree substituted for masked-out rows.
    """
    n = jax.tree.leaves(data)[0].shape[0]
    m = 1
    while m < n:
        m *= 2

    def prep(x, i):
        i = jnp.asarray(i, x.dtype)
        x = jnp.where(valid.reshape((-1,) + (1,) * (x.ndim - 1)), x, i)
        if m > n:
            x = jnp.concatenate([x, jnp.broadcast_to(i, (m - n, *x.shape[1:]))], axis=0)
        return x

    data = jax.tree.map(prep, data, identity)
    k = m
    while k > 1:
        k //= 2
        lo = jax.tree.map(lambda x: x[:k], data)
        hi = jax.tree.map(lambda x: x[k : 2 * k], data)
        data = fn(lo, hi)
    return jax.tree.map(lambda x: x[0], data)


def count_block(b: Block):
    return jnp.sum(b.valid.astype(jnp.int64 if jax.config.jax_enable_x64 else jnp.int32))


NAMED_IDENTITIES = {
    "sum": 0,
    "max": -jnp.inf,
    "min": jnp.inf,
}

NAMED_FNS = {
    "sum": lambda a, b: jax.tree.map(jnp.add, a, b),
    "max": lambda a, b: jax.tree.map(jnp.maximum, a, b),
    "min": lambda a, b: jax.tree.map(jnp.minimum, a, b),
}
