"""IProperties — the ignis.* configuration system (paper §3.4, Fig. 6).

Dict-like with defaults, validation and prefix views. Property keys follow
the paper's naming (``ignis.executor.instances`` …) adapted to the TPU
runtime (executors = mesh devices).

Since PR 9 every property lives in a typed registry (``PropSpec``: name,
type, default, validator, docstring — docs/properties.md). The runtime
behaviour is deliberately forgiving, matching the paper's
properties-file model:

* setting an **unknown** ``ignis.*`` key warns once per key (a misspelt
  scheduler knob should be loud, but third-party/app-private keys under
  other prefixes pass silently);
* setting an **invalid** value warns but stores it — consumers read with
  the typed getters whose defaults absorb garbage, and subsystems that
  must reject a value do so at use time (e.g. the streaming admission
  controller on an unknown shed policy), never at assignment time;
* ``validate()`` reports every current violation for tools and tests,
  and ``tools/check_props.py`` gates that each registered property is
  documented.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass(frozen=True)
class PropSpec:
    """One registered ``ignis.*`` property: its canonical string default,
    declared type (for docs/tools — storage stays stringly, as in the
    paper's properties files), optional validator (value → error string or
    None), and a docstring surfaced by ``describe()`` and docs tooling."""

    name: str
    type: str  # int | float | bool | str | bytes | enum
    default: str
    doc: str
    validator: Optional[Callable[[str], Optional[str]]] = None
    choices: tuple = field(default=())

    def check(self, value: str) -> Optional[str]:
        """Error message for an invalid ``value``, else None."""
        v = str(value).strip()
        if self.choices and v.lower() not in self.choices:
            return f"{self.name}={value!r}: expected one of {self.choices}"
        if self.type == "int":
            try:
                int(v)
            except ValueError:
                return f"{self.name}={value!r}: expected an integer"
        elif self.type == "float":
            try:
                float(v)
            except ValueError:
                return f"{self.name}={value!r}: expected a number"
        elif self.type == "bool":
            if v.lower() not in ("1", "0", "true", "false", "yes", "no",
                                 "on", "off"):
                return f"{self.name}={value!r}: expected a boolean"
        elif self.type == "bytes":
            s = v.upper()
            for suf in ("GB", "MB", "KB", "B"):
                if s.endswith(suf):
                    s = s[: -len(suf)]
                    break
            try:
                float(s)
            except ValueError:
                return f"{self.name}={value!r}: expected a size (e.g. 4GB)"
        if self.validator is not None:
            return self.validator(v)
        return None


REGISTRY: dict[str, PropSpec] = {}


def register(name: str, type: str, default: str, doc: str,
             validator=None, choices: tuple = ()) -> PropSpec:
    spec = PropSpec(name, type, default, doc, validator,
                    tuple(c.lower() for c in choices))
    REGISTRY[name] = spec
    return spec


def _auto_or_float(v: str) -> Optional[str]:
    if v.lower() == "auto":
        return None
    try:
        float(v)
    except ValueError:
        return f"expected a number of seconds or 'auto', got {v!r}"
    return None


# -- cluster / executor shape (paper §3.4) ----------------------------------
register("ignis.executor.image", "str", "ignishpc/jax",
         "Container image name (cosmetic under the TPU runtime).")
register("ignis.executor.instances", "int", "1",
         "Devices along the data axis of the cluster mesh.")
register("ignis.executor.cores", "int", "1",
         "Model-axis devices per executor.")
register("ignis.executor.memory", "bytes", "16GB",
         "Per-executor memory budget for the capacity model.")
register("ignis.driver.memory", "bytes", "4GB",
         "Driver process memory budget.")
register("ignis.partition.type", "str", "memory",
         "Partition storage tier (paper §3.8).",
         choices=("memory", "rawmemory", "disk"))
register("ignis.partition.compression", "int", "6",
         "zlib level for the disk partition tier.")
register("ignis.partitions.per.executor", "int", "1",
         "Default partition count multiplier per executor.")
register("ignis.scheduler", "str", "local",
         "Job scheduler backend (launch/submit.py).",
         choices=("local", "slurm-sim"))
register("ignis.mode", "str", "ignis",
         "Execution mode: ignis, or spark for the round-trip baseline.",
         choices=("ignis", "spark"))
register("ignis.transport.compression", "int", "0",
         "zlib level for inter-process transport framing.")

# -- shuffle / join (DESIGN.md §6) ------------------------------------------
register("ignis.shuffle.capacity.factor", "float", "2.0",
         "Initial fan-out guess multiplier for the adaptive shuffle.")
register("ignis.shuffle.plan.cache.size", "int", "64",
         "Compiled wide-stage plan LRU entries.")
register("ignis.shuffle.memory.headroom", "float", "1.25",
         "Capacity-memory fit margin before overflow retry.")
register("ignis.join.max.matches", "int", "8",
         "Per-key match cap for the bounded join kernel.")

# -- fault tolerance (docs/fault_tolerance.md) ------------------------------
register("ignis.task.attempts", "int", "2",
         "Total scheduler attempts per job task (1 = never retry).")
register("ignis.task.speculative", "bool", "false",
         "Duplicate straggling gang tasks after the speculative timeout.")
register("ignis.task.speculative.timeout", "str", "30",
         "Straggler deadline in seconds, or 'auto' to derive it from the "
         "cost model's observed task history (docs/profiling.md §auto).",
         validator=_auto_or_float)
register("ignis.task.speculative.factor", "float", "3.0",
         "With timeout=auto: deadline = factor x the typical observed "
         "duration of tasks with the same signature.")

# -- stage fusion / cost model (DESIGN.md §5, §13) --------------------------
register("ignis.fusion.enabled", "bool", "true",
         "Fuse maximal narrow chains into compiled stages.")
register("ignis.fusion.mode", "str", "static",
         "Fusion boundary policy: static fuses every eligible chain; cost "
         "asks the cost model whether compiling a fused stage will pay for "
         "itself (docs/profiling.md §fusion).",
         choices=("static", "cost"))
register("ignis.fusion.plan.cache.size", "int", "128",
         "Compiled fused-stage plan LRU entries.")

# -- kernel tier (docs/kernels.md) ------------------------------------------
register("ignis.kernels", "str", "auto",
         "Pallas kernel tier mode: auto picks compiled kernels where the "
         "backend supports them; interpret forces CI conformance mode.",
         choices=("auto", "on", "interpret", "off"))
register("ignis.kernels.blocks", "str", "128,256,512",
         "Autotune sweep block-size candidates (comma separated).")
register("ignis.kernels.tune.cache.size", "int", "512",
         "Autotune memo LRU entries.")

# -- elastic mesh (docs/elasticity.md) --------------------------------------
register("ignis.elastic.enabled", "bool", "false",
         "Let ElasticPolicy.poll()/on_admit() resize the worker mesh; off, "
         "the policy only reports what it WOULD do.")
register("ignis.elastic.min.executors", "int", "1",
         "Autoscaling floor: the policy never shrinks the world below this.")
register("ignis.elastic.max.executors", "int", "0",
         "Autoscaling ceiling (0 = every visible device).")
register("ignis.elastic.step", "int", "1",
         "Maximum ranks added/retired per policy decision.")
register("ignis.elastic.queue.per.executor", "int", "4",
         "Target scheduler queue depth per executor: desired world = "
         "ceil(queue / this), clamped to [min, max].")
register("ignis.elastic.cooldown.polls", "int", "1",
         "Consecutive same-direction polls required before the policy acts "
         "(deterministic hysteresis — no wall-clock cooldowns).")

# -- streaming / serving (docs/streaming.md) --------------------------------
register("ignis.stream.batch.rows", "int", "256",
         "Micro-batch size in rows.")
register("ignis.stream.max.inflight", "int", "8",
         "Global in-flight micro-batch cap.")
register("ignis.stream.tenant.quota", "int", "4",
         "Per-tenant in-flight micro-batch quota.")
register("ignis.stream.queue.depth", "int", "16",
         "Admission waiter queue depth.")
register("ignis.stream.shed.policy", "str", "block",
         "Overload policy: block applies backpressure (the only "
         "exactly-once-deterministic choice); shed drops and counts.")
register("ignis.stream.checkpoint.interval", "int", "0",
         "Micro-batches between offset/state checkpoints (0 = off).")
register("ignis.serve.queue.depth", "int", "64",
         "Serve front-door request queue bound.")

#: canonical {name: default} view of the registry — the pre-PR-9 module
#: constant, kept because properties files and tests seed from it
DEFAULTS = {name: spec.default for name, spec in REGISTRY.items()}

_warned_keys: set[str] = set()


def _warn_once(key: str, msg: str):
    if key in _warned_keys:
        return
    _warned_keys.add(key)
    warnings.warn(msg, stacklevel=3)


class IProperties:
    def __init__(self, base: dict | None = None):
        self._kv = dict(DEFAULTS)
        if base:
            for k, v in base.items():
                self[k] = v

    def __getitem__(self, k):
        return self._kv[k]

    def __setitem__(self, k, v):
        k, v = str(k), str(v)
        spec = REGISTRY.get(k)
        if spec is None:
            if k.startswith("ignis."):
                _warn_once(k, f"unknown property {k!r} — not in the ignis.* "
                              f"registry (docs/properties.md); stored as-is")
        else:
            err = spec.check(v)
            if err is not None:
                # stored anyway: typed getters absorb garbage via their
                # defaults, and use-time rejection stays with the subsystem
                _warn_once(f"{k}={v}", f"invalid property value: {err}")
        self._kv[k] = v

    def __contains__(self, k):
        return k in self._kv

    def get(self, k, default=None):
        return self._kv.get(k, default)

    def get_int(self, k, default=0):
        try:
            return int(self._kv.get(k, default))
        except ValueError:
            return default

    def get_bool(self, k, default=False):
        v = self._kv.get(k)
        if v is None:
            return default
        return str(v).strip().lower() in ("1", "true", "yes", "on")

    def get_float(self, k, default=0.0):
        try:
            return float(self._kv.get(k, default))
        except ValueError:
            return default

    def get_bytes(self, k, default="0B"):
        s = self._kv.get(k, default).upper().strip()
        for suf, mul in (("GB", 2**30), ("MB", 2**20), ("KB", 2**10), ("B", 1)):
            if s.endswith(suf):
                return int(float(s[: -len(suf)]) * mul)
        return int(float(s))

    def view(self, prefix: str) -> dict:
        return {k: v for k, v in self._kv.items() if k.startswith(prefix)}

    def copy(self) -> "IProperties":
        c = IProperties.__new__(IProperties)
        c._kv = dict(self._kv)
        return c

    def validate(self) -> list[str]:
        """Every current violation: invalid values of registered props and
        unknown ``ignis.*`` keys. Reporting, not enforcement — see module
        docstring for why assignment never raises."""
        problems = []
        for k, v in sorted(self._kv.items()):
            spec = REGISTRY.get(k)
            if spec is None:
                if k.startswith("ignis."):
                    problems.append(f"unknown property {k!r}")
                continue
            err = spec.check(v)
            if err is not None:
                problems.append(err)
        return problems

    def describe(self, k: str) -> Optional[PropSpec]:
        """The registry spec for ``k`` (None when unregistered)."""
        return REGISTRY.get(k)

    def __repr__(self):
        return f"IProperties({len(self._kv)} keys)"
