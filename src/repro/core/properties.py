"""IProperties — the ignis.* configuration system (paper §3.4, Fig. 6).

Dict-like with defaults, validation and prefix views. Property keys follow
the paper's naming (``ignis.executor.instances`` …) adapted to the TPU
runtime (executors = mesh devices).
"""
from __future__ import annotations

DEFAULTS = {
    "ignis.executor.image": "ignishpc/jax",
    "ignis.executor.instances": "1",  # devices along the data axis
    "ignis.executor.cores": "1",  # model-axis devices per executor
    "ignis.executor.memory": "16GB",
    "ignis.partition.type": "memory",  # memory | rawmemory | disk (paper §3.8)
    "ignis.partition.compression": "6",
    "ignis.partitions.per.executor": "1",
    "ignis.driver.memory": "4GB",
    "ignis.scheduler": "local",  # local | slurm-sim (launch/submit.py)
    "ignis.mode": "ignis",  # ignis | spark  (spark = round-trip baseline)
    "ignis.shuffle.capacity.factor": "2.0",
    "ignis.shuffle.plan.cache.size": "64",  # compiled wide-stage LRU entries
    "ignis.shuffle.memory.headroom": "1.25",  # capacity-memory fit margin
    "ignis.join.max.matches": "8",
    "ignis.transport.compression": "0",
    # fault tolerance (docs/fault_tolerance.md): total scheduler attempts
    # per job task (1 = never retry), and the gang-task straggler policy
    # (speculative duplicate after the timeout, DagEngine.evaluate_speculative)
    "ignis.task.attempts": "2",
    "ignis.task.speculative": "false",
    "ignis.task.speculative.timeout": "30",
    "ignis.fusion.enabled": "true",  # stage compilation (DESIGN.md §5)
    "ignis.fusion.plan.cache.size": "128",  # compiled-plan LRU entries
    # kernel tier (docs/kernels.md): auto = compiled Pallas where the
    # backend supports it, bit-identical plain-JAX fallback elsewhere;
    # on / interpret / off force the choice (interpret = CI conformance)
    "ignis.kernels": "auto",
    "ignis.kernels.blocks": "128,256,512",  # autotune sweep candidates
    "ignis.kernels.tune.cache.size": "512",  # autotune memo LRU entries
    # streaming / multi-tenant serving (docs/streaming.md): micro-batch
    # size, admission bounds (global in-flight cap, per-tenant quota,
    # waiter queue depth), overload policy (block = backpressure, the only
    # exactly-once-deterministic choice; shed = drop-and-count), commit
    # interval between offset/state checkpoints (0 = no checkpointing),
    # and the serve front door's request-queue bound
    "ignis.stream.batch.rows": "256",
    "ignis.stream.max.inflight": "8",
    "ignis.stream.tenant.quota": "4",
    "ignis.stream.queue.depth": "16",
    "ignis.stream.shed.policy": "block",
    "ignis.stream.checkpoint.interval": "0",
    "ignis.serve.queue.depth": "64",
}


class IProperties:
    def __init__(self, base: dict | None = None):
        self._kv = dict(DEFAULTS)
        if base:
            self._kv.update(base)

    def __getitem__(self, k):
        return self._kv[k]

    def __setitem__(self, k, v):
        self._kv[str(k)] = str(v)

    def __contains__(self, k):
        return k in self._kv

    def get(self, k, default=None):
        return self._kv.get(k, default)

    def get_int(self, k, default=0):
        try:
            return int(self._kv.get(k, default))
        except ValueError:
            return default

    def get_bool(self, k, default=False):
        v = self._kv.get(k)
        if v is None:
            return default
        return str(v).strip().lower() in ("1", "true", "yes", "on")

    def get_float(self, k, default=0.0):
        try:
            return float(self._kv.get(k, default))
        except ValueError:
            return default

    def get_bytes(self, k, default="0B"):
        s = self._kv.get(k, default).upper().strip()
        for suf, mul in (("GB", 2**30), ("MB", 2**20), ("KB", 2**10), ("B", 1)):
            if s.endswith(suf):
                return int(float(s[: -len(suf)]) * mul)
        return int(float(s))

    def view(self, prefix: str) -> dict:
        return {k: v for k, v in self._kv.items() if k.startswith(prefix)}

    def copy(self) -> "IProperties":
        return IProperties(dict(self._kv))

    def __repr__(self):
        return f"IProperties({len(self._kv)} keys)"
