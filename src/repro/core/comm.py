"""The "MPI" layer: collective primitives over shard_map (paper §2.2, §3.6).

Every routine takes an IContext (the communicator) and operates on arrays
sharded along the context axis. These are the primitives the executor module
builds the dataflow operators out of, and the ones native SPMD apps call —
the analogue of MPICH under both worlds, with jax.lax collectives on
ICI/DCN instead of send/recv on Infiniband.

"Non-blocking" variants are jax's async dispatch itself (every call below
returns before the transfer completes; jax.block_until_ready is MPI_Wait).

Every collective binds to the context's OWN mesh — hand it a group context
(``IContext.split``/``group``, docs/collectives.md) and it runs on the
group's sub-mesh and axis, never touching executors outside the group.
Inputs are placed onto the context's mesh first (a no-op when already
there), so an array produced under one communicator can enter a collective
on another — the device_put IS the inter-group reshard edge.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core.context import IContext


def _smap(ctx: IContext, f, in_specs, out_specs):
    return compat.shard_map(f, mesh=ctx.mesh, in_specs=in_specs, out_specs=out_specs)


def _sharded(ctx):  # leading dim sharded over the context axis
    return P(ctx.axis)


def _placed(ctx: IContext, x, spec=None):
    """Commit ``x`` to the context's mesh (no-op when already resident).
    A shard_map over a group mesh rejects operands committed to a different
    device set; placing first makes every collective group-portable."""
    spec = _sharded(ctx) if spec is None else spec
    return jax.device_put(x, jax.NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# collectives (gather / scatter / bcast / reduce / allreduce / alltoall …)
# ---------------------------------------------------------------------------


def allreduce(ctx: IContext, x, op: str = "sum"):
    """MPI_Allreduce over executor shards: x is axis-sharded on dim 0."""
    red = {"sum": jax.lax.psum, "max": jax.lax.pmax, "min": jax.lax.pmin}[op]

    def f(xs):
        local = {"sum": jnp.sum, "max": jnp.max, "min": jnp.min}[op](xs, axis=0)
        return red(local, ctx.axis)

    return _smap(ctx, f, (_sharded(ctx),), P())(_placed(ctx, x))


def reduce(ctx: IContext, x, op: str = "sum"):
    """MPI_Reduce (root=driver): same wire pattern as allreduce on TPU."""
    return allreduce(ctx, x, op)


def bcast(ctx: IContext, x):
    """MPI_Bcast: replicate a driver value across executors."""
    return _placed(ctx, x, P())


def gather(ctx: IContext, x):
    """MPI_Allgather: axis-sharded (n, …) → replicated (n, …)."""

    def f(xs):
        return jax.lax.all_gather(xs, ctx.axis, tiled=True)

    return _smap(ctx, f, (_sharded(ctx),), P())(_placed(ctx, x))


def scatter(ctx: IContext, x):
    """MPI_Scatter: replicated (n, …) → axis-sharded (n, …)."""
    return _placed(ctx, x)


def alltoall(ctx: IContext, x):
    """MPI_Alltoall. x: (p·k, …) axis-sharded on dim 0; shard i holds the
    (k, …) rows destined for each peer in order. Returns same shape with
    rows regrouped by source."""
    p = ctx.executors
    n = x.shape[0]
    if n % p or (n // p) % p:
        # a silent reshape here would regroup rows to the WRONG peers
        raise ValueError(
            f"alltoall needs the local row count divisible by the communicator "
            f"size: total {n} rows over {p} executors gives "
            f"{n / p:g} local rows, which must be a multiple of {p}")

    def f(xs):  # xs local: (k_total, …) with k_total = n/p — regroup to (p, k)
        k = xs.shape[0] // p
        y = xs.reshape(p, k, *xs.shape[1:])
        y = jax.lax.all_to_all(y, ctx.axis, split_axis=0, concat_axis=0, tiled=False)
        return y.reshape(p * k, *xs.shape[1:])

    return _smap(ctx, f, (_sharded(ctx),), _sharded(ctx))(_placed(ctx, x))


def ppermute(ctx: IContext, x, shift: int = 1):
    """MPI_Sendrecv ring: shard i's rows go to shard (i+shift) % p."""
    p = ctx.executors
    perm = [(i, (i + shift) % p) for i in range(p)]

    def f(xs):
        return jax.lax.ppermute(xs, ctx.axis, perm)

    return _smap(ctx, f, (_sharded(ctx),), _sharded(ctx))(_placed(ctx, x))


def barrier(ctx: IContext):
    """MPI_Barrier: a zero-byte allreduce, blocked on."""
    z = scatter(ctx, jnp.zeros((ctx.executors,), jnp.int32))
    jax.block_until_ready(allreduce(ctx, z))


def exscan(ctx: IContext, x, op: str = "sum"):
    """MPI_Exscan (exclusive prefix over executor ranks) of per-shard scalars.

    x: (p,) axis-sharded (one scalar per executor)."""

    def f(xs):
        all_ = jax.lax.all_gather(xs, ctx.axis, tiled=True)  # (p,)
        idx = jax.lax.axis_index(ctx.axis)
        mask = jnp.arange(all_.shape[0]) < idx
        return jnp.sum(all_ * mask, axis=0, keepdims=True)

    return _smap(ctx, f, (_sharded(ctx),), _sharded(ctx))(_placed(ctx, x))


# ---------------------------------------------------------------------------
# helpers for data placement
# ---------------------------------------------------------------------------


def shard_rows(ctx: IContext, x):
    """Place an (N, …) array sharded by rows over the executor axis."""
    return _placed(ctx, x)


def replicate(ctx: IContext, x):
    return _placed(ctx, x, P())
