"""The "MPI" layer: nonblocking, persistent collectives over shard_map
(paper §2.2, §3.6; UCC model — SNIPPETS.md §3, docs/collectives.md).

Every routine takes an IContext (the communicator) and operates on arrays
sharded along the context axis. These are the primitives the executor module
builds the dataflow operators out of, and the ones native SPMD apps call —
the analogue of MPICH under both worlds, with jax.lax collectives on
ICI/DCN instead of send/recv on Infiniband.

Three call shapes per collective, mirroring UCC's design goals:

* **blocking** — ``allreduce(ctx, x)``: dispatch + ``wait()``; the result is
  ready when the call returns.
* **nonblocking** — ``iallreduce(ctx, x) -> CollHandle``: the MPI_Iallreduce
  shape. The collective is dispatched (jax async dispatch = the wire
  transfer in flight) and the handle is the future; ``handle.wait()`` is
  MPI_Wait, ``handle.test()`` is MPI_Test. The job scheduler and the DAG
  engine await handles instead of blocking a worker thread, so independent
  branches overlap compute with communication (core/job.py, core/dag.py).
* **persistent** — ``persistent(ctx, "allreduce", x) -> CollPlan``: the
  MPI_*_init / MPI_Start shape (UCC: "init once and invoke multiple
  times"). The collective's shard_map is traced and jit-compiled ONCE per
  (collective, static args, operand avals, communicator mesh) and cached in
  a process-wide LRU (the collective analogue of the wide-plan cache,
  DESIGN.md §6/§10); ``plan.start(x)`` re-invokes the compiled plan with no
  Python-side retracing. The i*/blocking entry points route through the
  same cache, so every repeated collective is init-once/invoke-many
  automatically — hit/miss telemetry surfaces in ``worker.shuffle_stats()``
  and the scheduler stats (``comm_stats()`` is the raw view).

Every collective binds to the context's OWN mesh — hand it a group context
(``IContext.split``/``group``, docs/collectives.md) and it runs on the
group's sub-mesh and axis, never touching executors outside the group.
Inputs are placed onto the context's mesh first (``IContext.place``, a
no-op when already there), so an array produced under one communicator can
enter a collective on another — the device_put IS the inter-group reshard
edge. Handles are group-portable the same way: a handle started on one
communicator may be awaited from a thread bound to another (the result is
committed to the issuing group's mesh; consumers reshard on ingress).

Fault injection (docs/fault_tolerance.md): ``handle.wait()`` of a still-
pending handle passes the ``comm.handle`` site, so chaos plans can kill a
collective between dispatch and completion; the scheduler retries the
owning task through the job's shared memo (core/job.py).
"""
from __future__ import annotations

import contextlib
import itertools
import threading
from collections import OrderedDict
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat, faults
from repro.core.context import IContext
from repro.core.metrics import Counters

_handle_ids = itertools.count()


# ---------------------------------------------------------------------------
# nonblocking handles (MPI_Request / ucc_coll_req)
# ---------------------------------------------------------------------------


class CollHandle:
    """Future for a dispatched collective.

    The operation is already in flight when the handle exists (jax async
    dispatch); ``wait()`` blocks until the device result is ready and
    returns it. ``wait()`` is idempotent — a second wait returns the same
    completed value without re-entering the fault site (MPI semantics:
    waiting on an inactive request is a no-op). ``test()`` is the
    nonblocking completion probe.

    Handles created inside a job task are tracked (``track()``); any handle
    the task never awaited is drained by the scheduler at task end, so a
    leaked in-flight collective can neither outlive its job silently nor
    escape fault accounting (the never-awaited-at-job-end chaos rule).

    Completion is thread-safe: handles are group-portable across threads
    (module docstring), so ``wait``/``test``/``chain`` may race — a
    per-handle lock makes exactly one thread finalise (apply ``_transform``
    and publish the value); every other waiter returns the same completed
    value, never a double-transformed one.
    """

    __slots__ = ("coll", "ctx", "id", "_value", "_transform", "_done", "_scope",
                 "_lock")

    def __init__(self, coll: str, ctx, value, transform: Optional[Callable] = None):
        self.coll = coll
        self.ctx = ctx  # the issuing communicator (group-portable: carried here)
        self.id = next(_handle_ids)
        self._value = value
        self._transform = transform
        self._done = False
        self._lock = threading.Lock()
        scope = getattr(_scopes, "pending", None)
        self._scope = scope
        if scope is not None:
            scope.append(self)
        _engine.stats_bump("handles_created")

    # -- introspection ---------------------------------------------------
    @property
    def pending(self) -> bool:
        return not self._done

    def done(self) -> bool:
        """MPI_Test's completion half: True once the device result is ready
        (never blocks)."""
        if self._done:
            return True
        return all(
            getattr(l, "is_ready", lambda: True)()
            for l in jax.tree_util.tree_leaves(self._value)
        )

    def test(self):
        """MPI_Test: ``(True, value)`` when complete, ``(False, None)``
        otherwise. Completion via test() finalises the handle like wait()."""
        if not self._done and not self.done():
            return False, None
        return True, self.wait()

    # -- completion ------------------------------------------------------
    def wait(self, _phase: str = "wait"):
        """MPI_Wait: block until the collective completes, return its value.
        Idempotent after completion. The ``comm.handle`` fault site fires
        here (phase="wait", or "flush" for the scheduler's end-of-task
        drain) while the handle is still pending — an injected failure
        models losing the transfer mid-flight, and leaves the handle
        pending so a scheduler retry re-issues the collective."""
        if self._done:  # fast path: _done is published AFTER _value (below)
            return self._value
        with self._lock:
            if self._done:  # another thread finalised while we waited
                return self._value
            faults.check("comm.handle", coll=self.coll, phase=_phase)
            value = jax.block_until_ready(self._value)
            if self._transform is not None:
                value = self._transform(value)
            self._value = value
            self._transform = None
            self._done = True  # publish: value must be stored first
            scope = self._scope
            if scope is not None:
                self._scope = None
                try:
                    scope.remove(self)
                except ValueError:
                    pass
        _engine.stats_bump("handles_awaited")
        return self._value

    def chain(self, fn: Callable) -> "CollHandle":
        """Append a host-side transform applied to the awaited value (used
        by the driver layer to adapt app results without forcing a wait)."""
        with self._lock:
            if self._done:
                self._value = fn(self._value)
                return self
            prev = self._transform
            self._transform = fn if prev is None else (lambda v: fn(prev(v)))
            return self

    def __repr__(self):
        state = "done" if self._done else "pending"
        return f"<CollHandle #{self.id} {self.coll} [{state}]>"


def is_handle(x) -> bool:
    return isinstance(x, CollHandle)


def wait_all(handles) -> list:
    """MPI_Waitall over an iterable of handles (completion in given order)."""
    return [h.wait() for h in handles]


# -- task-scoped handle tracking (the never-awaited-at-job-end rule) --------

_scopes = threading.local()


@contextlib.contextmanager
def track():
    """Collect every handle created on this thread inside the block. The job
    scheduler wraps each task attempt in one ``track()`` scope and drains
    whatever is still pending when the task function returns
    (core/job.py)."""
    prev = getattr(_scopes, "pending", None)
    cur: list[CollHandle] = []
    _scopes.pending = cur
    try:
        yield cur
    finally:
        _scopes.pending = prev


# ---------------------------------------------------------------------------
# persistent-plan engine (init once / invoke many — UCC design goal)
# ---------------------------------------------------------------------------


class CommEngine:
    """Process-wide persistent collective plans + telemetry.

    One compiled plan per (collective, static args, operand avals, mesh) in
    an LRU — keyed like the shuffle engine's wide-plan cache (DESIGN.md §6)
    so a plan traced for a p=4 group never serves the p=8 world. The engine
    is process-wide (not per-worker) because a collective's identity is its
    communicator, not the worker that issued it: two workers sharing one
    mesh share plans, exactly as two MPI libraries sharing one fabric
    would share UCC teams."""

    def __init__(self, plan_cache_size: int = 128):
        self.plan_cache_size = plan_cache_size
        self._plans: "OrderedDict[tuple, Callable]" = OrderedDict()
        self._building: dict = {}  # key -> Event: trace+jit in flight
        self._lock = threading.Lock()
        self.stats = Counters("coll", {
            "coll_calls": 0,          # collectives dispatched (any shape)
            "coll_plan_hits": 0,      # persistent-plan cache hits
            "coll_plan_misses": 0,    # traces+compiles (init-once events)
            "coll_plan_evictions": 0,
            "handles_created": 0,
            "handles_awaited": 0,
        })

    def stats_bump(self, key: str, n: int = 1):
        with self._lock:
            self.stats[key] += n

    def plan(self, key: tuple, builder: Callable[[], Callable]) -> Callable:
        """The compiled plan for ``key``, building (trace + jit) on miss.

        Exactly one thread builds a given key: a concurrent miss parks on
        the builder's in-flight event and re-reads the cache, so two
        threads racing the same collective cost one trace total and
        ``coll_plan_misses`` counts distinct init-once events (the
        ``recompiles=0`` gate in bench_collectives relies on this). The
        build itself runs outside the lock — tracing can re-enter plan()
        (nested collectives) and must not self-deadlock."""
        while True:
            with self._lock:
                fn = self._plans.get(key)
                if fn is not None:
                    self._plans.move_to_end(key)
                    self.stats["coll_plan_hits"] += 1
                    return fn
                building = self._building.get(key)
                if building is None:
                    self._building[key] = building = threading.Event()
                    self.stats["coll_plan_misses"] += 1
                    break
            building.wait()  # builder finished (or failed) → re-read cache
        try:
            fn = jax.jit(builder())
        except BaseException:
            # failed build: unpark waiters with the cache still empty so
            # one of them (or a retry) becomes the next builder
            with self._lock:
                self._building.pop(key, None)
            building.set()
            raise
        with self._lock:
            self._plans[key] = fn
            self._building.pop(key, None)
            while len(self._plans) > self.plan_cache_size:
                self._plans.popitem(last=False)
                self.stats["coll_plan_evictions"] += 1
        building.set()
        return fn

    def clear(self):
        """Drop every compiled plan (benchmarks use this to measure the
        init-once cost; correctness never depends on cache state)."""
        with self._lock:
            self._plans.clear()


_engine = CommEngine()


def engine() -> CommEngine:
    return _engine


def comm_stats() -> dict:
    """Snapshot of the collective engine telemetry (also merged into
    ``worker.shuffle_stats()``)."""
    with _engine._lock:
        return dict(_engine.stats)


def _aval(x) -> tuple:
    return tuple(
        (tuple(l.shape), str(l.dtype)) for l in jax.tree_util.tree_leaves(x)
    )


class CollPlan:
    """An initialised persistent collective (MPI_Allreduce_init analogue):
    ``start()`` dispatches one invocation and returns its ``CollHandle``
    (MPI_Start); calling the plan is the blocking facade. The compiled
    kernel is shared through the process-wide plan cache, so equivalent
    plans (same collective, statics, avals, mesh) cost one trace total."""

    __slots__ = ("coll", "ctx", "_fn", "_transform", "_prep")

    def __init__(self, coll: str, ctx, fn: Callable, transform=None, prep=None):
        self.coll = coll
        self.ctx = ctx
        self._fn = fn
        self._transform = transform
        self._prep = prep  # host-side operand validation/placement

    def start(self, *operands) -> CollHandle:
        """Dispatch one invocation (MPI_Start) → nonblocking handle."""
        if self._prep is not None:
            operands = self._prep(*operands)
        _engine.stats_bump("coll_calls")
        return CollHandle(self.coll, self.ctx, self._fn(*operands),
                          transform=self._transform)

    def __call__(self, *operands):
        return self.start(*operands).wait()


# ---------------------------------------------------------------------------
# collective builders: each returns (traced_fn_builder, transform, prep)
# ---------------------------------------------------------------------------


def _sharded(ctx):  # leading dim sharded over the context axis
    return P(ctx.axis)


def _placed(ctx: IContext, x, spec=None):
    """Commit ``x`` to the context's mesh (no-op when already resident) —
    delegates to ``IContext.place`` so every subsystem shares one reshard
    edge (docs/collectives.md)."""
    return ctx.place(x, spec)


def _smap(ctx: IContext, f, in_specs, out_specs):
    return compat.shard_map(f, mesh=ctx.mesh, in_specs=in_specs, out_specs=out_specs)


_REDUCERS = {"sum": (jnp.sum, jax.lax.psum),
             "max": (jnp.max, jax.lax.pmax),
             "min": (jnp.min, jax.lax.pmin)}


def _plan_for(ctx: IContext, coll: str, statics: tuple, avals: tuple,
              builder: Callable[[], Callable], transform=None) -> CollPlan:
    fn = _engine.plan((coll, statics, avals, ctx.mesh, ctx.axis), builder)
    return CollPlan(coll, ctx, fn, transform=transform,
                    prep=lambda *ops: tuple(_placed(ctx, o) for o in ops))


def _allreduce_plan(ctx: IContext, x, op: str) -> CollPlan:
    if op not in _REDUCERS:
        raise ValueError(f"allreduce op must be one of {sorted(_REDUCERS)}, got {op!r}")
    local, wire = _REDUCERS[op]

    def builder():
        def f(xs):
            return wire(local(xs, axis=0), ctx.axis)

        return _smap(ctx, f, (_sharded(ctx),), P())

    return _plan_for(ctx, "allreduce", (op,), _aval(x), builder)


def _gather_plan(ctx: IContext, x) -> CollPlan:
    def builder():
        def f(xs):
            return jax.lax.all_gather(xs, ctx.axis, tiled=True)

        return _smap(ctx, f, (_sharded(ctx),), P())

    return _plan_for(ctx, "gather", (), _aval(x), builder)


def _alltoall_check(ctx: IContext, x):
    p = ctx.executors
    n = x.shape[0]
    if n % p or (n // p) % p:
        # a silent reshape here would regroup rows to the WRONG peers
        raise ValueError(
            f"alltoall needs the local row count divisible by the communicator "
            f"size: total {n} rows over {p} executors gives "
            f"{n / p:g} local rows, which must be a multiple of {p}")


def _alltoall_plan(ctx: IContext, x) -> CollPlan:
    """MPI_Alltoall. x: (p·k, …) axis-sharded on dim 0; shard i holds the
    (k, …) rows destined for each peer in order. Returns same shape with
    rows regrouped by source."""
    _alltoall_check(ctx, x)  # BEFORE any mesh work: invalid shapes must not fly
    p = ctx.executors

    def builder():
        def f(xs):  # xs local: (k_total, …) with k_total = n/p — regroup to (p, k)
            k = xs.shape[0] // p
            y = xs.reshape(p, k, *xs.shape[1:])
            y = jax.lax.all_to_all(y, ctx.axis, split_axis=0, concat_axis=0,
                                   tiled=False)
            return y.reshape(p * k, *xs.shape[1:])

        return _smap(ctx, f, (_sharded(ctx),), _sharded(ctx))

    return _plan_for(ctx, "alltoall", (), _aval(x), builder)


def _ppermute_plan(ctx: IContext, x, shift: int) -> CollPlan:
    p = ctx.executors
    perm = [(i, (i + shift) % p) for i in range(p)]

    def builder():
        def f(xs):
            return jax.lax.ppermute(xs, ctx.axis, perm)

        return _smap(ctx, f, (_sharded(ctx),), _sharded(ctx))

    return _plan_for(ctx, "ppermute", (shift,), _aval(x), builder)


def _exscan_plan(ctx: IContext, x, op: str) -> CollPlan:
    """MPI_Exscan (exclusive prefix over executor ranks) of per-shard
    scalars. x: (p,) axis-sharded (one scalar per executor)."""
    if op != "sum":
        raise ValueError(f"exscan supports op='sum' only, got {op!r}")

    def builder():
        def f(xs):
            all_ = jax.lax.all_gather(xs, ctx.axis, tiled=True)  # (p,)
            idx = jax.lax.axis_index(ctx.axis)
            mask = jnp.arange(all_.shape[0]) < idx
            return jnp.sum(all_ * mask, axis=0, keepdims=True)

        return _smap(ctx, f, (_sharded(ctx),), _sharded(ctx))

    return _plan_for(ctx, "exscan", (op,), _aval(x), builder)


def _barrier_plan(ctx: IContext) -> CollPlan:
    z = jnp.zeros((ctx.executors,), jnp.int32)

    def builder():
        def f(xs):
            return jax.lax.psum(jnp.sum(xs, axis=0), ctx.axis)

        return _smap(ctx, f, (_sharded(ctx),), P())

    return CollPlan(
        "barrier", ctx,
        lambda: _engine.plan(("barrier", (), _aval(z), ctx.mesh, ctx.axis),
                             builder)(_placed(ctx, z)),
        transform=lambda _v: None)


# ---------------------------------------------------------------------------
# the persistent API (init once / invoke many)
# ---------------------------------------------------------------------------

_PLAN_BUILDERS = {
    "allreduce": lambda ctx, x, op="sum": _allreduce_plan(ctx, x, op),
    "reduce": lambda ctx, x, op="sum": _allreduce_plan(ctx, x, op),
    "gather": lambda ctx, x: _gather_plan(ctx, x),
    "alltoall": lambda ctx, x: _alltoall_plan(ctx, x),
    "ppermute": lambda ctx, x, shift=1: _ppermute_plan(ctx, x, shift),
    "exscan": lambda ctx, x, op="sum": _exscan_plan(ctx, x, op),
}


def persistent(ctx: IContext, coll: str, x=None, **statics) -> CollPlan:
    """Initialise a persistent collective plan for operands shaped like
    ``x`` (MPI_*_init): ``plan.start(x)`` dispatches an invocation,
    ``plan(x)`` is the blocking facade. Plans are cheap to re-create — the
    compiled kernel lives in the process-wide LRU, so init-once is a cache
    property, not an object-lifetime obligation."""
    if coll == "barrier":
        return _barrier_plan(ctx)
    if coll == "bcast":
        return CollPlan("bcast", ctx, lambda v: _placed(ctx, v, P()))
    if coll == "scatter":
        return CollPlan("scatter", ctx, lambda v: _placed(ctx, v))
    builder = _PLAN_BUILDERS.get(coll)
    if builder is None:
        raise ValueError(f"unknown collective {coll!r} "
                         f"(have {sorted(_PLAN_BUILDERS) + ['barrier', 'bcast', 'scatter']})")
    if x is None:
        raise ValueError(f"persistent({coll!r}) needs a prototype operand")
    return builder(ctx, x, **statics)


def persistent_program(tag: str, mesh, statics: tuple,
                       builder: Callable[[], Callable]) -> Callable:
    """Init-once/invoke-many plan for a whole SPMD program (a native app's
    shard_map body): the same LRU + telemetry as single-collective plans,
    keyed by (tag, statics, mesh). Native apps route their hot loops
    through this so repeated calls skip the Python-side re-trace — which
    is what lets a native branch genuinely overlap a dataflow branch in an
    async job (the re-trace is GIL-bound; compiled execution is not)."""
    return _engine.plan(("spmd", tag, statics, mesh), builder)


# ---------------------------------------------------------------------------
# nonblocking collectives (MPI_I* — dispatch now, CollHandle as the future)
# ---------------------------------------------------------------------------


def iallreduce(ctx: IContext, x, op: str = "sum") -> CollHandle:
    """MPI_Iallreduce over executor shards: x is axis-sharded on dim 0."""
    return _allreduce_plan(ctx, x, op).start(x)


def ireduce(ctx: IContext, x, op: str = "sum") -> CollHandle:
    """MPI_Ireduce (root=driver): same wire pattern as allreduce on TPU."""
    return iallreduce(ctx, x, op)


def ibcast(ctx: IContext, x) -> CollHandle:
    """MPI_Ibcast: replicate a driver value across executors."""
    _engine.stats_bump("coll_calls")
    return CollHandle("bcast", ctx, _placed(ctx, x, P()))


def igather(ctx: IContext, x) -> CollHandle:
    """MPI_Iallgather: axis-sharded (n, …) → replicated (n, …)."""
    return _gather_plan(ctx, x).start(x)


def iscatter(ctx: IContext, x) -> CollHandle:
    """MPI_Iscatter: replicated (n, …) → axis-sharded (n, …)."""
    _engine.stats_bump("coll_calls")
    return CollHandle("scatter", ctx, _placed(ctx, x))


def ialltoall(ctx: IContext, x) -> CollHandle:
    """MPI_Ialltoall — shape validation is eager (the ValueError fires at
    dispatch, not at wait: an invalid exchange must never enter flight)."""
    return _alltoall_plan(ctx, x).start(x)


def ippermute(ctx: IContext, x, shift: int = 1) -> CollHandle:
    """MPI_Isend/Irecv ring: shard i's rows go to shard (i+shift) % p."""
    return _ppermute_plan(ctx, x, shift).start(x)


def iexscan(ctx: IContext, x, op: str = "sum") -> CollHandle:
    return _exscan_plan(ctx, x, op).start(x)


def ibarrier(ctx: IContext) -> CollHandle:
    """MPI_Ibarrier: a zero-byte allreduce in flight; wait() returns None."""
    return _barrier_plan(ctx).start()


# ---------------------------------------------------------------------------
# blocking facades (each is literally i*(…).wait())
# ---------------------------------------------------------------------------


def allreduce(ctx: IContext, x, op: str = "sum"):
    """MPI_Allreduce: blocking facade over ``iallreduce``."""
    return iallreduce(ctx, x, op).wait()


def reduce(ctx: IContext, x, op: str = "sum"):
    """MPI_Reduce (root=driver): same wire pattern as allreduce on TPU."""
    return allreduce(ctx, x, op)


def bcast(ctx: IContext, x):
    """MPI_Bcast: replicate a driver value across executors."""
    return ibcast(ctx, x).wait()


def gather(ctx: IContext, x):
    """MPI_Allgather: axis-sharded (n, …) → replicated (n, …)."""
    return igather(ctx, x).wait()


def scatter(ctx: IContext, x):
    """MPI_Scatter: replicated (n, …) → axis-sharded (n, …)."""
    return iscatter(ctx, x).wait()


def alltoall(ctx: IContext, x):
    """MPI_Alltoall (see ``ialltoall`` for the validation contract)."""
    return ialltoall(ctx, x).wait()


def ppermute(ctx: IContext, x, shift: int = 1):
    """MPI_Sendrecv ring: shard i's rows go to shard (i+shift) % p."""
    return ippermute(ctx, x, shift).wait()


def barrier(ctx: IContext):
    """MPI_Barrier: a zero-byte allreduce, blocked on."""
    ibarrier(ctx).wait()


def exscan(ctx: IContext, x, op: str = "sum"):
    """MPI_Exscan (exclusive prefix over executor ranks) of per-shard scalars."""
    return iexscan(ctx, x, op).wait()


# ---------------------------------------------------------------------------
# helpers for data placement
# ---------------------------------------------------------------------------


def shard_rows(ctx: IContext, x):
    """Place an (N, …) array sharded by rows over the executor axis."""
    return _placed(ctx, x)


def replicate(ctx: IContext, x):
    return _placed(ctx, x, P())
