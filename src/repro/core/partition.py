"""Partition model (paper §3.8).

A *Block* is the unit of lineage: a pytree of arrays sharing a leading row
dim (padded to the executor count) plus a validity mask — the fixed-shape
dataflow representation (filters mask, they don't compact; compaction
happens at shuffles and at the driver boundary). One executor holds one
row-shard of every block; "several partitions per executor" (IgnisHPC's fix
over Ignis) = several blocks per PartitionSet.

Row pytrees: scalars, tuples, dicts — anything jax.tree handles. KV rows are
``{"key": k, "value": v}``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Block:
    data: Any  # pytree of arrays, leading dim N (equal across leaves)
    valid: jax.Array  # bool[N]

    @property
    def capacity(self) -> int:
        return jax.tree.leaves(self.data)[0].shape[0]

    def tree(self):
        return {"data": self.data, "valid": self.valid}


def block_aval(block: "Block") -> tuple:
    """Hashable shape/dtype summary of a Block — the cache-key half that
    makes a compiled plan (narrow or wide) reusable only for compatible
    block geometry. Shared by the DAG plan cache, the shuffle engine's
    wide-plan cache, and source-node lineage signatures."""
    leaves, treedef = jax.tree_util.tree_flatten(block.data)
    return (
        treedef,
        tuple((l.shape, str(l.dtype)) for l in leaves),
        block.valid.shape,
    )


def rows_of(data) -> int:
    return jax.tree.leaves(data)[0].shape[0]


def pad_to(n: int, p: int) -> int:
    return ((n + p - 1) // p) * p


def from_host(rows, p: int, put=None) -> Block:
    """Build a Block from host data (list of row pytrees or a pytree of
    stacked arrays). Pads rows to a multiple of p."""
    if isinstance(rows, list):
        data = jax.tree.map(lambda *xs: np.stack(xs), *rows)
    else:
        data = jax.tree.map(np.asarray, rows)
    n = rows_of(data)
    cap = max(pad_to(n, p), p)
    pad = cap - n

    def padleaf(x):
        w = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        return np.pad(x, w)

    data = jax.tree.map(padleaf, data)
    valid = np.arange(cap) < n
    if put is not None:
        data = jax.tree.map(put, data)
        valid = put(valid)
    return Block(jax.tree.map(jnp.asarray, data), jnp.asarray(valid))


def to_host(block: Block):
    """Compact a Block to a host list of valid row pytrees (driver boundary)."""
    valid = np.asarray(jax.device_get(block.valid))
    data = jax.device_get(block.data)
    idx = np.nonzero(valid)[0]
    leaves, treedef = jax.tree.flatten(data)
    out = []
    for i in idx:
        out.append(jax.tree.unflatten(treedef, [np.asarray(l[i]) for l in leaves]))
    return out


def block_devices(block: Block):
    """The device set a Block is committed to (None for host/uncommitted)."""
    leaf = jax.tree.leaves(block.data)[0]
    sharding = getattr(leaf, "sharding", None)
    if sharding is None:
        return None
    try:
        return frozenset(sharding.device_set)
    except Exception:  # pragma: no cover — non-addressable / exotic shardings
        return None


def place_block(block: Block, mesh, axis: str) -> Block:
    """Reshard a Block onto ``mesh`` rows-over-``axis`` — the inter-group
    reshard edge (docs/collectives.md): sub-mesh → sub-mesh device_put, a
    no-op when the block is already resident there."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    rows = NamedSharding(mesh, P(axis))

    def put(x):
        return jax.device_put(x, rows)

    return Block(jax.tree.map(put, block.data), put(block.valid))


def concat_blocks(blocks: list[Block]) -> Block:
    if len(blocks) == 1:
        return blocks[0]
    # blocks produced under different communicators (union of two group
    # results) cannot concatenate directly — commit stragglers to the first
    # block's devices first (jnp.concatenate rejects mixed device sets)
    ref = block_devices(blocks[0])
    if ref is not None and any(block_devices(b) not in (None, ref) for b in blocks[1:]):
        ref_data, ref_valid = blocks[0].data, blocks[0].valid
        blocks = [blocks[0]] + [
            Block(
                jax.tree.map(lambda x, r: jax.device_put(x, r.sharding), b.data, ref_data),
                jax.device_put(b.valid, ref_valid.sharding),
            )
            for b in blocks[1:]
        ]
    data = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *[b.data for b in blocks])
    valid = jnp.concatenate([b.valid for b in blocks], axis=0)
    return Block(data, valid)


def split_block(block: Block, k: int, p: int) -> list[Block]:
    """Split into k blocks with per-block capacity a multiple of p."""
    n = block.capacity
    per = max(pad_to((n + k - 1) // k, p), p)
    out = []
    for i in range(k):
        lo = i * per
        if lo >= n:
            data = jax.tree.map(lambda x: jnp.zeros((p, *x.shape[1:]), x.dtype), block.data)
            out.append(Block(data, jnp.zeros((p,), bool)))
            continue
        hi = min(lo + per, n)
        data = jax.tree.map(lambda x: x[lo:hi], block.data)
        valid = block.valid[lo:hi]
        if hi - lo < per and i < k - 1:
            pass  # middle blocks are full by construction
        out.append(Block(data, valid))
    return out
