"""IDataFrame — the Spark-inspired lazy dataflow API (paper §4, Table 1).

Transformations register TaskNodes (lazy); actions trigger DAG evaluation.
All wide operators execute as collectives on the worker's fabric ("ignis"
mode). "spark" mode (paper's baseline) routes every block through the
driver host between operators — the JVM-pipe / driver-evaluation cost the
paper measures against.

Row functions may be Python callables, ``ISource`` wrappers or text lambdas
(paper §4.2) — resolved by ``textlambda.resolve``.

Wide (shuffle-backed) operators route through the worker's adaptive shuffle
engine (``shuffle_plan.ShuffleManager``, DESIGN.md §6): each registers a
structural lineage signature so capacities are remembered across actions and
re-built lineages. Per-operator semantics (wide/narrow classification,
fusability, capacity/padding behavior, spark mode) are documented in
docs/dataframe.md.
"""
from __future__ import annotations

import json as _json
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comm
from repro.core import executor as ex
from repro.core import shuffle as sh
from repro.core.dag import TaskNode, node_sig
from repro.core.partition import Block, concat_blocks, from_host, split_block, to_host
from repro.core.shuffle_plan import _static_token, fn_token
from repro.core.textlambda import resolve


def _pack_default(row):
    """Default sortable packing of a row (distinct/sort keys).

    Scalars pass through; (a, b) int pairs pack to (a<<16)|b — fine for the
    graph demos (vertex ids < 2^16); users pass key_fn for wider domains.
    """
    if isinstance(row, tuple) and len(row) == 2:
        return (row[0].astype(jnp.int32) << 16) | (row[1].astype(jnp.int32) & 0xFFFF)
    if isinstance(row, dict) and set(row) == {"key", "value"}:
        return row["key"]
    return row


class IDataFrame:
    def __init__(self, worker, node: TaskNode):
        self.worker = worker
        self.node = node
        if node.owner is None:
            # job-scheduler routing (core/job.py): edges between differently-
            # owned nodes are cross-worker task boundaries
            node.owner = worker

    # ------------------------------------------------------------------
    # plumbing
    # ------------------------------------------------------------------
    @property
    def _ctx(self):
        return self.worker.context

    @property
    def _engine(self):
        return self.worker.engine

    def _narrow(self, op: str, kernel, key: tuple = (), fusable: bool = True) -> "IDataFrame":
        """Register a narrow op from a Block → Block kernel.

        The kernel doubles as the node's ``block_fn`` (unfused / repair path)
        and, when ``fusable``, as its ``fuse_fn`` — the planner composes
        consecutive fuse_fns into one jitted stage (DESIGN.md §5). ``key``
        extends the op name into the plan-cache signature. In spark mode every
        op pays the driver pipe, so nothing can fuse across it."""
        def block_fn(ps, _k=kernel):
            return _k(ps[0])

        fuse_fn = kernel if fusable else None
        # fn-valued key parts are tokenised structurally (code + closure
        # cells), so a re-built identical lineage maps to the same fuse_key →
        # same plan-cache entry and the same shuffle capacity-memory slot.
        tkey = tuple(fn_token(k) if callable(k) else k for k in key)
        fuse_key = (op, *tkey) if fuse_fn is not None else None
        if self.worker.mode == "spark":
            block_fn = self.worker._pipe_wrap(block_fn)
            fuse_fn = fuse_key = None
        node = TaskNode(op, [self.node], block_fn=block_fn, narrow=True,
                        fuse_fn=fuse_fn, fuse_key=fuse_key)
        node.sig = ("n", fuse_key if fuse_key is not None else (op, node.id),
                    node_sig(self.node))
        return IDataFrame(self.worker, node)

    def _wide(self, op: str, fn, extra_parents=(), key: tuple = (),
              shuffle: bool = False, needs_sig: bool = False) -> "IDataFrame":
        """Register a wide op. ``key`` extends the structural signature;
        ``needs_sig=True`` ops receive ``fn(parent_results, sig)`` so they can
        consult the shuffle engine's capacity memory; ``shuffle=True`` marks
        the node for explain()'s capacity annotations."""
        parents = [self.node, *extra_parents]
        tkey = tuple(fn_token(k) if callable(k) else k for k in key)
        sig = ("w", op, *tkey, *(node_sig(p) for p in parents))
        if needs_sig:
            inner = fn
            fn = lambda prs, _inner=inner, _sig=sig: _inner(prs, _sig)  # noqa: E731
        if self.worker.mode == "spark":
            fn = self.worker._pipe_wrap_wide(fn)
        node = TaskNode(op, parents, fn=fn, narrow=False)
        node.sig = sig
        if shuffle:
            node.shuffle_sig = sig
        return IDataFrame(self.worker, node)

    def _blocks(self) -> list[Block]:
        return self._engine.evaluate(self.node)

    def _merged(self) -> Block:
        return concat_blocks(self._blocks())

    # ------------------------------------------------------------------
    # conversion transformations (narrow)
    # ------------------------------------------------------------------
    def map(self, fn) -> "IDataFrame":
        fn = resolve(fn)
        return self._narrow("map", ex.map_kernel(fn), key=(fn,))

    def filter(self, fn) -> "IDataFrame":
        fn = resolve(fn)
        return self._narrow("filter", ex.filter_kernel(fn), key=(fn,))

    def flatmap(self, fn, fanout: int) -> "IDataFrame":
        fn = resolve(fn)
        return self._narrow("flatmap", ex.flatmap_kernel(fn, fanout), key=(fn, fanout))

    def map_partitions(self, fn) -> "IDataFrame":
        # fn sees raw block data and may do host-side work → opaque to fusion
        fn = resolve(fn)
        return self._narrow(
            "mapPartitions",
            lambda b: ex.map_partitions_block(b, fn),
            fusable=False,
        )

    def key_by(self, fn) -> "IDataFrame":
        fn = resolve(fn)
        return self._narrow("keyBy", ex.key_by_kernel(fn), key=(fn,))

    def map_values(self, fn) -> "IDataFrame":
        fn = resolve(fn)
        return self._narrow("mapValues", ex.map_values_kernel(fn), key=(fn,))

    def keys(self) -> "IDataFrame":
        return self._narrow("keys", ex.keys_block)

    def values(self) -> "IDataFrame":
        return self._narrow("values", ex.values_block)

    def sample(self, fraction: float, seed: int = 0) -> "IDataFrame":
        return self._narrow("sample", ex.sample_kernel(fraction, seed),
                            key=(fraction, seed))

    def sample_by_key(self, fractions: dict, seed: int = 0) -> "IDataFrame":
        """Stratified sampling on a KV frame: per-key keep fractions."""
        items = sorted((int(k), float(v)) for k, v in fractions.items())
        keys_arr = jnp.asarray([k for k, _ in items], jnp.int32)
        frac_arr = jnp.asarray([v for _, v in items], jnp.float32)

        def kernel(b):
            k = b.data["key"].astype(jnp.int32)
            idx = jnp.searchsorted(keys_arr, k)
            idxc = jnp.clip(idx, 0, keys_arr.shape[0] - 1)
            f = jnp.where(keys_arr[idxc] == k, frac_arr[idxc], 0.0)
            u = jax.random.uniform(jax.random.PRNGKey(seed + b.capacity), (b.capacity,))
            return Block(b.data, b.valid & (u < f))

        return self._narrow("sampleByKey", kernel, key=(tuple(items), seed))

    def take_sample(self, n: int, seed: int = 0) -> list:
        """Action: uniform sample of n valid rows (without replacement)."""
        rows = self.collect()
        import random

        rng = random.Random(seed)
        return rng.sample(rows, min(n, len(rows)))

    def foreach_async(self, fn, job=None, group=None):
        fn = resolve(fn)

        def act(blocks):
            for b in blocks:
                for row in to_host(b):
                    fn(row)

        return self._submit("foreach", act, job=job, group=group)

    def foreach(self, fn):
        """Action: apply a host-side fn to every valid row (paper's Void fns)."""
        return self.foreach_async(fn).result()

    sampleByKey = sample_by_key
    takeSample = take_sample

    # camelCase aliases (paper API)
    flatMap = flatmap
    keyBy = key_by
    mapValues = map_values
    mapPartitions = map_partitions

    # ------------------------------------------------------------------
    # SQL-ish / set ops
    # ------------------------------------------------------------------
    def union(self, other: "IDataFrame") -> "IDataFrame":
        def fn(parent_results):
            return parent_results[0] + parent_results[1]

        return self._wide("union", fn, extra_parents=[other.node])

    def distinct(self, key_fn=None) -> "IDataFrame":
        key_fn = resolve(key_fn) if key_fn else _pack_default
        worker = self.worker

        def fn(parent_results, sig):
            b = concat_blocks(parent_results[0])
            return [worker.shuffle.distinct(sig, b, key_fn)]

        return self._wide("distinct", fn, key=(key_fn,), shuffle=True,
                          needs_sig=True)

    def join(self, other: "IDataFrame", max_matches: int | None = None) -> "IDataFrame":
        """Inner join of two KV frames → rows (key, (lvalue, rvalue))."""
        M = max_matches or self.worker.join_max_matches
        worker = self.worker

        def fn(parent_results, sig):
            lb = concat_blocks(parent_results[0])
            rb = concat_blocks(parent_results[1])
            return [worker.shuffle.join(sig, lb, rb, M)]

        return self._wide("join", fn, extra_parents=[other.node], key=(M,),
                          shuffle=True, needs_sig=True)

    # ------------------------------------------------------------------
    # sort / group / reduceByKey
    # ------------------------------------------------------------------
    def sort_by(self, key_fn, ascending: bool = True) -> "IDataFrame":
        key_fn = resolve(key_fn)
        worker = self.worker

        def fn(parent_results, sig):
            b = concat_blocks(parent_results[0])
            return [worker.shuffle.sort(sig, b, key_fn, ascending)]

        return self._wide("sortBy", fn, key=(key_fn, ascending), shuffle=True,
                          needs_sig=True)

    def sort(self, ascending: bool = True) -> "IDataFrame":
        return self.sort_by(lambda r: r, ascending)

    def sort_by_key(self, ascending: bool = True) -> "IDataFrame":
        return self.sort_by(lambda r: r["key"], ascending)

    def reduce_by_key(self, fn, identity=0) -> "IDataFrame":
        """Merge values per key with ``fn`` (fused into the sort stage).

        A builtin ``fn`` (traces to one add/max/min over a single
        f32/i32 leaf) rides the Pallas kernel tier where the registry
        selects it, bit-identically to the jnp path — the chosen tier
        shows up in ``df.explain()`` (docs/kernels.md)."""
        fn = resolve(fn)
        worker = self.worker

        def node_fn(parent_results, sig):
            b = concat_blocks(parent_results[0])
            return [worker.shuffle.reduce_by_key(sig, b, fn, identity)]

        return self._wide("reduceByKey", node_fn, key=(fn, _static_token(identity)),
                          shuffle=True, needs_sig=True)

    def aggregate_by_key(self, zero, seq_fn, comb_fn) -> "IDataFrame":
        seq_fn, comb_fn = resolve(seq_fn), resolve(comb_fn)
        mapped = self.map_values(lambda v: seq_fn(zero, v))
        return mapped.reduce_by_key(comb_fn, zero)

    def group_by_key(self, group_capacity: int = 8) -> "IDataFrame":
        """Rows (key, (values[G], count)) at segment heads; G-bounded groups."""
        worker = self.worker
        G = group_capacity

        def node_fn(parent_results, sig):
            b = concat_blocks(parent_results[0])
            return [worker.shuffle.group_by_key(sig, b, G)]

        return self._wide("groupByKey", node_fn, key=(G,), shuffle=True,
                          needs_sig=True)

    def group_by(self, key_fn, group_capacity: int = 8) -> "IDataFrame":
        return self.key_by(key_fn).group_by_key(group_capacity)

    # camelCase aliases
    sortBy = sort_by
    sortByKey = sort_by_key
    reduceByKey = reduce_by_key
    aggregateByKey = aggregate_by_key
    groupByKey = group_by_key
    groupBy = group_by

    # ------------------------------------------------------------------
    # balancing / persistence
    # ------------------------------------------------------------------
    def repartition(self, k: int) -> "IDataFrame":
        p = self._ctx.executors

        def fn(parent_results):
            return split_block(concat_blocks(parent_results[0]), k, p)

        return self._wide("repartition", fn)

    def partition_by(self, key_fn=None) -> "IDataFrame":
        key_fn = resolve(key_fn) if key_fn else _pack_default
        worker = self.worker

        def fn(parent_results, sig):
            b = concat_blocks(parent_results[0])
            return [worker.shuffle.partition_by(sig, b, key_fn)]

        return self._wide("partitionBy", fn, key=(key_fn,), shuffle=True,
                          needs_sig=True)

    partitionBy = partition_by

    def compact(self) -> "IDataFrame":
        """Compact away invalid rows (lazy node; host round-trip at eval).

        Fixed shapes mean filters/joins/distinct leave masked holes and
        capacity padding that compound across iterative fixed-point loops
        (every new capacity is a fresh XLA compile). compact() is the
        driver-boundary materialisation Spark performs implicitly — use it
        after distinct() in loops (see examples/transitive_closure.py)."""
        worker = self.worker

        def fn(parent_results):
            rows = []
            for b in parent_results[0]:
                rows.extend(to_host(b))
            if not rows:  # nothing valid: keep (tiny) all-invalid parent block
                return parent_results[0][:1]
            return [from_host(rows, worker.executors, put=worker._put)]

        return self._wide("compact", fn)

    def persist(self) -> "IDataFrame":
        self.node.cached = True
        self.worker._register_cached(self.node)
        return self

    cache = persist

    def unpersist(self) -> "IDataFrame":
        """Drop the node's materialised blocks and stop caching: the next
        action recomputes from lineage. Scope note (docs/fault_tolerance.md):
        this evicts the NODE-level cache; an explicit long-lived ``IJob``
        additionally memoises evaluated subgraphs for reuse *within* that
        job — ``job.release()`` is the eviction point for that layer."""
        self.node.cached = False
        self.node.result = None
        return self

    uncache = unpersist

    def checkpoint(self, ckpt_dir: str) -> "IDataFrame":
        """Materialise this frame, persist its blocks through the checkpoint
        subsystem (src/repro/checkpoint: manifest + content hashes), and
        TRUNCATE the lineage here: the node's parents are unlinked and its
        repair path restores lost blocks from the checkpoint — block-wise,
        integrity-verified — instead of recomputing ancestors
        (docs/fault_tolerance.md). Spark's ``checkpoint()`` semantic with
        per-block restore granularity; the step is keyed by the node id and
        kept forever (``keep=0``), so give each frame its own directory."""
        from repro import checkpoint as ck

        node = self.node
        blocks = self._blocks()
        step = node.id
        ck.save(ckpt_dir, step,
                {f"b{i:05d}": {"data": b.data, "valid": b.valid}
                 for i, b in enumerate(blocks)},
                keep=0)
        metas = [
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                         {"data": b.data, "valid": b.valid})
            for b in blocks
        ]
        put = self.worker._put

        def _load(i: int) -> Block:
            key = f"b{i:05d}"
            t = ck.restore(ckpt_dir, step, {key: metas[i]})[key]
            return Block(jax.tree.map(put, t["data"]), put(t["valid"]))

        node.op = f"checkpoint({node.op})"
        node.parents = []
        node.narrow = False
        node.fn = lambda _parents, _n=len(blocks): [_load(i) for i in range(_n)]
        node.block_fn = node.fuse_fn = node.fuse_key = None
        node.restore_fn = _load
        node.cached = True
        node.result = blocks
        node.sig = ("ckpt", ckpt_dir, step)
        node.shuffle_sig = None
        self.worker._register_cached(node)
        return self

    def explain(self) -> str:
        """Physical plan for this frame's lineage: which narrow ops the
        planner fuses into single-dispatch stages (DESIGN.md §5), wide nodes
        annotated with their shuffle capacity state and — when the kernel
        tier ran them — the kernel selection (``kernel=segment_reduce[...]
        op=sum block=128``, docs/kernels.md), plus the shuffle engine's
        telemetry summary (DESIGN.md §6) and kernel-registry counters."""
        mgr = getattr(self.worker, "shuffle", None)
        plan = self._engine.explain(self.node,
                                    annotate=mgr.annotate if mgr else None)
        return plan + ("\n" + mgr.summary() if mgr else "")

    # ------------------------------------------------------------------
    # actions — lazy job submission + eager facades
    #
    # Every action has an ``*_async`` twin returning an ``IFuture``: the
    # lineage is handed to the job scheduler (core/job.py), which cuts it
    # into per-worker tasks (native calls and importData reshards become
    # their own task nodes) and overlaps independent branches. The eager
    # form is a thin facade: ``df.count()`` IS ``df.count_async().result()``
    # (docs/driver.md). Pass ``job=`` to group many submissions — possibly
    # across workers and frames — into one scheduled job DAG.
    # ------------------------------------------------------------------
    def _submit(self, name: str, blocks_fn=None, task_fn=None, job=None,
                group=None):
        from repro.core.job import IJob

        if job is None:
            job = IJob(f"{name}@{self.worker.name}")
        return job.submit_action(self, name, blocks_fn=blocks_fn, task_fn=task_fn,
                                 group=group)

    def count_async(self, job=None, group=None):
        # the per-block counts ride a nonblocking handle: the task fn only
        # DISPATCHES the reads, and the scheduler awaits the handle after
        # releasing the worker's job lock (core/job.py _settle) — so the
        # next task's tracing/planning overlaps this one's in-flight device
        # work instead of queueing behind a blocking device_get
        def act(blocks):
            counts = [ex.count_block(b) for b in blocks]
            return comm.CollHandle(
                "action.count", None, counts,
                transform=lambda cs: sum(int(c) for c in jax.device_get(cs)))

        return self._submit("count", act, job=job, group=group)

    def count(self) -> int:
        return self.count_async().result()

    def reduce_async(self, fn, identity=0, job=None, group=None):
        fn = resolve(fn)

        def act(blocks):
            b = concat_blocks(blocks)
            vfn = lambda a, c: jax.tree.map(fn, a, c)  # noqa: E731
            out = ex.pairwise_reduce(b.data, b.valid, vfn, identity)
            return comm.CollHandle("action.reduce", None, out,
                                   transform=jax.device_get)

        return self._submit("reduce", act, job=job, group=group)

    def reduce(self, fn, identity=0):
        return self.reduce_async(fn, identity).result()

    tree_reduce = reduce
    treeReduce = reduce

    def aggregate_async(self, zero, seq_fn, comb_fn, job=None):
        seq_fn, comb_fn = resolve(seq_fn), resolve(comb_fn)
        return self.map(lambda r: seq_fn(zero, r)).reduce_async(comb_fn, zero, job=job)

    def aggregate(self, zero, seq_fn, comb_fn):
        return self.aggregate_async(zero, seq_fn, comb_fn).result()

    treeAggregate = aggregate

    def fold_async(self, zero, fn, job=None):
        return self.map(lambda r: r).reduce_async(fn, zero, job=job)

    def fold(self, zero, fn):
        return self.fold_async(zero, fn).result()

    def max_async(self, key_fn=None, job=None):
        return self._submit(
            "max", lambda blocks: self._extreme_of(blocks, key_fn, True), job=job
        )

    def max(self, key_fn=None):
        """Without key_fn: elementwise tree-max of valid rows. With key_fn:
        the ROW maximising key_fn(row) (Spark's max(key=...) — argmax)."""
        return self.max_async(key_fn).result()

    def min_async(self, key_fn=None, job=None):
        return self._submit(
            "min", lambda blocks: self._extreme_of(blocks, key_fn, False), job=job
        )

    def min(self, key_fn=None):
        """Without key_fn: elementwise tree-min. With key_fn: the row
        minimising key_fn(row) (argmin)."""
        return self.min_async(key_fn).result()

    def _extreme_of(self, blocks, key_fn, largest: bool):
        b = concat_blocks(blocks)
        if key_fn is None:
            op = jnp.maximum if largest else jnp.minimum
            sent = sh._sentinel_low if largest else sh._sentinel
            ident = jax.tree.map(lambda x: sent(x.dtype), b.data)
            vfn = lambda a, c: jax.tree.map(op, a, c)  # noqa: E731
            return jax.device_get(ex.pairwise_reduce(b.data, b.valid, vfn, ident))
        key_fn = resolve(key_fn)
        keys = jax.vmap(key_fn)(b.data)
        sent = (sh._sentinel_low if largest else sh._sentinel)(keys.dtype)
        masked = jnp.where(b.valid, keys, sent)
        i = int(jax.device_get(jnp.argmax(masked) if largest else jnp.argmin(masked)))
        if not bool(jax.device_get(b.valid[i])):
            # a valid row tying the sentinel can shadow the winner; fall back
            # to the host (also the empty-frame path)
            rows = [r for blk in blocks for r in to_host(blk)]
            if not rows:
                raise ValueError("max()/min() with key_fn on an empty dataframe")
            pick = max if largest else min
            return pick(rows, key=lambda r: float(np.asarray(key_fn(r))))
        return jax.device_get(jax.tree.map(lambda x: x[i], b.data))

    def collect_async(self, job=None, group=None):
        def act(blocks):
            def tx(_ready):
                out = []
                for b in blocks:
                    out.extend(to_host(b))
                return out

            return comm.CollHandle(
                "action.collect", None,
                [(b.data, b.valid) for b in blocks], transform=tx)

        return self._submit("collect", act, job=job, group=group)

    def collect(self) -> list:
        return self.collect_async().result()

    def take_async(self, k: int, job=None, group=None):
        """Early-exit take: blocks materialise one at a time through the
        engine's lazy block iterator and evaluation stops as soon as ``k``
        valid rows exist — a 100-block lineage pays for one block when the
        first block satisfies the request."""
        worker, node = self.worker, self.node

        def run(memo):
            out = []
            for b in worker.engine.evaluate_blocks_iter(node, memo=memo):
                out.extend(to_host(b))
                if len(out) >= k:
                    break
            return out[:k]

        return self._submit("take", task_fn=run, job=job, group=group)

    def take(self, k: int) -> list:
        return self.take_async(k).result()

    def top_async(self, k: int, key_fn=None, job=None):
        key_fn = resolve(key_fn) if key_fn else (lambda r: r)
        return self.sort_by(key_fn, ascending=False).take_async(k, job=job)

    def top(self, k: int, key_fn=None) -> list:
        return self.top_async(k, key_fn).result()

    @staticmethod
    def _kv_dict(blocks) -> dict:
        rows = [r for b in blocks for r in to_host(b)]
        return {int(np.asarray(r["key"])): int(np.asarray(r["value"])) for r in rows}

    def count_by_key_async(self, job=None):
        ones = self.map_values(lambda v: jnp.int32(1))
        red = ones.reduce_by_key(lambda a, b: a + b, 0)
        return red._submit("countByKey", self._kv_dict, job=job)

    def count_by_key(self) -> dict:
        return self.count_by_key_async().result()

    def count_by_value_async(self, job=None):
        kv = self.map(lambda r: {"key": r, "value": jnp.int32(1)})
        red = kv.reduce_by_key(lambda a, b: a + b, 0)
        return red._submit("countByValue", self._kv_dict, job=job)

    def count_by_value(self) -> dict:
        return self.count_by_value_async().result()

    countByKey = count_by_key
    countByValue = count_by_value

    # ------------------------------------------------------------------
    # I/O
    # ------------------------------------------------------------------
    def save_as_text_file(self, path: str):
        with open(path, "w") as f:
            for row in self.collect():
                f.write(f"{_row_repr(row)}\n")

    def save_as_json_file(self, path: str):
        with open(path, "w") as f:
            _json.dump([_row_json(r) for r in self.collect()], f)

    def save_as_object_file(self, path: str):
        np.save(path, np.asarray(self.collect(), dtype=object), allow_pickle=True)

    saveAsTextFile = save_as_text_file
    saveAsJsonFile = save_as_json_file
    saveAsObjectFile = save_as_object_file


def _row_repr(row):
    if isinstance(row, dict):
        return {k: _row_repr(v) for k, v in row.items()}
    if isinstance(row, tuple):
        return tuple(_row_repr(v) for v in row)
    x = np.asarray(row)
    return x.item() if x.ndim == 0 else x.tolist()


def _row_json(row):
    r = _row_repr(row)
    if isinstance(r, tuple):
        return list(r)
    return r
