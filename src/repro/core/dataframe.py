"""IDataFrame — the Spark-inspired lazy dataflow API (paper §4, Table 1).

Transformations register TaskNodes (lazy); actions trigger DAG evaluation.
All wide operators execute as collectives on the worker's fabric ("ignis"
mode). "spark" mode (paper's baseline) routes every block through the
driver host between operators — the JVM-pipe / driver-evaluation cost the
paper measures against.

Row functions may be Python callables, ``ISource`` wrappers or text lambdas
(paper §4.2) — resolved by ``textlambda.resolve``.
"""
from __future__ import annotations

import json as _json
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compat
from repro.core import executor as ex
from repro.core import shuffle as sh
from repro.core.dag import TaskNode
from repro.core.partition import Block, concat_blocks, from_host, split_block, to_host
from repro.core.textlambda import resolve


def _pack_default(row):
    """Default sortable packing of a row (distinct/sort keys).

    Scalars pass through; (a, b) int pairs pack to (a<<16)|b — fine for the
    graph demos (vertex ids < 2^16); users pass key_fn for wider domains.
    """
    if isinstance(row, tuple) and len(row) == 2:
        return (row[0].astype(jnp.int32) << 16) | (row[1].astype(jnp.int32) & 0xFFFF)
    if isinstance(row, dict) and set(row) == {"key", "value"}:
        return row["key"]
    return row


class IDataFrame:
    def __init__(self, worker, node: TaskNode):
        self.worker = worker
        self.node = node

    # ------------------------------------------------------------------
    # plumbing
    # ------------------------------------------------------------------
    @property
    def _ctx(self):
        return self.worker.context

    @property
    def _engine(self):
        return self.worker.engine

    def _narrow(self, op: str, kernel, key: tuple = (), fusable: bool = True) -> "IDataFrame":
        """Register a narrow op from a Block → Block kernel.

        The kernel doubles as the node's ``block_fn`` (unfused / repair path)
        and, when ``fusable``, as its ``fuse_fn`` — the planner composes
        consecutive fuse_fns into one jitted stage (DESIGN.md §5). ``key``
        extends the op name into the plan-cache signature. In spark mode every
        op pays the driver pipe, so nothing can fuse across it."""
        def block_fn(ps, _k=kernel):
            return _k(ps[0])

        fuse_fn = kernel if fusable else None
        fuse_key = (op, *key) if fuse_fn is not None else None
        if self.worker.mode == "spark":
            block_fn = self.worker._pipe_wrap(block_fn)
            fuse_fn = fuse_key = None
        node = TaskNode(op, [self.node], block_fn=block_fn, narrow=True,
                        fuse_fn=fuse_fn, fuse_key=fuse_key)
        return IDataFrame(self.worker, node)

    def _wide(self, op: str, fn, extra_parents=()) -> "IDataFrame":
        if self.worker.mode == "spark":
            fn = self.worker._pipe_wrap_wide(fn)
        node = TaskNode(op, [self.node, *extra_parents], fn=fn, narrow=False)
        return IDataFrame(self.worker, node)

    def _blocks(self) -> list[Block]:
        return self._engine.evaluate(self.node)

    def _merged(self) -> Block:
        return concat_blocks(self._blocks())

    # ------------------------------------------------------------------
    # conversion transformations (narrow)
    # ------------------------------------------------------------------
    def map(self, fn) -> "IDataFrame":
        fn = resolve(fn)
        return self._narrow("map", ex.map_kernel(fn), key=(fn,))

    def filter(self, fn) -> "IDataFrame":
        fn = resolve(fn)
        return self._narrow("filter", ex.filter_kernel(fn), key=(fn,))

    def flatmap(self, fn, fanout: int) -> "IDataFrame":
        fn = resolve(fn)
        return self._narrow("flatmap", ex.flatmap_kernel(fn, fanout), key=(fn, fanout))

    def map_partitions(self, fn) -> "IDataFrame":
        # fn sees raw block data and may do host-side work → opaque to fusion
        fn = resolve(fn)
        return self._narrow(
            "mapPartitions",
            lambda b: ex.map_partitions_block(b, fn),
            fusable=False,
        )

    def key_by(self, fn) -> "IDataFrame":
        fn = resolve(fn)
        return self._narrow("keyBy", ex.key_by_kernel(fn), key=(fn,))

    def map_values(self, fn) -> "IDataFrame":
        fn = resolve(fn)
        return self._narrow("mapValues", ex.map_values_kernel(fn), key=(fn,))

    def keys(self) -> "IDataFrame":
        return self._narrow("keys", ex.keys_block)

    def values(self) -> "IDataFrame":
        return self._narrow("values", ex.values_block)

    def sample(self, fraction: float, seed: int = 0) -> "IDataFrame":
        return self._narrow("sample", ex.sample_kernel(fraction, seed),
                            key=(fraction, seed))

    def sample_by_key(self, fractions: dict, seed: int = 0) -> "IDataFrame":
        """Stratified sampling on a KV frame: per-key keep fractions."""
        items = sorted((int(k), float(v)) for k, v in fractions.items())
        keys_arr = jnp.asarray([k for k, _ in items], jnp.int32)
        frac_arr = jnp.asarray([v for _, v in items], jnp.float32)

        def kernel(b):
            k = b.data["key"].astype(jnp.int32)
            idx = jnp.searchsorted(keys_arr, k)
            idxc = jnp.clip(idx, 0, keys_arr.shape[0] - 1)
            f = jnp.where(keys_arr[idxc] == k, frac_arr[idxc], 0.0)
            u = jax.random.uniform(jax.random.PRNGKey(seed + b.capacity), (b.capacity,))
            return Block(b.data, b.valid & (u < f))

        return self._narrow("sampleByKey", kernel, key=(tuple(items), seed))

    def take_sample(self, n: int, seed: int = 0) -> list:
        """Action: uniform sample of n valid rows (without replacement)."""
        rows = self.collect()
        import random

        rng = random.Random(seed)
        return rng.sample(rows, min(n, len(rows)))

    def foreach(self, fn):
        """Action: apply a host-side fn to every valid row (paper's Void fns)."""
        fn = resolve(fn)
        for row in self.collect():
            fn(row)

    sampleByKey = sample_by_key
    takeSample = take_sample

    # camelCase aliases (paper API)
    flatMap = flatmap
    keyBy = key_by
    mapValues = map_values
    mapPartitions = map_partitions

    # ------------------------------------------------------------------
    # SQL-ish / set ops
    # ------------------------------------------------------------------
    def union(self, other: "IDataFrame") -> "IDataFrame":
        def fn(parent_results):
            return parent_results[0] + parent_results[1]

        return self._wide("union", fn, extra_parents=[other.node])

    def distinct(self, key_fn=None) -> "IDataFrame":
        key_fn = resolve(key_fn) if key_fn else _pack_default
        ctx = self._ctx

        def fn(parent_results):
            b = concat_blocks(parent_results[0])
            sb, keys = sh.sort_block(ctx, b, key_fn, self.worker.capacity_factor)
            heads = sh.segment_heads(keys, sb.valid)
            return [Block(sb.data, heads)]

        return self._wide("distinct", fn)

    def join(self, other: "IDataFrame", max_matches: int | None = None) -> "IDataFrame":
        """Inner join of two KV frames → rows (key, (lvalue, rvalue))."""
        M = max_matches or self.worker.join_max_matches
        ctx = self._ctx
        cf = self.worker.capacity_factor

        def fn(parent_results):
            lb = concat_blocks(parent_results[0])
            rb = concat_blocks(parent_results[1])
            lk, lv, ld, o1 = sh.hash_exchange(ctx, lb.data["key"], lb.valid,
                                              lb.data["value"], cf)
            rk, rv, rd, o2 = sh.hash_exchange(ctx, rb.data["key"], rb.valid,
                                              rb.data["value"], cf)
            if int(jax.device_get(o1)) or int(jax.device_get(o2)):
                big = float(ctx.executors)
                lk, lv, ld, _ = sh.hash_exchange(ctx, lb.data["key"], lb.valid,
                                                 lb.data["value"], big)
                rk, rv, rd, _ = sh.hash_exchange(ctx, rb.data["key"], rb.valid,
                                                 rb.data["value"], big)
            p = ctx.executors
            m = M
            for _attempt in range(5):  # overflow → double the fan-out bound
                if p == 1:
                    rows, ok, ovf = sh.local_join(lk, lv, ld, rk, rv, rd, m)
                else:
                    from jax.sharding import PartitionSpec as P

                    def _local(a, b, c, d, e, g, m=m):
                        rows, ok, ovf = sh.local_join(a, b, c, d, e, g, m)
                        return rows, ok, jax.lax.psum(ovf, ctx.axis)

                    f = compat.shard_map(
                        _local,
                        mesh=ctx.mesh,
                        in_specs=(P(ctx.axis),) * 6,
                        out_specs=(P(ctx.axis), P(ctx.axis), P()),
                    )
                    rows, ok, ovf = f(lk, lv, ld, rk, rv, rd)
                if int(jax.device_get(jnp.sum(ovf))) == 0:
                    break
                m *= 2
            return [Block(rows, ok)]

        return self._wide("join", fn, extra_parents=[other.node])

    # ------------------------------------------------------------------
    # sort / group / reduceByKey
    # ------------------------------------------------------------------
    def sort_by(self, key_fn, ascending: bool = True) -> "IDataFrame":
        key_fn = resolve(key_fn)
        ctx = self._ctx
        cf = self.worker.capacity_factor

        def fn(parent_results):
            b = concat_blocks(parent_results[0])
            sb, _ = sh.sort_block(ctx, b, key_fn, cf, ascending)
            return [sb]

        return self._wide("sortBy", fn)

    def sort(self, ascending: bool = True) -> "IDataFrame":
        return self.sort_by(lambda r: r, ascending)

    def sort_by_key(self, ascending: bool = True) -> "IDataFrame":
        return self.sort_by(lambda r: r["key"], ascending)

    def reduce_by_key(self, fn, identity=0) -> "IDataFrame":
        fn = resolve(fn)
        ctx = self._ctx
        cf = self.worker.capacity_factor

        def node_fn(parent_results):
            b = concat_blocks(parent_results[0])
            sb, keys = sh.sort_block(ctx, b, lambda r: r["key"], cf)
            vfn = lambda a, b2: jax.tree.map(lambda x, y: fn(x, y), a, b2)
            heads, red = sh.segmented_reduce(keys, sb.valid, sb.data["value"], vfn, identity)
            return [Block({"key": sb.data["key"], "value": red}, heads)]

        return self._wide("reduceByKey", node_fn)

    def aggregate_by_key(self, zero, seq_fn, comb_fn) -> "IDataFrame":
        seq_fn, comb_fn = resolve(seq_fn), resolve(comb_fn)
        mapped = self.map_values(lambda v: seq_fn(zero, v))
        return mapped.reduce_by_key(comb_fn, zero)

    def group_by_key(self, group_capacity: int = 8) -> "IDataFrame":
        """Rows (key, (values[G], count)) at segment heads; G-bounded groups."""
        ctx = self._ctx
        cf = self.worker.capacity_factor
        G = group_capacity

        def node_fn(parent_results):
            b = concat_blocks(parent_results[0])
            sb, keys = sh.sort_block(ctx, b, lambda r: r["key"], cf)
            heads = sh.segment_heads(keys, sb.valid)
            n = keys.shape[0]
            idx = jnp.arange(n)
            raw = idx[:, None] + jnp.arange(G)[None, :]
            gidx = jnp.clip(raw, 0, n - 1)
            same = (keys[gidx] == keys[:, None]) & sb.valid[gidx] & (raw < n)
            vals = jax.tree.map(lambda x: x[gidx], sb.data["value"])
            counts = same.sum(-1)
            return [
                Block(
                    {"key": sb.data["key"], "value": {"items": vals, "mask": same,
                                                      "count": counts}},
                    heads,
                )
            ]

        return self._wide("groupByKey", node_fn)

    def group_by(self, key_fn, group_capacity: int = 8) -> "IDataFrame":
        return self.key_by(key_fn).group_by_key(group_capacity)

    # camelCase aliases
    sortBy = sort_by
    sortByKey = sort_by_key
    reduceByKey = reduce_by_key
    aggregateByKey = aggregate_by_key
    groupByKey = group_by_key
    groupBy = group_by

    # ------------------------------------------------------------------
    # balancing / persistence
    # ------------------------------------------------------------------
    def repartition(self, k: int) -> "IDataFrame":
        p = self._ctx.executors

        def fn(parent_results):
            return split_block(concat_blocks(parent_results[0]), k, p)

        return self._wide("repartition", fn)

    def partition_by(self, key_fn=None) -> "IDataFrame":
        key_fn = resolve(key_fn) if key_fn else _pack_default
        ctx = self._ctx
        cf = self.worker.capacity_factor

        def fn(parent_results):
            b = concat_blocks(parent_results[0])
            keys = jax.vmap(key_fn)(b.data)
            k2, v2, d2, ovf = sh.hash_exchange(ctx, keys, b.valid, b.data, cf)
            if int(jax.device_get(ovf)) > 0:
                k2, v2, d2, _ = sh.hash_exchange(ctx, keys, b.valid, b.data,
                                                 float(ctx.executors))
            return [Block(d2, v2)]

        return self._wide("partitionBy", fn)

    partitionBy = partition_by

    def compact(self) -> "IDataFrame":
        """Compact away invalid rows (lazy node; host round-trip at eval).

        Fixed shapes mean filters/joins/distinct leave masked holes and
        capacity padding that compound across iterative fixed-point loops
        (every new capacity is a fresh XLA compile). compact() is the
        driver-boundary materialisation Spark performs implicitly — use it
        after distinct() in loops (see examples/transitive_closure.py)."""
        worker = self.worker

        def fn(parent_results):
            rows = []
            for b in parent_results[0]:
                rows.extend(to_host(b))
            if not rows:  # nothing valid: keep (tiny) all-invalid parent block
                return parent_results[0][:1]
            return [from_host(rows, worker.executors, put=worker._put)]

        return self._wide("compact", fn)

    def persist(self) -> "IDataFrame":
        self.node.cached = True
        return self

    cache = persist

    def unpersist(self) -> "IDataFrame":
        self.node.cached = False
        self.node.result = None
        return self

    uncache = unpersist

    def explain(self) -> str:
        """Physical plan for this frame's lineage: which narrow ops the
        planner fuses into single-dispatch stages (DESIGN.md §5)."""
        return self._engine.explain(self.node)

    # ------------------------------------------------------------------
    # actions
    # ------------------------------------------------------------------
    def count(self) -> int:
        total = 0
        for b in self._blocks():
            total += int(jax.device_get(ex.count_block(b)))
        return total

    def reduce(self, fn, identity=0):
        fn = resolve(fn)
        b = self._merged()
        vfn = lambda a, c: jax.tree.map(fn, a, c)
        out = ex.pairwise_reduce(b.data, b.valid, vfn, identity)
        return jax.device_get(out)

    tree_reduce = reduce
    treeReduce = reduce

    def aggregate(self, zero, seq_fn, comb_fn):
        seq_fn, comb_fn = resolve(seq_fn), resolve(comb_fn)
        return self.map(lambda r: seq_fn(zero, r)).reduce(comb_fn, zero)

    treeAggregate = aggregate

    def fold(self, zero, fn):
        return self.map(lambda r: r).reduce(fn, zero)

    def max(self, key_fn=None):
        df = self if key_fn is None else self
        b = df._merged()
        vfn = lambda a, c: jax.tree.map(jnp.maximum, a, c)
        return jax.device_get(ex.pairwise_reduce(b.data, b.valid, vfn, -jnp.inf))

    def min(self, key_fn=None):
        b = self._merged()
        vfn = lambda a, c: jax.tree.map(jnp.minimum, a, c)
        return jax.device_get(ex.pairwise_reduce(b.data, b.valid, vfn, jnp.inf))

    def collect(self) -> list:
        out = []
        for b in self._blocks():
            out.extend(to_host(b))
        return out

    def take(self, k: int) -> list:
        return self.collect()[:k]

    def top(self, k: int, key_fn=None) -> list:
        key_fn = resolve(key_fn) if key_fn else (lambda r: r)
        return self.sort_by(key_fn, ascending=False).take(k)

    def count_by_key(self) -> dict:
        ones = self.map_values(lambda v: jnp.int32(1))
        rows = ones.reduce_by_key(lambda a, b: a + b, 0).collect()
        return {int(np.asarray(r["key"])): int(np.asarray(r["value"])) for r in rows}

    def count_by_value(self) -> dict:
        kv = self.map(lambda r: {"key": r, "value": jnp.int32(1)})
        rows = kv.reduce_by_key(lambda a, b: a + b, 0).collect()
        return {int(np.asarray(r["key"])): int(np.asarray(r["value"])) for r in rows}

    countByKey = count_by_key
    countByValue = count_by_value

    # ------------------------------------------------------------------
    # I/O
    # ------------------------------------------------------------------
    def save_as_text_file(self, path: str):
        with open(path, "w") as f:
            for row in self.collect():
                f.write(f"{_row_repr(row)}\n")

    def save_as_json_file(self, path: str):
        with open(path, "w") as f:
            _json.dump([_row_json(r) for r in self.collect()], f)

    def save_as_object_file(self, path: str):
        np.save(path, np.asarray(self.collect(), dtype=object), allow_pickle=True)

    saveAsTextFile = save_as_text_file
    saveAsJsonFile = save_as_json_file
    saveAsObjectFile = save_as_object_file


def _row_repr(row):
    if isinstance(row, dict):
        return {k: _row_repr(v) for k, v in row.items()}
    if isinstance(row, tuple):
        return tuple(_row_repr(v) for v in row)
    x = np.asarray(row)
    return x.item() if x.ndim == 0 else x.tolist()


def _row_json(row):
    r = _row_repr(row)
    if isinstance(r, tuple):
        return list(r)
    return r
