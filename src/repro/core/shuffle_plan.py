"""Adaptive shuffle engine (DESIGN.md §6): capacity memory, fused wide
stages, deferred overflow checks, and shuffle telemetry.

The static-shape tradeoff (DESIGN.md §1) makes every exchange capacity-bound:
a bucket that overflows forces a retry at a new capacity, i.e. a fresh XLA
compile — and the seed engine paid a host sync per exchange just to find out.
The ``ShuffleManager`` closes that gap three ways:

1. **Capacity memory.** Every wide node carries a structural lineage
   signature; the manager remembers, per ``(signature, input rows)``, the
   capacity factor that fit — sized from the *observed* max bucket demand,
   not the worst case — so repeated actions (and re-built identical
   lineages) pick a fitting capacity on the first try: zero retries, zero
   recompiles.
2. **Fused wide stages + wide-plan cache.** sort→segment-heads→segmented-
   reduce chains (reduceByKey / distinct / groupByKey) trace as ONE jitted
   stage (shuffle.sort_stage + post hook) instead of three dispatches;
   compiled stages live in an LRU keyed by (op kind, capacity, fn tokens,
   block avals) — the wide-op analogue of the narrow plan cache
   (DESIGN.md §5).
3. **Deferred overflow checks.** Stages return replicated device scalars;
   the manager performs ONE host sync per wide node (none at p=1 for
   sorts/exchanges), retries at a capacity derived from the observed fill
   (guaranteed to fit — the fill is demand, independent of capacity), and
   records the outcome.

Telemetry lives in ``stats`` (exchanges, overflow/fan-out retries, deferred
checks, capacity-memory hits, wide-plan compiles, bytes moved) — surfaced via
``worker.shuffle_stats()`` and the ``== shuffle ==`` section of
``df.explain()``.
"""
from __future__ import annotations

import threading
import time
import types
from collections import OrderedDict
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comm, faults
from repro.core import shuffle as sh
from repro.core.metrics import Counters
from repro.core.partition import Block, block_aval as _block_aval, block_devices, place_block
from repro.kernels.registry import KernelRegistry, builtin_reduce_op


class _Opaque(Exception):
    """A captured value the token cannot represent faithfully — fall back to
    the function object itself (identity-based, always correct)."""


# value types whose (type, value) pair fully determines traced behavior
_VALUE_TYPES = (int, float, bool, complex, str, bytes, type(None))


def _code_names(code) -> set:
    """Global names referenced by a code object, including nested lambdas."""
    names = set(code.co_names)
    for c in code.co_consts:
        if isinstance(c, types.CodeType):
            names |= _code_names(c)
    return names


def _val_token(v, seen: frozenset):
    if isinstance(v, _VALUE_TYPES):
        # tag with the type: 1, 1.0 and True compare equal in Python but
        # trace to different dtypes — they must not share a compiled kernel
        return (type(v).__name__, v)
    if isinstance(v, tuple):
        return ("tuple", tuple(_val_token(x, seen) for x in v))
    if isinstance(v, types.ModuleType):
        return ("module", v.__name__)
    if callable(v):
        return fn_token(v, seen)
    raise _Opaque


def fn_token(fn, _seen: frozenset = frozenset()):
    """Structural identity of a row fn: (code, closure cells, defaults,
    referenced-global values).

    Two lambdas created by re-running the same source line share a code
    object, so re-built lineages (benchmark loops, iterative drivers) map to
    the same token and hit the capacity memory / plan cache. Behavior-bearing
    state is part of the token: closure cell values, defaults, and the values
    of module globals the code references (a rebuilt ``lambda x: x * SCALE``
    after ``SCALE`` changed must NOT reuse the old plan). Falls back to the
    function object itself — identity-based, always correct, just fewer
    cross-rebuild hits — for bound methods (behavior lives in ``__self__``)
    and whenever any captured value is not a plain value type (arrays,
    arbitrary objects: their mutable state is invisible to a token).
    """
    code = getattr(fn, "__code__", None)
    if code is None or getattr(fn, "__self__", None) is not None:
        return fn
    if id(fn) in _seen:  # self-referential function: code identifies the cycle
        return ("recursive", code)
    seen = _seen | {id(fn)}
    try:
        cells: tuple = ()
        if getattr(fn, "__closure__", None):
            cells = tuple(_val_token(c.cell_contents, seen) for c in fn.__closure__)
        defaults = tuple(_val_token(v, seen)
                         for v in (getattr(fn, "__defaults__", None) or ()))
        g = getattr(fn, "__globals__", {})
        gtok = tuple((name, _val_token(g[name], seen))
                     for name in sorted(_code_names(code)) if name in g)
        token = ("fn", code, cells, defaults, gtok)
        hash(token)
    except (_Opaque, TypeError):
        return fn
    return token


def _static_token(x):
    """Hashable token for a static pytree argument (e.g. a reduce identity).

    Unhashable leaves (arrays) are fingerprinted by dtype/shape/bytes —
    repr() would truncate large arrays and collide distinct identities."""
    try:
        hash(x)
        return x
    except TypeError:
        leaves, treedef = jax.tree_util.tree_flatten(x)

        def leaf(l):
            a = np.asarray(l)
            return (str(a.dtype), a.shape, a.tobytes())

        return (treedef, tuple(leaf(l) for l in leaves))


def _row_bytes(b: Block, key_bytes: int = 8) -> int:
    """Approximate bytes per exchanged row (payload leaves + key + validity)."""
    per = sum(
        int(np.prod(l.shape[1:], dtype=np.int64)) * l.dtype.itemsize
        for l in jax.tree.leaves(b.data)
    )
    return per + key_bytes + 1


class ShuffleManager:
    """Runs every wide (shuffle-backed) operator for one worker."""

    MAX_ATTEMPTS = 8  # join retry bound (capacity + fan-out combined)
    MEMORY_ENTRIES = 4096  # capacity/fan-out memory cap (FIFO eviction)

    def __init__(self, ctx, *, worker=None, capacity_factor: float = 2.0,
                 join_max_matches: int = 8, plan_cache_size: int = 64,
                 headroom: float = 1.25, kernels: Optional[KernelRegistry] = None):
        # with a worker, the manager follows the worker's CURRENT context —
        # a gang-scheduled task (core/job.py) swaps in a group communicator
        # and every wide stage runs on the group's sub-mesh and axis
        self._ctx = ctx
        self._worker = worker
        self.default_factor = float(capacity_factor)
        self.join_max_matches = int(join_max_matches)
        self.plan_cache_size = int(plan_cache_size)
        self.headroom = float(headroom)
        # kernel tier (docs/kernels.md): capability/selection + autotune
        # memo, consulted once per kernel-eligible wide node
        self.kernels = kernels if kernels is not None else KernelRegistry()
        self._capacity: "OrderedDict[tuple, float]" = OrderedDict()
        self._fanout: "OrderedDict[tuple, int]" = OrderedDict()
        self._kernel_notes: "OrderedDict[object, str]" = OrderedDict()
        self._op_memo: "OrderedDict[tuple, Optional[str]]" = OrderedDict()
        self._plans: "OrderedDict[tuple, Callable]" = OrderedDict()
        # gang-scheduled tasks on disjoint groups share this manager from
        # several threads; LRU get+move / insert+evict, the capacity/fanout
        # memories, and the stats counters (CI-gated by check_bench.py —
        # a lost `overflow_retries` increment could mask a regression) all
        # need their read-modify-write sequences kept atomic
        self._plan_lock = threading.Lock()
        # the "shuffle/" namespace of the worker's metrics tree
        # (core/metrics.py; worker.shuffle_stats() is the legacy facade)
        self.stats = Counters("shuffle", {
            "exchanges": 0,            # collective exchange stages executed
            "overflow_retries": 0,     # capacity retries (recompile + rerun)
            "fanout_retries": 0,       # join per-key match-bound doublings
            "overflow_checks": 0,      # deferred host syncs performed
            "capacity_memory_hits": 0,
            "capacity_memory_misses": 0,
            "wide_plan_hits": 0,
            "wide_plan_misses": 0,     # wide-stage compiles
            "wide_plan_evictions": 0,
            "bytes_moved": 0,          # exchanged-buffer bytes (estimate)
            "group_reshards": 0,       # blocks moved onto a different communicator
        })

    # ------------------------------------------------------------------
    # communicator binding
    # ------------------------------------------------------------------
    @property
    def ctx(self):
        return self._worker.context if self._worker is not None else self._ctx

    def _bump(self, key: str, n: int = 1):
        with self._plan_lock:
            self.stats[key] += n

    def _placed(self, b: Block) -> Block:
        """Commit a block to the active communicator's mesh before a wide
        stage — the ingress half of the inter-group reshard edge. A block
        produced on the world mesh (or another group's sub-mesh) is
        device_put onto this communicator; resident blocks pass through."""
        ctx = self.ctx
        devs = block_devices(b)
        if devs is not None and devs != frozenset(ctx.mesh.devices.flat):
            self._bump("group_reshards")
            return place_block(b, ctx.mesh, ctx.axis)
        return b

    # ------------------------------------------------------------------
    # capacity memory
    # ------------------------------------------------------------------
    @property
    def p(self) -> int:
        return self.ctx.executors

    def _factor(self, sig, rows) -> float:
        with self._plan_lock:
            f = self._capacity.get((sig, rows, self.p))
            if f is not None:
                self.stats["capacity_memory_hits"] += 1
                return f
            self.stats["capacity_memory_misses"] += 1
            return self.default_factor

    def _remember(self, sig, rows, factor: float):
        # keyed per communicator size: the fitting factor on a p=4 group is
        # not the fitting factor on the p=8 world for the same lineage
        with self._plan_lock:
            mem = self._capacity
            mem[(sig, rows, self.p)] = factor
            while len(mem) > self.MEMORY_ENTRIES:
                mem.popitem(last=False)

    def _fit(self, fill: int, n_local: int) -> float:
        """Capacity factor sized from observed bucket demand, with headroom,
        capped at the guaranteed-fit worst case (factor = p)."""
        base = fill * self.p / max(n_local, 1)
        return float(min(max(base * self.headroom, self.default_factor), self.p))

    # ------------------------------------------------------------------
    # wide-plan cache (compiled stage kernels; analogue of DESIGN.md §5)
    # ------------------------------------------------------------------
    def _plan(self, key: tuple, builder: Callable[[], Callable]):
        with self._plan_lock:
            fn = self._plans.get(key)
            if fn is not None:
                self._plans.move_to_end(key)
                self.stats["wide_plan_hits"] += 1
                return fn
            self.stats["wide_plan_misses"] += 1
        fn = jax.jit(builder())
        with self._plan_lock:
            self._plans[key] = fn
            while len(self._plans) > self.plan_cache_size:
                self._plans.popitem(last=False)
                self.stats["wide_plan_evictions"] += 1
        return fn

    def _account(self, b: Block, C: int):
        p = self.p
        if p > 1:
            with self._plan_lock:
                self.stats["exchanges"] += 1
                self.stats["bytes_moved"] += p * p * C * _row_bytes(b)

    def _adaptive(self, sig, rows, n_local: int, run) -> tuple:
        """The shared capacity sequence for single-exchange wide ops:
        memory lookup → run at the predicted capacity → one deferred
        overflow check → at most one fitted retry → remember what fit.
        ``run(C) -> (out, overflow, max_fill)``. The fitted retry cannot
        overflow again: max_fill is bucket *demand*, independent of C."""
        factor = self._factor(sig, rows)
        out, ovf, fill = run(sh.capacity_for(factor, n_local, self.p))
        if self.p > 1:
            self._bump("overflow_checks")
            # the deferred check rides a nonblocking handle: the overflow
            # scalars are the only host sync a wide stage performs, and the
            # handle gives them the same fault surface (``comm.handle``)
            # and telemetry as every other in-flight collective
            h = comm.CollHandle("shuffle.capacity", self.ctx, (ovf, fill))
            n_ovf, n_fill = (int(x) for x in jax.device_get(h.wait()))
            if n_ovf > 0:
                self._bump("overflow_retries")
                faults.check("shuffle.overflow", kind="capacity", fill=n_fill)
                factor = self._fit(n_fill, n_local)
                out, _, _ = run(sh.capacity_for(factor, n_local, self.p))
        self._remember(sig, rows, factor)
        return out

    # ------------------------------------------------------------------
    # kernel tier plumbing (docs/kernels.md): per-node selection + autotune
    # ------------------------------------------------------------------
    def _note(self, sig, txt: str):
        """Record the kernel selection for ``df.explain()`` annotation."""
        with self._plan_lock:
            self._kernel_notes[sig] = txt
            while len(self._kernel_notes) > self.MEMORY_ENTRIES:
                self._kernel_notes.popitem(last=False)

    def _reduce_op(self, fn, identity, value) -> Optional[str]:
        """Memoised ``builtin_reduce_op``: jaxpr recognition costs ~0.5 ms
        per call, which a fresh lineage would otherwise pay on EVERY
        reduceByKey — keying by the same fn/static tokens the wide-plan
        cache uses makes repeat consultations a dict hit (and keeps the
        auto-mode parity floor honest on interpret-only hosts)."""
        if value is None:
            return None
        try:
            key = (fn_token(fn), _static_token(identity),
                   tuple((str(getattr(l, "dtype", "?")), np.ndim(l))
                         for l in jax.tree_util.tree_leaves(value)))
        except Exception:
            return builtin_reduce_op(fn, identity, value)
        with self._plan_lock:
            if key in self._op_memo:
                self._op_memo.move_to_end(key)
                return self._op_memo[key]
        op = builtin_reduce_op(fn, identity, value)
        with self._plan_lock:
            self._op_memo[key] = op
            while len(self._op_memo) > self.MEMORY_ENTRIES:
                self._op_memo.popitem(last=False)
        return op

    def _time_calls(self, fn, *args) -> float:
        """Median-free micro-timer: one warm-up (compile), two timed runs."""
        jax.block_until_ready(fn(*args))
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        jax.block_until_ready(fn(*args))
        return time.perf_counter() - t0

    def _block_candidates(self, n: int) -> list:
        # candidates beyond n rows collapse to one tile — dedupe so small
        # inputs sweep (and key) only distinct effective block sizes
        n = max(int(n), 1)
        return sorted({min(int(c), n) for c in self.kernels.blocks})

    def _tune_reduce(self, b: Block, op: str, sel) -> int:
        """Tuned block size for the segment kernel on this block's aval."""
        from repro.kernels.segment_reduce.ops import segment_totals

        leaf = jax.tree_util.tree_leaves(b.data["value"])[0]
        D = () if leaf.ndim == 1 else leaf.shape[1:]
        n = b.capacity
        key = ("segment_reduce", op, str(leaf.dtype), D, n,
               sel.interpret, jax.default_backend())

        def timer(c: int) -> float:
            keys = jnp.zeros(n, jnp.int32)
            valid = jnp.ones(n, bool)
            vals = jnp.zeros((n, *D), leaf.dtype)
            f = jax.jit(lambda k, v, x: segment_totals(
                k, v, x, op=op, identity=0, block=c, interpret=sel.interpret))
            return self._time_calls(f, keys, valid, vals)

        return self.kernels.tune(key, self._block_candidates(n), timer)

    def _tune_route(self, n_local: int, sel) -> int:
        """Tuned block size for the bucket router at this exchange width."""
        p = self.p
        n = max(int(n_local), 1)
        key = ("bucket_route", p, n, sel.interpret, jax.default_backend())

        def timer(c: int) -> float:
            route = sh.make_bucket_route(p, max(n // p, 1), c, sel.interpret)
            f = jax.jit(route)
            return self._time_calls(f, jnp.zeros(n, jnp.int32))

        return self.kernels.tune(key, self._block_candidates(n), timer)

    def _select_route(self, sig, n_local: int):
        """Kernel-or-fallback decision for a hash-routed exchange: returns
        (selection, tuned_block), (None, None) for the argsort path."""
        if self.p <= 1:  # no exchange, nothing to route
            return None, None
        sel = self.kernels.select("bucket_route")
        if sel is None:
            return None, None
        try:
            blk = self._tune_route(n_local, sel)
        except Exception:
            self.kernels.demote()
            return None, None
        self._note(sig, f"{sel.describe()} block={blk}")
        return sel, blk

    # ------------------------------------------------------------------
    # sort-routed wide ops (sort / distinct / reduceByKey / groupByKey)
    # ------------------------------------------------------------------
    def _sorted(self, sig, b: Block, key_fn, ascending: bool, post, kind: tuple,
                kernel: Optional[str] = None) -> Block:
        b = self._placed(b)
        rows = b.capacity
        n_local = rows // max(self.p, 1)
        data, valid = self._adaptive(
            sig, rows, n_local,
            lambda C: self._run_sort_stage(kind, C, b, key_fn, ascending, post,
                                           kernel=kernel))
        return Block(data, valid)

    def _run_sort_stage(self, kind, C, b, key_fn, ascending, post, kernel=None):
        ctx = self.ctx
        # the mesh is part of the key: a stage traced for a p=4 group closes
        # over that group's communicator and must never serve the world (or
        # another group with a different device set)
        key = (kind, C, ascending, fn_token(key_fn), _block_aval(b), ctx.mesh)

        def builder():
            def run(data, valid):
                keys = jax.vmap(key_fn)(data)
                if not ascending:
                    keys = -keys
                return sh.sort_stage(ctx, keys, valid, data, C, post)

            return run

        fn = self._plan(key, builder)
        self._account(b, C)
        faults.check("shuffle.stage", kind=kind[0], p=self.p)
        if kernel is not None:
            faults.check("kernel.stage", kind=kind[0], kernel=kernel, p=self.p)
        return fn(b.data, b.valid)

    def sort(self, sig, b: Block, key_fn, ascending: bool = True) -> Block:
        return self._sorted(sig, b, key_fn, ascending, None, ("sort",))

    def distinct(self, sig, b: Block, key_fn) -> Block:
        return self._sorted(sig, b, key_fn, True, sh.heads_post, ("distinct",))

    def reduce_by_key(self, sig, b: Block, fn, identity) -> Block:
        # kernel tier: a builtin sum/max/min over a single supported leaf
        # runs on the Pallas segment kernel; everything else (arbitrary
        # fns, pytree values, unsupported dtypes) keeps the jnp oracle
        value = b.data.get("value") if isinstance(b.data, dict) else None
        op = self._reduce_op(fn, identity, value)
        sel = self.kernels.select("segment_reduce") if op is not None else None
        if sel is not None:
            try:
                blk = self._tune_reduce(b, op, sel)
            except Exception:
                self.kernels.demote()
                sel = None
        if sel is not None:
            self._note(sig, f"{sel.describe()} op={op} block={blk}")
            post = sh.make_reduce_post_kernel(op, identity, block=blk,
                                              interpret=sel.interpret)
            # the tuned block is part of the wide-plan key: a re-tune (memo
            # eviction) that lands on a different block recompiles, a memo
            # hit re-uses the compiled stage — zero recompiles on repeats
            kind = ("reduceByKey", "kernel", op, blk, sel.interpret,
                    _static_token(identity))
            return self._sorted(sig, b, lambda r: r["key"], True, post, kind,
                                kernel="segment_reduce")
        vfn = lambda a, c: jax.tree.map(lambda x, y: fn(x, y), a, c)  # noqa: E731
        post = sh.make_reduce_post(vfn, identity)
        kind = ("reduceByKey", fn_token(fn), _static_token(identity))
        return self._sorted(sig, b, lambda r: r["key"], True, post, kind)

    def group_by_key(self, sig, b: Block, group_capacity: int) -> Block:
        post = sh.make_group_post(group_capacity)
        kind = ("groupByKey", group_capacity)
        return self._sorted(sig, b, lambda r: r["key"], True, post, kind)

    # ------------------------------------------------------------------
    # hash-routed wide ops (partitionBy)
    # ------------------------------------------------------------------
    def partition_by(self, sig, b: Block, key_fn) -> Block:
        b = self._placed(b)
        rows = b.capacity
        n_local = rows // max(self.p, 1)
        sel, blk = self._select_route(sig, n_local)
        data, valid = self._adaptive(
            sig, rows, n_local,
            lambda C: self._run_hash_stage(C, b, key_fn, sel=sel, blk=blk))
        return Block(data, valid)

    def _run_hash_stage(self, C, b, key_fn, sel=None, blk=None):
        ctx = self.ctx
        route = None
        ktag = ()
        if sel is not None:
            route = sh.make_bucket_route(self.p, C, blk, sel.interpret)
            ktag = ("kernel", blk, sel.interpret)
        key = (("partitionBy",) + ktag, C, fn_token(key_fn), _block_aval(b), ctx.mesh)

        def builder():
            def run(data, valid):
                keys = jax.vmap(key_fn)(data)
                return sh.hash_stage(ctx, keys, valid, data, C, route=route)

            return run

        fn = self._plan(key, builder)
        self._account(b, C)
        faults.check("shuffle.stage", kind="partitionBy", p=self.p)
        if sel is not None:
            faults.check("kernel.stage", kind="partitionBy",
                         kernel="bucket_route", p=self.p)
        return fn(b.data, b.valid)

    # ------------------------------------------------------------------
    # join (both-side exchange + bounded-fan-out merge, one stage)
    # ------------------------------------------------------------------
    def join(self, sig, lb: Block, rb: Block, max_matches: int) -> Block:
        lb, rb = self._placed(lb), self._placed(rb)
        p = self.p
        nl, nr = lb.capacity, rb.capacity
        nl_local, nr_local = nl // max(p, 1), nr // max(p, 1)
        factor = self._factor(sig, (nl, nr))
        with self._plan_lock:
            M = self._fanout.get((sig, nl, nr, p), max_matches)
        sel, blk = self._select_route(sig, max(nl_local, nr_local))
        ctx = self.ctx
        attempts = 0
        while True:
            attempts += 1
            Cl = sh.capacity_for(factor, nl_local, p)
            Cr = sh.capacity_for(factor, nr_local, p)
            route_l = route_r = None
            ktag = ()
            if sel is not None:
                route_l = sh.make_bucket_route(p, Cl, blk, sel.interpret)
                route_r = sh.make_bucket_route(p, Cr, blk, sel.interpret)
                ktag = ("kernel", blk, sel.interpret)
            key = (("join", M) + ktag, Cl, Cr, _block_aval(lb), _block_aval(rb),
                   ctx.mesh)

            def builder(Cl=Cl, Cr=Cr, M=M, route_l=route_l, route_r=route_r):
                def run(ld, lv, rd, rv):
                    return sh.join_stage(ctx, ld["key"], lv, ld["value"],
                                         rd["key"], rv, rd["value"], Cl, Cr, M,
                                         route_l=route_l, route_r=route_r)

                return run

            fn = self._plan(key, builder)
            if p > 1:
                self._account(lb, Cl)
                self._account(rb, Cr)
            faults.check("shuffle.stage", kind="join", p=p, attempt=attempts - 1)
            if sel is not None:
                faults.check("kernel.stage", kind="join", kernel="bucket_route",
                             p=p, attempt=attempts - 1)
            rows, ok, eovf, lfill, rfill, fovf = fn(lb.data, lb.valid, rb.data, rb.valid)
            # one deferred check covers both exchanges AND the fan-out bound
            self._bump("overflow_checks")
            h = comm.CollHandle("shuffle.join", self.ctx, (eovf, lfill, rfill, fovf))
            n_e, n_lf, n_rf, n_f = (int(x) for x in jax.device_get(h.wait()))
            if n_e == 0 and n_f == 0:
                break
            if attempts >= self.MAX_ATTEMPTS:
                # never silently truncate (and never remember the failing
                # bounds): overflow is detected, not swallowed — DESIGN.md §1
                raise RuntimeError(
                    f"join overflow unresolved after {attempts} attempts "
                    f"(exchange_overflow={n_e}, fanout_overflow={n_f}, M={M}): "
                    f"raise max_matches / ignis.join.max.matches for this key skew")
            if n_e > 0:
                self._bump("overflow_retries")
                factor = max(self._fit(n_lf, nl_local), self._fit(n_rf, nr_local))
            else:
                self._bump("fanout_retries")
                M *= 2
        self._remember(sig, (nl, nr), factor)
        with self._plan_lock:
            self._fanout[(sig, nl, nr, p)] = M
            while len(self._fanout) > self.MEMORY_ENTRIES:
                self._fanout.popitem(last=False)
        return Block(rows, ok)

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------
    def annotate(self, node) -> str:
        """Per-node suffix for DagEngine.explain — shuffle capacity state
        plus the kernel-tier selection (docs/kernels.md)."""
        sig = getattr(node, "shuffle_sig", None)
        if sig is None:
            return ""
        knote = self._kernel_notes.get(sig)
        kernel = f" kernel={knote}" if knote else ""
        factors = [f for (s, _rows, _p), f in self._capacity.items() if s == sig]
        if factors:
            return f" {{shuffle: capacity_factor={factors[-1]:.2f} (memory){kernel}}}"
        return f" {{shuffle: capacity_factor={self.default_factor:.2f} (cold){kernel}}}"

    def summary(self) -> str:
        s = self.stats
        return (
            "== shuffle ==\n"
            f"exchanges={s['exchanges']} overflow_retries={s['overflow_retries']} "
            f"fanout_retries={s['fanout_retries']} overflow_checks={s['overflow_checks']}\n"
            f"capacity_memory: hits={s['capacity_memory_hits']} "
            f"misses={s['capacity_memory_misses']} entries={len(self._capacity)}\n"
            f"wide plans: compiled={s['wide_plan_misses']} hits={s['wide_plan_hits']} "
            f"evictions={s['wide_plan_evictions']} bytes_moved={s['bytes_moved']} "
            f"group_reshards={s['group_reshards']}\n"
            f"kernels: {self.kernels.describe()}"
        )
