"""Text lambdas + ISource (paper §4.2, Fig. 8).

IgnisHPC ships operator source as text so the driver language need not match
the executor language. Here the "executor language" is jnp: a text lambda is
compiled by the executor into a traceable row function with jnp/jax/np/math
in scope. ISource wraps a function reference plus driver→executor parameters
(paper Fig. 11's ``addParam``).
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

_NAMESPACE = {"jnp": jnp, "jax": jax, "np": np, "math": math}


def text_lambda(src: str) -> Callable:
    """Compile ``"lambda x: …"`` or ``"def fn(x): …"`` source text."""
    src = src.strip()
    scope = dict(_NAMESPACE)
    if src.startswith("lambda"):
        return eval(src, scope)  # noqa: S307 — executor-side operator compile
    exec(src, scope)  # noqa: S102
    fns = [v for k, v in scope.items() if callable(v) and k not in _NAMESPACE]
    if not fns:
        raise ValueError("text lambda defined no function")
    return fns[-1]


class ISource:
    """A function reference (callable, text, or registry name) + parameters."""

    def __init__(self, fn: Any):
        self.fn = fn
        self.params: dict[str, Any] = {}

    def add_param(self, name: str, value) -> "ISource":
        self.params[name] = value
        return self

    addParam = add_param

    def resolve(self) -> Callable:
        return resolve(self.fn)

    def token(self) -> tuple:
        """Hashable structural identity of (fn, params). Native call nodes
        embed this in their lineage signature (``node.sig``), so the fusion
        plan cache and the shuffle engine's capacity memory key on the
        actual call — app *and* parameters — rather than on node identity."""
        from repro.core.shuffle_plan import _static_token, fn_token

        f = self.fn if isinstance(self.fn, str) else fn_token(self.fn)
        return (f, tuple(sorted((k, _static_token(v)) for k, v in self.params.items())))


def resolve(fn) -> Callable:
    """Accept a callable, a text lambda, or an ISource; return a callable."""
    if fn is None:
        return None
    if isinstance(fn, ISource):
        return fn.resolve()
    if isinstance(fn, str):
        return text_lambda(fn)
    if callable(fn):
        return fn
    raise TypeError(f"cannot resolve operator from {type(fn)}")
