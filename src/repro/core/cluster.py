"""Ignis / ICluster / IWorker — the job hierarchy (paper §3.2, Fig. 2).

A *Cluster* owns a device mesh (its "containers"); *Workers* are
programming-model execution contexts on that mesh — the multi-language
adaptation (DESIGN.md §2): instead of a Python worker and a C++ worker, a
job creates dataflow workers and SPMD workers that interoperate through
``importData`` (the inter-worker communicator: a resharding device_put on
the same fabric, zero host round-trips) — or, in "spark" mode, through the
serialize→host→deserialize pipe the paper benchmarks against.
"""
from __future__ import annotations

import pickle
import threading
from typing import Optional

import jax
import numpy as np

from repro.core import comm as comm_mod
from repro.core import compat
from repro.core import faults
from repro.core.context import IContext
from repro.core.dag import DagEngine, TaskNode, node_sig
from repro.core.metrics import Counters, MetricsTree, warn_deprecated
from repro.core.shuffle_plan import ShuffleManager
from repro.core.dataframe import IDataFrame
from repro.core.native import get_app, load_library
from repro.core.partition import Block, block_aval, concat_blocks, from_host, place_block
from repro.core.properties import IProperties
from repro.core.textlambda import ISource
from repro.kernels.registry import KernelRegistry


class Ignis:
    """Framework lifecycle (paper Fig. 6 lines 6/42)."""

    _started = False

    @classmethod
    def start(cls):
        cls._started = True

    @classmethod
    def stop(cls):
        cls._started = False

    @classmethod
    def running(cls) -> bool:
        return cls._started

    @classmethod
    def scheduler(cls):
        """The process-wide job scheduler (docs/driver.md)."""
        from repro.core.job import default_scheduler

        return default_scheduler()

    @classmethod
    def job(cls, name: str = "job"):
        """Open a named job: a group of async submissions scheduled as one
        cross-worker DAG (paper §3.2 job hierarchy; docs/driver.md)."""
        from repro.core.job import IJob

        return IJob(name)


class ICluster:
    """A group of executor containers = a device mesh slice (paper §3.2)."""

    def __init__(self, props: Optional[IProperties] = None, mesh=None):
        self.props = props or IProperties()
        if mesh is None:
            n = min(
                self.props.get_int("ignis.executor.instances", 1), len(jax.devices())
            )
            mesh = compat.make_mesh((max(n, 1),), ("data",))
        self.mesh = mesh
        self.workers: list[IWorker] = []

    # paper §4: remote commands to containers — host-side here
    def execute(self, fn, *args, **kw):
        return fn(*args, **kw)

    def execute_script(self, src: str):
        scope = {}
        exec(src, scope)  # noqa: S102
        return scope

    def send_file(self, src: str, dst: str):
        with open(src, "rb") as f, open(dst, "wb") as g:
            g.write(f.read())

    sendFile = send_file
    executeScript = execute_script


class IWorker:
    """One programming-model context bound to a cluster (paper §3.2).

    kind: "dataflow" (IDataFrame ops) | "spmd" (native collective apps).
    Both share the cluster mesh — that is the paper's whole point.
    """

    def __init__(self, cluster: ICluster, kind: str = "dataflow", name: str = ""):
        if kind in ("python", "cpp", "java"):  # paper-style language names
            kind = "dataflow"
        self.cluster = cluster
        self.kind = kind
        self.name = name or f"{kind}-{len(cluster.workers)}"
        self._base_context = IContext(cluster.mesh, "data", cluster.props, self)
        self._ctx_local = threading.local()
        self.engine = DagEngine(
            fusion=cluster.props.get_bool("ignis.fusion.enabled", True),
            plan_cache_size=cluster.props.get_int("ignis.fusion.plan.cache.size", 128),
            fusion_mode=cluster.props.get("ignis.fusion.mode", "static"),
        )
        # the cost model (docs/profiling.md): every worker carries one —
        # cost-mode fusion prices chains through it, the scheduler feeds it
        # task-duration history, and timeout=auto reads that history. Pure
        # python and cheap; imported lazily to keep core importable alone.
        from repro.profile.cost import CostModel

        self.engine.cost_model = CostModel()
        self.mode = cluster.props.get("ignis.mode", "ignis")
        self.capacity_factor = cluster.props.get_float("ignis.shuffle.capacity.factor", 2.0)
        self.join_max_matches = cluster.props.get_int("ignis.join.max.matches", 8)
        self.shuffle = ShuffleManager(
            self._base_context,
            worker=self,
            capacity_factor=self.capacity_factor,
            join_max_matches=self.join_max_matches,
            plan_cache_size=cluster.props.get_int("ignis.shuffle.plan.cache.size", 64),
            headroom=cluster.props.get_float("ignis.shuffle.memory.headroom", 1.25),
            kernels=KernelRegistry(
                mode=cluster.props.get("ignis.kernels", "auto"),
                blocks=cluster.props.get("ignis.kernels.blocks", "128,256,512"),
                tune_cache_size=cluster.props.get_int(
                    "ignis.kernels.tune.cache.size", 512),
            ),
        )
        self._libraries: list[str] = []
        # unified introspection tree (docs/profiling.md): every subsystem's
        # counter namespace mounted under one surface. `coll` is a thunk —
        # the collective engine is process-wide and snapshots under its own
        # lock. JobTracer.attach(worker=...) mounts `profile` here.
        # elastic mesh telemetry (docs/elasticity.md): resize events and the
        # incremental-reshard counter split — `reshard_moves` (blocks whose
        # ownership changed, moved as pure data) vs `reshard_unchanged`
        # (cached blocks a resize left in place) vs `reshard_recomputes`
        # (blocks LOST mid-move — elastic.reshard faults — handed back to
        # block-wise lineage repair; 0 on every clean resize)
        self.elastic_stats = Counters("elastic", {
            "grows": 0,
            "shrinks": 0,
            "world_size": self._base_context.executors,
            "reshard_moves": 0,
            "reshard_unchanged": 0,
            "reshard_recomputes": 0,
        })
        self._metrics = MetricsTree(
            stages=self.engine.stats,
            shuffle=self.shuffle.stats,
            kernels=self.shuffle.kernels.stats,
            coll=comm_mod.comm_stats,
            elastic=self.elastic_stats,
        )
        # job-scheduler serialisation points (core/job.py): the base lock
        # covers the whole worker; gang-scheduled tasks instead hold one
        # GROUP lock each, so two tasks on disjoint sub-meshes of this
        # worker run concurrently. All re-entrant so nested eager actions
        # inside a running native task execute inline.
        self._job_lock = threading.RLock()
        # id(ctx) → (ctx, lock, pinned): the ctx reference pins the id
        # against reuse; pinned entries (worker.groups() splits) live
        # forever, ad-hoc entries are evicted FIFO beyond the cap so a
        # driver minting a fresh group per job cannot grow this unboundedly
        from collections import OrderedDict

        self._group_locks: "OrderedDict[int, tuple]" = OrderedDict()
        # n_groups → (base context the split was built from, groups): the
        # base reference is the world-identity the cache revalidates against
        # — a grow/shrink swaps _base_context, so stale sub-mesh splits are
        # rebuilt on next use instead of surviving the resize
        self._groups: dict[int, tuple] = {}
        self._groups_guard = threading.Lock()
        # serialises grow/shrink against each other (drain handles jobs)
        self._resize_lock = threading.RLock()
        # fault tolerance (docs/fault_tolerance.md): executors reported lost
        # (containers the resource manager reclaimed) and the registry of
        # cached nodes whose blocks a lost executor takes with it. WeakSet:
        # dropping every frame reference releases the lineage as before.
        import weakref

        self.executor_blacklist: set[int] = set()
        self._cached_nodes = weakref.WeakSet()
        cluster.workers.append(self)

    _GROUP_LOCK_CAP = 256

    # ------------------------------------------------------------------
    # communicator groups (MPI_Comm_split over the worker mesh)
    # ------------------------------------------------------------------
    @property
    def context(self) -> IContext:
        """The worker's ACTIVE communicator: the base (world) context, or
        the group communicator installed by ``use_group`` on this thread —
        how a gang-scheduled task retargets every collective, wide stage
        and native app onto its sub-mesh (docs/collectives.md)."""
        return getattr(self._ctx_local, "ctx", None) or self._base_context

    def use_group(self, ctx: "IContext | None"):
        """Context manager binding this THREAD's active communicator."""
        import contextlib

        @contextlib.contextmanager
        def _bind():
            prev = getattr(self._ctx_local, "ctx", None)
            self._ctx_local.ctx = ctx
            try:
                yield ctx or self._base_context
            finally:
                self._ctx_local.ctx = prev

        return _bind()

    def groups(self, n_groups: int) -> "list[IContext]":
        """The worker's cached ``n_groups``-way split of its base mesh.
        Cached so every job gang-scheduled at the same width shares one set
        of group communicators — and one group lock per slice, keeping two
        GROUPED jobs from oversubscribing the same slice concurrently.
        Ungrouped (world) tasks hold the worker lock, which deliberately
        does not exclude group locks: for strict slice isolation keep a
        worker's concurrent jobs all-grouped (mixing is safe — results are
        correct and caches are locked — just oversubscribed;
        docs/collectives.md)."""
        with self._groups_guard:
            entry = self._groups.get(n_groups)
            # revalidate against the CURRENT world, not just the blacklist:
            # a grow/shrink swaps _base_context, and a split built over the
            # old world would otherwise keep handing out stale sub-meshes
            # (docs/elasticity.md; the pre-elastic bug kept them forever)
            if entry is not None and entry[0] is not self._base_context:
                for g in entry[1]:
                    self._group_locks.pop(id(g), None)
                entry = None
            if entry is None:
                gs = self._base_context.split(n_groups)
                entry = self._groups[n_groups] = (self._base_context, gs)
                for g in gs:
                    self._group_locks[id(g)] = (g, threading.RLock(), True)
            gs = entry[1]
            # the cache must not bypass the executor blacklist: a split built
            # before a kill_executor would otherwise keep handing out groups
            # over the lost rank while a fresh split raises. The cache itself
            # survives — restore_executor() re-admits the same group objects.
            lost = sorted({r for g in gs for r in g.group_ranks
                           if r in self.executor_blacklist})
            if lost:
                raise ValueError(
                    f"groups({n_groups}) spans blacklisted executors {lost} "
                    f"(lost containers); restore_executor() to re-admit them")
            return gs

    def group_lock(self, ctx: IContext) -> threading.RLock:
        """The job lock guarding a group communicator's device slice. An
        unknown (caller-built) group context gets its own lock on demand;
        such ad-hoc entries are evicted FIFO beyond ``_GROUP_LOCK_CAP``
        (tasks created earlier keep their lock object — at worst an
        evicted-and-reminted slice is briefly oversubscribed, never
        corrupted, since every task still binds its own communicator)."""
        with self._groups_guard:
            entry = self._group_locks.get(id(ctx))
            if entry is None:
                entry = self._group_locks[id(ctx)] = (ctx, threading.RLock(), False)
                if len(self._group_locks) > self._GROUP_LOCK_CAP:
                    for key, (_c, _l, pinned) in list(self._group_locks.items()):
                        if not pinned:
                            del self._group_locks[key]
                            break
            return entry[1]

    # ------------------------------------------------------------------
    # elastic mesh: runtime grow/shrink (docs/elasticity.md, DESIGN.md §14)
    # ------------------------------------------------------------------
    def _world_devices(self) -> list:
        devs = np.asarray(self._base_context.mesh.devices)
        if devs.ndim != 1:
            raise ValueError(
                "elastic resize supports 1-D data meshes only "
                f"(this worker's mesh has axes {self._base_context.mesh.axis_names})")
        return list(devs.flat)

    def grow(self, n: int = 1) -> int:
        """Admit ``n`` executor ranks at runtime: in-flight tasks drain on
        the old communicator, the base context rebinds a mesh extended with
        ``n`` free devices, and cached partitions reshard incrementally
        (docs/elasticity.md). Returns the new world size."""
        if n < 1:
            raise ValueError(f"grow() needs n >= 1, got {n}")
        with self._resize_lock:
            cur = self._world_devices()
            have = {d.id for d in cur}
            pool = [d for d in jax.devices() if d.id not in have]
            if len(pool) < n:
                raise ValueError(
                    f"grow({n}): only {len(pool)} free device(s) beyond the "
                    f"current {len(cur)}-executor world")
            return self._resize(cur + pool[:n])

    def shrink(self, ranks) -> int:
        """Retire executor ranks at runtime: ``shrink(2)`` retires the two
        highest ranks, ``shrink([1, 3])`` retires exactly those ranks. At
        least one rank must survive. Cached blocks owned by retired devices
        move onto the survivors (incremental reshard — pure data movement,
        no lineage recompute). Returns the new world size."""
        with self._resize_lock:
            cur = self._world_devices()
            if isinstance(ranks, int):
                if ranks < 1:
                    raise ValueError(f"shrink() needs >= 1 rank, got {ranks}")
                ranks = range(len(cur) - ranks, len(cur))
            retire = sorted({int(r) for r in ranks})
            if not retire:
                raise ValueError("shrink() needs at least one rank")
            bad = [r for r in retire if not 0 <= r < len(cur)]
            if bad:
                raise ValueError(
                    f"shrink() ranks {bad} out of range for {len(cur)} executors")
            if len(retire) >= len(cur):
                raise ValueError(
                    f"shrink({retire}) would retire the whole {len(cur)}-rank "
                    f"world; at least one executor must survive")
            gone = set(retire)
            return self._resize([d for i, d in enumerate(cur) if i not in gone])

    def _resize(self, new_devices: list) -> int:
        """Swap the base communicator onto ``new_devices`` under a full
        drain: the worker job lock plus every pinned group lock (the
        ``groups()`` splits gang tasks serialise on) are held, so in-flight
        tasks finish on the OLD communicator and later submissions bind the
        resized mesh via ``worker.context``. Ad-hoc caller-built groups are
        not drained — the same tolerated oversubscription as group-lock
        eviction (DESIGN.md §8); their tasks keep computing on their own
        (stale but intact) sub-meshes. Call from a driver thread that holds
        no job locks."""
        old = self._base_context
        with self._groups_guard:
            drain = [lock for (_c, lock, pinned) in self._group_locks.values()
                     if pinned]
        held = []
        self._job_lock.acquire()
        held.append(self._job_lock)
        for lk in drain:
            lk.acquire()
            held.append(lk)
        try:
            old_devs = self._world_devices()
            old_world = frozenset(old_devs)
            new_ctx = IContext(
                compat.make_mesh_of(np.asarray(new_devices),
                                    old.mesh.axis_names),
                old.axis, self.cluster.props, self)
            new_ctx._vars = dict(old._vars)
            self._base_context = new_ctx
            # the blacklist is rank-indexed: re-key it by device identity
            # (a blacklisted rank whose device was retired is simply gone)
            dev_rank = {d: i for i, d in enumerate(new_devices)}
            self.executor_blacklist = {
                dev_rank[old_devs[r]] for r in self.executor_blacklist
                if r < len(old_devs) and old_devs[r] in dev_rank}
            # cached splits of the old world are stale; groups() also
            # revalidates by base identity, this just frees the locks now
            with self._groups_guard:
                for _base, gs in self._groups.values():
                    for g in gs:
                        self._group_locks.pop(id(g), None)
                self._groups.clear()
            from repro.distributed.elastic import reshard_cached

            moves, kept, recomputes = reshard_cached(self, old_world, new_ctx)
            st = self.elastic_stats
            st["grows" if len(new_devices) > len(old_devs) else "shrinks"] += 1
            st["world_size"] = len(new_devices)
            st["reshard_moves"] += moves
            st["reshard_unchanged"] += kept
            st["reshard_recomputes"] += recomputes
            return len(new_devices)
        finally:
            for lk in reversed(held):
                lk.release()

    # ------------------------------------------------------------------
    # executor failure (paper §3.5: container loss + blacklist)
    # ------------------------------------------------------------------
    def _register_cached(self, node: TaskNode):
        """Track a node holding materialised blocks (persist / parallelize /
        checkpoint) so a simulated executor loss can take its shard."""
        self._cached_nodes.add(node)

    def kill_executor(self, rank: int, blacklist: bool = True) -> int:
        """Simulate losing the container of executor ``rank``: every cached
        node of this worker loses its ``rank``-th block (the paper's
        partition-per-executor model — repair recomputes them from lineage
        or restores them from a checkpoint on the next action), and the
        rank is blacklisted so new communicator groups avoid it until
        ``restore_executor``. Returns the number of blocks lost."""
        killed = 0
        for node in list(self._cached_nodes):
            if (node.result is not None and rank < len(node.result)
                    and node.result[rank] is not None):
                DagEngine.kill_block(node, rank)
                killed += 1
        if blacklist:
            self.executor_blacklist.add(int(rank))
        return killed

    def restore_executor(self, rank: int):
        """Lift the blacklist for a recovered/replaced executor."""
        self.executor_blacklist.discard(int(rank))

    # ------------------------------------------------------------------
    # introspection: stage compilation (DESIGN.md §5)
    # ------------------------------------------------------------------
    def explain(self, df: IDataFrame) -> str:
        """Physical plan of a frame's lineage — fused stages + boundaries,
        shuffle capacity annotations, shuffle telemetry."""
        return df.explain()

    def metrics(self, path: str | None = None) -> dict:
        """The worker's namespaced metrics tree (docs/profiling.md §metrics):
        ``stages/`` (DagEngine), ``shuffle/`` (ShuffleManager), ``kernels/``
        (kernel tier), ``coll/`` (process-wide collective engine), and
        ``profile/`` once a tracer is mounted. ``path`` selects one subtree
        (``metrics("stages")``); unknown paths raise ``KeyError``."""
        return self._metrics.snapshot(path)

    def mount_metrics(self, name: str, source) -> None:
        """Mount (or re-mount) a counter namespace on this worker's metrics
        tree — how JobTracer exposes ``profile/`` (docs/profiling.md)."""
        self._metrics.mount(name, source)

    def stage_stats(self) -> dict:
        """Deprecated facade over ``metrics("stages")`` — engine telemetry
        snapshot: node/block computes, fused stage runs, plan-cache
        hits/misses/evictions. Same keys as always."""
        warn_deprecated("IWorker.stage_stats()", 'IWorker.metrics("stages")')
        return self._metrics.snapshot("stages")

    def shuffle_stats(self) -> dict:
        """Deprecated facade over the ``shuffle`` + ``kernels`` + ``coll``
        metrics subtrees, merged flat exactly as before PR 9: adaptive
        shuffle engine telemetry (DESIGN.md §6) — exchanges, overflow/
        fan-out retries, deferred checks, capacity-memory hits, wide-plan
        compiles/hits, bytes moved — plus the kernel tier's selection/
        autotune counters (docs/kernels.md) and the collective engine's
        persistent-plan and handle counters (DESIGN.md §10; process-wide,
        so two workers sharing one mesh see one set of plan counters)."""
        warn_deprecated("IWorker.shuffle_stats()",
                        'IWorker.metrics("shuffle"/"kernels"/"coll")')
        return {**self._metrics.snapshot("shuffle"),
                **self._metrics.snapshot("kernels"),
                **self._metrics.snapshot("coll")}

    # ------------------------------------------------------------------
    # data ingestion (driver communicator)
    # ------------------------------------------------------------------
    @property
    def executors(self) -> int:
        return self.context.executors

    def _put(self, x):
        from jax.sharding import NamedSharding, PartitionSpec as P

        return jax.device_put(x, NamedSharding(self.context.mesh, P(self.context.axis)))

    def parallelize(self, rows, blocks: int = 1) -> IDataFrame:
        p = self.executors
        if blocks <= 1:
            blk = [from_host(rows, p, put=self._put)]
        else:
            per = (len(rows) + blocks - 1) // blocks
            blk = [
                from_host(rows[i * per : (i + 1) * per], p, put=self._put)
                for i in range(blocks)
                if len(rows[i * per : (i + 1) * per])
            ]
        node = TaskNode("parallelize", [], fn=lambda _: blk, narrow=False)
        node.result = blk
        node.cached = True
        self._register_cached(node)
        # structural source signature: re-parallelizing same-shaped data maps
        # to the same lineage signature (shuffle capacity memory, DESIGN.md §6)
        node.sig = ("src", tuple(block_aval(b) for b in blk))
        return IDataFrame(self, node)

    def text_file(self, path: str, as_tokens: bool = False, blocks: int = 1):
        """Read a text file. Rows are (line-hash, length) pairs unless
        ``as_tokens`` — then the host tokenizer (the 'modality frontend' of
        text) maps words to ids and rows are token ids."""
        with open(path) as f:
            lines = [l.rstrip("\n") for l in f]
        if as_tokens:
            vocab: dict[str, int] = {}
            toks = []
            for line in lines:
                for w in line.split():
                    toks.append(vocab.setdefault(w, len(vocab)))
            self._text_vocab = vocab
            return self.parallelize(np.asarray(toks, np.int32), blocks)
        self._text_lines = lines
        rows = np.asarray([[hash(l) & 0x7FFFFFFF, len(l)] for l in lines], np.int32)
        return self.parallelize(rows, blocks)

    textFile = text_file

    def partition_json_file(self, path: str) -> IDataFrame:
        import json

        with open(path) as f:
            data = json.load(f)
        return self.parallelize(np.asarray(data))

    partitionJsonFile = partition_json_file

    # ------------------------------------------------------------------
    # inter-worker communicator (paper Fig. 4: importData)
    # ------------------------------------------------------------------
    def import_data(self, df: IDataFrame) -> IDataFrame:
        src_worker = df.worker

        def fn(parent_results):
            faults.check("reshard", kind="importData", src=src_worker.name,
                         dst=self.name)
            out = []
            for b in parent_results[0]:
                if self.mode == "spark" or src_worker.mode == "spark":
                    # the paper's pipe: serialize → host → deserialize
                    data = pickle.loads(pickle.dumps(jax.device_get(b.data)))
                    valid = np.asarray(jax.device_get(b.valid))
                    out.append(
                        Block(
                            jax.tree.map(self._put, data),
                            self._put(valid),
                        )
                    )
                else:
                    # on-fabric reshard: MPI inter-worker communicator
                    out.append(
                        Block(jax.tree.map(self._put, b.data), self._put(b.valid))
                    )
            return out

        node = TaskNode("importData", [df.node], fn=fn, narrow=False)
        return IDataFrame(self, node)

    importData = import_data

    # ------------------------------------------------------------------
    # native SPMD apps (paper §5)
    # ------------------------------------------------------------------
    def load_library(self, path_or_module: str) -> list[str]:
        names = load_library(path_or_module)
        self._libraries.extend(names)
        return names

    loadLibrary = load_library

    def _resolve_app(self, fn_name, params):
        """Resolve (app callable, display name, merged params, sig token)
        from a registry name, a callable, or an ISource with addParams."""
        if isinstance(fn_name, ISource):
            src, params = fn_name.fn, {**fn_name.params, **params}
        else:
            src = fn_name
        app = get_app(src) if isinstance(src, str) else src
        name = src if isinstance(src, str) else getattr(src, "__name__", "app")
        isrc = ISource(src)
        isrc.params = dict(params)
        return app, name, params, isrc.token()

    @staticmethod
    def _native_args(ctx, parent_results):
        """Materialise a native app's data args on the app's communicator.
        Under gang scheduling the bound ctx is a group sub-mesh while parent
        blocks may live on the world mesh (or another group) — the
        device_put here is the inter-group reshard edge for native tasks."""
        if not parent_results:
            return ()
        faults.check("reshard", kind="native")
        b = place_block(concat_blocks(parent_results[0]), ctx.mesh, ctx.axis)
        return (b.data, b.valid)

    def void_call_async(self, fn_name, df: IDataFrame | None = None, job=None,
                        **params):
        """Async voidCall: the app runs as a native TaskNode inside the job
        DAG — it appears in job explain()/stats, executes under the worker's
        job lock, and gets the same scheduling/fault path as ``call`` instead
        of firing eagerly outside the graph. Returns an IFuture resolving to
        the app's return value.

        ``job`` is reserved for the IJob here; an app parameter literally
        named "job" must go through ``ISource.add_param`` (the eager
        ``void_call`` keeps the unrestricted param namespace)."""
        return self._void_call_task(fn_name, df, params, job)

    def _void_call_task(self, fn_name, df, params: dict, job):
        app, name, params, tok = self._resolve_app(fn_name, params)
        parents = [df.node] if df is not None else []
        worker = self
        out_cell: dict = {}

        def fn(parent_results):
            ctx = worker.context.bind(params)  # execution-time binding
            out_cell["value"] = app(ctx, *worker._native_args(ctx, parent_results))
            return []  # void: no blocks enter the lineage

        node = TaskNode(f"voidCall:{name}", parents, fn=fn, narrow=False)
        node.task_kind = "native"
        node.owner = self
        node.sig = ("native", "voidCall", tok, *(node_sig(p) for p in parents))
        frame = IDataFrame(self, node)

        def task_fn(memo):
            worker.engine.evaluate(node, memo=memo)
            return out_cell.get("value")

        return frame._submit("voidCall", task_fn=task_fn, job=job)

    def void_call(self, fn_name, df: IDataFrame | None = None, **params):
        """Run a native app for effect (paper's voidCall) — facade over the
        async path. Params pass through verbatim (an app param named "job"
        reaches the app's context; only the async variant reserves it)."""
        return self._void_call_task(fn_name, df, params, None).result()

    def call(self, fn_name, df: IDataFrame | None = None, **params) -> IDataFrame:
        """Run a native app returning rows → IDataFrame (paper's call).

        The node is a first-class lineage citizen: the child IContext is
        bound when the task EXECUTES (late ``set_var`` updates are visible),
        and the (app, params) token is part of ``node.sig`` so downstream
        plan/capacity caches key on the actual call."""
        app, name, params, tok = self._resolve_app(fn_name, params)
        parents = [df.node] if df is not None else []
        worker = self

        def fn(parent_results):
            ctx = worker.context.bind(params)  # execution-time binding
            out = app(ctx, *worker._native_args(ctx, parent_results))
            if comm_mod.is_handle(out):
                # app handed back an in-flight collective: keep it
                # nonblocking — chain the Block adaptation onto the handle
                # and let the engine/scheduler await it (dag.py _compute)
                return out.chain(
                    lambda v: [v] if isinstance(v, Block) else [Block(*v)])
            if isinstance(out, Block):
                return [out]
            data, valid = out
            return [Block(data, valid)]

        node = TaskNode(f"call:{name}", parents, fn=fn, narrow=False)
        node.task_kind = "native"
        node.owner = self
        node.sig = ("native", "call", tok, *(node_sig(p) for p in parents))
        return IDataFrame(self, node)

    def call_partitions(self, fn_name, df: IDataFrame, **params) -> IDataFrame:
        """Partition-preserving native call: the app runs once per block
        with the worker communicator — no ``_merged()`` collapse. The node
        is narrow with block-wise lineage, so it composes with caching,
        stage boundaries, and ``kill_block`` repair (only the lost block
        re-runs the app)."""
        app, name, params, tok = self._resolve_app(fn_name, params)
        worker = self

        def block_fn(parent_blocks):
            ctx = worker.context.bind(params)  # execution-time binding
            b = parent_blocks[0]
            out = app(ctx, b.data, b.valid)
            if comm_mod.is_handle(out):
                out = out.wait()  # block-wise lineage is the sync point here
            if isinstance(out, Block):
                return out
            data, valid = out
            return Block(data, valid)

        node = TaskNode(
            f"callPartitions:{name}", [df.node], block_fn=block_fn, narrow=True
        )
        node.task_kind = "native"
        node.owner = self
        node.sig = ("native", "callPartitions", tok, node_sig(df.node))
        return IDataFrame(self, node)

    voidCall = void_call
    voidCallAsync = void_call_async
    callPartitions = call_partitions

    # ------------------------------------------------------------------
    # spark-mode pipe simulation (paper §2.1: system pipes outside the JVM)
    # ------------------------------------------------------------------
    # PySpark serializes RDD elements through the JVM↔worker pipe in pickle
    # batches (default batchSize=1024) — per-ELEMENT object serialization,
    # not one bulk buffer. That is the cost the paper measures (§2.1, §6.2);
    # we model it faithfully.
    _PIPE_BATCH = 1024

    def _pipe_block(self, b: Block) -> Block:
        """Charge the pipe cost: device→host, per-element pickle of every
        (valid) row in PySpark-sized batches, host→device. The data itself is
        returned unchanged — this models serialization cost, not semantics."""
        data = jax.device_get(b.data)
        valid = np.asarray(jax.device_get(b.valid))
        leaves, _ = jax.tree_util.tree_flatten(data)
        idx = np.nonzero(valid)[0]
        for lo in range(0, len(idx), self._PIPE_BATCH):
            sel = idx[lo : lo + self._PIPE_BATCH]
            batch = [[np.asarray(l[i]) for l in leaves] for i in sel]
            pickle.loads(pickle.dumps(batch))  # the JVM↔worker pipe
        return Block(jax.tree.map(self._put, data), self._put(valid))

    def _pipe_wrap(self, block_fn):
        def wrapped(parent_blocks):
            return self._pipe_block(block_fn(parent_blocks))

        return wrapped

    def _pipe_wrap_wide(self, node_fn):
        """Spark's shuffle path: results serialize through the host (JVM)."""

        def wrapped(parent_results):
            return [self._pipe_block(b) for b in node_fn(parent_results)]

        return wrapped
