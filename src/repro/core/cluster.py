"""Ignis / ICluster / IWorker — the job hierarchy (paper §3.2, Fig. 2).

A *Cluster* owns a device mesh (its "containers"); *Workers* are
programming-model execution contexts on that mesh — the multi-language
adaptation (DESIGN.md §2): instead of a Python worker and a C++ worker, a
job creates dataflow workers and SPMD workers that interoperate through
``importData`` (the inter-worker communicator: a resharding device_put on
the same fabric, zero host round-trips) — or, in "spark" mode, through the
serialize→host→deserialize pipe the paper benchmarks against.
"""
from __future__ import annotations

import pickle
from typing import Optional

import jax
import numpy as np

from repro.core import compat
from repro.core.context import IContext
from repro.core.dag import DagEngine, TaskNode
from repro.core.shuffle_plan import ShuffleManager
from repro.core.dataframe import IDataFrame
from repro.core.native import get_app, load_library
from repro.core.partition import Block, block_aval, from_host
from repro.core.properties import IProperties
from repro.core.textlambda import ISource


class Ignis:
    """Framework lifecycle (paper Fig. 6 lines 6/42)."""

    _started = False

    @classmethod
    def start(cls):
        cls._started = True

    @classmethod
    def stop(cls):
        cls._started = False

    @classmethod
    def running(cls) -> bool:
        return cls._started


class ICluster:
    """A group of executor containers = a device mesh slice (paper §3.2)."""

    def __init__(self, props: Optional[IProperties] = None, mesh=None):
        self.props = props or IProperties()
        if mesh is None:
            n = min(
                self.props.get_int("ignis.executor.instances", 1), len(jax.devices())
            )
            mesh = compat.make_mesh((max(n, 1),), ("data",))
        self.mesh = mesh
        self.workers: list[IWorker] = []

    # paper §4: remote commands to containers — host-side here
    def execute(self, fn, *args, **kw):
        return fn(*args, **kw)

    def execute_script(self, src: str):
        scope = {}
        exec(src, scope)  # noqa: S102
        return scope

    def send_file(self, src: str, dst: str):
        with open(src, "rb") as f, open(dst, "wb") as g:
            g.write(f.read())

    sendFile = send_file
    executeScript = execute_script


class IWorker:
    """One programming-model context bound to a cluster (paper §3.2).

    kind: "dataflow" (IDataFrame ops) | "spmd" (native collective apps).
    Both share the cluster mesh — that is the paper's whole point.
    """

    def __init__(self, cluster: ICluster, kind: str = "dataflow", name: str = ""):
        if kind in ("python", "cpp", "java"):  # paper-style language names
            kind = "dataflow"
        self.cluster = cluster
        self.kind = kind
        self.name = name or f"{kind}-{len(cluster.workers)}"
        self.context = IContext(cluster.mesh, "data", cluster.props, self)
        self.engine = DagEngine(
            fusion=cluster.props.get_bool("ignis.fusion.enabled", True),
            plan_cache_size=cluster.props.get_int("ignis.fusion.plan.cache.size", 128),
        )
        self.mode = cluster.props.get("ignis.mode", "ignis")
        self.capacity_factor = cluster.props.get_float("ignis.shuffle.capacity.factor", 2.0)
        self.join_max_matches = cluster.props.get_int("ignis.join.max.matches", 8)
        self.shuffle = ShuffleManager(
            self.context,
            capacity_factor=self.capacity_factor,
            join_max_matches=self.join_max_matches,
            plan_cache_size=cluster.props.get_int("ignis.shuffle.plan.cache.size", 64),
            headroom=cluster.props.get_float("ignis.shuffle.memory.headroom", 1.25),
        )
        self._libraries: list[str] = []
        cluster.workers.append(self)

    # ------------------------------------------------------------------
    # introspection: stage compilation (DESIGN.md §5)
    # ------------------------------------------------------------------
    def explain(self, df: IDataFrame) -> str:
        """Physical plan of a frame's lineage — fused stages + boundaries,
        shuffle capacity annotations, shuffle telemetry."""
        return df.explain()

    def stage_stats(self) -> dict:
        """Engine telemetry snapshot: node/block computes, fused stage runs,
        plan-cache hits/misses/evictions."""
        return dict(self.engine.stats)

    def shuffle_stats(self) -> dict:
        """Adaptive shuffle engine telemetry (DESIGN.md §6): exchanges,
        overflow/fan-out retries, deferred checks, capacity-memory hits,
        wide-plan compiles/hits, bytes moved."""
        return dict(self.shuffle.stats)

    # ------------------------------------------------------------------
    # data ingestion (driver communicator)
    # ------------------------------------------------------------------
    @property
    def executors(self) -> int:
        return self.context.executors

    def _put(self, x):
        from jax.sharding import NamedSharding, PartitionSpec as P

        return jax.device_put(x, NamedSharding(self.context.mesh, P(self.context.axis)))

    def parallelize(self, rows, blocks: int = 1) -> IDataFrame:
        p = self.executors
        if blocks <= 1:
            blk = [from_host(rows, p, put=self._put)]
        else:
            per = (len(rows) + blocks - 1) // blocks
            blk = [
                from_host(rows[i * per : (i + 1) * per], p, put=self._put)
                for i in range(blocks)
                if len(rows[i * per : (i + 1) * per])
            ]
        node = TaskNode("parallelize", [], fn=lambda _: blk, narrow=False)
        node.result = blk
        node.cached = True
        # structural source signature: re-parallelizing same-shaped data maps
        # to the same lineage signature (shuffle capacity memory, DESIGN.md §6)
        node.sig = ("src", tuple(block_aval(b) for b in blk))
        return IDataFrame(self, node)

    def text_file(self, path: str, as_tokens: bool = False, blocks: int = 1):
        """Read a text file. Rows are (line-hash, length) pairs unless
        ``as_tokens`` — then the host tokenizer (the 'modality frontend' of
        text) maps words to ids and rows are token ids."""
        with open(path) as f:
            lines = [l.rstrip("\n") for l in f]
        if as_tokens:
            vocab: dict[str, int] = {}
            toks = []
            for line in lines:
                for w in line.split():
                    toks.append(vocab.setdefault(w, len(vocab)))
            self._text_vocab = vocab
            return self.parallelize(np.asarray(toks, np.int32), blocks)
        self._text_lines = lines
        rows = np.asarray([[hash(l) & 0x7FFFFFFF, len(l)] for l in lines], np.int32)
        return self.parallelize(rows, blocks)

    textFile = text_file

    def partition_json_file(self, path: str) -> IDataFrame:
        import json

        with open(path) as f:
            data = json.load(f)
        return self.parallelize(np.asarray(data))

    partitionJsonFile = partition_json_file

    # ------------------------------------------------------------------
    # inter-worker communicator (paper Fig. 4: importData)
    # ------------------------------------------------------------------
    def import_data(self, df: IDataFrame) -> IDataFrame:
        src_worker = df.worker

        def fn(parent_results):
            out = []
            for b in parent_results[0]:
                if self.mode == "spark" or src_worker.mode == "spark":
                    # the paper's pipe: serialize → host → deserialize
                    data = pickle.loads(pickle.dumps(jax.device_get(b.data)))
                    valid = np.asarray(jax.device_get(b.valid))
                    out.append(
                        Block(
                            jax.tree.map(self._put, data),
                            self._put(valid),
                        )
                    )
                else:
                    # on-fabric reshard: MPI inter-worker communicator
                    out.append(
                        Block(jax.tree.map(self._put, b.data), self._put(b.valid))
                    )
            return out

        node = TaskNode("importData", [df.node], fn=fn, narrow=False)
        return IDataFrame(self, node)

    importData = import_data

    # ------------------------------------------------------------------
    # native SPMD apps (paper §5)
    # ------------------------------------------------------------------
    def load_library(self, path_or_module: str) -> list[str]:
        names = load_library(path_or_module)
        self._libraries.extend(names)
        return names

    loadLibrary = load_library

    def _call_ctx(self, params: dict) -> IContext:
        ctx = self.context.child()
        for k, v in params.items():
            ctx.set_var(k, v)
        return ctx

    def void_call(self, fn_name, df: IDataFrame | None = None, **params):
        """Run a native app for effect (paper's voidCall)."""
        src = fn_name.fn if isinstance(fn_name, ISource) else fn_name
        if isinstance(fn_name, ISource):
            params = {**fn_name.params, **params}
        app = get_app(src) if isinstance(src, str) else src
        ctx = self._call_ctx(params)
        args = ()
        if df is not None:
            b = df._merged()
            args = (b.data, b.valid)
        return app(ctx, *args)

    def call(self, fn_name, df: IDataFrame | None = None, **params) -> IDataFrame:
        """Run a native app returning rows → IDataFrame (paper's call)."""
        src = fn_name.fn if isinstance(fn_name, ISource) else fn_name
        if isinstance(fn_name, ISource):
            params = {**fn_name.params, **params}
        app = get_app(src) if isinstance(src, str) else src
        ctx = self._call_ctx(params)
        parents = [df.node] if df is not None else []

        def fn(parent_results):
            args = ()
            if parent_results:
                from repro.core.partition import concat_blocks

                b = concat_blocks(parent_results[0])
                args = (b.data, b.valid)
            out = app(ctx, *args)
            if isinstance(out, Block):
                return [out]
            data, valid = out
            return [Block(data, valid)]

        return IDataFrame(self, TaskNode(f"call:{src}", parents, fn=fn, narrow=False))

    voidCall = void_call

    # ------------------------------------------------------------------
    # spark-mode pipe simulation (paper §2.1: system pipes outside the JVM)
    # ------------------------------------------------------------------
    # PySpark serializes RDD elements through the JVM↔worker pipe in pickle
    # batches (default batchSize=1024) — per-ELEMENT object serialization,
    # not one bulk buffer. That is the cost the paper measures (§2.1, §6.2);
    # we model it faithfully.
    _PIPE_BATCH = 1024

    def _pipe_block(self, b: Block) -> Block:
        """Charge the pipe cost: device→host, per-element pickle of every
        (valid) row in PySpark-sized batches, host→device. The data itself is
        returned unchanged — this models serialization cost, not semantics."""
        data = jax.device_get(b.data)
        valid = np.asarray(jax.device_get(b.valid))
        leaves, _ = jax.tree_util.tree_flatten(data)
        idx = np.nonzero(valid)[0]
        for lo in range(0, len(idx), self._PIPE_BATCH):
            sel = idx[lo : lo + self._PIPE_BATCH]
            batch = [[np.asarray(l[i]) for l in leaves] for i in sel]
            pickle.loads(pickle.dumps(batch))  # the JVM↔worker pipe
        return Block(jax.tree.map(self._put, data), self._put(valid))

    def _pipe_wrap(self, block_fn):
        def wrapped(parent_blocks):
            return self._pipe_block(block_fn(parent_blocks))

        return wrapped

    def _pipe_wrap_wide(self, node_fn):
        """Spark's shuffle path: results serialize through the host (JVM)."""

        def wrapped(parent_results):
            return [self._pipe_block(b) for b in node_fn(parent_results)]

        return wrapped
