"""Wide (shuffle-backed) operators on the collective fabric (paper §3.6, §6.2).

* PSRS distributed sort — Parallel Sorting by Regular Sampling, exactly the
  algorithm the paper uses for TeraSort: local sort → regular samples →
  all-gather → global pivots → bucket → all_to_all → local merge.
* hash exchange — reduceByKey/join/partitionBy routing (MPI_Alltoall).
* sorted segmented reduce — log-depth associative_scan over key segments
  (the jnp oracle of kernels/segment_reduce).
* sort-merge / hash join with bounded fan-out.

All fixed-shape: buckets are capacity-padded, overflow is *detected* (psum),
never silently dropped — the price of static shapes on a systolic machine
(DESIGN.md §1). This module is sync-free: every stage returns device scalars
``(overflow, max_fill)`` alongside its data, and the adaptive shuffle engine
(shuffle_plan.py, DESIGN.md §6) performs one deferred host check per wide
node, retries with a capacity derived from the observed ``max_fill``, and
remembers the fit for the next action.

Stages take a ``post`` hook — a per-shard local transform fused into the same
shard_map body — so sort→segment-heads→segmented-reduce chains (reduceByKey,
distinct, groupByKey) execute as ONE wide stage instead of three dispatches.
Post hooks are valid because PSRS/hash routing sends equal keys to one shard:
no key segment ever spans a shard boundary.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core.context import IContext
from repro.core.partition import Block


def _sentinel(dtype):
    """Largest value of dtype — sorts invalid rows to the tail."""
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(jnp.inf, dtype)
    return jnp.asarray(jnp.iinfo(dtype).max, dtype)


def _sentinel_low(dtype):
    """Smallest value of dtype — masks invalid rows out of an argmax."""
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(-jnp.inf, dtype)
    return jnp.asarray(jnp.iinfo(dtype).min, dtype)


def _hash_u32(x):
    """splitmix-style avalanche on int keys → uint32."""
    h = x.astype(jnp.uint32)
    h = (h ^ (h >> 16)) * jnp.uint32(0x7FEB352D)
    h = (h ^ (h >> 15)) * jnp.uint32(0x846CA68B)
    return h ^ (h >> 16)


def capacity_for(factor: float, n_local: int, p: int) -> int:
    """Per-destination bucket capacity for a given capacity factor.

    ``factor = p`` is the worst case: C = n_local fits even when every row
    of a shard routes to one destination."""
    return max(int(math.ceil(factor * n_local / p)), 1)


# ---------------------------------------------------------------------------
# pack-by-destination + all_to_all  (shared by PSRS and hash exchange)
# ---------------------------------------------------------------------------


def _pack_exchange(dest, payload, axis, p, C, route=None):
    """Inside shard_map: route rows to `dest` buckets with capacity C.

    dest: (n,) int32 in [0, p); payload: pytree of (n, …) leaves (must include
    its own validity leaf). Returns (pytree of (p·C, …), overflow, max_fill).
    Dropped rows (bucket overflow) are counted, not silently lost; max_fill is
    the largest bucket demand observed — the capacity that *would* have fit,
    independent of C, so one retry sized from it always succeeds.

    ``route`` (optional) is a kernel-backed router ``dest -> (pos, keep,
    counts)`` (kernels/moe_route.bucket_route, docs/kernels.md): capacity
    ordinals in row order — exactly the rank the stable argsort below
    assigns, so kept rows land in the same unique slots and the packed
    buffer is bit-identical; only the sliced-off overflow scratch slot can
    differ (duplicate writes, different order).
    """
    n = dest.shape[0]
    if route is not None:
        pos, keep, counts = route(dest)
        order = None  # rows scatter from row order directly
        slot = jnp.where(keep, dest * C + pos, p * C)
    else:
        order = jnp.argsort(dest, stable=True)
        ds = dest[order]
        counts = jnp.bincount(ds, length=p)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(n) - starts[ds]
        keep = pos < C
        slot = jnp.where(keep, ds * C + pos, p * C)  # overflow → scratch slot
    overflow = (n - keep.sum()).astype(jnp.int32)
    max_fill = counts.max().astype(jnp.int32)

    def pack(x):
        xs = x if order is None else x[order]
        buf = jnp.zeros((p * C + 1, *x.shape[1:]), x.dtype)
        buf = buf.at[slot].set(xs)
        return buf[: p * C]

    packed = jax.tree.map(pack, payload)

    def xchg(x):
        y = x.reshape(p, C, *x.shape[1:])
        y = jax.lax.all_to_all(y, axis, split_axis=0, concat_axis=0, tiled=False)
        return y.reshape(p * C, *x.shape[1:])

    return jax.tree.map(xchg, packed), overflow, max_fill


# ---------------------------------------------------------------------------
# fused wide stages (PSRS sort / hash exchange + local post-transform)
# ---------------------------------------------------------------------------


def _passthrough(k, v, d):
    return d, v


def sort_stage(ctx: IContext, keys, valid, data, C: int, post=None):
    """One fused wide sort stage, no host syncs.

    PSRS exchange + local merge + ``post`` (a per-shard local transform;
    default returns ``(data, valid)``) traced as a single computation.
    Returns ``(post_out, overflow, max_fill)`` — the scalars are replicated
    int32 device values; the caller decides when (if ever) to sync on them.
    """
    post = post or _passthrough
    p = ctx.executors
    zero = jnp.zeros((), jnp.int32)
    if p == 1:
        big = _sentinel(keys.dtype)
        order = jnp.argsort(jnp.where(valid, keys, big), stable=True)
        out = post(keys[order], valid[order], jax.tree.map(lambda x: x[order], data))
        return out, zero, zero

    n_local = keys.shape[0] // p

    def f(k, v, d):
        big = _sentinel(k.dtype)
        ks = jnp.where(v, k, big)
        order = jnp.argsort(ks, stable=True)
        ks, vs = ks[order], v[order]
        ds = jax.tree.map(lambda x: x[order], d)
        korig = k[order]
        # regular sampling: p evenly spaced local samples
        idx = ((jnp.arange(1, p + 1) * n_local) // (p + 1)).astype(jnp.int32)
        samples = ks[idx]
        all_samples = jax.lax.all_gather(samples, ctx.axis, tiled=True)  # (p·p,)
        pivots = jnp.sort(all_samples)[p - 1 :: p][: p - 1]
        dest = jnp.searchsorted(pivots, ks, side="right").astype(jnp.int32)
        payload = {"k": korig, "valid": vs, "data": ds}
        out, overflow, fill = _pack_exchange(dest, payload, ctx.axis, p, C)
        # local merge
        big2 = _sentinel(out["k"].dtype)
        km = jnp.where(out["valid"], out["k"], big2)
        order2 = jnp.argsort(km, stable=True)
        res = jax.tree.map(lambda x: x[order2], out)
        return (
            post(res["k"], res["valid"], res["data"]),
            jax.lax.psum(overflow, ctx.axis),
            jax.lax.pmax(fill, ctx.axis),
        )

    fn = compat.shard_map(
        f,
        mesh=ctx.mesh,
        in_specs=(P(ctx.axis), P(ctx.axis), P(ctx.axis)),
        out_specs=(P(ctx.axis), P(), P()),
    )
    return fn(keys, valid, data)


def hash_stage(ctx: IContext, keys, valid, data, C: int, post=None, route=None):
    """One fused wide hash-exchange stage (partitionBy / reduce routing), no
    host syncs. Same contract as ``sort_stage``; equal keys land on one
    executor but arrive unsorted. ``route`` is the optional kernel-backed
    bucket router (see ``_pack_exchange``)."""
    post = post or _passthrough
    p = ctx.executors
    zero = jnp.zeros((), jnp.int32)
    if p == 1:
        return post(keys, valid, data), zero, zero

    def f(k, v, d):
        dest = (_hash_u32(k) % jnp.uint32(p)).astype(jnp.int32)
        dest = jnp.where(v, dest, p - 1)  # park invalid rows anywhere stable
        payload = {"k": k, "valid": v, "data": d}
        out, overflow, fill = _pack_exchange(dest, payload, ctx.axis, p, C, route)
        return (
            post(out["k"], out["valid"], out["data"]),
            jax.lax.psum(overflow, ctx.axis),
            jax.lax.pmax(fill, ctx.axis),
        )

    fn = compat.shard_map(
        f,
        mesh=ctx.mesh,
        in_specs=(P(ctx.axis), P(ctx.axis), P(ctx.axis)),
        out_specs=(P(ctx.axis), P(), P()),
    )
    return fn(keys, valid, data)


def join_stage(ctx: IContext, lk, lvalid, lvals, rk, rvalid, rvals,
               Cl: int, Cr: int, M: int, route_l=None, route_r=None):
    """Both-side hash exchange + local sort-merge join in ONE wide stage.

    Returns ``(rows, ok, exch_overflow, lfill, rfill, fan_overflow)`` — four
    replicated int32 scalars fetched by the caller in a single deferred sync:
    exchange overflow retries with capacities sized from the fills; fan-out
    overflow retries with a doubled per-key match bound M. ``route_l`` /
    ``route_r`` are per-side kernel-backed bucket routers (capacity-specific:
    Cl ≠ Cr — see ``_pack_exchange``).
    """
    p = ctx.executors
    zero = jnp.zeros((), jnp.int32)
    if p == 1:
        rows, ok, fovf = local_join(lk, lvalid, lvals, rk, rvalid, rvals, M)
        return rows, ok, zero, zero, zero, fovf.astype(jnp.int32)

    def f(lk_, lv_, ld_, rk_, rv_, rd_):
        ldest = jnp.where(lv_, (_hash_u32(lk_) % jnp.uint32(p)).astype(jnp.int32), p - 1)
        rdest = jnp.where(rv_, (_hash_u32(rk_) % jnp.uint32(p)).astype(jnp.int32), p - 1)
        lout, lovf, lfill = _pack_exchange(
            ldest, {"k": lk_, "valid": lv_, "data": ld_}, ctx.axis, p, Cl, route_l)
        rout, rovf, rfill = _pack_exchange(
            rdest, {"k": rk_, "valid": rv_, "data": rd_}, ctx.axis, p, Cr, route_r)
        rows, ok, fovf = local_join(
            lout["k"], lout["valid"], lout["data"],
            rout["k"], rout["valid"], rout["data"], M)
        return (
            rows,
            ok,
            jax.lax.psum(lovf + rovf, ctx.axis),
            jax.lax.pmax(lfill, ctx.axis),
            jax.lax.pmax(rfill, ctx.axis),
            jax.lax.psum(fovf.astype(jnp.int32), ctx.axis),
        )

    fn = compat.shard_map(
        f,
        mesh=ctx.mesh,
        in_specs=(P(ctx.axis),) * 6,
        out_specs=(P(ctx.axis), P(ctx.axis), P(), P(), P(), P()),
    )
    return fn(lk, lvalid, lvals, rk, rvalid, rvals)


# ---------------------------------------------------------------------------
# legacy single-shot wrappers (direct-primitive tests; no retry, no memory)
# ---------------------------------------------------------------------------


def psrs_sort(ctx: IContext, keys, valid, data, capacity_factor=2.0):
    """Distributed sort by `keys`. All inputs axis-sharded on dim 0.

    Returns (keys', valid', data', overflow) — globally sorted (shard i holds
    keys ≤ shard i+1), invalid rows pushed to the tail of each shard."""
    p = ctx.executors
    C = capacity_for(capacity_factor, keys.shape[0] // max(p, 1), p)
    out, ovf, _ = sort_stage(ctx, keys, valid, data, C, post=lambda k, v, d: (k, v, d))
    k, v, d = out
    return k, v, d, ovf


def hash_exchange(ctx: IContext, keys, valid, data, capacity_factor=2.0):
    """Route rows so equal keys land on the same executor. Same-shape padded
    output + overflow count."""
    p = ctx.executors
    if p == 1:
        return keys, valid, data, jnp.zeros((), jnp.int32)
    C = capacity_for(capacity_factor, keys.shape[0] // p, p)
    out, ovf, _ = hash_stage(ctx, keys, valid, data, C, post=lambda k, v, d: (k, v, d))
    k, v, d = out
    return k, v, d, ovf


# ---------------------------------------------------------------------------
# sorted segmented reduce (jnp oracle of kernels/segment_reduce)
# ---------------------------------------------------------------------------


def segment_heads(keys, valid):
    prev = jnp.concatenate([keys[:1], keys[:-1]])
    first = jnp.arange(keys.shape[0]) == 0
    return valid & (first | (keys != prev) | ~jnp.concatenate([valid[:1], valid[:-1]]))


def segmented_reduce(keys, valid, values, fn, identity):
    """Reduce consecutive equal-key runs (keys must be sorted, invalid at
    arbitrary positions). Returns (head_mask, reduced_values_at_heads).

    fn: associative binary row fn (pytrees); identity: row pytree.
    """
    n = keys.shape[0]
    heads = segment_heads(keys, valid)
    heads_ext = heads | ~valid

    vals = jax.tree.map(
        lambda x, i: jnp.where(
            valid.reshape((-1,) + (1,) * (x.ndim - 1)), x, jnp.asarray(i, x.dtype)
        ),
        values,
        identity,
    )

    def comb(a, b):
        va, ha = a
        vb, hb = b
        merged = fn(va, vb)
        v = jax.tree.map(
            lambda m, y: jnp.where(hb.reshape((-1,) + (1,) * (y.ndim - 1)), y, m),
            merged,
            vb,
        )
        return (v, ha | hb)

    scanned, _ = jax.lax.associative_scan(comb, (vals, heads_ext))
    # last row of each segment = (next head_ext) - 1
    idx = jnp.arange(n)
    head_pos = jnp.where(heads_ext, idx, n)
    suff_min = jax.lax.cummin(head_pos[::-1])[::-1]
    nxt = jnp.concatenate([suff_min[1:], jnp.full((1,), n)])
    last_pos = jnp.clip(jnp.where(nxt >= n, n - 1, nxt - 1), 0, n - 1)
    out = jax.tree.map(lambda s: s[last_pos], scanned)
    return heads, out


# ---------------------------------------------------------------------------
# post hooks: the sort→heads→reduce fusion targets (run per shard inside the
# wide stage — valid because equal keys never span shards)
# ---------------------------------------------------------------------------


def heads_post(keys, valid, data):
    """distinct: keep the first row of every equal-key run."""
    return data, segment_heads(keys, valid)


def make_reduce_post(fn, identity):
    """reduceByKey: segmented reduce fused into the sort stage."""

    def post(keys, valid, data):
        heads, red = segmented_reduce(keys, valid, data["value"], fn, identity)
        return {"key": data["key"], "value": red}, heads

    return post


def make_reduce_post_kernel(op: str, identity, block: int, interpret: bool):
    """reduceByKey on the kernel tier (docs/kernels.md): the Pallas
    segmented scan + prefix pass replaces ``segmented_reduce``, fused into
    the same wide stage. Only built for values the registry recognized as
    a single supported-dtype leaf with a builtin op — bit-identical to
    ``make_reduce_post`` for associative-exact data."""
    from repro.kernels.segment_reduce.ops import segment_totals

    def post(keys, valid, data):
        leaves, treedef = jax.tree_util.tree_flatten(data["value"])
        ident = jax.tree_util.tree_leaves(identity)[0]
        heads, red = segment_totals(keys, valid, leaves[0], op=op,
                                    identity=ident, block=block,
                                    interpret=interpret)
        value = jax.tree_util.tree_unflatten(treedef, [red])
        return {"key": data["key"], "value": value}, heads

    return post


def make_bucket_route(p: int, C: int, block: int, interpret: bool):
    """Kernel-backed exchange router for ``_pack_exchange`` (module-level
    so plan-cache keys stay stable across rebuilds)."""
    from repro.kernels.moe_route.ops import bucket_route

    def route(dest):
        return bucket_route(dest, p, C, block=block, interpret=interpret)

    return route


def make_group_post(G: int):
    """groupByKey: G-bounded gather of each key run, fused into the sort
    stage. Rows (key, {items[G], mask[G], count}) at segment heads."""

    def post(keys, valid, data):
        heads = segment_heads(keys, valid)
        n = keys.shape[0]
        idx = jnp.arange(n)
        raw = idx[:, None] + jnp.arange(G)[None, :]
        gidx = jnp.clip(raw, 0, n - 1)
        same = (keys[gidx] == keys[:, None]) & valid[gidx] & (raw < n)
        vals = jax.tree.map(lambda x: x[gidx], data["value"])
        counts = same.sum(-1)
        return (
            {"key": data["key"], "value": {"items": vals, "mask": same, "count": counts}},
            heads,
        )

    return post


# ---------------------------------------------------------------------------
# local (post-exchange) join with bounded fan-out
# ---------------------------------------------------------------------------


def local_join(lk, lvalid, lvals, rk, rvalid, rvals, max_matches: int):
    """Sort-merge join on one shard. Returns dict rows of capacity n_left·M."""
    big = _sentinel(rk.dtype)
    rs = jnp.where(rvalid, rk, big)
    order = jnp.argsort(rs, stable=True)
    rs = rs[order]
    rv = jax.tree.map(lambda x: x[order], rvals)
    rvalid_s = rvalid[order]

    lo = jnp.searchsorted(rs, lk, side="left")
    hi = jnp.searchsorted(rs, lk, side="right")
    M = max_matches
    j = lo[:, None] + jnp.arange(M)[None, :]  # (n_left, M)
    ok = (j < hi[:, None]) & lvalid[:, None]
    jc = jnp.clip(j, 0, rs.shape[0] - 1)
    ok &= rvalid_s[jc]
    out_overflow = jnp.maximum(hi - lo - M, 0).sum()

    n = lk.shape[0]

    def expand_l(x):
        return jnp.repeat(x, M, axis=0)

    def take_r(x):
        return x[jc].reshape(n * M, *x.shape[1:])

    rows = {
        "key": expand_l(lk),
        "value": (jax.tree.map(expand_l, lvals), jax.tree.map(take_r, rv)),
    }
    return rows, ok.reshape(n * M), out_overflow
