"""Wide (shuffle-backed) operators on the collective fabric (paper §3.6, §6.2).

* PSRS distributed sort — Parallel Sorting by Regular Sampling, exactly the
  algorithm the paper uses for TeraSort: local sort → regular samples →
  all-gather → global pivots → bucket → all_to_all → local merge.
* hash exchange — reduceByKey/join/partitionBy routing (MPI_Alltoall).
* sorted segmented reduce — log-depth associative_scan over key segments
  (the jnp oracle of kernels/segment_reduce).
* sort-merge / hash join with bounded fan-out.

All fixed-shape: buckets are capacity-padded, overflow is detected (psum)
and the driver retries with worst-case capacity — the price of static shapes
on a systolic machine, recorded in DESIGN.md.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core.context import IContext
from repro.core.partition import Block


def _sentinel(dtype):
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(jnp.inf, dtype)
    return jnp.asarray(jnp.iinfo(dtype).max, dtype)


def _hash_u32(x):
    """splitmix-style avalanche on int keys → uint32."""
    h = x.astype(jnp.uint32)
    h = (h ^ (h >> 16)) * jnp.uint32(0x7FEB352D)
    h = (h ^ (h >> 15)) * jnp.uint32(0x846CA68B)
    return h ^ (h >> 16)


# ---------------------------------------------------------------------------
# pack-by-destination + all_to_all  (shared by PSRS and hash exchange)
# ---------------------------------------------------------------------------


def _pack_exchange(dest, payload, axis, p, C):
    """Inside shard_map: route rows to `dest` buckets with capacity C.

    dest: (n,) int32 in [0, p); payload: pytree of (n, …) leaves (must include
    its own validity leaf). Returns (pytree of (p·C, …), overflow_count).
    Dropped rows (bucket overflow) are counted, not silently lost.
    """
    n = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    ds = dest[order]
    counts = jnp.bincount(ds, length=p)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(n) - starts[ds]
    keep = pos < C
    slot = jnp.where(keep, ds * C + pos, p * C)  # overflow → scratch slot
    overflow = n - keep.sum()

    def pack(x):
        xs = x[order]
        buf = jnp.zeros((p * C + 1, *x.shape[1:]), x.dtype)
        buf = buf.at[slot].set(xs)
        return buf[: p * C]

    packed = jax.tree.map(pack, payload)

    def xchg(x):
        y = x.reshape(p, C, *x.shape[1:])
        y = jax.lax.all_to_all(y, axis, split_axis=0, concat_axis=0, tiled=False)
        return y.reshape(p * C, *x.shape[1:])

    return jax.tree.map(xchg, packed), overflow


# ---------------------------------------------------------------------------
# PSRS sort
# ---------------------------------------------------------------------------


def psrs_sort(ctx: IContext, keys, valid, data, capacity_factor=2.0):
    """Distributed sort by `keys`. All inputs axis-sharded on dim 0.

    Returns (keys', valid', data', overflow) — globally sorted (shard i holds
    keys ≤ shard i+1), invalid rows pushed to the tail of the last shard.
    Output has capacity_factor× the rows (padding).
    """
    p = ctx.executors
    if p == 1:
        big = _sentinel(keys.dtype)
        k = jnp.where(valid, keys, big)
        order = jnp.argsort(k, stable=True)
        return (
            keys[order],
            valid[order],
            jax.tree.map(lambda x: x[order], data),
            jnp.zeros((), jnp.int32),
        )

    n_local = keys.shape[0] // p
    C = max(int(math.ceil(capacity_factor * n_local / p)), 1)

    def f(k, v, d):
        big = _sentinel(k.dtype)
        ks = jnp.where(v, k, big)
        order = jnp.argsort(ks, stable=True)
        ks, vs = ks[order], v[order]
        ds = jax.tree.map(lambda x: x[order], d)
        korig = k[order]
        # regular sampling: p evenly spaced local samples
        idx = ((jnp.arange(1, p + 1) * n_local) // (p + 1)).astype(jnp.int32)
        samples = ks[idx]
        all_samples = jax.lax.all_gather(samples, ctx.axis, tiled=True)  # (p·p,)
        pivots = jnp.sort(all_samples)[p - 1 :: p][: p - 1]
        dest = jnp.searchsorted(pivots, ks, side="right").astype(jnp.int32)
        payload = {"k": korig, "valid": vs, "data": ds}
        out, overflow = _pack_exchange(dest, payload, ctx.axis, p, C)
        # local merge
        big2 = _sentinel(out["k"].dtype)
        km = jnp.where(out["valid"], out["k"], big2)
        order2 = jnp.argsort(km, stable=True)
        res = jax.tree.map(lambda x: x[order2], out)
        return res["k"], res["valid"], res["data"], jax.lax.psum(overflow, ctx.axis)

    fn = compat.shard_map(
        f,
        mesh=ctx.mesh,
        in_specs=(P(ctx.axis), P(ctx.axis), P(ctx.axis)),
        out_specs=(P(ctx.axis), P(ctx.axis), P(ctx.axis), P()),
    )
    return fn(keys, valid, data)


def sort_block(ctx: IContext, b: Block, key_fn, capacity_factor=2.0, ascending=True):
    keys = jax.vmap(key_fn)(b.data)
    if not ascending:
        keys = -keys
    k, v, d, ovf = psrs_sort(ctx, keys, b.valid, b.data, capacity_factor)
    if int(jax.device_get(ovf)) > 0:  # retry with worst-case capacity
        k, v, d, ovf = psrs_sort(ctx, keys, b.valid, b.data, float(ctx.executors))
    return Block(d, v), (k if ascending else -k)


# ---------------------------------------------------------------------------
# hash exchange (partitionBy / reduceByKey / join routing)
# ---------------------------------------------------------------------------


def hash_exchange(ctx: IContext, keys, valid, data, capacity_factor=2.0):
    """Route rows so equal keys land on the same executor. Same-shape padded
    output + overflow count."""
    p = ctx.executors
    if p == 1:
        return keys, valid, data, jnp.zeros((), jnp.int32)
    n_local = keys.shape[0] // p
    C = max(int(math.ceil(capacity_factor * n_local / p)), 1)

    def f(k, v, d):
        dest = (_hash_u32(k) % jnp.uint32(p)).astype(jnp.int32)
        dest = jnp.where(v, dest, p - 1)  # park invalid rows anywhere stable
        payload = {"k": k, "valid": v, "data": d}
        out, overflow = _pack_exchange(dest, payload, ctx.axis, p, C)
        return out["k"], out["valid"], out["data"], jax.lax.psum(overflow, ctx.axis)

    fn = compat.shard_map(
        f,
        mesh=ctx.mesh,
        in_specs=(P(ctx.axis), P(ctx.axis), P(ctx.axis)),
        out_specs=(P(ctx.axis), P(ctx.axis), P(ctx.axis), P()),
    )
    return fn(keys, valid, data)


# ---------------------------------------------------------------------------
# sorted segmented reduce (jnp oracle of kernels/segment_reduce)
# ---------------------------------------------------------------------------


def segment_heads(keys, valid):
    prev = jnp.concatenate([keys[:1], keys[:-1]])
    first = jnp.arange(keys.shape[0]) == 0
    return valid & (first | (keys != prev) | ~jnp.concatenate([valid[:1], valid[:-1]]))


def segmented_reduce(keys, valid, values, fn, identity):
    """Reduce consecutive equal-key runs (keys must be sorted, invalid at
    arbitrary positions). Returns (head_mask, reduced_values_at_heads).

    fn: associative binary row fn (pytrees); identity: row pytree.
    """
    n = keys.shape[0]
    heads = segment_heads(keys, valid)
    heads_ext = heads | ~valid

    vals = jax.tree.map(
        lambda x, i: jnp.where(
            valid.reshape((-1,) + (1,) * (x.ndim - 1)), x, jnp.asarray(i, x.dtype)
        ),
        values,
        identity,
    )

    def comb(a, b):
        va, ha = a
        vb, hb = b
        merged = fn(va, vb)
        v = jax.tree.map(
            lambda m, y: jnp.where(hb.reshape((-1,) + (1,) * (y.ndim - 1)), y, m),
            merged,
            vb,
        )
        return (v, ha | hb)

    scanned, _ = jax.lax.associative_scan(comb, (vals, heads_ext))
    # last row of each segment = (next head_ext) - 1
    idx = jnp.arange(n)
    head_pos = jnp.where(heads_ext, idx, n)
    suff_min = jax.lax.cummin(head_pos[::-1])[::-1]
    nxt = jnp.concatenate([suff_min[1:], jnp.full((1,), n)])
    last_pos = jnp.clip(jnp.where(nxt >= n, n - 1, nxt - 1), 0, n - 1)
    out = jax.tree.map(lambda s: s[last_pos], scanned)
    return heads, out


# ---------------------------------------------------------------------------
# local (post-exchange) join with bounded fan-out
# ---------------------------------------------------------------------------


def local_join(lk, lvalid, lvals, rk, rvalid, rvals, max_matches: int):
    """Sort-merge join on one shard. Returns dict rows of capacity n_left·M."""
    big = _sentinel(rk.dtype)
    rs = jnp.where(rvalid, rk, big)
    order = jnp.argsort(rs, stable=True)
    rs = rs[order]
    rv = jax.tree.map(lambda x: x[order], rvals)
    rvalid_s = rvalid[order]

    lo = jnp.searchsorted(rs, lk, side="left")
    hi = jnp.searchsorted(rs, lk, side="right")
    M = max_matches
    j = lo[:, None] + jnp.arange(M)[None, :]  # (n_left, M)
    ok = (j < hi[:, None]) & lvalid[:, None]
    jc = jnp.clip(j, 0, rs.shape[0] - 1)
    ok &= rvalid_s[jc]
    out_overflow = jnp.maximum(hi - lo - M, 0).sum()

    n = lk.shape[0]

    def expand_l(x):
        return jnp.repeat(x, M, axis=0)

    def take_r(x):
        return x[jc].reshape(n * M, *x.shape[1:])

    rows = {
        "key": expand_l(lk),
        "value": (jax.tree.map(expand_l, lvals), jax.tree.map(take_r, rv)),
    }
    return rows, ok.reshape(n * M), out_overflow
