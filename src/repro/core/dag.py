"""Lazy task-dependency graph with lineage fault tolerance (paper §3.5, Fig 3)
and stage compilation (DESIGN.md §5).

Driver calls register TaskNodes; nothing executes until an *action*. A node's
result is kept only for the duration of one action evaluation unless the user
``cache()``d it. Narrow nodes (map/filter/…) have block-wise lineage: block i
depends only on the parents' block i, so a lost cached block is recomputed
alone; wide nodes (shuffles) recompute whole-node. Executor/container tasks
(paper Fig. 3) correspond to the mesh existing — checked at evaluation.

Stage compilation: before an action runs, a planner pass collapses maximal
chains of fusable narrow nodes into ``FusedStage``s — one composed block
function, ``jax.jit``-compiled once per (op-chain signature, block avals) and
reused across blocks and across actions via the engine's compiled-plan cache.
This is the paper's §3.5 task pipelining (one executor task per stage, not
per operator) realised as XLA fusion: a map.filter.map chain costs one
dispatch and zero intermediate materialisations instead of three Python-level
block_fn calls. Fusion is an *overlay*: the constituent TaskNodes keep their
``block_fn``s, so lineage repair of a cached stage output still re-derives
individual blocks by walking the original narrow chain.
"""
from __future__ import annotations

import itertools
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core import comm, faults
from repro.core.metrics import Counters

_ids = itertools.count()


@dataclass
class TaskNode:
    op: str
    parents: list
    # fn(list_of_parent_block_lists) -> list[Block]      (wide)
    # block_fn(parent_blocks_at_i: list[Block]) -> Block (narrow)
    fn: Optional[Callable] = None
    block_fn: Optional[Callable] = None
    narrow: bool = False
    cached: bool = False
    # fusion metadata (DESIGN.md §5): a jit-traceable Block -> Block kernel
    # equivalent to block_fn for single-parent narrow ops, plus a hashable
    # signature component. None ⇒ the op is opaque to the planner (wide ops,
    # spark-mode pipe-wrapped ops, non-traceable partition fns).
    fuse_fn: Optional[Callable] = None
    fuse_key: Optional[tuple] = None
    # structural lineage signature (set by the dataframe layer): identifies
    # "the same computation" across actions and across re-built lineages —
    # the key of the shuffle engine's capacity memory (DESIGN.md §6). For
    # shuffle-backed wide ops, shuffle_sig is set (= sig) so explain() can
    # annotate the node with its capacity state.
    sig: Optional[tuple] = None
    shuffle_sig: Optional[tuple] = None
    # job-scheduler routing (core/job.py): the IWorker whose engine owns this
    # node, and the task class it maps to in a job DAG ("dataflow" | "native").
    # Owner is stamped by the driver layer (IDataFrame / worker.call) — an
    # edge whose endpoints have different owners is a cross-worker task
    # boundary; native nodes are always their own job task.
    owner: Optional[object] = None
    task_kind: str = "dataflow"
    # checkpoint-aware lineage (docs/fault_tolerance.md): a per-block loader
    # installed by IDataFrame.checkpoint(). When set, repair of a lost block
    # reads it back from stable storage instead of walking parents — the
    # node IS the truncation point of its lineage.
    restore_fn: Optional[Callable] = None
    id: int = field(default_factory=lambda: next(_ids))
    # runtime state
    result: Optional[list] = None  # list[Block] when materialised
    compute_count: int = 0  # telemetry for lineage tests

    def __hash__(self):
        return self.id

    def __eq__(self, other):
        return self is other


def node_sig(node: "TaskNode") -> tuple:
    """The node's structural signature, falling back to an id-unique tuple
    (still stable across repeated actions on the same node)."""
    return node.sig if node.sig is not None else ("id", node.id)


class FusedStage:
    """A maximal chain of fusable narrow nodes, head → tail.

    Interior nodes are never materialised; the stage's composed kernel maps a
    parent block straight to the tail's block. The tail keeps normal TaskNode
    semantics (memoisation, cache(), lineage repair)."""

    __slots__ = ("nodes", "signature")

    def __init__(self, nodes: list[TaskNode]):
        self.nodes = nodes  # head..tail order
        self.signature = tuple(n.fuse_key for n in nodes)

    @property
    def head(self) -> TaskNode:
        return self.nodes[0]

    @property
    def tail(self) -> TaskNode:
        return self.nodes[-1]

    def describe(self) -> str:
        return " -> ".join(n.op for n in self.nodes)


def _block_aval(block) -> tuple:
    from repro.core.partition import block_aval

    return block_aval(block)


class DagEngine:
    """Evaluates actions over the task graph with memoisation + lineage.

    ``fusion=True`` enables the stage-compilation planner; the compiled-plan
    cache holds up to ``plan_cache_size`` jitted stage kernels (LRU)."""

    def __init__(self, fusion: bool = True, plan_cache_size: int = 128,
                 fusion_mode: str = "static", cost_model=None):
        self.fusion = fusion
        # fusion boundary policy (docs/profiling.md §fusion): "static"
        # fuses every eligible chain; "cost" asks the cost model whether
        # the stage's XLA compile will pay for itself
        self.fusion_mode = fusion_mode
        self.cost_model = cost_model  # repro.profile.cost.CostModel | None
        # live span hook (docs/profiling.md): JobTracer.attach_worker sets
        # this to its buffer's record(name, cat, t0, t1, **args)
        self.trace_hook = None
        self.plan_cache_size = plan_cache_size
        self._plan_cache: "OrderedDict[tuple, Callable]" = OrderedDict()
        # gang-scheduled tasks (core/job.py) enter one engine from several
        # threads at once (disjoint sub-meshes of one worker); the LRU's
        # get+move/insert+evict sequences are not atomic under the GIL
        import threading

        self._plan_lock = threading.Lock()
        # the "stages/" namespace of the worker's metrics tree
        # (core/metrics.py; worker.stage_stats() is the legacy facade)
        self.stats = Counters("stages", {
            "node_computes": 0,
            "wide_computes": 0,
            "block_recomputes": 0,
            "fused_stages": 0,
            "fused_ops": 0,
            "plan_cache_hits": 0,
            "plan_cache_misses": 0,
            "plan_cache_evictions": 0,
            "iter_block_computes": 0,
            "block_restores": 0,  # blocks repaired from a checkpoint
            "speculative_retries": 0,  # straggler duplicates launched
            "handle_awaits": 0,  # CollHandle-valued node results awaited
            "fusion_deferred": 0,  # chains the cost policy left unfused
        })

    # ---- planner (stage compilation) ----------------------------------------
    @staticmethod
    def _fusable(node: TaskNode) -> bool:
        return (
            node.narrow
            and node.fuse_fn is not None
            and len(node.parents) == 1
            and node.result is None
        )

    def _walk(self, root: TaskNode):
        """Iterative post-order DFS → (order: parents-before-consumers,
        refs: consumer counts within the reachable graph). Mirrors _eval's
        short-circuit: the subgraph below a hole-free materialised node will
        never recompute, so it is not descended into — planning stays O(live
        graph) on iterative workloads with ever-growing lineage."""

        def expand(n: TaskNode):
            if n.result is not None and not self._has_holes(n):
                return iter(())
            return iter(n.parents)

        refs: dict[TaskNode, int] = {}
        order: list[TaskNode] = []
        seen = {root}
        stack: list[tuple[TaskNode, iter]] = [(root, expand(root))]
        while stack:
            node, it = stack[-1]
            child = next(it, None)
            if child is None:
                order.append(node)
                stack.pop()
                continue
            refs[child] = refs.get(child, 0) + 1
            if child not in seen:
                seen.add(child)
                stack.append((child, expand(child)))
        return order, refs

    def plan(self, root: TaskNode,
             observe: bool = True) -> dict[TaskNode, FusedStage]:
        """Plan the action: map each fused-stage *tail* to its FusedStage.

        A chain grows from a tail down through parents that are fusable, not
        cached, unmaterialised and single-consumer — every condition marks a
        node whose blocks someone else needs, i.e. a stage boundary.

        Under ``fusion_mode="cost"`` each maximal chain additionally passes
        through ``CostModel.should_fuse`` (docs/profiling.md §fusion): a
        first-sighting signature whose dispatch savings cannot amortise the
        XLA compile is left UNFUSED this evaluation (counted in
        ``fusion_deferred``) and fuses from its second sighting, once the
        plan-cache reuse the compile needs is evidenced. ``observe=False``
        (``explain()``) makes the decision read-only so rendering a plan
        never perturbs it."""
        if not self.fusion:
            return {}
        pricing = self.fusion_mode == "cost" and self.cost_model is not None
        order, refs = self._walk(root)
        plans: dict[TaskNode, FusedStage] = {}
        absorbed: set[TaskNode] = set()
        for node in reversed(order):  # consumers first ⇒ maximal chains
            if node in absorbed or not self._fusable(node):
                continue
            chain = [node]
            p = node.parents[0]
            while (
                self._fusable(p)
                and not p.cached
                and refs.get(p, 0) == 1
                and p not in absorbed
            ):
                chain.append(p)
                p = p.parents[0]
            if len(chain) >= 2:
                chain.reverse()
                stage = FusedStage(chain)
                if pricing:
                    # block-count hint: a materialised stage input tells us
                    # how many dispatches one run saves; unknown → 1
                    src = stage.head.parents[0]
                    nblocks = (len(src.result)
                               if getattr(src, "result", None) else 1)
                    if observe:
                        fuse = self.cost_model.should_fuse(
                            stage.signature, len(chain), nblocks)
                    else:
                        fuse = self.cost_model.peek_fuse(stage.signature)
                    if not fuse:
                        self.stats["fusion_deferred"] += 1
                        absorbed.update(chain)  # evaluate unfused this time
                        continue
                plans[node] = stage
                absorbed.update(chain)
        return plans

    def explain(self, root: TaskNode, annotate=None) -> str:
        """Render the physical plan — which operators fuse into which stages.

        ``annotate(node) -> str`` lets another subsystem append per-node
        state (the shuffle engine adds capacity-memory annotations)."""
        plans = self.plan(root, observe=False)
        lines = ["== physical plan =="]
        emitted: set[int] = set()

        def tags(n: TaskNode) -> str:
            t = []
            if not n.narrow:
                t.append("wide")
            if n.task_kind == "native":
                t.append("native")
            if n.cached:
                t.append("cached")
            if n.result is not None:
                t.append("materialised")
            return f" [{', '.join(t)}]" if t else ""

        # iterative DFS — lineage graphs routinely exceed recursion depth
        stack: list[tuple[TaskNode, int]] = [(root, 0)]
        while stack:
            node, depth = stack.pop()
            if node.id in emitted:
                lines.append("  " * depth + f"({node.op}#{node.id} — shared, see above)")
                continue
            emitted.add(node.id)
            stage = plans.get(node)
            if stage is not None:
                lines.append(
                    "  " * depth
                    + f"FusedStage[{stage.describe()}]  ({len(stage.nodes)} ops, "
                    f"1 jit dispatch/block){' [cached]' if node.cached else ''}"
                )
                parents = stage.head.parents
            else:
                extra = annotate(node) if annotate is not None else ""
                lines.append("  " * depth + f"{node.op}#{node.id}{tags(node)}{extra}")
                parents = node.parents
            stack.extend((p, depth + 1) for p in reversed(parents))
        return "\n".join(lines)

    # ---- compiled-plan cache -------------------------------------------------
    def _compiled(self, stage: FusedStage, block) -> Callable:
        """Jitted composed kernel for this stage specialised to the block's
        avals — fetched from (or inserted into) the LRU plan cache."""
        import jax

        key = (stage.signature, _block_aval(block))
        with self._plan_lock:
            fn = self._plan_cache.get(key)
            if fn is not None:
                self._plan_cache.move_to_end(key)
                self.stats["plan_cache_hits"] += 1
                return fn
            self.stats["plan_cache_misses"] += 1
        kernels = [n.fuse_fn for n in stage.nodes]

        def composed(data, valid):
            from repro.core.partition import Block

            b = Block(data, valid)
            for k in kernels:
                b = k(b)
            return b.data, b.valid

        fn = jax.jit(composed)
        with self._plan_lock:
            self._plan_cache[key] = fn
            while len(self._plan_cache) > self.plan_cache_size:
                self._plan_cache.popitem(last=False)
                self.stats["plan_cache_evictions"] += 1
        return fn

    # ---- evaluation ---------------------------------------------------------
    def evaluate(self, node: TaskNode, memo: dict | None = None):
        memo = {} if memo is None else memo
        return self._eval(node, memo, self.plan(node))

    def evaluate_blocks_iter(self, node: TaskNode, memo: dict | None = None,
                             plans: dict | None = None):
        """Yield the node's blocks one at a time, pulling narrow chains
        lazily — early-exit actions (``take``) stop computing the moment
        they have enough rows instead of materialising every block. Fused
        stages stay fused: a stage tail yields one compiled dispatch per
        parent block through the same plan cache as full evaluation.

        Cached nodes and wide/opaque nodes fall back to full evaluation
        (their granularity is not incremental, and partial results must
        never be written into a ``cache()`` slot)."""
        from repro.core.partition import Block

        memo = {} if memo is None else memo
        plans = self.plan(node) if plans is None else plans
        if node.result is not None and not self._has_holes(node):
            yield from node.result
            return
        if node in memo:
            yield from memo[node]
            return
        stage = plans.get(node)
        if stage is not None and not node.cached:
            out = []
            for pb in self.evaluate_blocks_iter(stage.head.parents[0], memo, plans):
                faults.check("dag.block", op=stage.tail.op, block=len(out), fused=True)
                self.stats["iter_block_computes"] += 1
                data, valid = self._compiled(stage, pb)(pb.data, pb.valid)
                b = Block(data, valid)
                out.append(b)
                yield b
            for n in stage.nodes:  # telemetry parity with _compute_stage
                n.compute_count += 1
            self.stats["fused_stages"] += 1
            self.stats["fused_ops"] += len(stage.nodes)
            memo[node] = out
            return
        if (
            node.narrow
            and node.block_fn is not None
            and node.parents
            and not node.cached
        ):
            iters = [self.evaluate_blocks_iter(p, memo, plans) for p in node.parents]
            out = []
            for parents_i in zip(*iters):
                faults.check("dag.block", op=node.op, block=len(out), fused=False)
                self.stats["iter_block_computes"] += 1
                b = node.block_fn(list(parents_i))
                out.append(b)
                yield b
            # fully consumed ⇒ the node is materialised: record it in the
            # (possibly job-shared) memo so later tasks reuse instead of
            # recomputing; an abandoned (early-exit) iterator writes nothing
            node.compute_count += 1
            memo[node] = out
            return
        yield from self._eval(node, memo, plans)

    def _eval(self, node: TaskNode, memo: dict, plans: dict | None = None):
        plans = {} if plans is None else plans
        if node.result is not None and not self._has_holes(node):
            return node.result
        if node in memo:
            return memo[node]
        if node.result is not None and self._has_holes(node):
            blocks = self._repair(node, memo, plans)
        else:
            stage = plans.get(node)
            if stage is not None:
                blocks = self._compute_stage(stage, memo, plans)
            else:
                parent_results = [self._eval(p, memo, plans) for p in node.parents]
                blocks = self._compute(node, parent_results)
        memo[node] = blocks
        if node.cached:
            node.result = blocks
        return blocks

    def _compute(self, node: TaskNode, parent_results):
        node.compute_count += 1
        self.stats["node_computes"] += 1
        if node.narrow and node.block_fn is not None:
            nblocks = len(parent_results[0]) if parent_results else 0
            out = []
            for i in range(nblocks):
                faults.check("dag.block", op=node.op, block=i, fused=False)
                out.append(node.block_fn([pr[i] for pr in parent_results]))
            return out
        faults.check("dag.node", op=node.op)
        self.stats["wide_computes"] += 1
        hook = self.trace_hook
        t0 = time.perf_counter() if hook is not None else 0.0
        out = node.fn(parent_results)
        if hook is not None:
            hook(f"wide:{node.op}", "engine", t0, time.perf_counter(),
                 op=node.op, node=node.id)
        if comm.is_handle(out):
            # a wide/native node may return a nonblocking collective handle
            # (e.g. an SPMD app handing back an in-flight result); the
            # engine is the synchronisation point for lineage, so it awaits
            # here — a FaultInjected from the pending handle surfaces like
            # any node failure and retries through the scheduler
            out = out.wait()
            self.stats["handle_awaits"] += 1
        return out

    def _compute_stage(self, stage: FusedStage, memo: dict, plans: dict):
        """Run a fused stage: one compiled kernel per block, head's parent to
        tail, no interior materialisation."""
        from repro.core.partition import Block

        parent_blocks = self._eval(stage.head.parents[0], memo, plans)
        hook = self.trace_hook
        t0 = time.perf_counter() if hook is not None else 0.0
        out = []
        for i, b in enumerate(parent_blocks):
            faults.check("dag.block", op=stage.tail.op, block=i, fused=True)
            fn = self._compiled(stage, b)
            data, valid = fn(b.data, b.valid)
            out.append(Block(data, valid))
        if hook is not None:
            hook(f"stage:{stage.tail.op}", "engine", t0, time.perf_counter(),
                 ops=len(stage.nodes), blocks=len(out),
                 stage=stage.describe())
        for n in stage.nodes:  # telemetry parity with the unfused path
            n.compute_count += 1
        self.stats["node_computes"] += len(stage.nodes)
        self.stats["fused_stages"] += 1
        self.stats["fused_ops"] += len(stage.nodes)
        return out

    # ---- lineage repair ------------------------------------------------------
    @staticmethod
    def _has_holes(node: TaskNode) -> bool:
        return node.result is not None and any(b is None for b in node.result)

    def _repair(self, node: TaskNode, memo: dict, plans: dict | None = None):
        """Recompute only the missing blocks of a cached node (narrow lineage);
        wide nodes fall back to full recompute. A fused-stage tail repairs by
        walking its constituent ops' block_fns — fusion never loses lineage.
        A checkpointed node (``restore_fn``) repairs from stable storage:
        lineage is truncated there, ancestors are never re-read."""
        plans = {} if plans is None else plans
        if node.restore_fn is not None:
            blocks = list(node.result)
            for i, b in enumerate(blocks):
                if b is None:
                    faults.check("dag.repair", op=node.op, block=i)
                    blocks[i] = node.restore_fn(i)
                    self.stats["block_restores"] += 1
            node.result = blocks
            return blocks
        if not node.narrow or node.block_fn is None:
            node.result = None
            parent_results = [self._eval(p, memo, plans) for p in node.parents]
            return self._compute(node, parent_results)
        blocks = list(node.result)
        for i, b in enumerate(blocks):
            if b is None:
                faults.check("dag.repair", op=node.op, block=i)
                parents_i = [self._parent_block(p, i, memo, plans) for p in node.parents]
                blocks[i] = node.block_fn(parents_i)
                self.stats["block_recomputes"] += 1
        node.result = blocks
        return blocks

    def _parent_block(self, parent: TaskNode, i: int, memo: dict, plans: dict | None = None):
        if parent.result is not None and parent.result[i] is not None:
            return parent.result[i]
        if parent.restore_fn is not None:
            blk = parent.restore_fn(i)
            self.stats["block_restores"] += 1
            if parent.result is not None:
                parent.result[i] = blk
            return blk
        if parent.narrow and parent.block_fn is not None and parent.parents:
            blk = parent.block_fn(
                [self._parent_block(gp, i, memo, plans) for gp in parent.parents]
            )
            self.stats["block_recomputes"] += 1
            if parent.cached and parent.result is not None:
                parent.result[i] = blk
            return blk
        return self._eval(parent, memo, plans)[i]

    # ---- failure injection (tests / chaos) -----------------------------------
    @staticmethod
    def kill_block(node: TaskNode, i: int):
        """Simulate losing the executor holding block i of a cached node."""
        if node.result is not None:
            node.result = [None if j == i else b for j, b in enumerate(node.result)]

    @staticmethod
    def kill_executor(nodes, i: int):
        for n in nodes:
            DagEngine.kill_block(n, i)

    # ---- straggler mitigation -------------------------------------------------
    def evaluate_speculative(self, node: TaskNode, timeout_s: float = 30.0,
                             memo: dict | None = None, bind=None):
        """Speculative re-execution of slow tasks (paper §3.5 recovery path,
        generalised to stragglers): evaluate with a deadline; a task that
        exceeds it is re-launched (deterministic winner: first completion).
        The job scheduler applies this as the straggler policy for gang
        tasks when ``ignis.task.speculative`` is set (core/job.py).

        Each attempt evaluates through a private overlay of ``memo`` so the
        duplicate never races the straggler's half-written entries; the
        winner's materialisations are committed back to the shared memo.
        ``bind`` (a context-manager factory) is entered by EVERY attempt
        thread — thread-locals like the worker's active communicator do not
        cross thread spawns, so a gang task must re-bind its group here or
        its wide stages would silently retarget to the world mesh.

        On a single-process runtime the duplicate runs serially; on a real
        multi-host deployment the retry lands on a different executor set.
        """
        import contextlib
        import threading

        base = {} if memo is None else memo
        lock = threading.Lock()
        result: dict = {}
        done = threading.Event()

        def run():
            local = _OverlayMemo(base)
            try:
                with bind() if bind is not None else contextlib.nullcontext():
                    blocks = self._eval(node, local, self.plan(node))
            except Exception as e:  # surfaced to caller (first resolution wins)
                with lock:
                    if not done.is_set():
                        result["error"] = e
                        done.set()
                return
            with lock:
                if not done.is_set():
                    result["blocks"] = blocks
                    for k, v in local.items():  # commit the winner's work
                        base[k] = v
                    done.set()

        t = threading.Thread(target=run, daemon=True)
        t.start()
        if not done.wait(timeout_s):
            # straggler: launch the speculative duplicate and take the winner
            self.stats["speculative_retries"] += 1
            t2 = threading.Thread(target=run, daemon=True)
            t2.start()
            done.wait()
        if "error" in result:
            raise result["error"]
        return result["blocks"]


class _OverlayMemo(dict):
    """Read-through/write-local view of an evaluation memo: speculative
    attempts see everything already materialised in the shared memo but
    keep their own writes private until the winner commits them."""

    __slots__ = ("_base",)

    def __init__(self, base: dict):
        super().__init__()
        self._base = base

    def __contains__(self, key):
        return dict.__contains__(self, key) or key in self._base

    def __getitem__(self, key):
        try:
            return dict.__getitem__(self, key)
        except KeyError:
            return self._base[key]

    def get(self, key, default=None):
        if dict.__contains__(self, key):
            return dict.__getitem__(self, key)
        return self._base.get(key, default)
