"""Lazy task-dependency graph with lineage fault tolerance (paper §3.5, Fig 3).

Driver calls register TaskNodes; nothing executes until an *action*. A node's
result is kept only for the duration of one action evaluation unless the user
``cache()``d it. Narrow nodes (map/filter/…) have block-wise lineage: block i
depends only on the parents' block i, so a lost cached block is recomputed
alone; wide nodes (shuffles) recompute whole-node. Executor/container tasks
(paper Fig. 3) correspond to the mesh existing — checked at evaluation.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional

_ids = itertools.count()


@dataclass
class TaskNode:
    op: str
    parents: list
    # fn(list_of_parent_block_lists) -> list[Block]      (wide)
    # block_fn(parent_blocks_at_i: list[Block]) -> Block (narrow)
    fn: Optional[Callable] = None
    block_fn: Optional[Callable] = None
    narrow: bool = False
    cached: bool = False
    id: int = field(default_factory=lambda: next(_ids))
    # runtime state
    result: Optional[list] = None  # list[Block] when materialised
    compute_count: int = 0  # telemetry for lineage tests

    def __hash__(self):
        return self.id

    def __eq__(self, other):
        return self is other


class DagEngine:
    """Evaluates actions over the task graph with memoisation + lineage."""

    def __init__(self):
        self.stats = {"node_computes": 0, "block_recomputes": 0}

    # ---- evaluation ---------------------------------------------------------
    def evaluate(self, node: TaskNode, memo: dict | None = None):
        memo = {} if memo is None else memo
        return self._eval(node, memo)

    def _eval(self, node: TaskNode, memo: dict):
        if node.result is not None and not self._has_holes(node):
            return node.result
        if node in memo:
            return memo[node]
        if node.result is not None and self._has_holes(node):
            blocks = self._repair(node, memo)
        else:
            parent_results = [self._eval(p, memo) for p in node.parents]
            blocks = self._compute(node, parent_results)
        memo[node] = blocks
        if node.cached:
            node.result = blocks
        return blocks

    def _compute(self, node: TaskNode, parent_results):
        node.compute_count += 1
        self.stats["node_computes"] += 1
        if node.narrow and node.block_fn is not None:
            nblocks = len(parent_results[0]) if parent_results else 0
            return [
                node.block_fn([pr[i] for pr in parent_results]) for i in range(nblocks)
            ]
        return node.fn(parent_results)

    # ---- lineage repair ------------------------------------------------------
    @staticmethod
    def _has_holes(node: TaskNode) -> bool:
        return node.result is not None and any(b is None for b in node.result)

    def _repair(self, node: TaskNode, memo: dict):
        """Recompute only the missing blocks of a cached node (narrow lineage);
        wide nodes fall back to full recompute."""
        if not node.narrow or node.block_fn is None:
            node.result = None
            parent_results = [self._eval(p, memo) for p in node.parents]
            return self._compute(node, parent_results)
        blocks = list(node.result)
        for i, b in enumerate(blocks):
            if b is None:
                parents_i = [self._parent_block(p, i, memo) for p in node.parents]
                blocks[i] = node.block_fn(parents_i)
                self.stats["block_recomputes"] += 1
        node.result = blocks
        return blocks

    def _parent_block(self, parent: TaskNode, i: int, memo: dict):
        if parent.result is not None and parent.result[i] is not None:
            return parent.result[i]
        if parent.narrow and parent.block_fn is not None and parent.parents:
            blk = parent.block_fn(
                [self._parent_block(gp, i, memo) for gp in parent.parents]
            )
            self.stats["block_recomputes"] += 1
            if parent.cached and parent.result is not None:
                parent.result[i] = blk
            return blk
        return self._eval(parent, memo)[i]

    # ---- failure injection (tests / chaos) -----------------------------------
    @staticmethod
    def kill_block(node: TaskNode, i: int):
        """Simulate losing the executor holding block i of a cached node."""
        if node.result is not None:
            node.result = [None if j == i else b for j, b in enumerate(node.result)]

    @staticmethod
    def kill_executor(nodes, i: int):
        for n in nodes:
            DagEngine.kill_block(n, i)

    # ---- straggler mitigation -------------------------------------------------
    def evaluate_speculative(self, node: TaskNode, timeout_s: float = 30.0):
        """Speculative re-execution of slow tasks (paper §3.5 recovery path,
        generalised to stragglers): evaluate with a deadline; a task that
        exceeds it is re-launched (deterministic winner: first completion).

        On a single-process runtime the duplicate runs serially; on a real
        multi-host deployment the retry lands on a different executor set.
        """
        import threading

        result: dict = {}
        done = threading.Event()

        def run():
            try:
                result["blocks"] = self.evaluate(node)
            except Exception as e:  # pragma: no cover — surfaced to caller
                result["error"] = e
            done.set()

        t = threading.Thread(target=run, daemon=True)
        t.start()
        if not done.wait(timeout_s):
            # straggler: launch the speculative duplicate and take the winner
            self.stats["speculative_retries"] = self.stats.get("speculative_retries", 0) + 1
            t2 = threading.Thread(target=run, daemon=True)
            t2.start()
            done.wait()
        if "error" in result:
            raise result["error"]
        return result["blocks"]
