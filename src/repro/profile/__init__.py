"""repro.profile — profiling, cost modelling, and what-if replay
(docs/profiling.md, DESIGN.md §13).

The introspection-and-decision subsystem: ``JobTracer`` captures per-task
phase spans (lock-wait / compute / collective-settle) and engine stage
spans into Chrome-trace timelines; ``CostModel`` prices work statically
(jaxpr / compiled HLO via launch/hlo_cost.py) and learns task-duration
history; ``replay`` re-schedules a captured trace under hypothetical gang
splits, placements, and speculative timeouts. The scheduler consumes the
model for cost-aware fusion boundaries (``ignis.fusion.mode=cost``) and
auto speculative timeouts (``ignis.task.speculative.timeout=auto``)."""
from repro.profile.cost import CostEstimate, CostModel, DeviceParams  # noqa: F401
from repro.profile.replay import (  # noqa: F401
    Hypothesis, Schedule, Trace, TaskRecord, capture, predicted_vs_measured,
    simulate,
)
from repro.profile.spans import Span, TraceBuffer, to_chrome, validate  # noqa: F401
from repro.profile.tracer import JobTracer, task_lane  # noqa: F401
