"""Trace spans and the Chrome-trace exporter (docs/profiling.md §schema).

A ``Span`` is one closed interval of wall time on one thread: a task body,
its lock wait, its collective settle, or an engine-level stage/node
compute. ``TraceBuffer`` collects spans thread-safely and renders the
Chrome trace event format (the ``chrome://tracing`` / Perfetto JSON
schema: complete ``"X"`` events with microsecond ``ts``/``dur``, thread
metadata ``"M"`` events).

Threads, not lanes, are the nesting domain: after a settle hands a task's
lock off (core/job.py ``_settle``), the *next* task on the same lane
overlaps the first task's collective await — so same-lane spans may
interleave, while same-thread spans always nest. The exporter therefore
keys ``tid`` on the executing thread and carries the lane/gang label in
``args["lane"]``, which is what the schema tests validate
(tests/test_profile.py).
"""
from __future__ import annotations

import json
import threading
from dataclasses import dataclass, field


@dataclass(frozen=True)
class Span:
    name: str      # "compute", "lock_wait", "settle", "stage:...", ...
    cat: str       # "task" | "engine" | "sched"
    t0: float      # perf_counter seconds
    t1: float
    tid: int       # executing thread id
    args: dict = field(default_factory=dict)  # lane, kind, attempt, ...

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


class TraceBuffer:
    """Append-only, thread-safe span store for one tracer."""

    def __init__(self):
        self._lock = threading.Lock()
        self._spans: list[Span] = []

    def add(self, span: Span):
        with self._lock:
            self._spans.append(span)

    def record(self, name: str, cat: str, t0: float, t1: float,
               tid: int | None = None, **args):
        self.add(Span(name, cat, t0, t1,
                      threading.get_ident() if tid is None else tid, args))

    def spans(self) -> list[Span]:
        with self._lock:
            return list(self._spans)

    def __len__(self):
        with self._lock:
            return len(self._spans)

    def clear(self):
        with self._lock:
            self._spans.clear()


def to_chrome(spans: list[Span], process_name: str = "ignis") -> dict:
    """Render spans as a Chrome trace JSON object.

    ``ts``/``dur`` are microseconds relative to the earliest span (Chrome
    renders absolute perf_counter values poorly); every distinct tid gets
    a ``thread_name`` metadata event naming the lanes it ran."""
    if not spans:
        return {"traceEvents": [], "displayTimeUnit": "ms"}
    epoch = min(s.t0 for s in spans)
    events: list[dict] = [{
        "name": "process_name", "ph": "M", "pid": 0, "tid": 0,
        "args": {"name": process_name},
    }]
    lanes_by_tid: dict[int, set] = {}
    for s in spans:
        lanes_by_tid.setdefault(s.tid, set()).add(s.args.get("lane", "driver"))
    for tid, lanes in sorted(lanes_by_tid.items()):
        events.append({
            "name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
            "args": {"name": "worker [" + ", ".join(sorted(lanes)) + "]"},
        })
    for s in sorted(spans, key=lambda s: (s.t0, -s.t1)):
        events.append({
            "name": s.name, "cat": s.cat, "ph": "X", "pid": 0, "tid": s.tid,
            "ts": round((s.t0 - epoch) * 1e6, 3),
            "dur": round(max(0.0, s.dur) * 1e6, 3),
            "args": dict(s.args),
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def save_chrome(spans: list[Span], path: str, process_name: str = "ignis"):
    with open(path, "w", encoding="utf-8") as f:
        json.dump(to_chrome(spans, process_name), f)


def validate(trace: dict) -> list[str]:
    """Schema violations in a Chrome trace object: malformed events,
    negative durations, same-thread spans that overlap without nesting.
    Empty list = valid. Used by tests and the bench harness — an exported
    timeline that Chrome renders misleadingly should fail loudly here."""
    problems: list[str] = []
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    by_tid: dict[int, list[dict]] = {}
    for i, e in enumerate(events):
        ph = e.get("ph")
        if ph == "M":
            continue
        if ph != "X":
            problems.append(f"event {i}: unexpected ph {ph!r}")
            continue
        for k in ("name", "ts", "dur", "tid", "pid"):
            if k not in e:
                problems.append(f"event {i}: missing {k!r}")
        if e.get("dur", 0) < 0:
            problems.append(f"event {i} ({e.get('name')}): negative dur")
        if e.get("ts", 0) < 0:
            problems.append(f"event {i} ({e.get('name')}): negative ts")
        by_tid.setdefault(e.get("tid", 0), []).append(e)
    for tid, evs in by_tid.items():
        evs = sorted(evs, key=lambda e: (e["ts"], -(e["ts"] + e["dur"])))
        stack: list[tuple] = []  # (end, name)
        for e in evs:
            t0, t1 = e["ts"], e["ts"] + e["dur"]
            while stack and stack[-1][0] <= t0 + 1e-9:
                stack.pop()
            if stack and t1 > stack[-1][0] + 1e-6:
                problems.append(
                    f"tid {tid}: {e['name']!r} [{t0},{t1}] overlaps "
                    f"{stack[-1][1]!r} (ends {stack[-1][0]}) without nesting")
            stack.append((t1, e["name"]))
    return problems
