"""What-if replay: deterministically re-schedule a captured trace under a
hypothesis (docs/profiling.md §replay).

The shape of byteprofile-analysis's device-time replayer: a captured job
becomes a list of ``TaskRecord``s (duration + dependencies + lane), and an
event-driven list scheduler replays them against *hypothetical* resources
— a different gang split, a lane placement remap, a different speculative
timeout — reporting the predicted makespan without touching a device.

Replay is exact about structure and deliberately simple about physics:
a lane (a gang group's slice of the mesh, or a worker's serial job lock)
runs one task at a time; a task starts when its dependencies are done and
its lane is free; durations come from the capture (or from a ``CostModel``
for tasks the capture never ran). Determinism is a schema guarantee:
ties break on ``(ready_time, task id)``, so the same trace and the same
hypothesis produce the identical schedule — tested in
tests/test_profile.py and gated in benchmarks/bench_cost_model.py, which
also gates the identity-hypothesis replay against the measured makespan.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass(frozen=True)
class TaskRecord:
    """One captured task: everything replay needs, nothing it doesn't."""

    id: int
    name: str
    kind: str            # "stage" | "action" | "native" | "reshard" | "serve"
    lane: str            # gang-group label / worker name / "driver"
    dur_s: float         # measured body duration (lock wait excluded)
    deps: tuple = ()     # ids of tasks this one waits on
    settle_s: float = 0.0  # collective-await tail (overlappable on the lane)


@dataclass(frozen=True)
class Trace:
    tasks: tuple
    wall_s: float = 0.0  # measured makespan of the capture, when known

    def lanes(self) -> list[str]:
        return sorted({t.lane for t in self.tasks})


def capture(job) -> Trace:
    """Snapshot a finished (or running) job into a replayable Trace.

    Durations are task-body wall time (``t_start``→``t_end``; the lock
    wait is scheduling, not work — replay re-derives queueing from the
    hypothesis). The settle tail (``t_compute_end``→``t_settle_end``) is
    recorded separately because a dropped-lock settle does NOT occupy the
    lane — replay models it as lane-free tail time exactly like the live
    scheduler's one-way lock drop."""
    from repro.profile.tracer import task_lane

    records = []
    t_first = t_last = None
    for t in sorted(job.tasks, key=lambda t: t.id):
        if not t.t_end:
            continue
        dur = max(0.0, t.t_end - t.t_start)
        settle = 0.0
        if getattr(t, "lock_dropped", False) and t.t_settle_end > t.t_compute_end:
            settle = min(dur, t.t_settle_end - t.t_compute_end)
        records.append(TaskRecord(
            id=t.id, name=t.name, kind=t.kind, lane=task_lane(t),
            dur_s=dur - settle, settle_s=settle,
            deps=tuple(d.id for d in t.deps),
        ))
        t_first = t.t_start if t_first is None else min(t_first, t.t_start)
        t_last = t.t_end if t_last is None else max(t_last, t.t_end)
    wall = (t_last - t_first) if records else 0.0
    return Trace(tasks=tuple(records), wall_s=wall)


@dataclass(frozen=True)
class Hypothesis:
    """What to vary. Identity (no fields set) replays the capture as-is.

    * ``lanes``: re-deal every gang-group lane round-robin onto ``lanes``
      synthetic lanes — "what if the job ran with gang=2 instead of 4?"
    * ``placement``: explicit lane→lane remap (consolidate or split named
      lanes); applied after ``lanes``.
    * ``speculative_timeout_s``: cap any task's duration at
      ``timeout + typical(kind)`` — the effect of a speculative duplicate
      finishing in typical time once the original exceeds the deadline.
    * ``scale``: multiply every duration (slower/faster hardware).
    """

    lanes: Optional[int] = None
    placement: dict = field(default_factory=dict)
    speculative_timeout_s: Optional[float] = None
    scale: float = 1.0


@dataclass(frozen=True)
class Schedule:
    makespan_s: float
    task_times: dict          # id -> (start_s, end_s)
    order: tuple              # ids in start order
    lanes: tuple              # lane labels used

    def explain(self) -> str:
        lines = [f"== replay schedule ({len(self.order)} tasks, "
                 f"makespan {self.makespan_s * 1e3:.1f}ms) =="]
        for tid in self.order:
            s, e = self.task_times[tid]
            lines.append(f"  t{tid}  [{s * 1e3:9.3f}, {e * 1e3:9.3f}] ms")
        return "\n".join(lines)


def _typical_by_kind(trace: Trace) -> dict:
    by: dict = {}
    for t in trace.tasks:
        by.setdefault(t.kind, []).append(t.dur_s)
    return {k: sorted(v)[len(v) // 2] for k, v in by.items()}


def _apply_hypothesis(trace: Trace, hyp: Hypothesis) -> list[TaskRecord]:
    tasks = list(trace.tasks)
    if hyp.lanes is not None and hyp.lanes > 0:
        # re-deal captured lanes round-robin onto n synthetic lanes,
        # in sorted-label order so the remap is deterministic
        remap = {lane: f"lane{i % hyp.lanes}"
                 for i, lane in enumerate(sorted({t.lane for t in tasks}))}
        tasks = [TaskRecord(t.id, t.name, t.kind, remap[t.lane], t.dur_s,
                            t.deps, t.settle_s) for t in tasks]
    if hyp.placement:
        tasks = [TaskRecord(t.id, t.name, t.kind,
                            hyp.placement.get(t.lane, t.lane), t.dur_s,
                            t.deps, t.settle_s) for t in tasks]
    if hyp.speculative_timeout_s is not None:
        typical = _typical_by_kind(trace)
        cut = hyp.speculative_timeout_s
        tasks = [TaskRecord(t.id, t.name, t.kind, t.lane,
                            min(t.dur_s, cut + typical.get(t.kind, 0.0)),
                            t.deps, t.settle_s) for t in tasks]
    if hyp.scale != 1.0:
        tasks = [TaskRecord(t.id, t.name, t.kind, t.lane, t.dur_s * hyp.scale,
                            t.deps, t.settle_s * hyp.scale) for t in tasks]
    return tasks


def simulate(trace: Trace, hypothesis: Hypothesis | None = None,
             price: Optional[Callable[[TaskRecord], float]] = None) -> Schedule:
    """Deterministic event-driven list scheduling of the trace under the
    hypothesis.

    Lanes are serial resources; a task occupies its lane for ``dur_s``,
    then its settle tail runs off-lane (the nonblocking overlap window) —
    dependents wait for settle, the lane does not. ``price(record)``
    overrides a record's duration (a ``CostModel`` pricing hypothetical
    work); ties break on (ready, lane-free, id) so identical inputs give
    the identical schedule."""
    hyp = hypothesis or Hypothesis()
    tasks = _apply_hypothesis(trace, hyp)
    by_id = {t.id: t for t in tasks}
    dependents: dict = {t.id: [] for t in tasks}
    remaining: dict = {}
    for t in tasks:
        deps = [d for d in t.deps if d in by_id]
        remaining[t.id] = len(deps)
        for d in deps:
            dependents[d].append(t.id)

    lane_free: dict = {t.lane: 0.0 for t in tasks}
    done_at: dict = {}       # id -> end incl. settle (what dependents see)
    task_times: dict = {}
    order: list = []
    ready: list = []         # (ready_s, id)
    for t in tasks:
        if remaining[t.id] == 0:
            heapq.heappush(ready, (0.0, t.id))

    scheduled = 0
    while ready:
        ready_s, tid = heapq.heappop(ready)
        t = by_id[tid]
        dur = t.dur_s if price is None else max(0.0, price(t))
        start = max(ready_s, lane_free[t.lane])
        lane_end = start + dur          # lane busy through the body
        end = lane_end + t.settle_s     # dependents wait for the settle too
        lane_free[t.lane] = lane_end
        done_at[tid] = end
        task_times[tid] = (start, end)
        order.append(tid)
        scheduled += 1
        for d in dependents[tid]:
            remaining[d] -= 1
            if remaining[d] == 0:
                ready_d = max((done_at[x] for x in by_id[d].deps
                               if x in done_at), default=end)
                heapq.heappush(ready, (ready_d, d))

    # cycles or missing deps leave tasks unscheduled — surface, don't hang
    if scheduled != len(tasks):
        stuck = sorted(set(by_id) - set(done_at))
        raise ValueError(f"replay: {len(stuck)} tasks never became ready "
                         f"(dependency cycle?): {stuck[:8]}")
    makespan = max(done_at.values(), default=0.0)
    return Schedule(makespan_s=makespan, task_times=task_times,
                    order=tuple(order), lanes=tuple(sorted(lane_free)))


def predicted_vs_measured(job, hypothesis: Hypothesis | None = None) -> dict:
    """Convenience for benchmarks/tests: capture ``job``, replay under the
    (identity by default) hypothesis, report predicted vs measured
    makespan and their min/max accuracy ratio."""
    trace = capture(job)
    sched = simulate(trace, hypothesis)
    pred, meas = sched.makespan_s, trace.wall_s
    acc = (min(pred, meas) / max(pred, meas)) if pred > 0 and meas > 0 else 0.0
    return {"predicted_s": pred, "measured_s": meas, "accuracy": acc,
            "tasks": len(trace.tasks), "lanes": len(trace.lanes())}
