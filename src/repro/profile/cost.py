"""The cost model: price work before running it, learn from having run it
(docs/profiling.md §cost, DESIGN.md §13).

Two complementary halves share one object so scheduler decisions have a
single thing to consult:

* **static pricing** — walk a jaxpr (``price_jaxpr``, pre-execution: the
  planner has tracers, not devices) or compiled HLO text (``price_hlo``,
  exact post-lowering truth via the seed ``launch/hlo_cost.py`` parser)
  into a ``CostEstimate`` (flops, HBM bytes, wire bytes, dispatches), then
  convert to predicted seconds through ``DeviceParams`` — a roofline-style
  max-of-terms is wrong here because the runtime interleaves phases, so
  the model *sums* terms and lets calibration absorb overlap;
* **dynamic history** — observed durations of tasks and stages keyed by
  structural signature (``node_sig`` / ``FusedStage.signature``), the
  empirical side that speculative-timeout derivation and fusion
  amortisation read.

Consumers in this PR: ``DagEngine.plan`` (cost-aware fusion boundaries,
``ignis.fusion.mode=cost``) and ``IJob._evaluator`` (speculative timeouts,
``ignis.task.speculative.timeout=auto``); the replay simulator prices
hypothetical tasks it has no observation for.
"""
from __future__ import annotations

import statistics
import threading
from collections import deque
from dataclasses import dataclass, replace


@dataclass(frozen=True)
class DeviceParams:
    """Sustained-rate device constants. Defaults are deliberately modest
    host-CPU figures — CI runs on XLA:CPU; ``calibration.calibrate()``
    replaces them with measured rates, and ``CostModel.fit`` rescales the
    whole prediction against traced reality."""

    flops_per_s: float = 5e10
    hbm_bytes_per_s: float = 1e10
    wire_bytes_per_s: float = 2e9
    dispatch_s: float = 50e-6       # per eager/jit call overhead
    compile_s_per_op: float = 8e-3  # XLA compile cost per fused operator


@dataclass(frozen=True)
class CostEstimate:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    wire_bytes: float = 0.0
    dispatches: float = 0.0

    def __add__(self, other: "CostEstimate") -> "CostEstimate":
        return CostEstimate(
            self.flops + other.flops,
            self.hbm_bytes + other.hbm_bytes,
            self.wire_bytes + other.wire_bytes,
            self.dispatches + other.dispatches,
        )

    def scaled(self, k: float) -> "CostEstimate":
        return CostEstimate(self.flops * k, self.hbm_bytes * k,
                            self.wire_bytes * k, self.dispatches * k)


def _aval_bytes(aval) -> int:
    try:
        import numpy as np

        n = 1
        for d in aval.shape:
            n *= int(d)
        return n * np.dtype(aval.dtype).itemsize
    except Exception:
        return 0


def _aval_elems(aval) -> int:
    n = 1
    for d in getattr(aval, "shape", ()):
        n *= int(d)
    return n


def _dot_flops(eqn) -> float:
    """2·batch·M·N·K for a dot_general from its dimension numbers."""
    lhs, rhs = (v.aval for v in eqn.invars[:2])
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    batch = 1
    for d in lb:
        batch *= int(lhs.shape[d])
    k = 1
    for d in lc:
        k *= int(lhs.shape[d])
    m = 1
    for i, d in enumerate(lhs.shape):
        if i not in lc and i not in lb:
            m *= int(d)
    n = 1
    for i, d in enumerate(rhs.shape):
        if i not in rc and i not in rb:
            n *= int(d)
    return 2.0 * batch * m * n * k


#: primitives that move/reshape data without arithmetic
_FREE_PRIMS = frozenset((
    "broadcast_in_dim", "reshape", "squeeze", "transpose", "convert_element_type",
    "slice", "dynamic_slice", "dynamic_update_slice", "concatenate", "pad",
    "gather", "scatter", "copy", "device_put", "stop_gradient", "iota",
))


class CostModel:
    """See module docstring. Thread-safe: gang tasks consult one model from
    several scheduler threads at once."""

    def __init__(self, params: DeviceParams | None = None,
                 history: int = 64):
        self.params = params or DeviceParams()
        self._scale = 1.0  # fit() multiplier applied to every prediction
        self._lock = threading.Lock()
        self._history = history
        self._task_durs: dict = {}      # key -> deque[float seconds]
        self._stage_sightings: dict = {}  # stage signature -> times planned
        self.stats = {
            "jaxprs_priced": 0,
            "hlo_priced": 0,
            "fuse_decisions": 0,
            "fuse_deferrals": 0,
            "auto_timeouts": 0,
            "tasks_observed": 0,
        }

    # ------------------------------------------------------------------
    # static pricing
    # ------------------------------------------------------------------
    def price_jaxpr(self, jaxpr, nblocks: int = 1) -> CostEstimate:
        """Price an (open or closed) jaxpr: flops from dot_generals plus one
        flop per output element of every arithmetic primitive, HBM bytes as
        operand+result traffic, one dispatch per equation (the un-jitted
        eager execution shape — jitting collapses dispatches to 1, which is
        exactly the delta the fusion policy prices). ``nblocks`` scales the
        estimate across a node's block loop."""
        inner = getattr(jaxpr, "jaxpr", jaxpr)
        est = self._price_open_jaxpr(inner)
        with self._lock:
            self.stats["jaxprs_priced"] += 1
        return est.scaled(nblocks)

    def _price_open_jaxpr(self, jaxpr) -> CostEstimate:
        flops = hbm = dispatches = 0.0
        for eqn in jaxpr.eqns:
            sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if sub is not None:
                inner = getattr(sub, "jaxpr", sub)
                sub_est = self._price_open_jaxpr(inner)
                mult = 1.0
                if eqn.primitive.name in ("while", "scan"):
                    mult = float(eqn.params.get("length", 1) or 1)
                flops += sub_est.flops * mult
                hbm += sub_est.hbm_bytes * mult
                dispatches += sub_est.dispatches
                continue
            out_elems = sum(_aval_elems(v.aval) for v in eqn.outvars)
            hbm += sum(_aval_bytes(v.aval) for v in eqn.invars
                       if hasattr(v, "aval"))
            hbm += sum(_aval_bytes(v.aval) for v in eqn.outvars)
            dispatches += 1
            name = eqn.primitive.name
            if name == "dot_general":
                flops += _dot_flops(eqn)
            elif name not in _FREE_PRIMS:
                flops += out_elems
        return CostEstimate(flops, hbm, 0.0, dispatches)

    def price_hlo(self, hlo_text: str, collective: bool = True) -> CostEstimate:
        """Price compiled HLO text through the seed parser
        (launch/hlo_cost.py): exact flops/HBM/wire accounting including
        while-loop trip counts and fusion boundary buffers."""
        from repro.launch.hlo_cost import analyze

        a = analyze(hlo_text)
        with self._lock:
            self.stats["hlo_priced"] += 1
        return CostEstimate(
            flops=a["flops_per_device"],
            hbm_bytes=a["hbm_bytes_per_device"],
            wire_bytes=a["wire_bytes_per_device"] if collective else 0.0,
            dispatches=1.0,
        )

    def price_fn(self, fn, *avals) -> CostEstimate:
        """Price a python function by tracing it to a jaxpr on abstract
        inputs (``jax.ShapeDtypeStruct`` — no device work)."""
        import jax

        return self.price_jaxpr(jax.make_jaxpr(fn)(*avals))

    def predict_s(self, est: CostEstimate) -> float:
        """Predicted wall seconds for an estimate — summed terms (see
        module docstring), scaled by the ``fit()`` calibration factor."""
        p = self.params
        return self._scale * (
            est.flops / p.flops_per_s
            + est.hbm_bytes / p.hbm_bytes_per_s
            + est.wire_bytes / p.wire_bytes_per_s
            + est.dispatches * p.dispatch_s
        )

    def fit(self, pairs: list[tuple[float, float]]) -> float:
        """Calibrate against (predicted_s, observed_s) pairs: the scale
        becomes the median observed/predicted ratio (robust to a stray
        straggler pair). Returns the new scale."""
        ratios = [obs / pred for pred, obs in pairs if pred > 0 and obs > 0]
        if ratios:
            self._scale *= statistics.median(ratios)
        return self._scale

    def with_params(self, **kw) -> "CostModel":
        m = CostModel(replace(self.params, **kw), history=self._history)
        m._scale = self._scale
        return m

    # ------------------------------------------------------------------
    # decision 1: cost-aware fusion boundaries (DagEngine.plan)
    # ------------------------------------------------------------------
    def should_fuse(self, signature, n_ops: int, nblocks: int = 1) -> bool:
        """Is compiling this narrow chain into one fused stage worth it?

        Fusing trades an XLA compile (``compile_s_per_op x n_ops``, paid
        once per (signature, block-aval)) for saved dispatch overhead
        (``(n_ops - 1) x nblocks`` fewer kernel launches per run). On the
        FIRST sighting of a signature the compile is unamortised — fuse
        only if this single run already saves more than the compile costs
        (huge block counts). From the second sighting on, the plan cache
        means the compile is sunk or amortising across repeats: always
        fuse. This is the shape-churn asymmetry the static policy misses —
        a pipeline that never repeats a stage signature pays compile after
        compile for dispatch savings it never banks."""
        p = self.params
        with self._lock:
            seen = self._stage_sightings.get(signature, 0)
            self._stage_sightings[signature] = seen + 1
            self.stats["fuse_decisions"] += 1
            if seen > 0:
                return True
            saved = (max(0, n_ops - 1)) * max(1, nblocks) * p.dispatch_s
            compile_cost = n_ops * p.compile_s_per_op
            if saved >= compile_cost:
                return True
            self.stats["fuse_deferrals"] += 1
            return False

    def peek_fuse(self, signature) -> bool:
        """``should_fuse`` without recording a sighting — for ``explain()``
        and tests that must not perturb the decision state."""
        with self._lock:
            return self._stage_sightings.get(signature, 0) > 0

    # ------------------------------------------------------------------
    # decision 2: cost-derived speculative timeouts (IJob._evaluator)
    # ------------------------------------------------------------------
    def observe_task(self, key, dur_s: float):
        """Record one observed task duration under a structural key —
        typically ``(kind, node_sig(node))``."""
        if dur_s < 0:
            return
        with self._lock:
            q = self._task_durs.get(key)
            if q is None:
                q = self._task_durs[key] = deque(maxlen=self._history)
            q.append(dur_s)
            self.stats["tasks_observed"] += 1

    def typical_s(self, key) -> float | None:
        """Median observed duration for ``key`` (None with no history)."""
        with self._lock:
            q = self._task_durs.get(key)
            if not q:
                return None
            return statistics.median(q)

    def speculative_timeout_s(self, key, factor: float = 3.0,
                              default_s: float = 30.0) -> float:
        """The straggler deadline for a task: ``factor x`` its typical
        observed duration, floored at 50 ms so scheduling jitter on
        microsecond tasks cannot spawn duplicates, falling back to
        ``default_s`` before any history exists."""
        typical = self.typical_s(key)
        with self._lock:
            self.stats["auto_timeouts"] += 1
        if typical is None:
            return default_s
        return max(0.05, factor * typical)

    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        with self._lock:
            return {**self.stats,
                    "scale": self._scale,
                    "task_keys": len(self._task_durs),
                    "stage_signatures": len(self._stage_sightings)}
