"""Calibrating DeviceParams against the machine actually running
(docs/profiling.md §calibration).

The static defaults in ``cost.DeviceParams`` are order-of-magnitude CPU
figures; two cheap microprobes replace them with measured sustained rates
(a square matmul for flops/s, an element-wise copy-scale for HBM bytes/s,
a tiny jitted no-op loop for dispatch overhead), and ``fit_from_trace``
closes the remaining gap by rescaling predictions against a captured
trace's observed stage durations. Probes run on the default backend —
the same place stage kernels execute — and take tens of milliseconds
total at the default sizes."""
from __future__ import annotations

import time

from repro.profile.cost import CostModel, DeviceParams


def _time_best(fn, repeats: int = 3) -> float:
    """Best-of-N wall time of ``fn()`` — best, not mean, because probe
    noise is one-sided (GC, scheduler preemption only ever add time)."""
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def calibrate(n: int = 512, repeats: int = 3) -> DeviceParams:
    """Measured DeviceParams for the current jax default backend."""
    import jax
    import jax.numpy as jnp

    a = jnp.ones((n, n), jnp.float32)
    b = jnp.ones((n, n), jnp.float32)

    mm = jax.jit(lambda x, y: x @ y)
    cp = jax.jit(lambda x: x * 2.0 + 1.0)
    nop = jax.jit(lambda x: x)

    # warm: exclude compile from the probes
    mm(a, b).block_until_ready()
    cp(a).block_until_ready()
    nop(a).block_until_ready()

    t_mm = _time_best(lambda: mm(a, b).block_until_ready(), repeats)
    t_cp = _time_best(lambda: cp(a).block_until_ready(), repeats)
    t_nop = _time_best(lambda: nop(a).block_until_ready(), repeats)

    flops = 2.0 * n * n * n
    # copy-scale touches in + out once each: 2 arrays of n*n f32
    hbm_bytes = 2.0 * n * n * 4
    return DeviceParams(
        flops_per_s=max(1e6, flops / max(1e-9, t_mm - t_nop)),
        hbm_bytes_per_s=max(1e6, hbm_bytes / max(1e-9, t_cp - t_nop)),
        dispatch_s=max(1e-6, t_nop),
    )


def calibrated_model(n: int = 512, repeats: int = 3) -> CostModel:
    return CostModel(calibrate(n, repeats))


def fit_from_trace(model: CostModel, pairs) -> float:
    """Rescale ``model`` so predictions match observed (predicted_s,
    observed_s) pairs — thin alias of ``CostModel.fit`` kept here so the
    calibration surface is one module."""
    return model.fit(list(pairs))
