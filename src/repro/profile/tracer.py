"""JobTracer — the per-job/per-worker profiler (docs/profiling.md).

Attach to a job before running actions; export a Chrome-trace timeline
after::

    tracer = JobTracer()
    tracer.attach(job)            # task spans: lock-wait/compute/settle
    tracer.attach_worker(worker)  # engine spans + metrics "profile/" mount
    ... run actions ...
    tracer.save("trace.json")     # open in chrome://tracing / Perfetto

Task phases come from timestamps the scheduler already stamps on each
``JobTask`` (core/job.py): ``t_start``→``t_end`` is the task body,
``t_lock_wait`` the serialisation-lock wait that preceded it,
``t_compute_end``→``t_settle_end`` the collective settle (the window the
nonblocking design overlaps with the next task — visible in the timeline
as a settle span running beside a peer's compute). Engine spans
(fused-stage and wide-node computes) stream in live through the
``DagEngine.trace_hook`` while attached. The tracer also feeds every
finished task's duration into its ``CostModel``'s history, which is what
``ignis.task.speculative.timeout=auto`` reads.
"""
from __future__ import annotations

import threading
import time

from repro.profile.cost import CostModel
from repro.profile.spans import Span, TraceBuffer, save_chrome, to_chrome


def task_lane(task) -> str:
    """The lane label for a task: its gang group's label (matching
    ``job.explain()``'s ``group=`` annotation), else its worker name,
    else the driver."""
    if task.group is not None:
        return task.group.label()
    if task.worker is not None:
        return task.worker.name
    return "driver"


class JobTracer:
    """Collects spans for any number of jobs/workers; one buffer, one
    timeline. Thread-safe (the scheduler completes tasks on pool threads)."""

    def __init__(self, cost_model: CostModel | None = None):
        self.buffer = TraceBuffer()
        self.cost = cost_model or CostModel()
        self._lock = threading.Lock()
        self._jobs: list = []
        self._workers: list = []
        self._t0 = time.perf_counter()

    # ------------------------------------------------------------------
    # attachment
    # ------------------------------------------------------------------
    def attach(self, job) -> "JobTracer":
        """Trace ``job``: the scheduler notifies this tracer as each task
        resolves (span emission + cost-history observation)."""
        job.tracer = self
        with self._lock:
            self._jobs.append(job)
        return self

    def attach_worker(self, worker) -> "JobTracer":
        """Trace ``worker``'s engine (fused-stage/wide-node spans via the
        ``DagEngine.trace_hook``) and mount ``profile/`` on its metrics
        tree; also adopts the worker engine's cost model so observations
        and decisions share state."""
        worker.engine.trace_hook = self.buffer.record
        if getattr(worker.engine, "cost_model", None) is not None:
            self.cost = worker.engine.cost_model
        if hasattr(worker, "mount_metrics"):
            worker.mount_metrics("profile", self.summary)
        with self._lock:
            self._workers.append(worker)
        return self

    def detach(self):
        with self._lock:
            jobs, self._jobs = self._jobs, []
            workers, self._workers = self._workers, []
        for job in jobs:
            if job.tracer is self:
                job.tracer = None
        for w in workers:
            if getattr(w.engine, "trace_hook", None) is self.buffer.record:
                w.engine.trace_hook = None

    # ------------------------------------------------------------------
    # scheduler callback (core/job.py `_run_locked` end)
    # ------------------------------------------------------------------
    def task_done(self, task):
        """Emit the task's phase spans from its stamped timestamps and feed
        the cost history. Called once per resolved task, failed or not."""
        if not task.t_end:
            return
        lane = task_lane(task)
        tid = task.tid or 0
        args = {"lane": lane, "kind": task.kind, "task": task.name,
                "state": task.state, "attempt": task.attempt}
        if task.t_lock_wait > 0:
            self.buffer.add(Span("lock_wait", "sched",
                                 task.t_start - task.t_lock_wait,
                                 task.t_start, tid, dict(args)))
        # whole-task span; compute/settle children nest inside it
        self.buffer.add(Span(task.name, "task", task.t_start, task.t_end,
                             tid, dict(args)))
        t_compute_end = task.t_compute_end or task.t_end
        self.buffer.add(Span("compute", "task", task.t_start,
                             min(t_compute_end, task.t_end), tid, dict(args)))
        if task.t_settle_end > t_compute_end:
            self.buffer.add(Span("settle", "task", t_compute_end,
                                 min(task.t_settle_end, task.t_end), tid,
                                 {**args, "overlapped": task.lock_dropped}))
        key = self.task_key(task)
        if key is not None:
            self.cost.observe_task(key, task.t_end - task.t_start)

    @staticmethod
    def task_key(task):
        """The cost-history key for a task — shared with the scheduler's
        own observation path so both feed one history."""
        from repro.core.job import task_history_key

        return task_history_key(task)

    # ------------------------------------------------------------------
    # export / introspection
    # ------------------------------------------------------------------
    def spans(self) -> list[Span]:
        return self.buffer.spans()

    def to_chrome(self) -> dict:
        return to_chrome(self.buffer.spans())

    def save(self, path: str):
        save_chrome(self.buffer.spans(), path)

    def summary(self) -> dict:
        """The ``profile/`` metrics namespace: span counts and per-phase
        wall totals (milliseconds)."""
        spans = self.buffer.spans()
        task_spans = [s for s in spans if s.cat == "task" and s.name
                      not in ("compute", "settle")]
        by = lambda name: sum(s.dur for s in spans if s.name == name)
        return {
            "spans": len(spans),
            "tasks": len(task_spans),
            "engine_spans": sum(1 for s in spans if s.cat == "engine"),
            "compute_ms": by("compute") * 1e3,
            "lock_wait_ms": by("lock_wait") * 1e3,
            "settle_ms": by("settle") * 1e3,
            "makespan_ms": ((max(s.t1 for s in spans) - min(s.t0 for s in spans)) * 1e3
                            if spans else 0.0),
            "cost": self.cost.snapshot(),
        }
