"""ServeFrontDoor — continuous-batching decode ticks as scheduler tasks
(docs/streaming.md).

Wraps a ``serving.engine.ServeEngine``: each decode tick becomes a job task
(kind ``serve``) chained on the previous tick and pinned to a dedicated
gang group, so serving shares the ``JobScheduler`` DAG with ingestion pumps
and ordinary dataflow jobs — ticks serialize under their group lock while
everything else overlaps (the paper's hybrid pattern at serving time).

Admission: a bounded front-door queue (``ignis.serve.queue.depth``) sheds
requests beyond the bound — overload is a policy outcome, counted per
tenant in the shared telemetry, never an error. A tick that dies BEFORE its
decode (the ``job.task`` fault site fires ahead of the task fn) retries via
the scheduler; the engine's state advances exactly once per successful
tick, so retried ticks never double-decode.
"""
from __future__ import annotations

import threading
import time
from typing import Optional

from repro.core.job import IFuture, JobTask
from repro.serving.engine import Request


class ServeTicket:
    """Front-door handle for one submitted request: resolves to the retired
    ``Request`` (or marks the request shed at admission)."""

    __slots__ = ("request", "tenant", "shed", "t_submit", "latency_ms", "_event")

    def __init__(self, request: Optional[Request], tenant: str, shed: bool = False):
        self.request = request
        self.tenant = tenant
        self.shed = shed
        self.t_submit = time.perf_counter()
        self.latency_ms = 0.0
        self._event = threading.Event()
        if shed:
            self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Optional[Request]:
        if not self._event.wait(timeout):
            raise TimeoutError("request still in flight")
        return None if self.shed else self.request

    def _resolve(self):
        self.latency_ms = (time.perf_counter() - self.t_submit) * 1e3
        self._event.set()


class ServeFrontDoor:
    def __init__(self, engine, worker, *, group=None, name: str = "serve",
                 job=None, scheduler=None, telemetry=None, props=None):
        from repro.core.job import default_scheduler
        from repro.streaming.telemetry import StreamTelemetry

        self.engine = engine
        self.worker = worker
        self.group = group
        self.name = name
        # an attached IJob records tick tasks for stats()/explain() — the
        # DAG view of serving and ingestion sharing one scheduler
        self.job = job
        self.scheduler = (scheduler if scheduler is not None
                          else job.scheduler if job is not None
                          else default_scheduler())
        self.telemetry = telemetry or StreamTelemetry()
        props = props if props is not None else worker.cluster.props
        self.queue_depth = props.get_int("ignis.serve.queue.depth", 64)
        self._lock = threading.Lock()
        self._tickets: dict[int, ServeTicket] = {}
        self._next_rid = 0
        self._tick_no = 0
        self._prev_tick: Optional[JobTask] = None
        self.completed: list[ServeTicket] = []

    # ------------------------------------------------------------------
    def submit(self, prompt, *, max_new_tokens: int = 32, eos_id=None,
               tenant: str = "t0") -> ServeTicket:
        """Admit (or shed) one request. Admission is queue-depth bounded —
        the engine's waiting queue, not the in-flight slots, is the bound:
        live decode slots drain at a fixed rate, the queue is where
        overload accumulates."""
        with self._lock:
            if len(self.engine.queue) >= self.queue_depth:
                self.telemetry.record_shed(tenant)
                return ServeTicket(None, tenant, shed=True)
            rid = self._next_rid
            self._next_rid += 1
            req = Request(rid, prompt, max_new_tokens=max_new_tokens,
                          eos_id=eos_id)
            ticket = ServeTicket(req, tenant)
            self._tickets[rid] = ticket
            self.engine.submit(req)
            self.telemetry.record_admitted(tenant)
        return ticket

    # ------------------------------------------------------------------
    def _tick_fn(self):
        """One engine tick under the serve group's lock. Retirement drains
        through the engine's ``retired`` list (the same channel
        ``run_to_completion`` uses), so a request admitted and finished
        within this very tick resolves its ticket here."""
        self.engine.step()
        retired, self.engine.retired = self.engine.retired, []
        out = []
        with self._lock:
            for req in retired:
                ticket = self._tickets.pop(req.rid, None)
                if ticket is None:
                    continue
                ticket._resolve()
                self.completed.append(ticket)
                self.telemetry.record_completed(ticket.tenant, ticket.latency_ms)
                out.append(ticket)
        return out

    def tick_async(self) -> IFuture:
        """Schedule ONE decode tick as a job task. Ticks chain (each deps on
        the previous) and carry the serve group's lock, so they serialize
        among themselves while the scheduler interleaves them with
        ingestion micro-batches on other groups."""
        deps = [self._prev_tick] if self._prev_tick is not None else []
        task = JobTask(f"{self.name}.tick#{self._tick_no}", "serve",
                       self.worker, self._tick_fn, deps, group=self.group)
        self._tick_no += 1
        self._prev_tick = task
        if self.job is not None:
            self.job.tasks.append(task)
        self.scheduler.submit(task)
        return IFuture(task)

    def drained(self) -> bool:
        return not self.engine.queue and not any(
            r is not None for r in self.engine.live)

    def run_until_drained(self, max_ticks: int = 10_000) -> list:
        """Tick (as scheduler tasks) until queue and slots drain; returns
        the tickets completed during the run."""
        start = len(self.completed)
        ticks = 0
        while not self.drained() and ticks < max_ticks:
            self.tick_async().result()
            ticks += 1
        return self.completed[start:]

    def stats(self) -> dict:
        return {
            "ticks": self._tick_no,
            "completed": len(self.completed),
            "waiting": len(self.engine.queue),
            "live": sum(r is not None for r in self.engine.live),
            "telemetry": self.telemetry.snapshot(),
        }
