"""Per-tenant streaming/serving telemetry (docs/streaming.md).

One ``StreamTelemetry`` is shared by every pump and front door of a
deployment; attach it to an ``IJob`` (``telemetry.attach(job)``) and the
counters surface under the ``"stream"`` section of ``job.stats()`` next to
the scheduler's own numbers. ``summary()`` renders the explain-style text
block (one line per tenant: admitted/shed/completed, replay count, latency
p50/p99)."""
from __future__ import annotations

import threading
from typing import Optional

import numpy as np


class _TenantStats:
    __slots__ = ("admitted", "shed", "completed", "replayed", "latencies_ms")

    def __init__(self):
        self.admitted = 0
        self.shed = 0
        self.completed = 0
        self.replayed = 0  # sum of extra scheduler attempts over all commits
        self.latencies_ms: list[float] = []


def _pct(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


class StreamTelemetry:
    def __init__(self):
        self._lock = threading.Lock()
        self._tenants: dict[str, _TenantStats] = {}

    def _t(self, tenant: str) -> _TenantStats:
        st = self._tenants.get(tenant)
        if st is None:
            st = self._tenants[tenant] = _TenantStats()
        return st

    # ---- recording (called from pump threads and done-callbacks) -------
    def record_admitted(self, tenant: str, n: int = 1):
        with self._lock:
            self._t(tenant).admitted += n

    def record_shed(self, tenant: str, n: int = 1):
        with self._lock:
            self._t(tenant).shed += n

    def record_completed(self, tenant: str, latency_ms: float, replays: int = 0):
        with self._lock:
            st = self._t(tenant)
            st.completed += 1
            st.replayed += replays
            st.latencies_ms.append(float(latency_ms))

    # ---- reading -------------------------------------------------------
    def snapshot(self, controller=None) -> dict:
        with self._lock:
            tenants = {
                name: {
                    "admitted": st.admitted,
                    "shed": st.shed,
                    "completed": st.completed,
                    "batches_replayed": st.replayed,
                    "inflight": (controller.tenant_inflight(name)
                                 if controller is not None else 0),
                    "latency_p50_ms": _pct(st.latencies_ms, 50),
                    "latency_p99_ms": _pct(st.latencies_ms, 99),
                }
                for name, st in sorted(self._tenants.items())
            }
        totals = {
            "admitted": sum(t["admitted"] for t in tenants.values()),
            "shed": sum(t["shed"] for t in tenants.values()),
            "completed": sum(t["completed"] for t in tenants.values()),
            "batches_replayed": sum(t["batches_replayed"] for t in tenants.values()),
            "inflight": controller.inflight if controller is not None else 0,
        }
        return {"tenants": tenants, **totals}

    def summary(self, controller=None) -> str:
        snap = self.snapshot(controller)
        lines = [
            f"== stream telemetry ({len(snap['tenants'])} tenants, "
            f"{snap['completed']} completed, {snap['shed']} shed, "
            f"{snap['batches_replayed']} replayed) =="
        ]
        for name, t in snap["tenants"].items():
            lines.append(
                f"  {name}: admitted={t['admitted']} shed={t['shed']} "
                f"completed={t['completed']} replayed={t['batches_replayed']} "
                f"inflight={t['inflight']} "
                f"p50={t['latency_p50_ms']:.2f}ms p99={t['latency_p99_ms']:.2f}ms"
            )
        return "\n".join(lines)

    def attach(self, job, controller=None):
        """Surface this telemetry under ``job.stats()['stream']``."""
        job.stream = lambda: self.snapshot(controller)
        return job
