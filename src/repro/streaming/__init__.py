"""Streaming micro-batch ingestion + multi-tenant serving (docs/streaming.md).

The subsystem turns unbounded sources into bounded sequences of micro-batch
job-task submissions on the PR-3 ``IJob`` scheduler: per-tenant gang groups
are the isolation primitive (docs/collectives.md), admission control +
driver-side backpressure bound the in-flight depth, and stream offsets +
operator state checkpoint through ``repro.checkpoint`` for exactly-once
restart. ``ServeFrontDoor`` runs continuous-batching decode ticks as
scheduler tasks so serving and ingestion overlap in one DAG — the paper's
hybrid pattern at serving time.
"""
from repro.streaming.admission import AdmissionController
from repro.streaming.context import StreamContext
from repro.streaming.frontend import TenantFrontEnd
from repro.streaming.serve import ServeFrontDoor, ServeTicket
from repro.streaming.source import (
    ArraySource,
    IteratorSource,
    StreamSource,
    TenantRequestSource,
)
from repro.streaming.telemetry import StreamTelemetry

__all__ = [
    "AdmissionController",
    "ArraySource",
    "IteratorSource",
    "ServeFrontDoor",
    "ServeTicket",
    "StreamContext",
    "StreamSource",
    "StreamTelemetry",
    "TenantFrontEnd",
    "TenantRequestSource",
]
