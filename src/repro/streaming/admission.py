"""Admission control — the multi-tenant overload policy (docs/streaming.md).

One controller is shared by every pump/front-door of a serving deployment;
it decides, per micro-batch (or per serve request), between three outcomes:

  ``admit``  a slot is available globally AND within the tenant's quota
  ``wait``   over a bound, policy ``block`` → the CALLER applies
             backpressure (the driver-side pump parks on its own oldest
             future; worker threads are never blocked)
  ``shed``   over a bound, policy ``shed`` → the unit of work is dropped,
             counted, and the stream/serve queue moves on

Bounds come from ``ignis.stream.*`` properties. The ``stream.admit`` fault
site is wired here: an injected fault forces a ``shed`` decision (overload
is a POLICY outcome, not a task error — nothing retries).

Determinism note: only policy ``block`` composes with the exactly-once
replay guarantees — a shed decision depends on instantaneous load, which a
replayed run will not reproduce. Shed mode trades determinism for bounded
latency; the telemetry keeps the loss visible (docs/streaming.md).
"""
from __future__ import annotations

import threading
from typing import Optional

from repro.core import faults


class AdmissionController:
    def __init__(self, props=None, *, max_inflight: Optional[int] = None,
                 tenant_quota: Optional[int] = None,
                 queue_depth: Optional[int] = None, policy: Optional[str] = None):
        get_int = props.get_int if props is not None else lambda k, d: d
        get = props.get if props is not None else lambda k, d: d
        self.max_inflight = max_inflight if max_inflight is not None else \
            get_int("ignis.stream.max.inflight", 8)
        self.tenant_quota = tenant_quota if tenant_quota is not None else \
            get_int("ignis.stream.tenant.quota", 4)
        self.queue_depth = queue_depth if queue_depth is not None else \
            get_int("ignis.stream.queue.depth", 16)
        self.policy = policy if policy is not None else \
            get("ignis.stream.shed.policy", "block")
        if self.policy not in ("block", "shed"):
            raise ValueError(f"unknown shed policy {self.policy!r}")
        self._cond = threading.Condition()
        self._inflight: dict[str, int] = {}
        self._waiting = 0

    # ------------------------------------------------------------------
    @property
    def inflight(self) -> int:
        with self._cond:
            return sum(self._inflight.values())

    def tenant_inflight(self, tenant: str) -> int:
        with self._cond:
            return self._inflight.get(tenant, 0)

    # ------------------------------------------------------------------
    def try_admit(self, tenant: str) -> str:
        """One admission decision: ``admit`` | ``wait`` | ``shed``."""
        try:
            faults.check("stream.admit", tenant=tenant)
        except faults.FaultInjected:
            return "shed"  # injected overload: policy-forced shed, no retry
        with self._cond:
            total = sum(self._inflight.values())
            mine = self._inflight.get(tenant, 0)
            if total < self.max_inflight and mine < self.tenant_quota:
                self._inflight[tenant] = mine + 1
                return "admit"
            if self.policy == "shed" or self._waiting >= self.queue_depth:
                return "shed"
            return "wait"

    def wait_for_change(self, timeout: float = 0.05):
        """Park until some slot is released (bounded — a caller in ``wait``
        with nothing of its own in flight must not spin; another tenant's
        commit is what frees the global bound)."""
        with self._cond:
            self._waiting += 1
            try:
                self._cond.wait(timeout)
            finally:
                self._waiting -= 1

    def release(self, tenant: str):
        with self._cond:
            n = self._inflight.get(tenant, 0)
            if n <= 1:
                self._inflight.pop(tenant, None)
            else:
                self._inflight[tenant] = n - 1
            self._cond.notify_all()
