"""Stream sources — replayable unbounded inputs (docs/streaming.md).

The exactly-once contract lives HERE: ``poll(offset, max_rows)`` must be a
pure function of its arguments — polling the same offset twice (a replayed
micro-batch after a kill, or a restart from a checkpointed offset) returns
bit-identical rows. Everything downstream (deterministic batch functions,
in-order commits, offset checkpoints) builds on that property.
"""
from __future__ import annotations

import threading
from typing import Callable, Iterator, Optional, Protocol, Tuple

import numpy as np


class StreamSource(Protocol):
    def poll(self, offset: int, max_rows: int) -> Tuple[Optional[np.ndarray], int]:
        """Up to ``max_rows`` rows starting at ``offset``; returns
        ``(rows, next_offset)``. ``rows is None`` (or empty) means the
        source is exhausted at ``offset`` — an unbounded source never is.
        MUST be deterministic in ``(offset, max_rows)``."""
        ...


class ArraySource:
    """A bounded in-memory source: offsets are row indices into one array."""

    def __init__(self, rows: np.ndarray):
        self.rows = np.asarray(rows)

    def poll(self, offset: int, max_rows: int):
        if offset >= len(self.rows):
            return None, offset
        chunk = self.rows[offset : offset + max_rows]
        return chunk, offset + len(chunk)


class IteratorSource:
    """Adapter for iterator-shaped inputs (the seed ``data/pipeline.py``
    generators). Replay works by RECONSTRUCTION: ``factory()`` must return a
    fresh, deterministic iterator of row-arrays, and a poll at an offset
    behind the cursor rebuilds the iterator and skips forward — so a
    replayed batch sees the same rows without the source buffering its whole
    history. Offsets count ROWS, not iterator items; items are concatenated
    and re-chunked to ``max_rows``."""

    def __init__(self, factory: Callable[[], Iterator[np.ndarray]]):
        self.factory = factory
        self._lock = threading.Lock()
        self._it: Optional[Iterator[np.ndarray]] = None
        self._pos = 0  # row offset of the iterator cursor
        self._buf: Optional[np.ndarray] = None  # rows read but not consumed

    def _reset(self):
        self._it = iter(self.factory())
        self._pos = 0
        self._buf = None

    def poll(self, offset: int, max_rows: int):
        with self._lock:
            if self._it is None or offset < self._pos:
                self._reset()
            # skip forward to ``offset`` (drops rows a committed batch
            # already consumed), then accumulate up to max_rows
            out: list[np.ndarray] = []
            have = 0
            while True:
                if self._buf is not None and len(self._buf):
                    chunk = self._buf
                    self._buf = None
                else:
                    try:
                        chunk = np.atleast_1d(np.asarray(next(self._it)))
                    except StopIteration:
                        break
                if self._pos + len(chunk) <= offset:  # entirely pre-offset
                    self._pos += len(chunk)
                    continue
                if self._pos < offset:  # straddles the offset
                    chunk = chunk[offset - self._pos :]
                    self._pos = offset
                take = min(len(chunk), max_rows - have)
                out.append(chunk[:take])
                if take < len(chunk):
                    self._buf = chunk[take:]
                self._pos += take
                have += take
                if have >= max_rows:
                    break
            if not out:
                return None, offset
            rows = np.concatenate(out) if len(out) > 1 else out[0]
            return rows, offset + len(rows)


class TenantRequestSource:
    """Synthetic unbounded per-tenant request stream. Row ``i`` is computed
    ARITHMETICALLY from ``(seed, tenant_id, i)`` — no RNG state, no history
    — so a replay at any batch boundary, or a restart from any checkpointed
    offset, reproduces the exact same rows. Rows are ``(global_index,
    payload)`` int32 pairs; ``limit`` bounds the stream for tests/benches
    (None → unbounded)."""

    _A, _B, _C, _M = 2654435761, 40503, 97, 10_000  # mix constants

    def __init__(self, tenant_id: int, seed: int = 0, limit: Optional[int] = None):
        self.tenant_id = int(tenant_id)
        self.seed = int(seed)
        self.limit = limit

    def poll(self, offset: int, max_rows: int):
        end = offset + max_rows
        if self.limit is not None:
            end = min(end, self.limit)
        if end <= offset:
            return None, offset
        idx = np.arange(offset, end, dtype=np.int64)
        mixed = (idx * self._A + self.tenant_id * self._B + self.seed * self._C)
        payload = (mixed % self._M).astype(np.int32)
        rows = np.stack([idx.astype(np.int32), payload], axis=1)
        return rows, int(end)
