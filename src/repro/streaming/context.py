"""StreamContext — unbounded source → micro-batch job submissions
(docs/streaming.md, DESIGN.md §12).

One pump (driver thread) per tenant stream:

  poll → admit → submit a micro-batch action on the ``IJob`` scheduler →
  commit results strictly in batch order → checkpoint (offset, batch
  index, operator state) every N commits.

Backpressure is DRIVER-side: the pump bounds its own in-flight futures and
parks on the oldest one (``IFuture.result``) when the admission controller
says ``wait`` — scheduler worker threads are never blocked, so ingestion
pumps, serve ticks and ordinary dataflow jobs keep overlapping in one DAG.

Exactly-once: the source is replayable (``source.py``), the batch function
is deterministic, commits happen in submission order on the pump thread,
and a checkpoint is only cut at a quiesce point (nothing in flight) — so a
killed micro-batch (``stream.batch`` fault → scheduler lineage retry) or a
full restart from ``ckpt_dir`` reconverges to bit-identical operator state,
with the replay count surfaced exactly (``batches_replayed``).
"""
from __future__ import annotations

import os
import time
from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.core import faults
from repro.core.job import IJob
from repro.core.partition import to_host


def _default_batch_fn(rows: np.ndarray) -> np.ndarray:
    """Deterministic per-batch summary: exact int64 column sums."""
    return np.sum(np.asarray(rows, dtype=np.int64), axis=0)


def _default_fold_fn(state, result):
    return np.asarray(state, dtype=np.int64) + np.asarray(result, dtype=np.int64)


class _Pending:
    __slots__ = ("index", "future", "next_offset", "t_submit")

    def __init__(self, index, future, next_offset, t_submit):
        self.index = index
        self.future = future
        self.next_offset = next_offset
        self.t_submit = t_submit


class StreamContext:
    """Micro-batch pump for ONE tenant stream.

    ``batch_fn(rows) -> result`` runs INSIDE the job task (retried via
    lineage on recoverable failure; must be deterministic);
    ``fold_fn(state, result) -> state`` runs on the pump thread at commit
    time, strictly in batch order. The default pair keeps exact int64
    column sums — bit-identity under replay is checkable with ``==``.
    """

    def __init__(self, worker, source, *, tenant: str = "t0", name: str = "stream",
                 group=None, job: Optional[IJob] = None,
                 batch_fn: Optional[Callable] = None,
                 fold_fn: Optional[Callable] = None,
                 init_state=None, ckpt_dir: Optional[str] = None,
                 admission=None, telemetry=None, props=None):
        from repro.streaming.admission import AdmissionController
        from repro.streaming.telemetry import StreamTelemetry

        self.worker = worker
        self.source = source
        self.tenant = tenant
        self.name = name
        self.group = group
        self.props = props if props is not None else worker.cluster.props
        self.batch_rows = self.props.get_int("ignis.stream.batch.rows", 256)
        self.ckpt_interval = self.props.get_int("ignis.stream.checkpoint.interval", 0)
        self.ckpt_dir = ckpt_dir
        self.job = job if job is not None else IJob(f"{name}:{tenant}")
        self.admission = admission if admission is not None else \
            AdmissionController(self.props)
        self.telemetry = telemetry if telemetry is not None else StreamTelemetry()
        self.telemetry.attach(self.job, self.admission)
        self.batch_fn = batch_fn or _default_batch_fn
        self.fold_fn = fold_fn or _default_fold_fn
        if ckpt_dir is not None and init_state is None:
            raise ValueError(
                "exactly-once restart needs a fixed state structure: pass "
                "init_state (a pytree of numpy arrays) with ckpt_dir")
        self._init_state = init_state
        # commit pointer: offset/batch index/state of the last COMMITTED batch
        self.state = None if init_state is None else _np_copy(init_state)
        self.offset = 0
        self.batch_index = 0  # next batch ordinal to submit
        self.committed = 0    # batches committed (== next commit ordinal)
        self.batches_replayed = 0
        self.shed_batches = 0
        self._pending: deque[_Pending] = deque()
        self._restored_from: Optional[int] = None
        if ckpt_dir is not None:
            self._maybe_restore()

    # ------------------------------------------------------------------
    # checkpoint / restore (exactly-once restart)
    # ------------------------------------------------------------------
    def _ckpt_tree(self):
        return {
            "offset": np.asarray(self.offset, np.int64),
            "committed": np.asarray(self.committed, np.int64),
            "replayed": np.asarray(self.batches_replayed, np.int64),
            "state": self.state,
        }

    def _maybe_restore(self):
        from repro import checkpoint as ck

        step = ck.latest_step(self.ckpt_dir)
        if step is None:
            return
        target = {
            "offset": np.zeros((), np.int64),
            "committed": np.zeros((), np.int64),
            "replayed": np.zeros((), np.int64),
            "state": self._init_state,
        }
        tree = ck.restore(self.ckpt_dir, step, target)
        self.offset = int(np.asarray(tree["offset"]))
        self.committed = self.batch_index = int(np.asarray(tree["committed"]))
        self.batches_replayed = int(np.asarray(tree["replayed"]))
        self.state = _np_copy(tree["state"])
        self._restored_from = step

    def _checkpoint(self, crash: bool = False):
        """Cut a checkpoint at a quiesce point: callers drain in-flight
        batches first, so (offset, committed, state) are mutually
        consistent — restoring replays nothing and skips nothing.
        ``crash=True`` skips the quiesce assert: in-order commits keep
        (offset, committed, state) consistent after EVERY commit, so the
        committed prefix is a valid checkpoint even with a failed batch
        still in flight — it will be replayed from the source on restart."""
        from repro import checkpoint as ck

        assert crash or not self._pending, "checkpoint requires a quiesced pump"
        os.makedirs(self.ckpt_dir, exist_ok=True)
        ck.save(self.ckpt_dir, self.committed, self._ckpt_tree(), keep=3)
        # the job memo pinned every evaluated micro-batch subgraph; state is
        # durable now, so release it — the streaming analogue of
        # lineage truncation at a checkpoint (docs/fault_tolerance.md)
        self.job.release()

    def _drain_then_checkpoint(self):
        """Drain to a quiesce point and cut the checkpoint. If a batch
        failure aborts the drain, cut a crash checkpoint of the committed
        prefix BEFORE propagating: without it, a fault landing on a batch
        that was pipelined behind the checkpoint trigger would abort the
        pump with NO checkpoint at all, and the restart would replay the
        whole stream instead of resuming from the last commit (the restart
        stays exactly-once either way — this bounds replay work, and makes
        ``restored_from`` deterministic for the chaos tier)."""
        try:
            self.drain()
        except BaseException:
            try:
                self._checkpoint(crash=True)
            except Exception:
                pass  # best-effort: the original abort must propagate
            raise
        self._checkpoint()

    @property
    def restored_from(self) -> Optional[int]:
        return self._restored_from

    # ------------------------------------------------------------------
    # the pump
    # ------------------------------------------------------------------
    def _submit_batch(self, rows: np.ndarray, next_offset: int):
        index = self.batch_index
        worker, tenant, batch_fn = self.worker, self.tenant, self.batch_fn
        with worker.use_group(self.group):
            # parallelize under the group binding: blocks land on the
            # tenant's mesh slice, and the action task below is pinned to
            # the same group — ingestion slices never contend on one lock
            frame = worker.parallelize(rows)
        node = frame.node

        def task_fn(memo, _node=node, _index=index):
            faults.check("stream.batch", tenant=tenant, batch=_index)
            blocks = worker.engine.evaluate(_node, memo=memo)
            out: list = []
            for b in blocks:
                out.extend(to_host(b))
            return batch_fn(np.asarray(out))

        fut = self.job.submit_action(frame, f"{self.name}.{tenant}.b{index}",
                                     task_fn=task_fn, group=self.group)
        self._pending.append(_Pending(index, fut, next_offset, time.perf_counter()))
        self.batch_index += 1
        self.telemetry.record_admitted(tenant)

    def _commit_head(self, block: bool):
        """Commit the oldest in-flight batch (strictly in order). Returns
        True if a batch was committed."""
        if not self._pending:
            return False
        head = self._pending[0]
        if not block and not head.future.done():
            return False
        result = head.future.result()  # propagates non-recoverable errors
        self._pending.popleft()
        task = head.future.task
        replays = task.attempt  # extra scheduler attempts == replays
        self.batches_replayed += replays
        if self.state is None:
            self.state = _np_copy(result)
        else:
            self.state = self.fold_fn(self.state, result)
        self.offset = head.next_offset
        self.committed += 1
        self.admission.release(self.tenant)
        self.telemetry.record_completed(
            self.tenant, (time.perf_counter() - head.t_submit) * 1e3, replays)
        if (self.ckpt_dir is not None and self.ckpt_interval > 0
                and self.committed % self.ckpt_interval == 0):
            self._drain_then_checkpoint()
        return True

    def _commit_ready(self):
        while self._commit_head(block=False):
            pass

    def drain(self):
        """Commit every in-flight batch (driver-side wait)."""
        while self._pending:
            self._commit_head(block=True)

    def run(self, max_batches: Optional[int] = None):
        """Pump until the source is exhausted (or ``max_batches`` more
        batches committed). Returns the folded operator state."""
        target = None if max_batches is None else self.committed + max_batches
        while target is None or self.batch_index < target:
            self._commit_ready()
            decision = self.admission.try_admit(self.tenant)
            if decision == "wait":
                # backpressure: park on OUR oldest future if any, else on
                # the controller (another tenant's commit frees the bound)
                if self._pending:
                    self._commit_head(block=True)
                else:
                    self.admission.wait_for_change()
                continue
            rows, next_offset = self.source.poll(self.offset_next_poll,
                                                 self.batch_rows)
            if rows is None or len(rows) == 0:
                if decision == "admit":  # slot acquired but nothing to run
                    self.admission.release(self.tenant)
                break
            if decision == "shed":
                # explicit load shedding: the batch is dropped and the
                # offset advances past it — visible in telemetry, and only
                # reachable under policy "shed" / injected stream.admit
                # faults (policy "block" never sheds: docs/streaming.md)
                self.shed_batches += 1
                self.telemetry.record_shed(self.tenant)
                self._apply_shed(next_offset)
                continue
            self._submit_batch(rows, next_offset)
        if self.ckpt_dir is not None and self.ckpt_interval > 0:
            self._drain_then_checkpoint()
        else:
            self.drain()
        return self.state

    def _apply_shed(self, next_offset: int):
        """Advance the poll cursor past a shed batch. The COMMIT offset only
        moves once every in-flight batch ahead of the shed point lands, so
        a crash mid-shed replays (rather than loses) trailing batches."""
        self.drain()
        self.offset = next_offset
        self.batch_index += 1  # a shed batch consumes its ordinal: the run
        # budget counts polled batches, so an all-shedding fault plan still
        # terminates

    @property
    def offset_next_poll(self) -> int:
        """Where the next poll starts: the committed offset plus everything
        already in flight."""
        return self._pending[-1].next_offset if self._pending else self.offset

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        return {
            "tenant": self.tenant,
            "committed": self.committed,
            "offset": self.offset,
            "inflight": len(self._pending),
            "batches_replayed": self.batches_replayed,
            "shed_batches": self.shed_batches,
            "restored_from": self._restored_from,
        }


def _np_copy(tree):
    import jax

    return jax.tree.map(lambda x: np.array(x, copy=True), tree)
