"""TenantFrontEnd — admit tenants onto per-tenant gang groups
(docs/streaming.md).

The isolation primitive is PR 4's communicator split (``worker.groups(n)``):
each admitted tenant's micro-batches are pinned to one group, so tenants
run concurrently on disjoint mesh slices under per-group locks — one
tenant's heavy stream cannot serialize another's (the oracle test compares
per-tenant results and latency against solo runs). All pumps share ONE
``IJob`` (the paper's one-DAG claim), one admission controller and one
telemetry sink; ``job.stats()['stream']`` aggregates across tenants.
"""
from __future__ import annotations

import threading
from typing import Optional

from repro.core.job import IJob
from repro.streaming.admission import AdmissionController
from repro.streaming.context import StreamContext
from repro.streaming.telemetry import StreamTelemetry


class TenantFrontEnd:
    def __init__(self, worker, *, n_groups: int = 1, name: str = "tenants",
                 props=None, admission: Optional[AdmissionController] = None,
                 telemetry: Optional[StreamTelemetry] = None, elastic=None):
        self.worker = worker
        self.name = name
        self.props = props if props is not None else worker.cluster.props
        # autoscaling hook (docs/elasticity.md): an ElasticPolicy here is
        # notified on every admit — tenants arrive, the mesh follows. The
        # front end's gang groups stay as built (pumps pin their group for
        # life); the grown ranks serve WORLD-communicator work and the next
        # front end built at the new size.
        self.elastic = elastic
        self.groups = worker.groups(n_groups) if n_groups > 1 else [None]
        self.job = IJob(name)
        self.admission = admission or AdmissionController(self.props)
        self.telemetry = telemetry or StreamTelemetry()
        self.telemetry.attach(self.job, self.admission)
        self._streams: dict[str, StreamContext] = {}
        self._next_group = 0

    def admit(self, tenant: str, source, *, ckpt_dir=None, init_state=None,
              batch_fn=None, fold_fn=None) -> StreamContext:
        """Admit a tenant: deal it the next gang group round-robin and build
        its pump. The pump shares the front end's job/admission/telemetry."""
        if tenant in self._streams:
            raise ValueError(f"tenant {tenant!r} already admitted")
        if self.elastic is not None:
            self.elastic.on_admit(len(self._streams) + 1)
        group = self.groups[self._next_group % len(self.groups)]
        self._next_group += 1
        sc = StreamContext(
            self.worker, source, tenant=tenant, name=self.name, group=group,
            job=self.job, admission=self.admission, telemetry=self.telemetry,
            props=self.props, ckpt_dir=ckpt_dir, init_state=init_state,
            batch_fn=batch_fn, fold_fn=fold_fn)
        self._streams[tenant] = sc
        return sc

    def stream(self, tenant: str) -> StreamContext:
        return self._streams[tenant]

    def run(self, max_batches: Optional[int] = None) -> dict:
        """Run every admitted tenant's pump concurrently (one driver thread
        per tenant — pumps park on futures, workers never block). Returns
        ``{tenant: final_state}``; re-raises the first pump error."""
        results: dict = {}
        errors: list = []

        def pump(tenant: str, sc: StreamContext):
            try:
                results[tenant] = sc.run(max_batches)
            except BaseException as e:  # surfaced to the caller below
                errors.append((tenant, e))

        threads = [
            threading.Thread(target=pump, args=(t, sc), daemon=True,
                             name=f"pump-{t}")
            for t, sc in self._streams.items()
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        if errors:
            tenant, err = errors[0]
            raise RuntimeError(f"tenant {tenant!r} pump failed") from err
        return results

    def stats(self) -> dict:
        return {
            "tenants": {t: sc.stats() for t, sc in self._streams.items()},
            "telemetry": self.telemetry.snapshot(self.admission),
            "job": self.job.stats(),
        }

    def summary(self) -> str:
        return self.telemetry.summary(self.admission)
