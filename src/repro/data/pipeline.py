"""Data pipeline built ON the dataflow layer — the paper's hybrid pattern
(Fig. 12): Big-Data tasks (tokenize / filter / pack) prepare the data, the
compute-intensive task (the train step) consumes it over the same fabric.

Byte-level tokenizer (no external vocab), document packing into fixed
seq_len rows with next-token labels and a loss mask (PAD positions carry
label -1, which the loss layer ignores — layers._ce_block), double-buffered
host→device feed. Packing and batching surface what they drop
(``stats=``): the tail tokens past the last full row and the partial batch
at each epoch end — silent discards would skew any data-accounting done on
top (docs/streaming.md uses the same accounting discipline for shed
micro-batches).
"""
from __future__ import annotations

import threading
from queue import Empty, Full, Queue
from typing import Iterator, Optional

import jax
import numpy as np

BOS, EOS, PAD = 256, 257, 258
VOCAB = 259  # bytes + specials


def byte_tokenize(text: str) -> np.ndarray:
    return np.frombuffer(text.encode("utf-8", errors="replace"), np.uint8).astype(np.int32)


def pack_sequences(docs, seq_len: int, stats: Optional[dict] = None) -> np.ndarray:
    """Pack tokenized docs (list of int arrays) into (n, seq_len+1) rows
    (the +1 column yields next-token labels).

    Tokens past the last full row are DROPPED (fixed-shape rows); pass a
    ``stats`` dict to receive ``dropped_tail_tokens`` (and ``packed_rows`` /
    ``stream_tokens`` for the denominator) instead of losing that count.
    """
    stream: list[int] = []
    for d in docs:
        stream.append(BOS)
        stream.extend(int(t) for t in d)
        stream.append(EOS)
    L = seq_len + 1
    n = max(len(stream) // L, 1)
    arr = np.full((n, L), PAD, np.int32)
    flat = np.asarray(stream[: n * L], np.int32)
    arr.reshape(-1)[: flat.size] = flat
    if stats is not None:
        stats["stream_tokens"] = len(stream)
        stats["packed_rows"] = n
        stats["dropped_tail_tokens"] = max(len(stream) - n * L, 0)
    return arr


def loss_mask_for(labels: np.ndarray) -> np.ndarray:
    """True where a label is a real next-token target (not PAD filler)."""
    return labels != PAD


def batches_from_rows(rows: np.ndarray, batch: int, *, seed: int = 0,
                      epochs: Optional[int] = None,
                      stats: Optional[dict] = None) -> Iterator[dict]:
    """Yield ``{"tokens", "labels", "loss_mask"}`` host batches forever (or
    for N epochs).

    ``loss_mask`` marks real next-token targets; PAD positions are also
    rewritten to label ``-1`` so the model's cross-entropy (which masks
    negative labels) never trains on padding. Rows that do not fill a batch
    at an epoch end are dropped; a ``stats`` dict receives the running
    ``dropped_partial_rows`` count (and ``epochs_done``) so the discard is
    visible rather than silent.
    """
    rng = np.random.default_rng(seed)
    e = 0
    if stats is not None:
        stats.setdefault("dropped_partial_rows", 0)
        stats.setdefault("epochs_done", 0)
    while epochs is None or e < epochs:
        order = rng.permutation(len(rows))
        n_full = (len(order) // batch) * batch
        for i in range(0, n_full, batch):
            sel = rows[order[i : i + batch]]
            labels = sel[:, 1:]
            mask = loss_mask_for(labels)
            yield {"tokens": sel[:, :-1],
                   "labels": np.where(mask, labels, -1).astype(labels.dtype),
                   "loss_mask": mask}
        e += 1
        if stats is not None:
            stats["dropped_partial_rows"] += len(order) - n_full
            stats["epochs_done"] = e


class TrainPipeline:
    """Double-buffered feed: a background thread stages the next host batch
    and device_puts it while the current step runs (compute/transfer
    overlap — one of the §Perf items)."""

    def __init__(self, batch_iter: Iterator[dict], sharding=None, depth: int = 2):
        self._it = batch_iter
        self._sharding = sharding
        self._q: Queue = Queue(maxsize=depth)
        self._stop = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _put(self, x):
        if self._sharding is not None:
            return jax.device_put(x, self._sharding)
        return jax.device_put(x)

    def _enqueue(self, item) -> bool:
        """Bounded put that stays interruptible: a plain ``Queue.put`` on a
        full queue parks forever, so a consumer that stops iterating (or
        calls ``close()``) would leak this thread blocked in ``put`` —
        ``close()`` could then never ``join`` it. Returns False once
        stopped."""
        while not self._stop:
            try:
                self._q.put(item, timeout=0.05)
                return True
            except Full:
                continue
        return False

    def _run(self):
        for hb in self._it:
            if self._stop:
                return
            if not self._enqueue({k: self._put(v) for k, v in hb.items()}):
                return
        self._enqueue(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        """Stop the producer and reclaim its thread. Safe with a FULL queue
        and a stopped consumer: the stop flag unblocks the producer's
        bounded put, the drain below frees any slot it may still be
        spinning on, and the join confirms the thread exited."""
        self._stop = True
        while True:
            try:
                self._q.get_nowait()
            except Empty:
                break
        self._thread.join(timeout=5.0)
