"""Data pipeline built ON the dataflow layer — the paper's hybrid pattern
(Fig. 12): Big-Data tasks (tokenize / filter / pack) prepare the data, the
compute-intensive task (the train step) consumes it over the same fabric.

Byte-level tokenizer (no external vocab), document packing into fixed
seq_len rows with next-token labels, double-buffered host→device feed.
"""
from __future__ import annotations

import threading
from queue import Queue
from typing import Iterator, Optional

import jax
import numpy as np

BOS, EOS, PAD = 256, 257, 258
VOCAB = 259  # bytes + specials


def byte_tokenize(text: str) -> np.ndarray:
    return np.frombuffer(text.encode("utf-8", errors="replace"), np.uint8).astype(np.int32)


def pack_sequences(docs, seq_len: int) -> np.ndarray:
    """Pack tokenized docs (list of int arrays) into (n, seq_len+1) rows
    (the +1 column yields next-token labels)."""
    stream: list[int] = []
    for d in docs:
        stream.append(BOS)
        stream.extend(int(t) for t in d)
        stream.append(EOS)
    L = seq_len + 1
    n = max(len(stream) // L, 1)
    arr = np.full((n, L), PAD, np.int32)
    flat = np.asarray(stream[: n * L], np.int32)
    arr.reshape(-1)[: flat.size] = flat
    return arr


def batches_from_rows(rows: np.ndarray, batch: int, *, seed: int = 0,
                      epochs: Optional[int] = None) -> Iterator[dict]:
    """Yield {"tokens", "labels"} host batches forever (or for N epochs)."""
    rng = np.random.default_rng(seed)
    e = 0
    while epochs is None or e < epochs:
        order = rng.permutation(len(rows))
        for i in range(0, len(order) - batch + 1, batch):
            sel = rows[order[i : i + batch]]
            yield {"tokens": sel[:, :-1], "labels": sel[:, 1:]}
        e += 1


class TrainPipeline:
    """Double-buffered feed: a background thread stages the next host batch
    and device_puts it while the current step runs (compute/transfer
    overlap — one of the §Perf items)."""

    def __init__(self, batch_iter: Iterator[dict], sharding=None, depth: int = 2):
        self._it = batch_iter
        self._sharding = sharding
        self._q: Queue = Queue(maxsize=depth)
        self._stop = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _put(self, x):
        if self._sharding is not None:
            return jax.device_put(x, self._sharding)
        return jax.device_put(x)

    def _run(self):
        for hb in self._it:
            if self._stop:
                return
            self._q.put({k: self._put(v) for k, v in hb.items()})
        self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop = True
