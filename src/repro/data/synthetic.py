"""Synthetic corpora for examples/benches (deterministic, no downloads)."""
from __future__ import annotations

import numpy as np

_WORDS = (
    "the of and a to in is you that it he was for on are as with his they I "
    "at be this have from or one had by word but not what all were we when "
    "your can said there use an each which she do how their if will up other "
    "about out many then them these so some her would make like him into time"
).split()


def synthetic_corpus(n_docs: int = 200, words_per_doc: int = 120, seed: int = 0):
    rng = np.random.default_rng(seed)
    docs = []
    for _ in range(n_docs):
        k = rng.integers(words_per_doc // 2, words_per_doc)
        docs.append(" ".join(rng.choice(_WORDS, size=k)))
    return docs


def synthetic_batches(vocab: int, batch: int, seq_len: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    while True:
        t = rng.integers(0, vocab, (batch, seq_len + 1), dtype=np.int32)
        yield {"tokens": t[:, :-1], "labels": t[:, 1:]}
