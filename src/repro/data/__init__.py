from repro.data.pipeline import TrainPipeline, byte_tokenize, pack_sequences  # noqa: F401
from repro.data.synthetic import synthetic_corpus, synthetic_batches  # noqa: F401
