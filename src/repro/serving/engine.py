"""Continuous-batching serve engine.

Fixed-slot design (static shapes): the KV cache is a (slots, …) slab; new
requests are admitted into free slots via single-row prefill, every engine
step runs ONE batched decode over all live slots, finished requests retire
and free their slot. Straggler mitigation at the serving layer: a request
exceeding its token budget is preempted (retired with truncation flag).

The decode step is jit-compiled once per (model, slots, cache_len) — slot
state updates are pure-functional cache swaps.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (Lp,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # runtime
    tokens: list = field(default_factory=list)
    done: bool = False
    truncated: bool = False


class ServeEngine:
    def __init__(self, bundle, params, *, slots: int = 4, cache_len: int = 256):
        self.bundle = bundle
        self.cfg = bundle.cfg
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.cache = bundle.make_cache(slots, cache_len)
        self.live: list[Optional[Request]] = [None] * slots
        # deque: admission pops from the head every tick; list.pop(0) is
        # O(queue) per admit, O(n^2) across a burst of n requests
        self.queue: deque[Request] = deque()
        # requests finished but not yet reported: the engine appends here the
        # moment a request retires (whether at prefill or mid-decode) and
        # run_to_completion() drains it — callers polling step() directly can
        # drain it themselves
        self.retired: list[Request] = []
        self._decode = jax.jit(lambda p, c, t: bundle.decode_step(p, c, t))
        self._last = np.zeros((slots,), np.int32)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _finish_check(self, req: Request, tok: int) -> bool:
        """Apply the retirement rules to the just-appended token."""
        if req.eos_id is not None and tok == req.eos_id:
            req.done = True
        if len(req.tokens) >= req.max_new_tokens:
            req.done = True
            req.truncated = req.eos_id is not None and tok != req.eos_id
        return req.done

    def _admit(self):
        for s in range(self.slots):
            if self.live[s] is not None:
                continue
            while self.queue:
                req = self.queue.popleft()
                self._prefill_into_slot(s, req)
                # the prefill already produced a token: a max_new_tokens=1
                # (or eos-on-first-token) request is complete HERE and must
                # retire without ever occupying the slot — it would
                # otherwise collect a second decode token past its budget
                if self._finish_check(req, req.tokens[-1]):
                    self.retired.append(req)
                    continue  # slot still free: admit the next waiter
                self.live[s] = req
                break

    def _prefill_into_slot(self, s: int, req: Request):
        """Single-request prefill, then splice its cache rows into slot s."""
        tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, cache1 = self.bundle.prefill(self.params, tokens=tokens)
        first = int(jax.device_get(jnp.argmax(logits[0])))
        req.tokens.append(first)
        self._last[s] = first
        self.cache = _splice(self.cache, cache1, s, self.cache_len)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Admit + one batched decode tick. Returns #live requests."""
        self._admit()
        if not any(r is not None for r in self.live):
            return 0
        toks = jnp.asarray(self._last, jnp.int32)[:, None]
        logits, self.cache = self._decode(self.params, self.cache, toks)
        nxt = np.asarray(jax.device_get(jnp.argmax(logits, axis=-1)), np.int32)
        for s, req in enumerate(self.live):
            if req is None:
                continue
            tok = int(nxt[s])
            req.tokens.append(tok)
            self._last[s] = tok
            if self._finish_check(req, tok):
                self.retired.append(req)
                self.live[s] = None  # slot freed; stale cache rows are
                # harmless: admission overwrites them via _splice
        return sum(r is not None for r in self.live)

    def run_to_completion(self, max_ticks: int = 10_000) -> list[Request]:
        """Tick until queue and slots drain; returns (and clears) the
        retired list. Retirement is recorded by ``step()`` itself — a
        before/after snapshot here would lose any request that is admitted
        AND finishes within one tick (the snapshot predates ``_admit``, so
        a ``max_new_tokens=1`` request never appeared in it)."""
        ticks = 0
        while (self.queue or any(r is not None for r in self.live)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        out, self.retired = self.retired, []
        return out


def _splice(cache, cache1, slot: int, cache_len: int):
    """Write request-cache (batch 1, len Lp) rows into slot `slot` of the
    slab (batch S, len cache_len)."""

    def one(slab, single):
        if slab.ndim == 1:  # pos / enc_len (B,)
            return slab.at[slot].set(single[0].astype(slab.dtype))
        if slab.ndim == single.ndim and slab.shape[0] == single.shape[0]:
            # per-layer stacked leaves: (L, B, S, ...) vs (L, 1, Lp, ...)
            if single.ndim >= 3 and slab.ndim >= 3 and single.shape[1] == 1:
                Lp = single.shape[2]
                pad = [(0, 0), (0, 0), (0, cache_len - Lp)] + [(0, 0)] * (single.ndim - 3)
                if single.shape[2] != cache_len and len(slab.shape) >= 3 and slab.shape[2] == cache_len:
                    single = jnp.pad(single, pad)
                return jax.lax.dynamic_update_slice_in_dim(slab, single.astype(slab.dtype), slot, axis=1)
            # state-like leaves (L, B, H, P, N) vs (L, 1, H, P, N)
            return jax.lax.dynamic_update_slice_in_dim(slab, single.astype(slab.dtype), slot, axis=1)
        return slab

    return jax.tree.map(one, cache, cache1)
