"""Sharding rules: map every param / input / cache leaf to a PartitionSpec.

Name-based logical-axis rules (the MaxText "logical axes" idea without a
parallel annotation tree): a leaf's dict path + rank decide its spec.

Presets
  dp       — weights & optimizer replicated (the paper-faithful Horovod/MPI
             all-reduce data parallelism); batch over ("pod","data").
  fsdp_tp  — weight rows (d_model) sharded over "data" (FSDP), columns
             (heads / d_ff / vocab) over "model" (TP); GSPMD inserts the
             per-layer all-gathers inside the scan.
  *_zero1  — suffix: optimizer moments sharded over "data" even when the
             params are replicated (ZeRO-1; beyond-paper §Perf).

Decode caches shard batch over ("pod","data") and heads/head_dim over
"model"; the batch-1 long_500k cell context-shards the KV sequence axis over
"data" instead (distributed flash-decode — GSPMD combines the partial
softmax with psums).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# leaf names whose matrices map (…, d_model, X): rows=fsdp(data), cols=tp(model)
_OUT_LAST = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj", "w1", "router", "vit_proj"}
# leaf names whose matrices map (…, X, d_model): rows=tp(model), cols=fsdp(data)
_IN_FIRST = {"wo", "w_down", "out_proj", "w2"}


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def lead_axes(cfg, mesh, B: int, kind: str = "train") -> tuple:
    """Mesh axes the batch dim shards over: the largest divisible candidate.

    dp preset has no TP, so the model axis is free to absorb batch (pure
    Horovod-style DP over the whole slice); fsdp_tp reserves "model" for TP.
    """
    names = mesh.axis_names
    if cfg.sharding_preset.startswith("dp"):
        cands = [
            tuple(names),
            tuple(a for a in ("data", "model") if a in names),
            batch_axes(mesh),
            ("data",) if "data" in names else (),
        ]
    else:
        cands = [batch_axes(mesh), ("data",) if "data" in names else ()]
    for c in cands:
        n = 1
        for a in c:
            n *= _axsize(mesh, a)
        if c and B % n == 0 and B >= n:
            return c
    return ()


def _axsize(mesh, name) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _div(mesh, axis_name, dim) -> bool:
    return dim % _axsize(mesh, axis_name) == 0


def _bsize(mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= _axsize(mesh, a)
    return n


def _leaf_name(path) -> str:
    for k in reversed(path):
        if hasattr(k, "key"):
            return str(k.key)
    return ""


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def _param_spec_one(path, aval, cfg, mesh) -> P:
    preset = cfg.sharding_preset.replace("_zero1", "")
    if preset == "dp":
        return P()
    fsdp_rows = preset in ("fsdp", "fsdp_tp")  # "tp": cols only (+ZeRO-1)
    name = _leaf_name(path)
    rank = len(aval.shape)
    if name == "embed" and rank == 2:
        v, d = aval.shape
        return P("model" if _div(mesh, "model", v) else None,
                 "data" if (fsdp_rows and _div(mesh, "data", d)) else None)
    if name == "lm_head" and rank == 2:
        d, v = aval.shape
        return P("data" if (fsdp_rows and _div(mesh, "data", d)) else None,
                 "model" if _div(mesh, "model", v) else None)
    # sequence-parallel attention: S carries the model axis through the
    # attention block, so its projections must NOT column-shard over "model"
    attn_mats = {"wq", "wk", "wv", "wo"}
    sp = getattr(cfg, "attn_sp", False)
    # expert parallelism: stacked expert mats (L, E, D, F) shard E over
    # "data" (EP) + cols over "model" (TP) — GSPMD turns the dispatch
    # scatter into the all-to-all token routing
    if rank == 4 and name in ("w_gate", "w_up", "w_down") and _div(
        mesh, "data", aval.shape[1]
    ):
        if name == "w_down":  # (L, E, F, D)
            row = "model" if _div(mesh, "model", aval.shape[2]) else None
            return P(None, "data", row, None)
        col = "model" if _div(mesh, "model", aval.shape[3]) else None
        return P(None, "data", None, col)
    if rank >= 2 and name in _OUT_LAST:
        r, c = aval.shape[-2], aval.shape[-1]
        row = "data" if (fsdp_rows and _div(mesh, "data", r)) else None
        col = "model" if (name != "router" and _div(mesh, "model", c)) else None
        if sp and name in attn_mats:
            col = None
        return P(*((None,) * (rank - 2)), row, col)
    if rank >= 2 and name in _IN_FIRST:
        r, c = aval.shape[-2], aval.shape[-1]
        row = "model" if _div(mesh, "model", r) else None
        col = "data" if (fsdp_rows and _div(mesh, "data", c)) else None
        if sp and name in attn_mats:
            row = None
        return P(*((None,) * (rank - 2)), row, col)
    if name == "conv_w" and rank >= 2 and _div(mesh, "model", aval.shape[-1]):
        return P(*((None,) * (rank - 1)), "model")
    return P()  # norms, biases, scalars, pos tables


def param_specs(params_tree, cfg, mesh):
    """PartitionSpec pytree mirroring ``params_tree`` (shapes or arrays)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_spec_one(path, leaf, cfg, mesh), params_tree
    )


def opt_specs(opt_tree, params_spec_tree, cfg, mesh):
    """Optimizer state specs: moments mirror params (or ZeRO-1-shard them)."""
    zero1 = cfg.sharding_preset.endswith("_zero1")

    def moment(spec, leaf):
        if not zero1:
            return spec
        # ZeRO-1: shard the first divisible dim over "data" if not already
        if any(s in ("data", ("data",)) for s in spec):
            return spec
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for i, d in enumerate(leaf.shape):
            if parts[i] is None and _div(mesh, "data", d) and d > 1:
                parts[i] = "data"
                break
        return P(*parts)

    return {
        "m": jax.tree.map(moment, params_spec_tree, opt_tree["m"]),
        "v": jax.tree.map(moment, params_spec_tree, opt_tree["v"]),
        "step": P(),
    }


# ---------------------------------------------------------------------------
# inputs / caches
# ---------------------------------------------------------------------------


def input_specs_sharding(inputs, cfg, mesh, kind: str = "train"):
    """Specs for a batch dict (tokens/labels/frames/patches or decode args)."""

    def one(path, leaf):
        name = _leaf_name(path)
        if name in ("cache",):  # handled by cache_specs
            return P()
        B = leaf.shape[0] if leaf.shape else 1
        lead = lead_axes(cfg, mesh, B, kind)
        return P(lead, *((None,) * (len(leaf.shape) - 1))) if leaf.shape else P()

    out = {}
    for k, v in inputs.items():
        if k == "cache":
            out[k] = cache_specs(v, cfg, mesh)
        else:
            out[k] = jax.tree_util.tree_map_with_path(one, v)
    return out


def cache_specs(cache_tree, cfg, mesh):
    """Decode-cache specs (see module docstring)."""

    def _lead(B):
        return lead_axes(cfg, mesh, B, "decode")

    def one(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        if name in ("k", "v", "k_cross", "v_cross") and len(shape) == 5:
            L, B, S, K, hd = shape
            bl = _lead(B)
            if bl:
                bspec, sspec = bl, None
            else:
                bspec, sspec = None, ("data" if _div(mesh, "data", S) else None)
            model_used = "model" in bl
            if not model_used and _div(mesh, "model", K):
                kspec, hspec = "model", None
            elif not model_used and _div(mesh, "model", hd):
                kspec, hspec = None, "model"
            else:
                kspec = hspec = None
            return P(None, bspec, sspec, kspec, hspec)
        if name == "state" and len(shape) >= 5:
            # (..., B, H, P, N)
            parts = [None] * len(shape)
            B, H = shape[-4], shape[-3]
            bl = _lead(B)
            if bl:
                parts[-4] = bl
            if "model" not in bl and _div(mesh, "model", H):
                parts[-3] = "model"
            return P(*parts)
        if name == "conv" and len(shape) >= 4:
            # (..., B, w, ch)
            parts = [None] * len(shape)
            B, ch = shape[-3], shape[-1]
            bl = _lead(B)
            if bl:
                parts[-3] = bl
            if "model" not in bl and _div(mesh, "model", ch):
                parts[-1] = "model"
            return P(*parts)
        if len(shape) == 1:  # pos, enc_len
            bl = _lead(shape[0])
            return P(bl) if bl else P()
        return P()

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def to_named(tree_of_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_of_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
