"""Elastic mesh — runtime grow/shrink of executor ranks (docs/elasticity.md,
DESIGN.md §14).

Three layers live here:

* **Checkpoint elasticity** (seed): ``restore_elastic`` re-places a saved
  train-state tree onto a differently-shaped mesh — the MPI-3 "dynamic
  process join" analogue the paper leans on for replacing lost executors.
  Checkpoints store full logical arrays, so elasticity is a placement
  decision at restore: build the new mesh, derive the sharding specs from
  the same rules, device_put. Divisibility permitting, ANY (pod, data,
  model) factorization restores the same training state.

* **Runtime elasticity**: the incremental reshard that backs
  ``IWorker.grow``/``IWorker.shrink`` (core/cluster.py). ``plan_reshard``
  is the pure move/keep rule; ``reshard_cached`` walks the worker's cached
  nodes and MOVES only the blocks whose ownership changed — never a full
  lineage recompute. A block lost mid-move (the ``elastic.reshard`` fault
  site) degrades to a lineage hole repaired block-wise on the next action.

* **Autoscaling**: ``ElasticPolicy`` — scheduler queue depth and tenant
  admissions (streaming/frontend.py) drive deterministic grow/shrink
  decisions off the ``ignis.elastic.*`` properties.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import restore
from repro.core import faults
from repro.core.metrics import Counters
from repro.core.partition import Block, block_devices, pad_to, place_block
from repro.distributed.sharding import opt_specs, param_specs, to_named


def restore_elastic(ckpt_dir: str, step: int, cfg, mesh, target: dict) -> dict:
    """Restore a train-state tree ``{"params": …[, "opt": …]}`` re-placed
    for ``mesh`` (which may have a different shape than the one that saved)."""
    psp = param_specs(target["params"], cfg, mesh)
    shardings = {"params": to_named(psp, mesh)}
    if "opt" in target:
        shardings["opt"] = to_named(opt_specs(target["opt"], psp, cfg, mesh), mesh)
    return restore(ckpt_dir, step, target, {**{k: None for k in target}, **shardings})


# ---------------------------------------------------------------------------
# incremental reshard: the move/keep rule and the block mover
# ---------------------------------------------------------------------------

def plan_reshard(devs: Optional[frozenset], old_world: frozenset,
                 new_world: frozenset) -> str:
    """Pure move/keep decision for one cached block across a resize.

    ``devs`` is the block's committed device set (``block_devices``; None =
    host/uncommitted). A block moves when its ownership changed: it touches
    a retired device, it was bound to the FULL old world (world partitions
    re-spread over the resized world — capacity must become a multiple of
    the new executor count before any wide stage runs), or it is not fully
    contained in the new world. A block resident wholly on a surviving
    sub-group keeps its placement — the genuinely unaffected partition: if
    a later task binds it to a different communicator, the lazy ingress
    reshard (shuffle ``_placed``/``place_block``) handles it then.
    """
    if devs is None:
        return "move"
    retired = old_world - new_world
    if devs & retired:
        return "move"
    if devs == old_world:
        return "move"
    if not devs <= new_world:
        return "move"
    return "keep"


def repad_block(block: Block, p: int, mesh, axis: str) -> Block:
    """Re-pad a Block's capacity to a multiple of ``p`` (zero data, False
    validity) and commit it rows-over-``axis`` on ``mesh`` — pure data
    movement, no lineage evaluation."""
    cap = block.capacity
    cap2 = max(pad_to(cap, p), p)
    if cap2 != cap:
        pad = cap2 - cap

        def padleaf(x):
            w = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
            return jnp.pad(x, w)

        block = Block(jax.tree.map(padleaf, block.data),
                      jnp.pad(block.valid, (0, pad)))
    return place_block(block, mesh, axis)


def reshard_cached(worker, old_world: frozenset, new_ctx) -> tuple[int, int, int]:
    """Move the cached blocks whose ownership changed onto ``new_ctx``'s
    mesh; keep the rest in place. Returns ``(moves, unchanged, recomputes)``
    where ``recomputes`` counts blocks LOST mid-move (``elastic.reshard``
    fault site): they are left as lineage holes for block-wise repair —
    the only path by which a resize ever causes recomputation."""
    moves = kept = recomputes = 0
    p = new_ctx.executors
    new_world = frozenset(new_ctx.mesh.devices.flat)
    for node in list(worker._cached_nodes):
        blocks = node.result
        if blocks is None:
            continue
        for i, b in enumerate(blocks):
            if b is None:
                continue  # a pre-existing hole: lineage repair owns it
            if plan_reshard(block_devices(b), old_world, new_world) == "keep":
                kept += 1
                continue
            try:
                faults.check("elastic.reshard", op=node.op, block=i)
                blocks[i] = repad_block(b, p, new_ctx.mesh, new_ctx.axis)
                moves += 1
            except faults.FaultInjected:
                # block lost in flight: hole now, block-wise repair later
                blocks[i] = None
                recomputes += 1
    return moves, kept, recomputes


# ---------------------------------------------------------------------------
# scheduler-driven autoscaling
# ---------------------------------------------------------------------------

class ElasticPolicy:
    """Deterministic autoscaler over ``ignis.elastic.*`` (docs/elasticity.md).

    Two triggers feed it: ``poll()`` reads the job scheduler's queue depth
    (``JobScheduler.queue_depth``) and moves the world toward
    ``ceil(queue / queue.per.executor)``, at most ``step`` ranks per
    decision, after ``cooldown.polls`` consecutive same-direction polls
    (hysteresis is poll-counted, never wall-clock — replayable in tests);
    ``on_admit(tenants)`` (streaming/frontend.py) grows immediately to at
    least one executor per admitted tenant. Both clamp to
    ``[min.executors, max.executors]`` and, unless ``ignis.elastic.enabled``,
    only RECORD the decision (``stats['denied']``) without resizing.
    """

    def __init__(self, worker, scheduler=None, props=None):
        self.worker = worker
        self._scheduler = scheduler
        p = props if props is not None else worker.cluster.props
        self.enabled = p.get_bool("ignis.elastic.enabled", False)
        self.min = max(1, p.get_int("ignis.elastic.min.executors", 1))
        mx = p.get_int("ignis.elastic.max.executors", 0)
        self.max = mx if mx > 0 else len(jax.devices())
        self.max = max(self.max, self.min)
        self.step = max(1, p.get_int("ignis.elastic.step", 1))
        self.queue_per = max(1, p.get_int("ignis.elastic.queue.per.executor", 4))
        self.cooldown = max(1, p.get_int("ignis.elastic.cooldown.polls", 1))
        self._dir = 0
        self._streak = 0
        self.stats = Counters("policy", {
            "polls": 0,           # poll() calls observed
            "grows": 0,           # grow decisions executed
            "shrinks": 0,         # shrink decisions executed
            "admit_grows": 0,     # grows triggered by tenant admission
            "denied": 0,          # decisions suppressed (enabled=false)
            "ranks_added": 0,
            "ranks_retired": 0,
        })

    # -- pure decision surface (property/hypothesis-testable) ---------------
    def desired(self, queue_depth: int) -> int:
        """The world size this queue depth asks for, clamped to [min, max]."""
        want = math.ceil(max(0, queue_depth) / self.queue_per)
        return max(self.min, min(self.max, want))

    def scheduler(self):
        if self._scheduler is None:
            from repro.core.job import default_scheduler

            self._scheduler = default_scheduler()
        return self._scheduler

    # -- triggers ------------------------------------------------------------
    def poll(self, queue_depth: Optional[int] = None) -> int:
        """One autoscaling observation. Returns the executed delta in ranks
        (0 when holding steady, cooling down, or disabled)."""
        if queue_depth is None:
            queue_depth = self.scheduler().queue_depth()
        self.stats["polls"] += 1
        p = self.worker.executors
        want = self.desired(queue_depth)
        direction = (want > p) - (want < p)
        if direction != self._dir:
            self._dir, self._streak = direction, 0
        self._streak += 1
        if direction == 0 or self._streak < self.cooldown:
            return 0
        self._streak = 0  # act, then demand a fresh streak
        delta = max(-self.step, min(self.step, want - p))
        return self._execute(delta)

    def on_admit(self, tenants: int) -> int:
        """Tenant admitted: grow to ≥ one executor per tenant, immediately
        (no cooldown — admission is the paper-adjacent provisioning event).
        Returns the executed delta in ranks."""
        p = self.worker.executors
        target = max(self.min, min(self.max, tenants))
        if target <= p:
            return 0
        grown = self._execute(target - p)
        if grown:
            self.stats["admit_grows"] += 1
        return grown

    def _execute(self, delta: int) -> int:
        if delta == 0:
            return 0
        if not self.enabled:
            self.stats["denied"] += 1
            return 0
        if delta > 0:
            self.worker.grow(delta)
            self.stats["grows"] += 1
            self.stats["ranks_added"] += delta
        else:
            self.worker.shrink(-delta)
            self.stats["shrinks"] += 1
            self.stats["ranks_retired"] += -delta
        return delta

    # -- checkpoint elasticity wired in --------------------------------------
    def restore(self, ckpt_dir: str, step: int, cfg, target: dict) -> dict:
        """Re-place checkpointed train state onto the worker's CURRENT
        (possibly just-resized) mesh — ``restore_elastic`` bound to the
        live world, so a grow/shrink is followed by one call here."""
        return restore_elastic(ckpt_dir, step, cfg,
                               self.worker.context.mesh, target)
