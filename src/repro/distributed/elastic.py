"""Elastic scaling: restore a checkpoint onto a different mesh (the MPI-3
"dynamic process join" analogue the paper leans on for replacing lost
executors — here: replace/resize the whole slice between runs).

Checkpoints store full logical arrays, so elasticity is a placement
decision at restore: build the new mesh, derive the new sharding specs from
the same rules, device_put. Divisibility permitting, ANY (pod, data, model)
factorization restores the same training state.
"""
from __future__ import annotations

from repro.checkpoint.checkpoint import restore
from repro.distributed.sharding import opt_specs, param_specs, to_named


def restore_elastic(ckpt_dir: str, step: int, cfg, mesh, target: dict) -> dict:
    """Restore a train-state tree ``{"params": …[, "opt": …]}`` re-placed
    for ``mesh`` (which may have a different shape than the one that saved)."""
    psp = param_specs(target["params"], cfg, mesh)
    shardings = {"params": to_named(psp, mesh)}
    if "opt" in target:
        shardings["opt"] = to_named(opt_specs(target["opt"], psp, cfg, mesh), mesh)
    return restore(ckpt_dir, step, target, {**{k: None for k in target}, **shardings})
