"""Pipeline parallelism: GPipe/1F1B-style microbatch streaming over a
"stage" mesh axis with collective_permute hops (the jax-native mapping of
the paper's point-to-point MPI layer: ppermute IS the Isend/Irecv ring).

``pipeline_apply`` runs a stage-sharded stack of layers over M microbatches
in M + S - 1 ticks; each tick every stage processes one in-flight microbatch
and the boundary activations hop stage→stage+1 via ppermute. Compute and the
permute overlap (async collectives) — the paper's compute/comm overlap item.

Layers-per-stage params are stacked on a leading stage axis and sharded
P("stage") so each device holds only its stage's weights.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat


def pipeline_apply(stage_params, x_micro, stage_fn, mesh, axis: str = "stage"):
    """stage_params: pytree with leading dim S (stages), sharded P(axis).
    x_micro: (M, mb, …) microbatched input, replicated.
    stage_fn(params_slice, x) -> y — one stage's compute.
    Returns (M, mb, …) outputs (as produced by the LAST stage).
    """
    S = mesh.shape[axis]
    M = x_micro.shape[0]
    ticks = M + S - 1
    perm = [(i, (i + 1) % S) for i in range(S)]

    def per_stage(params_s, xm):
        # params_s: this stage's slice — shard_map keeps the (now size-1)
        # sharded leading dim; drop it
        params_s = jax.tree.map(lambda a: a[0], params_s)
        sid = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(xm[0])  # in-flight activation for this stage
        outs = jnp.zeros_like(xm)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t (when valid)
            inject = jnp.where(t < M, t, M - 1)
            x_in = jnp.where(sid == 0, xm[inject], buf)
            y = stage_fn(params_s, x_in)
            # last stage emits output for microbatch (t - S + 1)
            m_out = t - (S - 1)
            emit = jnp.logical_and(sid == S - 1, m_out >= 0)
            idx = jnp.clip(m_out, 0, M - 1)
            cur = jax.lax.dynamic_slice_in_dim(outs, idx, 1, axis=0)
            new = jnp.where(emit, y[None], cur)
            outs = jax.lax.dynamic_update_slice_in_dim(outs, new, idx, axis=0)
            # hop the activation ring: stage i → i+1
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, outs)

        _, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # outputs live on the last stage: broadcast to all (psum of one-hot)
        mine = jnp.where(sid == S - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(mine, axis)

    fn = compat.shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
    )
    return fn(stage_params, x_micro)


def reference_apply(stage_params, x_micro, stage_fn):
    """Sequential oracle: run all stages over all microbatches."""
    S = jax.tree.leaves(stage_params)[0].shape[0]

    def one(x):
        for s in range(S):
            ps = jax.tree.map(lambda a: a[s], stage_params)
            x = stage_fn(ps, x)
        return x

    return jax.vmap(one)(x_micro)
