from repro.distributed.sharding import (  # noqa: F401
    batch_axes,
    cache_specs,
    input_specs_sharding,
    opt_specs,
    param_specs,
)
