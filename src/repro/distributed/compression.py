"""Gradient compression for the DP all-reduce (beyond-paper §Perf lever).

Two schemes, both with error feedback (the residual of the lossy step is
added back next step, preserving convergence — Karimireddy et al.):

  int8   — per-tensor absmax scaling to int8 before the reduce: 4× wire
           bytes off the gradient all-reduce (the dominant collective of the
           paper-faithful DP mode)
  topk   — keep the top fraction by magnitude (values + implicit mask),
           modelled here as zeroing before the reduce (dense wire layout;
           sparse layouts don't map to TPU all-reduce)

``compressed_grads`` is applied BEFORE the (sharding-induced) psum so XLA
reduces the low-precision representation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_ef_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant_int8(g):
    scale = jnp.maximum(jnp.abs(g).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def _topk_mask(g, frac):
    k = max(int(g.size * frac), 1)
    flat = jnp.abs(g.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def compressed_grads(grads, ef_state, method: str = "int8", topk_frac: float = 0.05):
    """Returns (grads_compressed, new_ef_state)."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        if method == "int8":
            gc = _quant_int8(gf)
        elif method == "topk":
            gc = _topk_mask(gf, topk_frac)
        elif method == "none":
            gc = gf
        else:
            raise ValueError(method)
        return gc.astype(g.dtype), gf - gc

    out = jax.tree.map(one, grads, ef_state)
    gc = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    ef = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return gc, ef
