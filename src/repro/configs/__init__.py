"""Assigned architecture configs (+ the paper's own example presets).

Importing this package registers every config; ``get_config(name)`` fetches.
"""
from repro.configs.base import ArchConfig, ShapeCell, SHAPES, get_config, list_configs  # noqa: F401

from repro.configs import (  # noqa: F401  (registration side-effects)
    yi_9b,
    qwen3_14b,
    gemma3_4b,
    olmo_1b,
    mamba2_780m,
    whisper_tiny,
    jamba_1_5_large_398b,
    internvl2_1b,
    phi3_5_moe_42b_a6_6b,
    mixtral_8x7b,
    paper_app,
)

ASSIGNED = [
    "yi-9b",
    "qwen3-14b",
    "gemma3-4b",
    "olmo-1b",
    "mamba2-780m",
    "whisper-tiny",
    "jamba-1.5-large-398b",
    "internvl2-1b",
    "phi3.5-moe-42b-a6.6b",
    "mixtral-8x7b",
]
