"""Whisper-tiny — encoder-decoder audio transformer; conv frontend is a STUB
(``input_specs`` provides precomputed frame embeddings). [arXiv:2212.04356; unverified]
"""
from repro.configs.base import ArchConfig, register

WHISPER_TINY = register(
    ArchConfig(
        name="whisper-tiny",
        family="audio",
        source="[arXiv:2212.04356; unverified]",
        num_layers=4,  # decoder layers
        enc_layers=4,
        d_model=384,
        num_heads=6,
        num_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab_size=51865,
        is_encdec=True,
        enc_seq=1500,
        frontend="audio_conv",
        norm_type="layernorm",
        rope_theta=0.0,  # whisper uses learned/sinusoidal positions, not RoPE
        sharding_preset="dp",
        long_context_ok=False,  # full attention decoder
    )
)
