"""Phi-3.5-MoE (42B total / 6.6B active) — 16 experts, top-2.

[hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""
from repro.configs.base import ArchConfig, register

PHI35_MOE = register(
    ArchConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        source="[hf:microsoft/Phi-3.5-MoE-instruct; hf]",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=6400,
        vocab_size=32064,
        num_experts=16,
        experts_per_token=2,
        moe_period=1,  # every layer is MoE
        rope_theta=10_000.0,
        sharding_preset="fsdp_tp",
        long_context_ok=False,  # full attention
    )
)
