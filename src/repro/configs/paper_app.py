"""Presets used by the paper-analogue examples and the end-to-end driver.

``ignis-100m`` is the ~100M-param LM trained for a few hundred steps by
``examples/hybrid_train.py`` (the paper's "hybrid application" pattern:
dataflow data pipeline feeding an SPMD training job on the same fabric).
"""
from repro.configs.base import ArchConfig, register

IGNIS_100M = register(
    ArchConfig(
        name="ignis-100m",
        family="dense",
        source="[this work]",
        num_layers=8,
        d_model=512,
        num_heads=8,
        num_kv_heads=4,
        head_dim=64,
        d_ff=2048,
        vocab_size=32768,
        rope_theta=10_000.0,
        sharding_preset="dp",
        remat="none",
        param_dtype="float32",
    )
)

IGNIS_TINY = register(
    ArchConfig(
        name="ignis-tiny",
        family="dense",
        source="[this work]",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=4096,
        sharding_preset="dp",
        remat="none",
        param_dtype="float32",
    )
)
