"""Jamba-1.5-Large (398B total) — hybrid Mamba+attention 1:7 interleave + MoE.

[arXiv:2403.19887; hf]. Structural approximation (documented in DESIGN.md):
period-8 blocks (1 attention + 7 mamba layers), MoE every 2 layers (16 experts,
top-2); 72 layers = 9 scanned blocks. Optimizer moments kept in bf16 to fit
HBM at 256 chips (beyond-paper memory policy, see EXPERIMENTS.md §Perf).
"""
from repro.configs.base import ArchConfig, register

JAMBA_1_5_LARGE = register(
    ArchConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        source="[arXiv:2403.19887; hf]",
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=65536,
        num_experts=16,
        experts_per_token=2,
        moe_period=2,
        attn_period=8,  # 1 attention layer per 8 (1:7 attn:mamba)
        ssm_state=128,
        ssm_headdim=64,
        ssm_expand=2,  # d_inner = 16384 → 256 SSD heads
        ssm_chunk=256,
        rope_theta=0.0,  # jamba uses no positional encoding on attention
        sharding_preset="fsdp_tp",
        long_context_ok=True,  # hybrid: KV cache only on 1/8 of layers
        opt_moment_dtype="bfloat16",
        loss_chunk=2048,
    )
)
