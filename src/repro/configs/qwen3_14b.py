"""Qwen3-14B — dense GQA LM with qk-norm. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ArchConfig, register

QWEN3_14B = register(
    ArchConfig(
        name="qwen3-14b",
        family="dense",
        source="[hf:Qwen/Qwen3-8B; hf]",
        num_layers=40,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=17408,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        sharding_preset="fsdp_tp",
        long_context_ok=False,  # pure full attention — long_500k skipped
        loss_chunk=2048,  # large vocab: chunk the CE over sequence
    )
)
