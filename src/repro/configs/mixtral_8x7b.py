"""Mixtral-8x7B — 8 experts top-2 MoE with sliding-window attention.

[arXiv:2401.04088; hf]
"""
from repro.configs.base import ArchConfig, register

MIXTRAL_8X7B = register(
    ArchConfig(
        name="mixtral-8x7b",
        family="moe",
        source="[arXiv:2401.04088; hf]",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        num_experts=8,
        experts_per_token=2,
        moe_period=1,
        sliding_window=4096,  # SWA → bounded KV per layer
        rope_theta=1_000_000.0,
        sharding_preset="fsdp_tp",
        long_context_ok=True,  # SWA is sub-quadratic: window-bounded KV
    )
)
