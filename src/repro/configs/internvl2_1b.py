"""InternVL2-1B — VLM: InternViT frontend (STUB: precomputed patch embeddings)
+ Qwen2-0.5B-class LM backbone. [arXiv:2404.16821; hf]
"""
from repro.configs.base import ArchConfig, register

INTERNVL2_1B = register(
    ArchConfig(
        name="internvl2-1b",
        family="vlm",
        source="[arXiv:2404.16821; hf]",
        num_layers=24,
        d_model=896,
        num_heads=14,
        num_kv_heads=2,
        head_dim=64,
        d_ff=4864,
        vocab_size=151655,
        frontend="vit_patch",
        num_patches=256,  # patch-embedding prefix provided by input_specs()
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        sharding_preset="dp",
        long_context_ok=False,  # pure full attention
    )
)
