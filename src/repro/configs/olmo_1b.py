"""OLMo-1B — dense LM (MHA: kv==heads), non-parametric LayerNorm.

[arXiv:2402.00838; hf]
"""
from repro.configs.base import ArchConfig, register

OLMO_1B = register(
    ArchConfig(
        name="olmo-1b",
        family="dense",
        source="[arXiv:2402.00838; hf]",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=8192,
        vocab_size=50304,
        norm_type="nonparam_ln",
        tie_embeddings=True,
        sharding_preset="dp",
        long_context_ok=False,  # pure full attention
    )
)
