"""Architecture configuration system.

Every assigned architecture is a frozen ``ArchConfig``. Configs are exact
(from the assignment table); ``reduced()`` derives a tiny same-family config
for CPU smoke tests. The full configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

# ---------------------------------------------------------------------------
# Input shape cells (assigned): every LM arch pairs with these four shapes.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str
    family: str  # dense | ssm | hybrid | moe | audio | vlm
    source: str  # citation string  [source; verified-tier]

    # transformer backbone
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # attention features
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # SWA window (mixtral)
    local_global_period: int = 0  # gemma3: N local layers then 1 global
    local_window: int = 1024
    attn_logit_softcap: float = 0.0

    # normalisation
    norm_type: str = "rmsnorm"  # rmsnorm | nonparam_ln (olmo)
    tie_embeddings: bool = False

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_period: int = 1  # MoE layer every N layers (others dense)
    capacity_factor: float = 1.25

    # SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_groups: int = 1

    # hybrid (jamba): one attention layer per `attn_period` layers
    attn_period: int = 0

    # encoder-decoder (whisper)
    is_encdec: bool = False
    enc_layers: int = 0
    enc_seq: int = 1500  # encoder positions (audio frames after conv stub)

    # modality frontend stub ("audio_conv" | "vit_patch" | None)
    frontend: Optional[str] = None
    num_patches: int = 256  # vlm: image patch-embedding prefix length

    # dtypes / memory policy
    param_dtype: str = "bfloat16"
    opt_moment_dtype: str = "float32"
    remat: str = "full"  # full | dots | none
    loss_chunk: int = 2048  # chunked cross-entropy over seq (0 = off)

    # attention impl
    attn_chunk: int = 1024  # query-chunked attention block size (jnp path)
    attn_impl: str = "chunked"  # chunked (jnp) | flash (Pallas kernel; interpret on CPU)

    # distribution
    sharding_preset: str = "dp"  # dp | fsdp | fsdp_tp | tp (+ "_zero1" suffix)
    attn_sp: bool = False  # sequence-parallel attention (seq over "model")
    grad_accum: int = 1  # microbatch gradient accumulation (activation memory ÷ N)
    moe_ep: bool = False  # expert parallelism: dispatch buffers pinned E-over-"data"
    grad_compress: str = "none"  # none | int8 | topk — DP all-reduce compression
    long_context_ok: bool = False  # may run the long_500k cell
    decode_ok: bool = True  # has a decode step

    def with_overrides(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ---- derived -----------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:  # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_state else 0

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6·N·D)."""
        from repro.models.model_zoo import analytic_param_count

        return analytic_param_count(self)

    def active_param_count(self) -> int:
        from repro.models.model_zoo import analytic_param_count

        return analytic_param_count(self, active_only=True)

    def shape_cells(self):
        """The shape cells this arch runs (others are documented skips)."""
        cells = []
        for s in SHAPES.values():
            if s.kind == "decode" and not self.decode_ok:
                continue
            if s.name == "long_500k" and not self.long_context_ok:
                continue
            cells.append(s)
        return cells

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            num_layers=min(self.num_layers, 4) or 0,
            d_model=64,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=16 if self.head_dim else 0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 256),
            attn_chunk=32,
            loss_chunk=0,
            remat="none",
            sharding_preset="dp",
        )
        if self.is_moe:
            kw.update(num_experts=4, experts_per_token=2)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16)
        if self.is_encdec:
            kw.update(enc_layers=2, enc_seq=64)
        if self.attn_period:
            kw.update(num_layers=self.attn_period)  # one hybrid block
        if self.local_global_period:
            kw.update(num_layers=self.local_global_period + 1, local_window=16)
        if self.sliding_window:
            kw.update(sliding_window=16)
        if self.frontend == "vit_patch":
            kw.update(num_patches=8)
        return self.with_overrides(**kw)


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        import repro.configs  # noqa: F401  (registers all)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)
