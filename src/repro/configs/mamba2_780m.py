"""Mamba2-780M — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060; unverified]
"""
from repro.configs.base import ArchConfig, register

MAMBA2_780M = register(
    ArchConfig(
        name="mamba2-780m",
        family="ssm",
        source="[arXiv:2405.21060; unverified]",
        num_layers=48,
        d_model=1536,
        d_ff=0,  # attention-free, no MLP: mamba2 blocks only
        vocab_size=50280,
        ssm_state=128,
        ssm_headdim=64,
        ssm_expand=2,  # d_inner = 3072 → 48 SSD heads
        ssm_chunk=256,
        norm_type="rmsnorm",
        tie_embeddings=True,
        sharding_preset="dp",
        long_context_ok=True,  # O(1) state — flagship long-context arch
    )
)
