"""Gemma3-4B — dense GQA LM, 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import ArchConfig, register

GEMMA3_4B = register(
    ArchConfig(
        name="gemma3-4b",
        family="dense",
        source="[hf:google/gemma-3-1b-pt; unverified]",
        num_layers=34,
        d_model=2560,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=10240,
        vocab_size=262144,
        local_global_period=5,  # 5 local layers then 1 global (pattern LLLLLG)
        local_window=1024,
        rope_theta=1_000_000.0,
        attn_logit_softcap=50.0,
        sharding_preset="fsdp_tp",
        # 5:1 local:global — local layers bounded; decode against sharded KV for
        # the global layers is O(L)/token, so the long_500k decode cell runs.
        long_context_ok=True,
        loss_chunk=1024,  # 262k vocab: chunk the CE over sequence
        tie_embeddings=True,
    )
)
