"""SHA-256 in pure jnp uint32 ops (vectorized over messages).

The paper's Minebench computes real SHA-256 proof-of-work hashes (§6.2);
this is the same compression function, restricted to single-chunk (≤55
byte) messages — a block-header digest + nonce fits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_K = np.array([
    0x428A2F98, 0x71374491, 0xB5C0FBCF, 0xE9B5DBA5, 0x3956C25B, 0x59F111F1,
    0x923F82A4, 0xAB1C5ED5, 0xD807AA98, 0x12835B01, 0x243185BE, 0x550C7DC3,
    0x72BE5D74, 0x80DEB1FE, 0x9BDC06A7, 0xC19BF174, 0xE49B69C1, 0xEFBE4786,
    0x0FC19DC6, 0x240CA1CC, 0x2DE92C6F, 0x4A7484AA, 0x5CB0A9DC, 0x76F988DA,
    0x983E5152, 0xA831C66D, 0xB00327C8, 0xBF597FC7, 0xC6E00BF3, 0xD5A79147,
    0x06CA6351, 0x14292967, 0x27B70A85, 0x2E1B2138, 0x4D2C6DFC, 0x53380D13,
    0x650A7354, 0x766A0ABB, 0x81C2C92E, 0x92722C85, 0xA2BFE8A1, 0xA81A664B,
    0xC24B8B70, 0xC76C51A3, 0xD192E819, 0xD6990624, 0xF40E3585, 0x106AA070,
    0x19A4C116, 0x1E376C08, 0x2748774C, 0x34B0BCB5, 0x391C0CB3, 0x4ED8AA4A,
    0x5B9CCA4F, 0x682E6FF3, 0x748F82EE, 0x78A5636F, 0x84C87814, 0x8CC70208,
    0x90BEFFFA, 0xA4506CEB, 0xBEF9A3F7, 0xC67178F2], dtype=np.uint32)

_H0 = np.array([
    0x6A09E667, 0xBB67AE85, 0x3C6EF372, 0xA54FF53A,
    0x510E527F, 0x9B05688C, 0x1F83D9AB, 0x5BE0CD19], dtype=np.uint32)


def _rotr(x, n):
    return (x >> jnp.uint32(n)) | (x << jnp.uint32(32 - n))


def sha256_words(w16):
    """Compress one padded 16-word chunk. w16: (..., 16) uint32 big-endian
    words. Returns (..., 8) uint32 digest.

    Rounds run under lax.fori_loop (rolled) — the unrolled 64-round graph
    compiles pathologically slowly on the CPU backend and no faster on TPU.
    """
    w16 = w16.astype(jnp.uint32)
    prefix = w16.shape[:-1]
    K = jnp.asarray(_K)
    w = jnp.concatenate([w16, jnp.zeros((*prefix, 48), jnp.uint32)], axis=-1)

    def sched(i, w):
        a = jax.lax.dynamic_index_in_dim(w, i - 15, -1, keepdims=False)
        b = jax.lax.dynamic_index_in_dim(w, i - 2, -1, keepdims=False)
        c16 = jax.lax.dynamic_index_in_dim(w, i - 16, -1, keepdims=False)
        c7 = jax.lax.dynamic_index_in_dim(w, i - 7, -1, keepdims=False)
        s0 = _rotr(a, 7) ^ _rotr(a, 18) ^ (a >> jnp.uint32(3))
        s1 = _rotr(b, 17) ^ _rotr(b, 19) ^ (b >> jnp.uint32(10))
        val = c16 + s0 + c7 + s1
        return jax.lax.dynamic_update_index_in_dim(w, val, i, -1)

    w = jax.lax.fori_loop(16, 64, sched, w)

    state0 = jnp.broadcast_to(jnp.asarray(_H0), (*prefix, 8))

    def rnd(i, st):
        a, b, c, d = st[..., 0], st[..., 1], st[..., 2], st[..., 3]
        e, f, g, h = st[..., 4], st[..., 5], st[..., 6], st[..., 7]
        S1 = _rotr(e, 6) ^ _rotr(e, 11) ^ _rotr(e, 25)
        ch = (e & f) ^ (~e & g)
        wi = jax.lax.dynamic_index_in_dim(w, i, -1, keepdims=False)
        t1 = h + S1 + ch + K[i] + wi
        S0 = _rotr(a, 2) ^ _rotr(a, 13) ^ _rotr(a, 22)
        maj = (a & b) ^ (a & c) ^ (b & c)
        t2 = S0 + maj
        return jnp.stack([t1 + t2, a, b, c, d + t1, e, f, g], axis=-1)

    st = jax.lax.fori_loop(0, 64, rnd, state0)
    return st + jnp.asarray(_H0)


def sha256_bytes_len(msg_words, nbytes: int):
    """Digest of an ≤55-byte message already packed into (..., 16) uint32
    words (big-endian), with the 0x80 pad bit and bit-length word applied
    here. msg_words must be zero beyond nbytes."""
    w = msg_words.astype(jnp.uint32)
    # set the 0x80 byte at position nbytes
    word_idx = nbytes // 4
    byte_in = nbytes % 4
    pad = jnp.uint32(0x80) << jnp.uint32(8 * (3 - byte_in))
    w = w.at[..., word_idx].add(pad)
    w = w.at[..., 15].set(jnp.uint32(nbytes * 8))
    return sha256_words(w)


def pack_bytes(data: np.ndarray) -> np.ndarray:
    """(…, 64) uint8 → (…, 16) uint32 big-endian words (host helper)."""
    d = data.astype(np.uint32).reshape(*data.shape[:-1], 16, 4)
    return (d[..., 0] << 24) | (d[..., 1] << 16) | (d[..., 2] << 8) | d[..., 3]
