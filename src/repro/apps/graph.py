"""PageRank + Transitive Closure (paper §6.2, Figs. 17–18) on IDataFrame.

PageRank follows the classic links.join(ranks) → contribs → reduceByKey
dataflow; TC is the fixed-point join/union/distinct loop of paper Fig. 6.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def make_graph(n_vertices: int = 64, n_edges: int = 256, seed: int = 0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_vertices, n_edges).astype(np.int32)
    dst = rng.integers(0, n_vertices, n_edges).astype(np.int32)
    keep = src != dst
    return np.stack([src[keep], dst[keep]], 1)


def pagerank(worker, edges: np.ndarray, iters: int = 5, damping: float = 0.85,
             fanout: int = 16):
    """edges: (E, 2). Returns {vertex: rank}. Uses join/reduceByKey/mapValues."""
    links = (
        worker.parallelize(edges)
        .map(lambda e: {"key": e[0], "value": e[1]})
        .cache()
    )
    verts = sorted({int(v) for e in edges for v in e})
    n = len(verts)
    ranks = worker.parallelize(np.asarray(verts, np.int32)).map(
        lambda v: {"key": v, "value": jnp.float32(1.0)}
    )
    # out-degrees (static per graph)
    deg = links.map_values(lambda d: jnp.float32(1.0)).reduce_by_key(
        lambda a, b: a + b, 0.0
    ).cache()

    base = worker.parallelize(np.asarray(verts, np.int32)).map(
        lambda v: {"key": v, "value": jnp.float32(0.0)}
    ).cache()

    for _ in range(iters):
        # (v, ((dst, deg), rank)) → contribs (dst, rank/deg)
        j = links.join(deg, max_matches=1)  # one degree entry per key
        jr = j.map(lambda r: {"key": r["key"],
                              "value": (r["value"][0], r["value"][1])}).join(
            ranks, max_matches=1  # one rank entry per key
        )
        contribs = jr.map(
            lambda r: {
                "key": r["value"][0][0],
                "value": r["value"][1] / jnp.maximum(r["value"][0][1], 1.0),
            }
        )
        # union with zero base keeps vertices that received no contributions
        sums = contribs.union(base).reduce_by_key(lambda a, b: a + b, 0.0)
        ranks = sums.map_values(lambda s: (1 - damping) + damping * s)
    out = {}
    for r in ranks.collect():
        out[int(np.asarray(r["key"]))] = float(np.asarray(r["value"]))
    return out


def pagerank_reference(edges: np.ndarray, iters: int = 5, damping: float = 0.85):
    verts = sorted({int(v) for e in edges for v in e})
    idx = {v: i for i, v in enumerate(verts)}
    n = len(verts)
    ranks = {v: 1.0 for v in verts}
    out_deg = {}
    for s, d in edges:
        out_deg[int(s)] = out_deg.get(int(s), 0) + 1
    for _ in range(iters):
        sums = {v: 0.0 for v in verts}
        for s, d in edges:
            sums[int(d)] += ranks[int(s)] / out_deg[int(s)]
        ranks = {v: (1 - damping) + damping * sums[v] for v in verts}
    return ranks


def transitive_closure(worker, edges: np.ndarray, max_rounds: int = 10,
                       max_matches: int = 16):
    """Paper Fig. 6: grow paths until fixed point. Returns edge set."""
    tc = worker.parallelize(edges).map(lambda e: (e[0], e[1])).distinct().cache()
    # edges reversed for the join: (dst → src)
    rev = worker.parallelize(edges).map(
        lambda e: {"key": e[0], "value": e[1]}
    ).cache()
    old = 0
    new = tc.count()
    rounds = 0
    while new != old and rounds < max_rounds:
        old = new
        # paths (x, y) joined with edges (y, z) → (x, z)
        lhs = tc.map(lambda e: {"key": e[1], "value": e[0]})
        joined = lhs.join(rev, max_matches=max_matches)
        new_edges = joined.map(
            lambda r: (r["value"][0], r["value"][1])
        )
        # compact() bounds padded-capacity growth across fixed-point rounds
        tc = tc.union(new_edges).distinct().compact().cache()
        new = tc.count()
        rounds += 1
    return tc


def tc_reference(edges: np.ndarray, max_rounds: int = 10) -> set:
    es = {(int(a), int(b)) for a, b in edges}
    for _ in range(max_rounds):
        new = {(x, w) for (x, y) in es for (z, w) in es if y == z}
        if new <= es:
            break
        es |= new
    return es
