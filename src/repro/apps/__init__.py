"""Applications from the paper's evaluation (§6), implemented on the
framework: Big-Data apps (Minebench, TeraSort, K-Means, PageRank,
Transitive Closure) and HPC proxy apps (stencil = LULESH/miniAMR analogue,
CG solver = AMG analogue) run as native SPMD programs via worker.call.
"""
