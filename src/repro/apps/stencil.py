"""HPC proxy apps run as native SPMD programs (paper §6.3 analogues).

* ``stencil`` — Jacobi relaxation with ring halo exchange (ppermute =
  Isend/Irecv): the LULESH / miniAMR communication pattern.
* ``cg_solver`` — matrix-free conjugate gradient on a 1-D Laplacian:
  Allreduce-dominated, the AMG pattern (dot products every iteration).

Both are written exactly like the paper's ported MPI apps (Fig. 10): the
function receives the framework communicator from the context — the
IGNIS_COMM_WORLD swap — and otherwise keeps its "native" structure. The
paper's Table 5 productivity claim corresponds to the @ignis_export +
context-parsing wrapper being the ONLY addition.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import comm, compat
from repro.core.native import ignis_export


def _spmd_plan(tag: str, mesh, axis: str, statics: tuple, prog, x):
    """Persistent plan for a whole SPMD program (comm.persistent_program):
    traced + compiled once per (program, statics, operand aval, mesh) and
    reused from the collective plan cache. The re-trace this avoids is
    pure-Python, GIL-bound work — hoisting it is what lets a native branch
    overlap a concurrently running dataflow branch (DESIGN.md §10)."""
    x = jnp.asarray(x)

    def builder():
        return compat.shard_map(prog, mesh=mesh, in_specs=(P(axis),),
                                out_specs=P(axis))

    return comm.persistent_program(
        tag, mesh, (axis, *statics, x.shape, str(x.dtype)), builder), x


# ---------------------------------------------------------------------------
# stencil (LULESH/miniAMR analogue)
# ---------------------------------------------------------------------------


def stencil_native(mesh, axis, grid, iters: int):
    """The 'native MPI' program: runs directly under shard_map (the
    benchmark's baseline — executing the app without the framework)."""
    p = mesh.shape[axis]
    perm_fwd = [(i, (i + 1) % p) for i in range(p)]
    perm_bwd = [((i + 1) % p, i) for i in range(p)]

    def prog(u):  # u: (rows_local, cols)
        def body(_, u):
            up = jax.lax.ppermute(u[-1:], axis, perm_fwd)  # halo from above
            dn = jax.lax.ppermute(u[:1], axis, perm_bwd)  # halo from below
            ext = jnp.concatenate([up, u, dn], axis=0)
            lap = (ext[:-2] + ext[2:] + jnp.roll(u, 1, 1) + jnp.roll(u, -1, 1)) * 0.25
            return lap

        return jax.lax.fori_loop(0, iters, body, u)

    fn, grid = _spmd_plan("stencil", mesh, axis, (iters,), prog, grid)
    return fn(grid)


@ignis_export("stencil_app")
def stencil_app(ctx, data=None, valid=None):
    """Framework-wrapped version (paper Fig. 10): args from the context."""
    iters = int(ctx.var("iters", 10))
    mesh, axis = ctx.comm()  # ← the MPI_COMM_WORLD swap
    out = stencil_native(mesh, axis, data, iters)
    return out, valid


# ---------------------------------------------------------------------------
# CG solver (AMG analogue — Allreduce-heavy)
# ---------------------------------------------------------------------------


def cg_native(mesh, axis, b, iters: int):
    """Solve A x = b for the 1-D Laplacian A = tridiag(-1, 2, -1), rows
    sharded over the axis; halo ppermute in matvec, psum in dots."""
    p = mesh.shape[axis]
    perm_fwd = [(i, (i + 1) % p) for i in range(p)]
    perm_bwd = [((i + 1) % p, i) for i in range(p)]

    def prog(b):  # b: (n_local,)
        idx = jax.lax.axis_index(axis)

        def matvec(x):
            up = jax.lax.ppermute(x[-1:], axis, perm_fwd)
            dn = jax.lax.ppermute(x[:1], axis, perm_bwd)
            up = jnp.where(idx == 0, 0.0, up)  # Dirichlet boundaries
            dn = jnp.where(idx == p - 1, 0.0, dn)
            xm = jnp.concatenate([up, x, dn])
            return 2 * x - xm[:-2] - xm[2:]

        def dot(a, c):
            return jax.lax.psum(jnp.vdot(a, c), axis)

        x = jnp.zeros_like(b)
        r = b - matvec(x)
        q = r
        rs = dot(r, r)

        def body(_, carry):
            x, r, q, rs = carry
            Aq = matvec(q)
            alpha = rs / jnp.maximum(dot(q, Aq), 1e-30)
            x = x + alpha * q
            r = r - alpha * Aq
            rs_new = dot(r, r)
            q = r + (rs_new / jnp.maximum(rs, 1e-30)) * q
            return x, r, q, rs_new

        x, r, q, rs = jax.lax.fori_loop(0, iters, body, (x, r, q, rs))
        return x

    fn, b = _spmd_plan("cg", mesh, axis, (iters,), prog, b)
    return fn(b)


@ignis_export("cg_app")
def cg_app(ctx, data=None, valid=None):
    iters = int(ctx.var("iters", 20))
    mesh, axis = ctx.comm()
    out = cg_native(mesh, axis, data, iters)
    # hand the in-flight result back as a nonblocking handle: the driver
    # layer chains the Block adaptation onto it and the engine awaits it
    # (docs/collectives.md — handle-returning native apps)
    return comm.CollHandle("spmd.cg", ctx, (out, valid))


def laplacian_matvec_ref(x):
    xm = jnp.pad(x, 1)
    return 2 * x - xm[:-2] - xm[2:]
