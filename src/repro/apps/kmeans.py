"""K-Means (paper §6.2, Fig. 16) — the iterative-app pattern.

Two execution strategies, the exact contrast the paper draws:

  ignis mode — the whole iteration loop runs ON the fabric
               (lax.fori_loop; executors exchange partial sums via the
               sharding-induced psum). The driver never evaluates
               intermediate results — paper §3.6's "no driver evaluations".
  spark mode — one driver evaluation per iteration: partial sums are
               collected to the host, combined, and new centers re-broadcast
               (Spark's stop-executors / driver / restart-executors cycle).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.native import ignis_export


def make_points(n: int = 4096, d: int = 16, k: int = 8, seed: int = 0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)) * 5
    asg = rng.integers(0, k, n)
    pts = centers[asg] + rng.normal(size=(n, d))
    return pts.astype(np.float32), centers.astype(np.float32)


def _assign(pts, centers):
    d2 = ((pts[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
    return jnp.argmin(d2, axis=1)


def _update(pts, asg, k):
    oh = jax.nn.one_hot(asg, k, dtype=pts.dtype)  # (n, k)
    sums = oh.T @ pts  # (k, d)
    counts = oh.sum(0)[:, None]
    return sums / jnp.maximum(counts, 1.0)


def kmeans_on_device(pts, centers0, iters: int):
    """ignis mode: whole loop fused on device."""
    k = centers0.shape[0]

    def body(_, centers):
        return _update(pts, _assign(pts, centers), k)

    return jax.lax.fori_loop(0, iters, body, centers0)


def kmeans_driver_eval(pts_dev, centers0, iters: int):
    """spark mode: per-iteration driver evaluation (device_get each step)."""
    k = centers0.shape[0]
    centers = np.asarray(centers0)
    assign_j = jax.jit(_assign)
    update_j = jax.jit(lambda p, a: _update(p, a, k))
    for _ in range(iters):
        asg = assign_j(pts_dev, jnp.asarray(centers))
        partial = update_j(pts_dev, asg)
        centers = np.asarray(jax.device_get(partial))  # driver round-trip
    return jnp.asarray(centers)


@ignis_export("kmeans_mpi")
def kmeans_native(ctx, data=None, valid=None):
    """Native-app form (paper Fig. 12 pattern): data rows = points."""
    iters = int(ctx.var("iters", 10))
    k = int(ctx.var("k", 8))
    seed = int(ctx.var("seed", 0))
    pts = data
    key = jax.random.PRNGKey(seed)
    init = pts[jax.random.choice(key, pts.shape[0], (k,), replace=False)]
    centers = kmeans_on_device(pts, init, iters)
    return centers, jnp.ones((k,), bool)
