"""Minebench (paper §6.2, Figs. 13–14): SHA-256 proof-of-work.

Two chained maps exactly as in the paper: map₁ (data-intensive) reduces a
block's transactions to a Merkle-style root; map₂ (compute-intensive)
iterates nonces over the real SHA-256 compression until the difficulty
condition is met (bounded iterations for benchmark determinism).

The multi-"language" variant runs map₁ on one worker and map₂ on another
with importData in between (paper Fig. 14) — in spark mode that hop
serializes through the host (the pipe cost the paper measures).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.sha256 import sha256_words
from repro.core.native import ignis_export


def make_blocks(n_blocks: int, txs_per_block: int = 16, seed: int = 0) -> np.ndarray:
    """Synthetic transaction sets: (n_blocks, txs_per_block, 16) uint32."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, 2**32, (n_blocks, txs_per_block, 16), dtype=np.uint32)


def merkle_root(txs):
    """map₁: pairwise SHA-256 reduction of the tx digests → (…, 8) root."""
    h = sha256_words(txs)  # (T, 8) digests
    while h.shape[-2] > 1:
        if h.shape[-2] % 2:
            h = jnp.concatenate([h, h[..., -1:, :]], axis=-2)
        pair = jnp.concatenate([h[..., 0::2, :], h[..., 1::2, :]], axis=-1)  # (T/2, 16)
        h = sha256_words(pair)
    return h[..., 0, :]


def mine(root, iters: int = 64, difficulty_bits: int = 12):
    """map₂: iterate nonces; return (best_nonce, found). root: (8,) words."""
    target = jnp.uint32(1) << jnp.uint32(32 - difficulty_bits)

    def body(i, carry):
        best, found = carry
        header = jnp.zeros((16,), jnp.uint32)
        header = header.at[:8].set(root)
        header = header.at[8].set(i.astype(jnp.uint32))
        header = header.at[15].set(jnp.uint32(36 * 8))
        d = sha256_words(header)
        hit = d[0] < target
        best = jnp.where(hit & ~found, i.astype(jnp.uint32), best)
        return best, found | hit

    best, found = jax.lax.fori_loop(0, iters, body, (jnp.uint32(0), jnp.bool_(False)))
    return best, found


def map1_fn(txs):
    return merkle_root(txs)


def make_map2_fn(iters: int = 64, difficulty_bits: int = 12):
    def f(root):
        nonce, found = mine(root, iters, difficulty_bits)
        return {"nonce": nonce, "found": found}

    return f


@ignis_export("minebench_mpi")
def minebench_native(ctx, data=None, valid=None):
    """Native SPMD variant: whole pipeline in one on-fabric program."""
    iters = int(ctx.var("iters", 64))
    bits = int(ctx.var("difficulty_bits", 12))
    roots = jax.vmap(merkle_root)(data)
    nonce, found = jax.vmap(lambda r: mine(r, iters, bits))(roots)
    return {"nonce": nonce, "found": found}, valid
