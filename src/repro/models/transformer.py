"""Decoder-only transformer LM (dense or MoE), scan-over-layers.

Covers: GQA (+qk-norm), RoPE, sliding-window (mixtral), local:global patterns
(gemma3), logit soft-caps, MoE every layer (phi3.5/mixtral), VLM patch-prefix
(internvl2). Layers are stacked on a leading axis and executed with
``lax.scan`` so the HLO stays one-layer sized; per-layer heterogeneity
(window size) rides along as scanned int32 xs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models.layers import (
    apply_norm,
    embed_init,
    lm_loss,
    make_mlp_params,
    make_norm_params,
    mlp,
)
from repro.models.moe import make_moe_params, moe_apply, moe_ffn_bsd, moe_ffn, capacity_for


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def make_layer_params(key, cfg):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": make_norm_params(key, cfg.d_model, cfg.norm_type),
        "attn": attn.make_attn_params(k1, cfg, _dtype(cfg)),
        "ln2": make_norm_params(key, cfg.d_model, cfg.norm_type),
    }
    if cfg.is_moe:
        p["ffn"] = make_moe_params(k2, cfg, _dtype(cfg))
    else:
        p["ffn"] = make_mlp_params(k2, cfg.d_model, cfg.d_ff, _dtype(cfg))
    return p


def stack_layers(keys, make_one):
    ps = [make_one(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)


def make_lm_params(key, cfg):
    ks = jax.random.split(key, 4 + cfg.num_layers)
    params = {
        "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model), _dtype(cfg)),
        "layers": stack_layers(ks[4:], lambda k: make_layer_params(k, cfg)),
        "final_norm": make_norm_params(ks[1], cfg.d_model, cfg.norm_type),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(ks[2], (cfg.d_model, cfg.vocab_size), _dtype(cfg))
    if cfg.frontend == "vit_patch":
        params["vit_proj"] = embed_init(ks[3], (1024, cfg.d_model), _dtype(cfg))
    return params


def head_matrix(params, cfg):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def layer_windows(cfg) -> np.ndarray:
    """Static per-layer attention window (GLOBAL_WINDOW = unbounded)."""
    n = cfg.num_layers
    if cfg.local_global_period:
        per = cfg.local_global_period
        w = [cfg.local_window if (i + 1) % (per + 1) else attn.GLOBAL_WINDOW for i in range(n)]
    elif cfg.sliding_window:
        w = [cfg.sliding_window] * n
    else:
        w = [attn.GLOBAL_WINDOW] * n
    return np.asarray(w, np.int32)


def _remat(f, cfg):
    if cfg.remat == "full":
        return jax.checkpoint(f)
    if cfg.remat == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return f


def _sp_seq(x, cfg):
    """Sequence-parallel attention (beyond-paper §Perf): pin the seq dim of
    (B, S, D) activations to the "model" axis around the attention block.
    Head counts never divide a 16-way TP axis cleanly for GQA configs
    (H=40, K=8, …); sharding S instead parallelises attention exactly and
    turns the giant partial-score all-reduces into small activation
    reshards + a per-layer KV all-gather."""
    if not cfg.attn_sp:
        return x
    from jax.sharding import PartitionSpec as P

    U = P.UNCONSTRAINED
    try:
        return jax.lax.with_sharding_constraint(x, P(U, "model", U))
    except Exception:  # no ambient mesh (CPU smoke tests)
        return x


def _sp_free(x, cfg):
    """Release the seq pin after attention (MLP resumes tensor parallelism)."""
    if not cfg.attn_sp:
        return x
    from jax.sharding import PartitionSpec as P

    U = P.UNCONSTRAINED
    try:
        return jax.lax.with_sharding_constraint(x, P(U, None, U))
    except Exception:
        return x


# ---------------------------------------------------------------------------
# forward (train / encode)
# ---------------------------------------------------------------------------


def embed_tokens(params, tokens, cfg, patches=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    if patches is not None:  # VLM: project + prepend patch embeddings
        pe = patches.astype(x.dtype) @ params["vit_proj"]
        x = jnp.concatenate([pe, x], axis=1)
    return x


def lm_forward(params, tokens, cfg, patches=None):
    """tokens: (B, S_text) → (h (B, S, D), aux_loss). S includes patches."""
    x = embed_tokens(params, tokens, cfg, patches)
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    warr = layer_windows(cfg)
    uniform = int(warr[0]) if bool((warr == warr[0]).all()) else None
    windows = jnp.asarray(warr)

    def layer(carry, xs):
        x, aux = carry
        lp, window = xs
        if uniform is not None:
            window = uniform  # static → flash kernel dispatch stays eligible
        a, _ = attn.attention(
            _sp_seq(apply_norm(x, lp["ln1"], cfg.norm_type), cfg),
            lp["attn"], cfg, pos, window=window,
        )
        x = x + _sp_free(a, cfg)
        h = apply_norm(x, lp["ln2"], cfg.norm_type)
        if cfg.is_moe:
            m, a_loss = moe_apply(h, lp["ffn"], cfg)
            aux = aux + a_loss
        else:
            m = mlp(h, lp["ffn"])
        # full SP: the residual carry (the bwd activation saved per layer)
        # lives S-sharded — 16× less HBM residency; GSPMD re-gathers around
        # the TP matmuls (Megatron sequence parallelism)
        return (_sp_seq(x + m, cfg), aux), None

    (x, aux), _ = jax.lax.scan(_remat(layer, cfg), (x, 0.0), (params["layers"], windows))
    return apply_norm(x, params["final_norm"], cfg.norm_type), aux


def lm_train_loss(params, batch, cfg):
    patches = batch.get("patches")
    h, aux = lm_forward(params, batch["tokens"], cfg, patches)
    labels = batch["labels"]
    if patches is not None:  # no loss on the patch prefix
        P = patches.shape[1]
        pad = jnp.full((labels.shape[0], P), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    loss = lm_loss(h, head_matrix(params, cfg), labels, cfg.loss_chunk)
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------


def lm_prefill(params, tokens, cfg, cache_len=None, patches=None):
    """Run the prompt, build KV caches sized ``cache_len`` (≥ S).

    Returns (last-position logits (B, V), cache dict).
    """
    x = embed_tokens(params, tokens, cfg, patches)
    B, S, _ = x.shape
    Smax = cache_len or S
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    warr = layer_windows(cfg)
    uniform = int(warr[0]) if bool((warr == warr[0]).all()) else None
    windows = jnp.asarray(warr)

    def layer(x, xs):
        lp, window = xs
        if uniform is not None:
            window = uniform
        a, (k, v) = attn.attention(
            apply_norm(x, lp["ln1"], cfg.norm_type), lp["attn"], cfg, pos, window=window
        )
        x = x + a
        h = apply_norm(x, lp["ln2"], cfg.norm_type)
        if cfg.is_moe:
            m, _ = moe_apply(h, lp["ffn"], cfg)
        else:
            m = mlp(h, lp["ffn"])
        if Smax > S:
            pad = [(0, 0), (0, Smax - S), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return x + m, (k, v)

    x, (ks, vs) = jax.lax.scan(layer, x, (params["layers"], windows))
    h = apply_norm(x, params["final_norm"], cfg.norm_type)
    logits = h[:, -1] @ head_matrix(params, cfg)
    cache = {"k": ks, "v": vs, "pos": jnp.full((B,), S, jnp.int32)}
    return logits, cache


def make_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def lm_decode_step(params, cache, tokens, cfg):
    """One decode step. tokens: (B, 1); cache['pos']: (B,) write positions.

    Returns (logits (B, V), new cache).
    """
    x = embed_tokens(params, tokens, cfg)
    pos = cache["pos"]
    windows = jnp.asarray(layer_windows(cfg))

    def layer(x, xs):
        lp, window, k_l, v_l = xs
        a, k_l, v_l = attn.decode_attention(
            apply_norm(x, lp["ln1"], cfg.norm_type), lp["attn"], cfg, pos, k_l, v_l,
            window=window,
        )
        x = x + a
        h = apply_norm(x, lp["ln2"], cfg.norm_type)
        if cfg.is_moe:
            m, _ = moe_apply(h, lp["ffn"], cfg)
        else:
            m = mlp(h, lp["ffn"])
        return x + m, (k_l, v_l)

    x, (ks, vs) = jax.lax.scan(layer, x, (params["layers"], windows, cache["k"], cache["v"]))
    h = apply_norm(x, params["final_norm"], cfg.norm_type)
    logits = h[:, -1] @ head_matrix(params, cfg)
    return logits, {"k": ks, "v": vs, "pos": pos + 1}
