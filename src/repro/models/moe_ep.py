"""Expert-parallel MoE dispatch as an explicit shard_map region.

GSPMD cannot auto-shard the argsort-based dispatch: the token permutation
crosses every shard, so it materialises full (T·K, D) gathers and
all-reduces them (measured: 68.7 GB × 9 blocks on the jamba train cell —
EXPERIMENTS.md §Perf). Every production MoE framework routes manually; this
is the jax-native version:

  per device (data axis):  route locally → bucket assignments by OWNER
  device (expert e lives on device e // E_loc) with per-source capacity →
  all_to_all (the MPI token exchange) → local expert FFN (weights arrive
  model-gathered at the shard_map boundary) → all_to_all back (the tiled
  exchange is an involution) → weighted combine at the source.

Requires num_experts % data-axis-size == 0 (jamba 16/16, phi3.5 16/16);
falls back to the GSPMD path otherwise (mixtral 8 on a 16-way axis).
Per-(source, expert) capacity semantics = capacity_factor fairness per
shard — the standard EP contract (tokens over capacity drop; aux loss
unchanged).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.models.moe import route


def _mesh_axis_size(axis: str):
    mesh = compat.get_ambient_mesh()
    if mesh is None or axis not in (mesh.axis_names or ()):
        return None
    return mesh.shape[axis]


def ep_applicable(cfg, axis: str = "data") -> bool:
    if not getattr(cfg, "moe_ep", False):
        return False
    p = _mesh_axis_size(axis)
    return p is not None and p > 1 and cfg.num_experts % p == 0


def moe_ffn_bsd_ep(x, params, cfg, axis: str = "data"):
    """(B, S, D) → (y, aux). Call only when ep_applicable(cfg)."""
    p = _mesh_axis_size(axis)
    E, K = cfg.num_experts, cfg.experts_per_token
    E_loc = E // p
    B, S, D = x.shape

    def local(xb, router, wg, wu, wd):
        # xb: (B_loc, S, D); weights arrive model-gathered: (E_loc, D, F)
        T = xb.shape[0] * xb.shape[1]
        xt = xb.reshape(T, D)
        w, idx, _probs = route(xt, router, K)
        C = max(int(cfg.capacity_factor * T * K / E), K)  # per-source/expert

        e_flat = idx.reshape(-1)
        t_flat = jnp.repeat(jnp.arange(T), K)
        w_flat = w.reshape(-1).astype(xt.dtype)
        dest = e_flat // E_loc  # owner device
        eloc = e_flat % E_loc  # expert index on the owner

        # rank within (dest, eloc) bucket → slot in the send buffer
        bucket = e_flat  # == dest * E_loc + eloc
        order = jnp.argsort(bucket, stable=True)
        bs = bucket[order]
        counts = jnp.bincount(bucket, length=E)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(T * K) - starts[bs]
        keep = pos < C
        slot_sorted = jnp.where(keep, bs * C + pos, E * C)  # E·C == p·E_loc·C

        send_x = jnp.zeros((E * C + 1, D), xt.dtype).at[slot_sorted].set(
            xt[t_flat[order]] * keep[:, None].astype(xt.dtype)
        )[: E * C]
        send_valid = jnp.zeros((E * C + 1,), bool).at[slot_sorted].set(keep)[: E * C]

        def xchg(v):
            y = v.reshape(p, E_loc * C, *v.shape[1:])
            y = jax.lax.all_to_all(y, axis, split_axis=0, concat_axis=0, tiled=False)
            return y.reshape(p * E_loc * C, *v.shape[1:])

        recv_x = xchg(send_x)  # (p·E_loc·C, D): all tokens for MY experts
        recv_valid = xchg(send_valid)
        recv_x = recv_x * recv_valid[:, None].astype(recv_x.dtype)

        # local expert FFN with TP inside the manual region: wg/wu arrive
        # (E_loc, D, F/tp), wd (E_loc, F/tp, D) — partial over F, one psum
        xe = recv_x.reshape(p, E_loc, C, D).transpose(1, 0, 2, 3).reshape(
            E_loc, p * C, D
        )
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg))
        u = jnp.einsum("ecd,edf->ecf", xe, wu)
        ye = jnp.einsum("ecf,efd->ecd", g * u, wd)
        ye = jax.lax.psum(ye, "model")
        ye = ye.reshape(E_loc, p, C, D).transpose(1, 0, 2, 3).reshape(p * E_loc * C, D)

        ret = xchg(ye)  # involution: back at the source, in send layout

        # combine: each kept assignment reads its slot and scatter-adds
        contrib = jnp.concatenate([ret, jnp.zeros((1, D), ret.dtype)])[slot_sorted]
        contrib = contrib * (w_flat[order] * keep.astype(xt.dtype))[:, None]
        y = jnp.zeros((T, D), xt.dtype).at[t_flat[order]].add(contrib)

        # load-balancing aux (local fractions; mean over devices)
        f = jnp.bincount(e_flat, length=E).astype(jnp.float32) / (T * K)
        Pm = jax.nn.softmax(xt.astype(jnp.float32) @ router, axis=-1).mean(0)
        aux = E * jnp.sum(f * Pm)
        return y.reshape(xb.shape), jax.lax.pmean(aux, axis)

    fn = compat.shard_map(
        local,
        in_specs=(
            P(axis, None, None),  # x batch-sharded (S gathered if SP outside)
            P(None, None),  # router replicated
            P(axis, None, "model"),  # experts: EP over data, TP over model
            P(axis, None, "model"),
            P(axis, "model", None),
        ),
        out_specs=(P(axis, None, None), P()),
    )
    return fn(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])
