"""GQA attention: query-chunked (memory O(S·chunk)), window/causal masks,
qk-norm, logit soft-cap, prefill + decode paths.

The chunked jnp path here is also the numerical oracle for the Pallas flash
kernel (``repro.kernels.flash_attention``); set ``use_flash=True`` on TPU to
dispatch to it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rmsnorm, rope, softcap

NEG_INF = -1e30
GLOBAL_WINDOW = 1 << 30  # "no window" sentinel usable inside traced selects


def make_attn_params(key, cfg, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, (cfg.d_model, cfg.q_dim), dtype=dtype),
        "wk": dense_init(k2, (cfg.d_model, cfg.kv_dim), dtype=dtype),
        "wv": dense_init(k3, (cfg.d_model, cfg.kv_dim), dtype=dtype),
        "wo": dense_init(k4, (cfg.q_dim, cfg.d_model), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.head_dim,), jnp.float32)
        p["k_norm"] = jnp.zeros((cfg.head_dim,), jnp.float32)
    return p


def _mask_bias(pos_q, pos_kv, window, causal):
    """(…, Sq, Skv) additive bias from position vectors. window is a traced or
    static int; GLOBAL_WINDOW means unbounded."""
    dq = pos_q[..., :, None]
    dk = pos_kv[..., None, :]
    ok = dk >= 0  # negative kv positions = padding (unwritten cache slots)
    if causal:
        ok &= dk <= dq
    ok &= (dq - dk) < window
    return jnp.where(ok, 0.0, NEG_INF)


def _pin_sq(x, sp):
    """Sequence-parallel: pin the Sq dim (axis -2) of score tensors to the
    "model" axis so fwd AND bwd agree on one layout (otherwise GSPMD flips
    between Sq- and Skv-sharded in the transpose and moves full scores)."""
    if not sp:
        return x
    from jax.sharding import PartitionSpec as P

    U = P.UNCONSTRAINED
    try:
        return jax.lax.with_sharding_constraint(
            x, P(*((U,) * (x.ndim - 2)), "model", U)
        )
    except Exception:
        return x


def _attend_block(q, k, v, bias, scale, cap, sp=False):
    """q: (B,Sq,K,G,hd) k/v: (B,Skv,K,hd) bias: (B,Sq,Skv) → (B,Sq,K,G,hd)."""
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32)
    s = softcap(s * scale, cap) + bias[:, None, None, :, :]
    s = _pin_sq(s, sp)
    p = _pin_sq(jax.nn.softmax(s, axis=-1), sp)
    return jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)


def attend(q, k, v, pos_q, pos_kv, *, window=GLOBAL_WINDOW, causal=True, cap=0.0,
           chunk=0, sp=False):
    """Grouped-query attention with on-the-fly masks.

    q: (B, Sq, H, hd); k, v: (B, Skv, K, hd); H = K·G.
    pos_q: (B, Sq) int32; pos_kv: (B, Skv) int32 (negative = invalid slot).
    ``chunk`` > 0 processes queries in blocks via ``lax.map`` so the full
    (Sq, Skv) score matrix is never materialised.
    """
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = hd**-0.5
    qg = q.reshape(B, Sq, K, G, hd)

    if not chunk or Sq <= chunk:
        bias = _mask_bias(pos_q, pos_kv, window, causal)
        o = _attend_block(qg, k, v, bias, scale, cap, sp=sp)
        return o.reshape(B, Sq, H, hd)

    n = Sq // chunk
    Sm = n * chunk
    qs = qg[:, :Sm].reshape(B, n, chunk, K, G, hd).swapaxes(0, 1)  # (n, B, chunk, K, G, hd)
    ps = pos_q[:, :Sm].reshape(B, n, chunk).swapaxes(0, 1)

    @jax.checkpoint  # don't save per-chunk probs: recompute in bwd (flash-style)
    def f(args):
        qb, pb = args
        bias = _mask_bias(pb, pos_kv, window, causal)
        return _attend_block(qb, k, v, bias, scale, cap)

    o = jax.lax.map(f, (qs, ps))  # (n, B, chunk, K, G, hd)
    o = o.swapaxes(0, 1).reshape(B, Sm, K, G, hd)
    if Sm < Sq:  # remainder block
        bias = _mask_bias(pos_q[:, Sm:], pos_kv, window, causal)
        tail = _attend_block(qg[:, Sm:], k, v, bias, scale, cap)
        o = jnp.concatenate([o, tail], axis=1)
    return o.reshape(B, Sq, H, hd)


def attention(x, p, cfg, pos, *, kv=None, window=GLOBAL_WINDOW, causal=True, pos_kv=None):
    """Full attention sub-layer for prefill/training.

    x: (B, S, D). If ``kv`` (B, Skv, D) is given, computes cross-attention
    (k/v projected from ``kv``; no RoPE on cross-attention).
    Returns (out, (k_heads, v_heads)) — the per-head K/V for cache writes.

    cfg.attn_impl == "flash" dispatches to the Pallas kernel (interpret mode
    on CPU) when the mask is expressible (static window / causal, no
    per-position invalidation) — scores never touch HBM on TPU.
    """
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, cfg.num_heads, cfg.head_dim)
    src = kv if kv is not None else x
    k = (src @ p["wk"]).reshape(B, src.shape[1], cfg.num_kv_heads, cfg.head_dim)
    v = (src @ p["wv"]).reshape(B, src.shape[1], cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if kv is None and cfg.rope_theta:
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    use_flash = (
        cfg.attn_impl == "flash"
        and pos_kv is None
        and not isinstance(window, jax.core.Tracer)  # static window only
    )
    if use_flash:
        from repro.kernels.flash_attention import flash_attention

        win = None if (window is None or window >= GLOBAL_WINDOW) else int(window)
        o = flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            causal, win, cfg.attn_logit_softcap,
            (src.shape[1] - S) if kv is None else 0,
        ).transpose(0, 2, 1, 3)
        return o.reshape(B, S, cfg.q_dim) @ p["wo"], (k, v)
    if pos_kv is None:
        pos_kv = pos if kv is None else jnp.broadcast_to(jnp.arange(src.shape[1])[None], (B, src.shape[1]))
    o = attend(q, k, v, pos, pos_kv, window=window, causal=causal, cap=cfg.attn_logit_softcap,
               chunk=cfg.attn_chunk, sp=cfg.attn_sp)
    return o.reshape(B, S, cfg.q_dim) @ p["wo"], (k, v)


def decode_attention(x, p, cfg, pos, k_cache, v_cache, *, window=GLOBAL_WINDOW):
    """One-token decode against a KV cache.

    x: (B, 1, D); pos: (B,) current positions; caches: (B, Smax, K, hd).
    Returns (out, new_k_cache, new_v_cache). Cache slots at index > pos are
    masked via the position trick (pos_kv entries beyond pos are invalid).
    """
    B, _, _ = x.shape
    q = (x @ p["wq"]).reshape(B, 1, cfg.num_heads, cfg.head_dim)
    k_new = (x @ p["wk"]).reshape(B, 1, cfg.num_kv_heads, cfg.head_dim)
    v_new = (x @ p["wv"]).reshape(B, 1, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k_new = rmsnorm(k_new, p["k_norm"])
    if cfg.rope_theta:
        q = rope(q, pos[:, None], cfg.rope_theta)
        k_new = rope(k_new, pos[:, None], cfg.rope_theta)

    # write the new K/V at `pos` (vmapped dynamic slice over batch)
    def upd(cache, new, i):
        return jax.lax.dynamic_update_slice_in_dim(cache, new, i, axis=0)

    k_cache = jax.vmap(upd)(k_cache, k_new.astype(k_cache.dtype), pos)
    v_cache = jax.vmap(upd)(v_cache, v_new.astype(v_cache.dtype), pos)

    Smax = k_cache.shape[1]
    idx = jnp.arange(Smax)[None, :]  # (1, Smax)
    pos_kv = jnp.where(idx <= pos[:, None], idx, -1)  # unwritten slots invalid
    o = attend(q, k_cache, v_cache, pos[:, None], pos_kv, window=window, causal=True,
               cap=cfg.attn_logit_softcap, chunk=0)
    return o.reshape(B, 1, cfg.q_dim) @ p["wo"], k_cache, v_cache
