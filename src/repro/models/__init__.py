"""Model zoo: scan-over-layers JAX definitions for every assigned arch."""
from repro.models.model_zoo import (  # noqa: F401
    build_model,
    analytic_param_count,
)
