"""build_model(cfg): one uniform bundle per architecture family.

Bundle surface (everything the launcher / dry-run / serving engine needs):
  init(key)                      → params
  train_loss(params, batch)     → scalar loss
  train_step(params, opt, batch)→ (params, opt, metrics)
  prefill(params, inputs)       → (logits, cache)
  decode_step(params, cache, tokens) → (logits, cache)
  input_specs(cell)             → abstract args for the cell's step function
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeCell
from repro.models import encdec, hybrid, ssm, transformer
from repro.optim.adamw import adamw_update, init_opt_state

VIT_DIM = 1024  # stub InternViT embedding width
WHISPER_TRAIN_ENC = 1500  # encoder frames for the train cell
WHISPER_PREFILL_DEC = 256  # decoder prompt length for the prefill cell


@dataclass
class ModelBundle:
    cfg: ArchConfig
    init: Callable
    train_loss: Callable
    prefill: Callable
    decode_step: Callable
    make_cache: Callable  # (batch, max_len) -> cache pytree (concrete zeros)

    def train_step(self, params, opt_state, batch, lr=3e-4):
        A = self.cfg.grad_accum
        if A <= 1:
            loss, grads = jax.value_and_grad(self.train_loss)(params, batch)
            if self.cfg.grad_compress != "none":
                from repro.distributed.compression import compressed_grads

                # stateless form for the dry-run path (EF state lives in the
                # real train loop, launch/train.py)
                zeros = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
                grads, _ = compressed_grads(grads, zeros, self.cfg.grad_compress)
        else:
            # microbatch accumulation: activation residency ÷ A (the global
            # batch is a schedule choice, not a memory obligation)
            micro = jax.tree.map(
                lambda x: x.reshape(A, x.shape[0] // A, *x.shape[1:]), batch
            )

            def step(acc, mb):
                g_sum, l_sum = acc
                l, g = jax.value_and_grad(self.train_loss)(params, mb)
                g_sum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_sum, g
                )
                return (g_sum, l_sum + l), None

            # seed the accumulator from microbatch 0 so it inherits the
            # grads' natural sharding (a zeros-init accumulator is unsharded
            # → GSPMD would all-reduce FULL grads every microbatch)
            l0, g0 = jax.value_and_grad(self.train_loss)(
                params, jax.tree.map(lambda x: x[0], micro)
            )
            g0 = jax.tree.map(lambda g: g.astype(jnp.float32), g0)
            rest = jax.tree.map(lambda x: x[1:], micro)
            (g_sum, l_sum), _ = jax.lax.scan(step, (g0, l0), rest)
            grads = jax.tree.map(lambda g: (g / A), g_sum)
            loss = l_sum / A
        params, opt_state = adamw_update(grads, opt_state, params, lr=lr)
        return params, opt_state, loss

    def init_opt(self, params):
        return init_opt_state(params, jnp.dtype(self.cfg.opt_moment_dtype))

    # ------------------------------------------------------------------
    # abstract inputs per shape cell (ShapeDtypeStruct — no allocation)
    # ------------------------------------------------------------------
    def input_specs(self, cell: ShapeCell) -> dict[str, Any]:
        cfg = self.cfg
        B, S = cell.global_batch, cell.seq_len
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct

        if cfg.family == "audio":
            if cell.kind == "train":
                return {
                    "frames": sds((B, WHISPER_TRAIN_ENC, cfg.d_model), jnp.bfloat16),
                    "tokens": sds((B, S), i32),
                    "labels": sds((B, S), i32),
                }
            if cell.kind == "prefill":
                return {
                    "frames": sds((B, S, cfg.d_model), jnp.bfloat16),
                    "tokens": sds((B, WHISPER_PREFILL_DEC), i32),
                }
            cache = jax.eval_shape(
                lambda: encdec.make_encdec_cache(cfg, B, S, cfg.enc_seq)
            )
            return {"cache": cache, "tokens": sds((B, 1), i32)}

        if cfg.family == "vlm":
            P = cfg.num_patches
            if cell.kind == "train":
                return {
                    "tokens": sds((B, S - P), i32),
                    "labels": sds((B, S - P), i32),
                    "patches": sds((B, P, VIT_DIM), jnp.bfloat16),
                }
            if cell.kind == "prefill":
                return {
                    "tokens": sds((B, S - P), i32),
                    "patches": sds((B, P, VIT_DIM), jnp.bfloat16),
                }
            cache = jax.eval_shape(lambda: self.make_cache(B, S))
            return {"cache": cache, "tokens": sds((B, 1), i32)}

        # plain LM families: dense / moe / ssm / hybrid
        if cell.kind == "train":
            return {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
        if cell.kind == "prefill":
            return {"tokens": sds((B, S), i32)}
        cache = jax.eval_shape(lambda: self.make_cache(B, S))
        return {"cache": cache, "tokens": sds((B, 1), i32)}

    def abstract_params(self, key=None):
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    def step_for_cell(self, cell: ShapeCell):
        """(callable, abstract-args tuple) for lower()/compile()."""
        specs = self.input_specs(cell)
        params = self.abstract_params()
        if cell.kind == "train":
            opt = jax.eval_shape(self.init_opt, params)
            fn = lambda p, o, b: self.train_step(p, o, b)
            return fn, (params, opt, specs)
        if cell.kind == "prefill":
            fn = lambda p, inputs: self.prefill(p, **inputs)
            return fn, (params, specs)
        fn = lambda p, cache, tok: self.decode_step(p, cache, tok)
        return fn, (params, specs["cache"], specs["tokens"])


# ---------------------------------------------------------------------------
# family builders
# ---------------------------------------------------------------------------


def _max_dec_for(cfg):
    # whisper learned decoder positions must cover the largest assigned cell
    return 32_768


def build_model(cfg: ArchConfig, *, max_dec=None) -> ModelBundle:
    f = cfg.family
    if f in ("dense", "moe", "vlm"):
        return ModelBundle(
            cfg=cfg,
            init=functools.partial(transformer.make_lm_params, cfg=cfg),
            train_loss=functools.partial(transformer.lm_train_loss, cfg=cfg),
            prefill=lambda params, **inp: transformer.lm_prefill(
                params, inp["tokens"], cfg, cache_len=inp.get("cache_len"),
                patches=inp.get("patches")
            ),
            decode_step=lambda params, cache, tok: transformer.lm_decode_step(
                params, cache, tok, cfg
            ),
            make_cache=lambda batch, max_len: transformer.make_cache(cfg, batch, max_len),
        )
    if f == "ssm":
        return ModelBundle(
            cfg=cfg,
            init=functools.partial(ssm.make_ssm_params, cfg=cfg),
            train_loss=functools.partial(ssm.ssm_train_loss, cfg=cfg),
            prefill=lambda params, **inp: ssm.ssm_prefill(params, inp["tokens"], cfg),
            decode_step=lambda params, cache, tok: ssm.ssm_decode_step(params, cache, tok, cfg),
            make_cache=lambda batch, max_len: ssm.make_ssm_cache(cfg, batch),
        )
    if f == "hybrid":
        return ModelBundle(
            cfg=cfg,
            init=functools.partial(hybrid.make_hybrid_params, cfg=cfg),
            train_loss=functools.partial(hybrid.hybrid_train_loss, cfg=cfg),
            prefill=lambda params, **inp: hybrid.hybrid_prefill(params, inp["tokens"], cfg),
            decode_step=lambda params, cache, tok: hybrid.hybrid_decode_step(
                params, cache, tok, cfg
            ),
            make_cache=lambda batch, max_len: hybrid.make_hybrid_cache(cfg, batch, max_len),
        )
    if f == "audio":
        md = max_dec or _max_dec_for(cfg)
        return ModelBundle(
            cfg=cfg,
            init=lambda key: encdec.make_encdec_params(key, cfg, max_dec=md, max_enc=32_768),
            train_loss=functools.partial(encdec.encdec_train_loss, cfg=cfg),
            prefill=lambda params, **inp: encdec.encdec_prefill(
                params, inp["frames"], inp["tokens"], cfg
            ),
            decode_step=lambda params, cache, tok: encdec.encdec_decode_step(
                params, cache, tok, cfg
            ),
            make_cache=lambda batch, max_len: encdec.make_encdec_cache(
                cfg, batch, max_len, cfg.enc_seq
            ),
        )
    raise ValueError(f"unknown family {f!r}")


# ---------------------------------------------------------------------------
# analytic parameter counts (MODEL_FLOPS = 6·N·D)
# ---------------------------------------------------------------------------


def analytic_param_count(cfg: ArchConfig, active_only: bool = False) -> int:
    bundle = build_model(cfg)
    shapes = bundle.abstract_params()
    total = int(sum(np.prod(l.shape) for l in jax.tree.leaves(shapes)))
    if active_only and cfg.is_moe:
        # subtract the unused expert fraction: each MoE layer activates k of E
        E, K, D, F = cfg.num_experts, cfg.experts_per_token, cfg.d_model, cfg.d_ff
        per_moe_layer = E * 3 * D * F
        if cfg.family == "hybrid":
            n_moe = (cfg.num_layers // cfg.attn_period) * sum(
                1 for i in range(1, hybrid.N_SLOTS) if i % cfg.moe_period == 1
            )
        else:
            n_moe = sum(1 for i in range(cfg.num_layers) if i % cfg.moe_period == 0)
        total -= int(n_moe * per_moe_layer * (1 - K / E))
    return total
