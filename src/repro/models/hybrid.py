"""Jamba-style hybrid: period-8 blocks (1 attention + 7 mamba layers), each
layer followed by dense-MLP or MoE (alternating). 72 layers = 9 scanned
blocks; the 8 heterogeneous slots are unrolled inside the block body so the
HLO stays one-block sized.

Attention layers carry the only KV cache (1/8 of layers) — the hybrid
long-context win the assignment calls out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba2
from repro.models.layers import (
    apply_norm,
    embed_init,
    lm_loss,
    make_mlp_params,
    make_norm_params,
    mlp,
)
from repro.models.moe import make_moe_params, moe_apply, moe_ffn_bsd
from repro.models.transformer import _remat, head_matrix, stack_layers

N_SLOTS = 8  # cfg.attn_period


def _n_blocks(cfg):
    assert cfg.num_layers % cfg.attn_period == 0
    return cfg.num_layers // cfg.attn_period


def _slot_is_moe(i, cfg):
    return cfg.is_moe and (i % cfg.moe_period == 1)  # odd slots → MoE


def make_block_params(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, N_SLOTS)
    bp = {
        "attn": {
            "ln1": make_norm_params(ks[0], cfg.d_model, cfg.norm_type),
            "attn": attn.make_attn_params(ks[0], cfg, dt),
            "ln2": make_norm_params(ks[0], cfg.d_model, cfg.norm_type),
            "ffn": make_mlp_params(ks[0], cfg.d_model, cfg.d_ff, dt),
        }
    }
    for i in range(1, N_SLOTS):
        ffn = (
            make_moe_params(ks[i], cfg, dt)
            if _slot_is_moe(i, cfg)
            else make_mlp_params(ks[i], cfg.d_model, cfg.d_ff, dt)
        )
        bp[f"s{i}"] = {
            "ln1": make_norm_params(ks[i], cfg.d_model, cfg.norm_type),
            "mixer": mamba2.make_mamba_params(ks[i], cfg, dt),
            "ln2": make_norm_params(ks[i], cfg.d_model, cfg.norm_type),
            "ffn": ffn,
        }
    return bp


def make_hybrid_params(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    nb = _n_blocks(cfg)
    ks = jax.random.split(key, 2 + nb)
    return {
        "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dt),
        "lm_head": embed_init(ks[1], (cfg.d_model, cfg.vocab_size), dt),
        "blocks": stack_layers(ks[2:], lambda k: make_block_params(k, cfg)),
        "final_norm": make_norm_params(ks[0], cfg.d_model, cfg.norm_type),
    }


def _ffn_apply(x, sp, i, cfg, aux):
    h = apply_norm(x, sp["ln2"], cfg.norm_type)
    if _slot_is_moe(i, cfg):
        m, a = moe_apply(h, sp["ffn"], cfg)
        return x + m, aux + a
    return x + mlp(h, sp["ffn"]), aux


def hybrid_forward(params, tokens, cfg):
    x = jnp.take(params["embed"], tokens, axis=0)
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def block(carry, bp):
        x, aux = carry
        a, _ = attn.attention(
            apply_norm(x, bp["attn"]["ln1"], cfg.norm_type), bp["attn"]["attn"], cfg, pos
        )
        x = x + a
        x = x + mlp(apply_norm(x, bp["attn"]["ln2"], cfg.norm_type), bp["attn"]["ffn"])
        for i in range(1, N_SLOTS):
            sp = bp[f"s{i}"]
            y, _t, _s = mamba2.mamba_mixer(
                apply_norm(x, sp["ln1"], cfg.norm_type), sp["mixer"], cfg
            )
            x, aux = _ffn_apply(x + y, sp, i, cfg, aux)
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(_remat(block, cfg), (x, 0.0), params["blocks"])
    return apply_norm(x, params["final_norm"], cfg.norm_type), aux


def hybrid_train_loss(params, batch, cfg):
    h, aux = hybrid_forward(params, batch["tokens"], cfg)
    loss = lm_loss(h, head_matrix(params, cfg), batch["labels"], cfg.loss_chunk)
    return loss + 0.01 * aux


def make_hybrid_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    nb = _n_blocks(cfg)
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "k": jnp.zeros((nb, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((nb, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        "conv": jnp.zeros((nb, N_SLOTS - 1, batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "state": jnp.zeros(
            (nb, N_SLOTS - 1, batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32
        ),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def hybrid_prefill(params, tokens, cfg, cache_len=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    B, S, _ = x.shape
    Smax = cache_len or S
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def block(x, bp):
        a, (k, v) = attn.attention(
            apply_norm(x, bp["attn"]["ln1"], cfg.norm_type), bp["attn"]["attn"], cfg, pos
        )
        x = x + a
        x = x + mlp(apply_norm(x, bp["attn"]["ln2"], cfg.norm_type), bp["attn"]["ffn"])
        tails, states = [], []
        for i in range(1, N_SLOTS):
            sp = bp[f"s{i}"]
            y, t, s = mamba2.mamba_mixer(
                apply_norm(x, sp["ln1"], cfg.norm_type), sp["mixer"], cfg
            )
            tails.append(t)
            states.append(s)
            x, _ = _ffn_apply(x + y, sp, i, cfg, 0.0)
        if Smax > S:
            padw = [(0, 0), (0, Smax - S), (0, 0), (0, 0)]
            k, v = jnp.pad(k, padw), jnp.pad(v, padw)
        return x, (k, v, jnp.stack(tails), jnp.stack(states))

    x, (ks, vs, convs, states) = jax.lax.scan(block, x, params["blocks"])
    h = apply_norm(x, params["final_norm"], cfg.norm_type)
    logits = h[:, -1] @ head_matrix(params, cfg)
    cache = {
        "k": ks,
        "v": vs,
        "conv": convs,
        "state": states,
        "pos": jnp.full((B,), S, jnp.int32),
    }
    return logits, cache


def hybrid_decode_step(params, cache, tokens, cfg):
    x = jnp.take(params["embed"], tokens, axis=0)
    pos = cache["pos"]

    def block(x, xs):
        bp, k_b, v_b, conv_b, state_b = xs
        a, k_b, v_b = attn.decode_attention(
            apply_norm(x, bp["attn"]["ln1"], cfg.norm_type), bp["attn"]["attn"], cfg, pos,
            k_b, v_b,
        )
        x = x + a
        x = x + mlp(apply_norm(x, bp["attn"]["ln2"], cfg.norm_type), bp["attn"]["ffn"])
        convs, states = [], []
        for i in range(1, N_SLOTS):
            sp = bp[f"s{i}"]
            y, c, s = mamba2.mamba_mixer_decode(
                apply_norm(x, sp["ln1"], cfg.norm_type), sp["mixer"], cfg,
                conv_b[i - 1], state_b[i - 1],
            )
            convs.append(c)
            states.append(s)
            x, _ = _ffn_apply(x + y, sp, i, cfg, 0.0)
        return x, (k_b, v_b, jnp.stack(convs), jnp.stack(states))

    x, (ks, vs, convs, states) = jax.lax.scan(
        block, x, (params["blocks"], cache["k"], cache["v"], cache["conv"], cache["state"])
    )
    h = apply_norm(x, params["final_norm"], cfg.norm_type)
    logits = h[:, -1] @ head_matrix(params, cfg)
    return logits, {"k": ks, "v": vs, "conv": convs, "state": states, "pos": pos + 1}
