"""Whisper-style encoder-decoder. The audio conv frontend is a STUB —
``input_specs()`` supplies precomputed frame embeddings (B, S_enc, D); a tiny
conv stub lives here only for the CPU smoke test.

Decoder positions are a learned table sized to the requested decode length
(the assigned decode_32k cell extends past whisper's published 448 cap — a
table extension, not retraining; noted in DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.layers import apply_norm, dense_init, embed_init, lm_loss, make_norm_params
from repro.models.transformer import _remat, stack_layers


# whisper uses a two-matrix GELU MLP (with biases), not SwiGLU
def make_gelu_mlp_params(key, d, f, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w1": dense_init(k1, (d, f), dtype=dtype),
        "b1": jnp.zeros((f,), jnp.float32),
        "w2": dense_init(k2, (f, d), dtype=dtype),
        "b2": jnp.zeros((d,), jnp.float32),
    }


def gelu_mlp(x, p):
    h = jax.nn.gelu(x @ p["w1"] + p["b1"].astype(x.dtype))
    return h @ p["w2"] + p["b2"].astype(x.dtype)


def make_encdec_params(key, cfg, max_dec=None, max_enc=None):
    dt = jnp.dtype(cfg.param_dtype)
    max_dec = max_dec or 448
    max_enc = max_enc or cfg.enc_seq
    ks = jax.random.split(key, 6)

    def enc_layer(k):
        return {
            "ln1": make_norm_params(k, cfg.d_model, cfg.norm_type),
            "attn": attn.make_attn_params(k, cfg, dt),
            "ln2": make_norm_params(k, cfg.d_model, cfg.norm_type),
            "ffn": make_gelu_mlp_params(k, cfg.d_model, cfg.d_ff, dt),
        }

    def dec_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": make_norm_params(k, cfg.d_model, cfg.norm_type),
            "self_attn": attn.make_attn_params(k1, cfg, dt),
            "lnx": make_norm_params(k, cfg.d_model, cfg.norm_type),
            "cross_attn": attn.make_attn_params(k2, cfg, dt),
            "ln2": make_norm_params(k, cfg.d_model, cfg.norm_type),
            "ffn": make_gelu_mlp_params(k, cfg.d_model, cfg.d_ff, dt),
        }

    return {
        "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dt),
        "pos_dec": embed_init(ks[1], (max_dec, cfg.d_model), dt),
        "pos_enc": embed_init(ks[2], (max_enc, cfg.d_model), dt),
        "enc_layers": stack_layers(jax.random.split(ks[3], cfg.enc_layers), enc_layer),
        "enc_norm": make_norm_params(ks[3], cfg.d_model, cfg.norm_type),
        "dec_layers": stack_layers(jax.random.split(ks[4], cfg.num_layers), dec_layer),
        "dec_norm": make_norm_params(ks[4], cfg.d_model, cfg.norm_type),
    }


def conv_frontend_stub(audio, cfg):
    """Smoke-test-only stand-in for whisper's mel+conv frontend: strided avg
    pooling of raw features into (B, S/2, D)."""
    B, S = audio.shape[0], audio.shape[1]
    x = audio.reshape(B, S // 2, -1)
    d = x.shape[-1]
    if d < cfg.d_model:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, cfg.d_model - d)))
    return x[..., : cfg.d_model]


def encode(params, frames, cfg):
    """frames: (B, S_enc, D) precomputed frame embeddings (frontend stub)."""
    S = frames.shape[1]
    x = frames + params["pos_enc"][None, :S].astype(frames.dtype)
    B = x.shape[0]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def layer(x, lp):
        a, _ = attn.attention(
            apply_norm(x, lp["ln1"], cfg.norm_type), lp["attn"], cfg, pos, causal=False
        )
        x = x + a
        return x + gelu_mlp(apply_norm(x, lp["ln2"], cfg.norm_type), lp["ffn"]), None

    x, _ = jax.lax.scan(_remat(layer, cfg), x, params["enc_layers"])
    return apply_norm(x, params["enc_norm"], cfg.norm_type)


def decode_train(params, tokens, enc_out, cfg):
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0) + params["pos_dec"][None, :S]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def layer(x, lp):
        a, _ = attn.attention(
            apply_norm(x, lp["ln1"], cfg.norm_type), lp["self_attn"], cfg, pos
        )
        x = x + a
        c, _ = attn.attention(
            apply_norm(x, lp["lnx"], cfg.norm_type), lp["cross_attn"], cfg, pos,
            kv=enc_out, causal=False,
        )
        x = x + c
        return x + gelu_mlp(apply_norm(x, lp["ln2"], cfg.norm_type), lp["ffn"]), None

    x, _ = jax.lax.scan(_remat(layer, cfg), x, params["dec_layers"])
    return apply_norm(x, params["dec_norm"], cfg.norm_type)


def encdec_train_loss(params, batch, cfg):
    enc_out = encode(params, batch["frames"], cfg)
    h = decode_train(params, batch["tokens"], enc_out, cfg)
    return lm_loss(h, params["embed"].T, batch["labels"], cfg.loss_chunk)


def encdec_prefill(params, frames, tokens, cfg, cache_len=None):
    """Encode audio, precompute cross K/V, prefill decoder prompt."""
    enc_out = encode(params, frames, cfg)
    B, S = tokens.shape
    Smax = cache_len or S
    x = jnp.take(params["embed"], tokens, axis=0) + params["pos_dec"][None, :S]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    Senc = enc_out.shape[1]

    def layer(x, lp):
        a, (k, v) = attn.attention(
            apply_norm(x, lp["ln1"], cfg.norm_type), lp["self_attn"], cfg, pos
        )
        x = x + a
        c, (kx, vx) = attn.attention(
            apply_norm(x, lp["lnx"], cfg.norm_type), lp["cross_attn"], cfg, pos,
            kv=enc_out, causal=False,
        )
        x = x + c
        x = x + gelu_mlp(apply_norm(x, lp["ln2"], cfg.norm_type), lp["ffn"])
        if Smax > S:
            padw = [(0, 0), (0, Smax - S), (0, 0), (0, 0)]
            k, v = jnp.pad(k, padw), jnp.pad(v, padw)
        return x, (k, v, kx, vx)

    x, (ks, vs, kxs, vxs) = jax.lax.scan(layer, x, params["dec_layers"])
    h = apply_norm(x, params["dec_norm"], cfg.norm_type)
    logits = h[:, -1] @ params["embed"].T
    cache = {
        "k": ks, "v": vs, "k_cross": kxs, "v_cross": vxs,
        "pos": jnp.full((B,), S, jnp.int32),
        "enc_len": jnp.full((B,), Senc, jnp.int32),
    }
    return logits, cache


def make_encdec_cache(cfg, batch, max_len, enc_len, dtype=jnp.bfloat16):
    L = cfg.num_layers
    kv = (L, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    kvx = (L, batch, enc_len, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
        "k_cross": jnp.zeros(kvx, dtype), "v_cross": jnp.zeros(kvx, dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
        "enc_len": jnp.full((batch,), enc_len, jnp.int32),
    }


def encdec_decode_step(params, cache, tokens, cfg):
    B = tokens.shape[0]
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0) + jnp.take(params["pos_dec"], pos, axis=0)[
        :, None, :
    ]
    Senc = cache["k_cross"].shape[2]
    pos_kv_x = jnp.broadcast_to(jnp.arange(Senc, dtype=jnp.int32)[None], (B, Senc))

    def layer(x, xs):
        lp, k_l, v_l, kx_l, vx_l = xs
        a, k_l, v_l = attn.decode_attention(
            apply_norm(x, lp["ln1"], cfg.norm_type), lp["self_attn"], cfg, pos, k_l, v_l
        )
        x = x + a
        # cross attention against precomputed encoder K/V
        h = apply_norm(x, lp["lnx"], cfg.norm_type)
        q = (h @ lp["cross_attn"]["wq"]).reshape(B, 1, cfg.num_heads, cfg.head_dim)
        o = attn.attend(q, kx_l, vx_l, pos[:, None], pos_kv_x, causal=False)
        x = x + o.reshape(B, 1, cfg.q_dim) @ lp["cross_attn"]["wo"]
        x = x + gelu_mlp(apply_norm(x, lp["ln2"], cfg.norm_type), lp["ffn"])
        return x, (k_l, v_l)

    x, (ks, vs) = jax.lax.scan(
        layer, x, (params["dec_layers"], cache["k"], cache["v"], cache["k_cross"], cache["v_cross"])
    )
    h = apply_norm(x, params["dec_norm"], cfg.norm_type)
    logits = h[:, -1] @ params["embed"].T
    return logits, {**cache, "k": ks, "v": vs, "pos": pos + 1}
