"""Mixture-of-Experts FFN with sort-based (gather/scatter) dispatch.

TPU-idiomatic: instead of the dense one-hot dispatch einsum (which
materialises a (tokens × experts × capacity) tensor), tokens are argsorted by
expert id, packed into an (E, capacity, D) buffer with capacity dropping, run
through batched per-expert SwiGLU matmuls, and scattered back with their
router weights. Load-balancing auxiliary loss follows Switch/ST-MoE.

Expert parallelism: shard the leading E axis of the expert weights over the
"model" mesh axis (see distributed/sharding.py); GSPMD turns the gather/
scatter into all-to-all routing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def make_moe_params(key, cfg, dtype):
    E, D, F = cfg.num_experts, cfg.d_model, cfg.d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": dense_init(k1, (D, E), dtype=jnp.float32),
        "w_gate": dense_init(k2, (E, D, F), in_axis=-2, dtype=dtype),
        "w_up": dense_init(k3, (E, D, F), in_axis=-2, dtype=dtype),
        "w_down": dense_init(k4, (E, F, D), in_axis=-2, dtype=dtype),
    }


def capacity_for(cfg, tokens: int) -> int:
    cap = int(cfg.capacity_factor * tokens * cfg.experts_per_token / cfg.num_experts)
    return max(cap, cfg.experts_per_token, 1)


def route(x, router, k):
    """Router: returns (weights (T,k), expert ids (T,k), probs (T,E))."""
    logits = x.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)  # renormalise top-k
    return w, idx, probs


def _pin_experts(x, cfg):
    """EP boundary: pin the (small) dispatch/combine buffers REPLICATED.

    The index-based scatter/gather between "data"-sharded tokens and
    E-sharded buffers defeats GSPMD (it all-reduces full f32 buffers per
    read). With the (E, C, D) buffer replicated, the scatter is a local
    partial + ONE bf16 all-reduce, the expert einsums keep their EP/TP
    sharding from the weights, and the combine gather is local."""
    if not getattr(cfg, "moe_ep", False):
        return x
    from jax.sharding import PartitionSpec as P

    try:
        return jax.lax.with_sharding_constraint(x, P(*((None,) * x.ndim)))
    except Exception:
        return x


def moe_ffn(x, p, cfg, capacity: int | None = None):
    """x: (T, D) flat tokens → (y (T, D), aux_loss scalar)."""
    T, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    C = capacity if capacity is not None else capacity_for(cfg, T)

    w, idx, probs = route(x, p["router"], K)

    e_flat = idx.reshape(-1)  # (T·K,) expert of each assignment
    t_flat = jnp.repeat(jnp.arange(T), K)  # token of each assignment
    w_flat = w.reshape(-1).astype(x.dtype)

    order = jnp.argsort(e_flat, stable=True)  # group assignments by expert
    es, ts, ws = e_flat[order], t_flat[order], w_flat[order]

    counts = jnp.bincount(e_flat, length=E)  # tokens per expert
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * K) - starts[es]  # rank within its expert
    keep = pos < C
    slot = es * C + jnp.where(keep, pos, 0)

    # pack: (E·C, D) buffer; dropped assignments contribute zero
    buf = jnp.zeros((E * C, D), x.dtype).at[slot].add(x[ts] * keep[:, None].astype(x.dtype))
    xe = _pin_experts(buf.reshape(E, C, D), cfg)

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"])
    ye = _pin_experts(ye, cfg).reshape(E * C, D)

    # unpack: scatter-add weighted expert outputs back to tokens
    contrib = ye[slot] * (ws * keep.astype(ws.dtype))[:, None]
    y = jnp.zeros((T, D), x.dtype).at[ts].add(contrib)

    # Switch-style load balancing: E · Σ_e f_e · P_e
    f = jnp.bincount(e_flat, length=E).astype(jnp.float32) / (T * K)
    P = probs.mean(axis=0)
    aux = E * jnp.sum(f * P)
    return y, aux


def moe_ffn_bsd(x, p, cfg):
    """(B, S, D) wrapper: flattens tokens, restores shape."""
    B, S, D = x.shape
    y, aux = moe_ffn(x.reshape(B * S, D), p, cfg)
    return y.reshape(B, S, D), aux


def moe_apply(x, p, cfg):
    """(B, S, D) MoE with automatic path choice: explicit shard_map expert
    parallelism when the mesh allows it, GSPMD auto-sharding otherwise."""
    from repro.models.moe_ep import ep_applicable, moe_ffn_bsd_ep

    try:
        if ep_applicable(cfg):
            return moe_ffn_bsd_ep(x, p, cfg)
    except Exception:
        pass
    return moe_ffn_bsd(x, p, cfg)
