"""Mamba-2 block: SSD (state-space duality) with chunked scan.

The chunked SSD here (``ssd_chunked``) is the numerical oracle for the Pallas
kernel in ``repro.kernels.ssd_scan``. Within a chunk the recurrence is
computed attention-style (decay-masked C·Bᵀ scores); across chunks a small
``lax.scan`` carries the (H, P, N) state — O(S) work, O(S·chunk) memory.

Decode is the O(1) recurrent update: h ← exp(Δ·A)·h + Δ·B⊗x ; y = C·h + D·x.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rmsnorm


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, A_log, Bm, Cm, chunk, init_state=None):
    """Chunked SSD as a scan over chunks.

    x:  (b, s, h, p)   inputs per head (already Δ-scaled is NOT expected here)
    dt: (b, s, h)      positive step sizes (softplus already applied)
    A_log: (h,)        A = -exp(A_log)
    Bm, Cm: (b, s, g, n) input/output projections per group (g divides h)
    Returns (y (b, s, h, p), final_state (b, h, p, n)).

    One chunk's (b, h, q, q) decay tensor is live at a time — the recurrence
    is sequential across chunks anyway, and the all-chunks-at-once einsum
    materialised (b, c, h, q, q) in HBM (1.3 TB/device for the jamba train
    cell; see EXPERIMENTS.md §Perf). Same structure as the Pallas kernel.
    """
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    s_real = s
    pad = (-s) % chunk
    if pad:  # zero-pad the tail: dt=0 ⇒ decay 1, input 0 — a state no-op
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    c, q = s // chunk, chunk
    hpg = h // g

    a = (-jnp.exp(A_log.astype(jnp.float32)))[None, None] * dt.astype(jnp.float32)  # (b,s,h) ≤ 0
    xdt = x * dt[..., None].astype(x.dtype)

    # chunk-major for the scan
    a_ = a.reshape(b, c, q, h).transpose(1, 0, 2, 3)  # (c,b,q,h)
    x_ = xdt.reshape(b, c, q, h, p).transpose(1, 0, 2, 3, 4)
    B_ = Bm.reshape(b, c, q, g, n).transpose(1, 0, 2, 3, 4)
    C_ = Cm.reshape(b, c, q, g, n).transpose(1, 0, 2, 3, 4)
    mask = jnp.tril(jnp.ones((q, q), bool))

    h0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((b, h, p, n), jnp.float32)
    )

    def step(hs, inp):
        a_c, x_c, B_c, C_c = inp  # (b,q,h) (b,q,h,p) (b,q,g,n) (b,q,g,n)
        ca = jnp.cumsum(a_c, axis=1)  # (b,q,h)
        # intra-chunk: scores[i,j] = (C_i·B_j)·exp(ca_i − ca_j), j ≤ i
        cb = jnp.einsum("bign,bjgn->bgij", C_c, B_c,
                        preferred_element_type=jnp.float32)
        seg = ca[:, :, None, :] - ca[:, None, :, :]  # (b,i,j,h)
        decay = jnp.where(mask[None, :, :, None], jnp.exp(seg), 0.0)
        cbh = jnp.repeat(cb, hpg, axis=1)  # (b,h,i,j)
        w_ij = cbh * jnp.moveaxis(decay, -1, 1)
        y_intra = jnp.einsum("bhij,bjhp->bihp", w_ij.astype(x.dtype), x_c)
        # inter-chunk from the carried state
        Ch = jnp.repeat(C_c, hpg, axis=2)  # (b,q,h,n)
        y_inter = jnp.einsum("bqhn,bhpn->bqhp", Ch.astype(x.dtype),
                             hs.astype(x.dtype))
        y_inter = y_inter * jnp.exp(ca)[..., None].astype(x.dtype)
        # state update
        wlast = jnp.exp(ca[:, -1:, :] - ca)  # (b,q,h)
        Bh = jnp.repeat(B_c, hpg, axis=2)  # (b,q,h,n)
        st = jnp.einsum("bqh,bqhn,bqhp->bhpn", wlast.astype(x.dtype),
                        Bh.astype(x.dtype), x_c)
        hs_new = jnp.exp(ca[:, -1, :])[:, :, None, None] * hs + st.astype(jnp.float32)
        return hs_new, (y_intra + y_inter)

    final, ys = jax.lax.scan(step, h0, (a_, x_, B_, C_))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y[:, :s_real], final


def ssd_decode(state, x, dt, A_log, Bm, Cm):
    """One-step recurrence. state: (b,h,p,n); x: (b,h,p); dt: (b,h);
    Bm, Cm: (b,g,n). Returns (y (b,h,p), new_state)."""
    b, h, p = x.shape
    g, n = Bm.shape[1], Bm.shape[2]
    hpg = h // g
    a = jnp.exp((-jnp.exp(A_log.astype(jnp.float32)))[None] * dt.astype(jnp.float32))  # (b,h)
    Bh = jnp.repeat(Bm, hpg, axis=1)  # (b,h,n)
    Ch = jnp.repeat(Cm, hpg, axis=1)
    upd = (x * dt[..., None])[..., :, None] * Bh[..., None, :]  # (b,h,p,n)
    state = a[..., None, None] * state + upd.astype(state.dtype)
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch.astype(state.dtype))
    return y.astype(x.dtype), state


# ---------------------------------------------------------------------------
# Mamba-2 mixer layer
# ---------------------------------------------------------------------------


def make_mamba_params(key, cfg, dtype):
    D = cfg.d_model
    di = cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * g * n
    ks = jax.random.split(key, 4)
    return {
        # in_proj → [z (di), xBC (di + 2gn), dt (h)]
        "in_proj": dense_init(ks[0], (D, 2 * di + 2 * g * n + h), dtype=dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, conv_dim), in_axis=0, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),
        "Dskip": jnp.ones((h,), jnp.float32),
        "norm": jnp.zeros((di,), jnp.float32),
        "out_proj": dense_init(ks[2], (di, D), dtype=dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (b, s, ch); w: (width, ch)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):  # width is tiny (4): unrolled shifts beat conv here
        out = out + pad[:, i : i + x.shape[1], :] * w[i][None, None, :]
    return out + b[None, None, :].astype(x.dtype)


def mamba_mixer(x, p, cfg):
    """x: (b, s, D) → (y (b, s, D), conv_tail (b, width-1, conv_dim), final_state).

    ``conv_tail`` is the raw (pre-conv) tail of xBC — the decode conv cache.
    """
    b, s, D = x.shape
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    ph = cfg.ssm_headdim

    zxbcdt = x @ p["in_proj"]
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * g * n], axis=-1)
    conv_tail = xBC[:, -(cfg.ssm_conv - 1) :, :]
    xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))
    xs, Bm, Cm = jnp.split(xBC, [di, di + g * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])

    y, state = ssd_chunked(
        xs.reshape(b, s, h, ph),
        dt,
        p["A_log"],
        Bm.reshape(b, s, g, n),
        Cm.reshape(b, s, g, n),
        cfg.ssm_chunk,
    )
    y = y + xs.reshape(b, s, h, ph) * p["Dskip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(b, s, di)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])  # gated RMSNorm (mamba2)
    return y @ p["out_proj"], conv_tail, state


def mamba_mixer_decode(x, p, cfg, conv_cache, state):
    """One-token decode. x: (b, 1, D); conv_cache: (b, width-1, conv_dim);
    state: (b, h, p, n). Returns (y (b,1,D), new_conv_cache, new_state)."""
    b, _, D = x.shape
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    ph = cfg.ssm_headdim

    zxbcdt = x[:, 0] @ p["in_proj"]
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * g * n], axis=-1)
    window = jnp.concatenate([conv_cache, xBC[:, None, :]], axis=1)  # (b, width, ch)
    conv = jnp.einsum("bwc,wc->bc", window, p["conv_w"].astype(x.dtype))
    xBC = jax.nn.silu(conv + p["conv_b"][None].astype(x.dtype))
    xs, Bm, Cm = jnp.split(xBC, [di, di + g * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None])

    y, state = ssd_decode(
        state, xs.reshape(b, h, ph), dt, p["A_log"], Bm.reshape(b, g, n), Cm.reshape(b, g, n)
    )
    y = y + xs.reshape(b, h, ph) * p["Dskip"][None, :, None].astype(x.dtype)
    y = y.reshape(b, di)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    return (y @ p["out_proj"])[:, None, :], window[:, 1:], state
