"""Shared building blocks: norms, RoPE, MLPs, embeddings, chunked losses.

All parameters are plain dict pytrees; initializers take an explicit PRNG key.
Compute dtype is bf16 by default with fp32 softmax/norm/loss accumulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis=-2, dtype=jnp.float32):
    """Truncated-normal fan-in init (as used by llama-family codebases)."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm(x, scale=None, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    if scale is not None:
        x = x * (1.0 + scale.astype(jnp.float32))
    return x.astype(dt)


def layernorm(x, scale=None, bias=None, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        x = x * scale.astype(jnp.float32)
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dt)


def make_norm_params(key, d, norm_type):
    if norm_type == "rmsnorm":
        return {"scale": jnp.zeros((d,), jnp.float32)}  # (1 + scale) convention
    if norm_type == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    if norm_type == "nonparam_ln":  # olmo: non-parametric LayerNorm
        return {}
    raise ValueError(norm_type)


def apply_norm(x, p, norm_type):
    if norm_type == "rmsnorm":
        return rmsnorm(x, p["scale"])
    if norm_type == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    if norm_type == "nonparam_ln":
        return layernorm(x)
    raise ValueError(norm_type)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x, positions, theta):
    """Apply rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    if not theta:
        return x
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (np.log(theta) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------


def make_mlp_params(key, d, f, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, f), dtype=dtype),
        "w_up": dense_init(k2, (d, f), dtype=dtype),
        "w_down": dense_init(k3, (f, d), dtype=dtype),
    }


def mlp(x, p):
    g = jax.nn.silu(x @ p["w_gate"])
    u = x @ p["w_up"]
    return (g * u) @ p["w_down"]


# ---------------------------------------------------------------------------
# LM head / loss
# ---------------------------------------------------------------------------


def lm_logits(h, head):
    """h: (B, S, D); head: (D, V) (already transposed if tied)."""
    return h @ head


def _ce_block(logits, labels):
    """fp32 cross-entropy; labels < 0 are masked out. Returns (sum, count)."""
    logits = logits.astype(jnp.float32)
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    ce = (lse - gold) * mask
    return ce.sum(), mask.sum()


def lm_loss(h, head, labels, chunk=0):
    """Cross-entropy over the vocabulary.

    ``chunk`` > 0 computes logits in sequence chunks via ``lax.map`` so the
    (B, S, V) tensor is never materialised (needed for 262k vocabularies).
    """
    if not chunk or h.shape[1] <= chunk:
        s, c = _ce_block(lm_logits(h, head), labels)
        return s / jnp.maximum(c, 1)
    B, S, _ = h.shape
    n = S // chunk
    hs = h[:, : n * chunk].reshape(B, n, chunk, -1).swapaxes(0, 1)
    ls = labels[:, : n * chunk].reshape(B, n, chunk).swapaxes(0, 1)

    def f(args):
        hb, lb = args
        return _ce_block(lm_logits(hb, head), lb)

    sums, counts = jax.lax.map(f, (hs, ls))
    tail_s = tail_c = 0.0
    if n * chunk < S:
        tail_s, tail_c = _ce_block(lm_logits(h[:, n * chunk :], head), labels[:, n * chunk :])
    return (sums.sum() + tail_s) / jnp.maximum(counts.sum() + tail_c, 1)


def softcap(x, cap):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap
