"""Attention-free SSM LM (mamba2-780m): embed → N × (norm + mamba2 mixer) → head.

Decode state is O(1): per-layer (conv_tail, ssm_state) — no KV cache, which is
what makes the long_500k cell trivial for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import mamba2
from repro.models.layers import apply_norm, embed_init, lm_loss, make_norm_params
from repro.models.transformer import _remat, head_matrix, stack_layers


def make_ssm_params(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 2 + cfg.num_layers)

    def one(k):
        return {
            "ln": make_norm_params(k, cfg.d_model, cfg.norm_type),
            "mixer": mamba2.make_mamba_params(k, cfg, dt),
        }

    params = {
        "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dt),
        "layers": stack_layers(ks[2:], one),
        "final_norm": make_norm_params(ks[1], cfg.d_model, cfg.norm_type),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(ks[1], (cfg.d_model, cfg.vocab_size), dt)
    return params


def ssm_forward(params, tokens, cfg):
    x = jnp.take(params["embed"], tokens, axis=0)

    def layer(x, lp):
        y, _tail, _st = mamba2.mamba_mixer(apply_norm(x, lp["ln"], cfg.norm_type), lp["mixer"], cfg)
        return x + y, None

    x, _ = jax.lax.scan(_remat(layer, cfg), x, params["layers"])
    return apply_norm(x, params["final_norm"], cfg.norm_type)


def ssm_train_loss(params, batch, cfg):
    h = ssm_forward(params, batch["tokens"], cfg)
    return lm_loss(h, head_matrix(params, cfg), batch["labels"], cfg.loss_chunk)


def make_ssm_cache(cfg, batch, dtype=jnp.bfloat16):
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "conv": jnp.zeros((cfg.num_layers, batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "state": jnp.zeros(
            (cfg.num_layers, batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32
        ),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def ssm_prefill(params, tokens, cfg):
    """Returns (last logits, cache) — cache is the O(1) recurrent state."""
    x = jnp.take(params["embed"], tokens, axis=0)

    def layer(x, lp):
        y, tail, st = mamba2.mamba_mixer(apply_norm(x, lp["ln"], cfg.norm_type), lp["mixer"], cfg)
        return x + y, (tail, st)

    x, (tails, states) = jax.lax.scan(layer, x, params["layers"])
    h = apply_norm(x, params["final_norm"], cfg.norm_type)
    logits = h[:, -1] @ head_matrix(params, cfg)
    B = tokens.shape[0]
    cache = {
        "conv": tails,
        "state": states,
        "pos": jnp.full((B,), tokens.shape[1], jnp.int32),
    }
    return logits, cache


def ssm_decode_step(params, cache, tokens, cfg):
    x = jnp.take(params["embed"], tokens, axis=0)  # (B, 1, D)

    def layer(x, xs):
        lp, conv_l, st_l = xs
        y, conv_l, st_l = mamba2.mamba_mixer_decode(
            apply_norm(x, lp["ln"], cfg.norm_type), lp["mixer"], cfg, conv_l, st_l
        )
        return x + y, (conv_l, st_l)

    x, (convs, states) = jax.lax.scan(layer, x, (params["layers"], cache["conv"], cache["state"]))
    h = apply_norm(x, params["final_norm"], cfg.norm_type)
    logits = h[:, -1] @ head_matrix(params, cfg)
    return logits, {"conv": convs, "state": states, "pos": cache["pos"] + 1}
