"""Production mesh factories.

Functions, not module-level constants — importing this module never touches
jax device state (device count is locked on first jax init).
"""
from __future__ import annotations

from repro.core.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi-pod adds a leading 2-pod DCN axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    return make_mesh((data, model), ("data", "model"))


def make_pp_mesh(stages: int, data: int = 1):
    """Pipeline-parallel mesh (stage axis first) for distributed/pipeline.py."""
    return make_mesh((stages, data), ("stage", "data"))
