import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST come before any other import (including repro.*):
# jax locks the device count on first init, and the production-mesh dry-run
# needs 512 placeholder host devices. Never set this globally — smoke tests
# and benches must see 1 device.

import argparse
import json
import subprocess
import sys
import time
import traceback

# TPU v5e roofline constants (target hardware; container runs CPU-only)
PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link (collective term uses 1 link/chip)

DEFAULT_JSONL = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "benchmarks", "results", "dryrun.jsonl"
)


def cell_key(arch, shape, multi_pod, tag=""):
    base = f"{arch}|{shape}|{'multi' if multi_pod else 'single'}"
    return f"{base}|{tag}" if tag else base


def _parse_override(s: str):
    k, _, v = s.partition("=")
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return k, v == "True"
    if v == "None":
        return k, None
    return k, v


def run_cell(arch: str, shape: str, multi_pod: bool, verbose: bool = True,
             overrides: dict | None = None, tag: str = "",
             dump_hlo: str | None = None) -> dict:
    import jax

    from repro.core import compat
    from repro.configs import SHAPES, get_config
    from repro.distributed.sharding import (
        cache_specs,
        input_specs_sharding,
        opt_specs,
        param_specs,
        to_named,
    )
    from repro.launch import hlo_cost
    from repro.launch.mesh import make_production_mesh
    from repro.models import build_model

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_overrides(**overrides)
    cell = SHAPES[shape]
    bundle = build_model(cfg)
    rec = {
        "key": cell_key(arch, shape, multi_pod, tag),
        "arch": arch,
        "shape": shape,
        "mesh": "multi" if multi_pod else "single",
        "chips": int(chips),
        "kind": cell.kind,
        "tag": tag,
        "overrides": dict(overrides or {}),
        "ok": False,
    }

    fn, args = bundle.step_for_cell(cell)

    if cell.kind == "train":
        params_av, opt_av, batch_av = args
        psp = param_specs(params_av, cfg, mesh)
        in_sh = (
            to_named(psp, mesh),
            to_named(opt_specs(opt_av, psp, cfg, mesh), mesh),
            to_named(input_specs_sharding(batch_av, cfg, mesh), mesh),
        )
        donate = (0, 1)
    elif cell.kind == "prefill":
        params_av, inp_av = args
        psp = param_specs(params_av, cfg, mesh)
        in_sh = (to_named(psp, mesh), to_named(input_specs_sharding(inp_av, cfg, mesh), mesh))
        donate = ()
    else:  # decode
        params_av, cache_av, tok_av = args
        psp = param_specs(params_av, cfg, mesh)
        tok_sh = input_specs_sharding({"tokens": tok_av}, cfg, mesh)["tokens"]
        in_sh = (
            to_named(psp, mesh),
            to_named(cache_specs(cache_av, cfg, mesh), mesh),
            to_named(tok_sh, mesh),
        )
        donate = (1,)

    with compat.set_mesh(mesh):
        lowered = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate).lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    if verbose:
        print(mem)  # proves it fits
    rec["memory"] = {
        k: int(getattr(mem, k, 0))
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        )
    }
    rec["memory"]["per_device_total"] = (
        rec["memory"]["argument_size_in_bytes"]
        + rec["memory"]["output_size_in_bytes"]
        + rec["memory"]["temp_size_in_bytes"]
        - rec["memory"]["alias_size_in_bytes"]
    )

    ca = compiled.cost_analysis() or {}
    if verbose:
        print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})  # FLOPs/bytes
    rec["xla_cost"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
    }

    hlo_text = compiled.as_text()
    if dump_hlo:
        with open(dump_hlo, "w") as f:
            f.write(hlo_text)
    parsed = hlo_cost.analyze(hlo_text)
    rec["parsed"] = parsed
    rec["top_collectives"] = hlo_cost.top_collectives(hlo_text, k=8)

    # roofline terms (seconds) — per-device numbers from the SPMD module
    compute_s = parsed["flops_per_device"] / PEAK_FLOPS
    memory_s = parsed["hbm_bytes_per_device"] / HBM_BW
    coll_s = parsed["wire_bytes_per_device"] / LINK_BW
    dominant = max(
        [("compute", compute_s), ("memory", memory_s), ("collective", coll_s)],
        key=lambda t: t[1],
    )[0]

    n_active = cfg.active_param_count()
    tokens = cell.global_batch * (cell.seq_len if cell.kind == "train" else 1)
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
    mf = (6 if cell.kind == "train" else 2) * n_active * tokens
    hlo_total = parsed["flops_per_device"] * chips
    rec["roofline"] = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "step_time_s": max(compute_s, memory_s, coll_s),
        "roofline_fraction": compute_s / max(compute_s, memory_s, coll_s)
        if max(compute_s, memory_s, coll_s) > 0
        else 0.0,
    }
    rec["ok"] = True
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def load_done(jsonl_path):
    done = {}
    if os.path.exists(jsonl_path):
        with open(jsonl_path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done[r["key"]] = r
                except json.JSONDecodeError:
                    pass
    return done


def append_record(jsonl_path, rec):
    os.makedirs(os.path.dirname(jsonl_path), exist_ok=True)
    with open(jsonl_path, "a") as f:
        f.write(json.dumps(rec) + "\n")


def run_all(jsonl_path, multi_pod_too=True, retry_failed=False, timeout=3000):
    from repro.configs import ASSIGNED, get_config

    done = load_done(jsonl_path)
    cells = []
    for mp in ([False, True] if multi_pod_too else [False]):
        for arch in ASSIGNED:
            for cell in get_config(arch).shape_cells():
                cells.append((arch, cell.name, mp))
    todo = [
        c
        for c in cells
        if cell_key(*c) not in done or (retry_failed and not done[cell_key(*c)].get("ok"))
    ]
    print(f"dry-run sweep: {len(cells)} cells, {len(cells)-len(todo)} done, {len(todo)} to go")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", ".."), env.get("PYTHONPATH", "")]
    )
    for i, (arch, shape, mp) in enumerate(todo):
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch, "--shape", shape,
               "--jsonl", jsonl_path]
        if mp:
            cmd.append("--multi-pod")
        print(f"[{i+1}/{len(todo)}] {cell_key(arch, shape, mp)}", flush=True)
        try:
            r = subprocess.run(cmd, env=env, timeout=timeout, capture_output=True, text=True)
            if r.returncode != 0:
                append_record(
                    jsonl_path,
                    {
                        "key": cell_key(arch, shape, mp), "arch": arch, "shape": shape,
                        "mesh": "multi" if mp else "single", "ok": False,
                        "error": (r.stderr or "")[-2000:],
                    },
                )
                print(f"  FAILED rc={r.returncode}: {(r.stderr or '')[-300:]}", flush=True)
        except subprocess.TimeoutExpired:
            append_record(
                jsonl_path,
                {
                    "key": cell_key(arch, shape, mp), "arch": arch, "shape": shape,
                    "mesh": "multi" if mp else "single", "ok": False, "error": "timeout",
                },
            )
            print("  TIMEOUT", flush=True)


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run: lower+compile+roofline")
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--retry-failed", action="store_true")
    ap.add_argument("--jsonl", default=os.path.normpath(DEFAULT_JSONL))
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (perf iteration)")
    ap.add_argument("--tag", default="", help="label for this perf variant")
    ap.add_argument("--dump-hlo", default=None)
    args = ap.parse_args()

    if args.all:
        run_all(args.jsonl, multi_pod_too=not args.single_pod_only,
                retry_failed=args.retry_failed)
        return

    overrides = dict(_parse_override(s) for s in args.override)
    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod,
                       overrides=overrides, tag=args.tag, dump_hlo=args.dump_hlo)
    except Exception:
        rec = {
            "key": cell_key(args.arch, args.shape, args.multi_pod, args.tag),
            "arch": args.arch, "shape": args.shape,
            "mesh": "multi" if args.multi_pod else "single", "tag": args.tag,
            "ok": False, "error": traceback.format_exc()[-2000:],
        }
        append_record(args.jsonl, rec)
        print(json.dumps({k: rec[k] for k in ("key", "ok")}, indent=2))
        raise
    append_record(args.jsonl, rec)
    print(json.dumps(rec, indent=2, default=str))


if __name__ == "__main__":
    main()
