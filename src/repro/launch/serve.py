"""Serving driver: ``python -m repro.launch.serve --arch <id> --requests N``.

Spins up the continuous-batching engine on a (reduced) model and runs a
synthetic request stream — the minimal "serve a small model with batched
requests" end-to-end path.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ignis-tiny")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--reduced", action="store_true")
    a = ap.parse_args()

    cfg = get_config(a.arch)
    if a.reduced:
        cfg = cfg.reduced()
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    eng = ServeEngine(bundle, params, slots=a.slots, cache_len=a.cache_len)

    rng = np.random.default_rng(0)
    for r in range(a.requests):
        plen = int(rng.integers(4, 16))
        eng.submit(Request(r, rng.integers(0, cfg.vocab_size, plen, dtype=np.int32),
                           max_new_tokens=a.max_new))
    t0 = time.time()
    done = eng.run_to_completion()
    dt = time.time() - t0
    toks = sum(len(r.tokens) for r in done)
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/max(dt,1e-9):.1f} tok/s)")


if __name__ == "__main__":
    main()
