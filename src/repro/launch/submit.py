"""ignis-submit analogue (paper §3.7, Fig. 5).

  python -m repro.launch.submit [--name X] [--properties k=v ...] \
         [--attach] <image> <driver.py> [driver args...]

The "resource manager" is simulated: the job spec (image, properties, mesh
request) is written to <jobdir>/job.json, then the driver runs in a fresh
process with IGNIS_* env carrying the properties — unattached by default
(paper: ignis-submit launches and exits), --attach streams output.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser("ignis-submit")
    ap.add_argument("--name", default=None)
    ap.add_argument("--properties", action="append", default=[])
    ap.add_argument("--attach", action="store_true")
    ap.add_argument("--jobs-dir", default="/tmp/ignis-jobs")
    ap.add_argument("image")
    ap.add_argument("driver")
    ap.add_argument("driver_args", nargs=argparse.REMAINDER)
    a = ap.parse_args(argv)

    props = {}
    for kv in a.properties:
        k, _, v = kv.partition("=")
        props[k] = v
    name = a.name or f"job-{int(time.time())}"
    jobdir = os.path.join(a.jobs_dir, name)
    os.makedirs(jobdir, exist_ok=True)
    spec = {"name": name, "image": a.image, "driver": a.driver,
            "args": a.driver_args, "properties": props}
    with open(os.path.join(jobdir, "job.json"), "w") as f:
        json.dump(spec, f, indent=2)

    env = dict(os.environ)
    for k, v in props.items():
        env["IGNIS_" + k.replace(".", "_").upper()] = v
    env["IGNIS_JOB_NAME"] = name
    cmd = [sys.executable, a.driver, *a.driver_args]
    log = open(os.path.join(jobdir, "driver.log"), "w")
    if a.attach:
        rc = subprocess.call(cmd, env=env, stdout=sys.stdout, stderr=sys.stderr)
        print(f"[ignis-submit] job {name} finished rc={rc}")
        return rc
    p = subprocess.Popen(cmd, env=env, stdout=log, stderr=subprocess.STDOUT,
                         start_new_session=True)
    print(f"[ignis-submit] launched job {name} (pid {p.pid}, log {log.name})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
