"""Post-partitioning HLO cost model: FLOPs, HBM traffic, collective bytes.

XLA's ``compiled.cost_analysis()`` visits while-loop bodies ONCE — a
scan-over-layers model under-reports by ~num_layers×. This parser walks the
optimized HLO text, memoizes per-computation costs, multiplies ``while``
bodies by their trip count (recovered from the loop-condition compare
constant), and attributes:

  flops      — 2·M·N·K for dots (contracting dims parsed from the attr),
               1/elem for everything else (negligible next to the dots)
  hbm_bytes  — per top-level op: operand bytes + result bytes (fusion nodes
               count their boundary buffers only — internals stay in VMEM)
  comm       — per collective kind: operand bytes (the §Roofline definition)
  wire_bytes — algorithm-modelled bytes on the wire per device:
               all-reduce 2·(n-1)/n · b ; all-gather / reduce-scatter /
               all-to-all (n-1)/n · b ; collective-permute 1·b

The module is partitioned (SPMD), so every number is PER DEVICE.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    # the fp8 family has grown spellings across XLA releases; all are 1 byte
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "f8e4m3fnuz": 1, "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e8m0fnu": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
    # sub-byte types round up: the parser prices HBM touches, and XLA packs
    # them per-buffer, so 1 byte is the honest ceiling at this granularity
    "s4": 1, "u4": 1, "s2": 1, "u2": 1, "f4e2m1fn": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_NAME_EQ_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^([\w\-]+)\((.*)$", re.DOTALL)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _split_instr(line: str):
    """'%x = TYPE opcode(operands), attrs' → (name, type_str, opcode, rest).

    TYPE may be a tuple type with nested parens and /*index=N*/ comments.
    Returns None if the line is not an instruction.
    """
    m = _NAME_EQ_RE.match(line)
    if not m:
        return None
    name, rest = m.group(1), m.group(2)
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str, remainder = rest[: i + 1], rest[i + 1 :].strip()
    else:
        type_str, _, remainder = rest.partition(" ")
    m2 = _OPCODE_RE.match(remainder)
    if not m2:
        return None
    return name, type_str, m2.group(1), m2.group(2)

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str  # operand list + attrs (raw tail of the line)
    operands: list = field(default_factory=list)
    is_root: bool = False


@dataclass
class Cost:
    flops: float = 0.0
    hbm: float = 0.0
    comm: dict = field(default_factory=dict)
    wire: float = 0.0
    unknown_trips: int = 0

    def __iadd__(self, o):
        self.flops += o.flops
        self.hbm += o.hbm
        self.wire += o.wire
        self.unknown_trips += o.unknown_trips
        for k, v in o.comm.items():
            self.comm[k] = self.comm.get(k, 0.0) + v
        return self

    def scaled(self, f):
        return Cost(
            self.flops * f, self.hbm * f, {k: v * f for k, v in self.comm.items()},
            self.wire * f, self.unknown_trips,
        )


_OPERAND_NAME_RE = re.compile(r"%[\w.\-]+")
_CALLS_RE = re.compile(
    r"(?:calls|body|condition|to_apply|true_computation|false_computation)=(%?[\w.\-]+)"
)
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")

# ops that move no HBM bytes of their own
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "copy-start", "copy-done",
}

# ops that move bytes but do no arithmetic — billing these 1 flop/elem (the
# generic fallback) triple-counted e.g. a bf16 add lowered as
# convert→add→convert; they cost HBM traffic only
_MOVE_OPS = {
    "convert", "broadcast", "reshape", "transpose", "slice", "concatenate",
    "pad", "gather", "copy", "reverse", "reduce-precision",
}


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[Instr]] = {}
        self.entry: str | None = None
        self._parse(text)
        self._cost_cache: dict[str, Cost] = {}

    # ---- parsing -----------------------------------------------------------
    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            if not line.strip() or line.startswith(("HloModule", "//", "#")):
                continue
            if not line.startswith(" ") and line.rstrip().endswith("{") and "->" in line:
                s = line.strip()
                is_entry = s.startswith("ENTRY")
                if is_entry:
                    s = s[len("ENTRY") :].strip()
                cur = s.split()[0].split("(")[0].lstrip("%")
                self.computations[cur] = []
                if is_entry:
                    self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            parsed = _split_instr(line)
            if parsed is None:
                continue
            name, type_str, opcode, rest = parsed
            ins = Instr(name, type_str, opcode, rest, is_root="ROOT" in line[:12])
            # operand names = %refs before any attr section in rest
            head = rest.split("),", 1)[0]
            ins.operands = [x.lstrip("%") for x in _OPERAND_NAME_RE.findall(head)]
            self.computations[cur].append(ins)
        if self.entry is None and self.computations:
            # entry is usually last
            self.entry = list(self.computations)[-1]

    def _symbols(self, comp: str) -> dict[str, Instr]:
        return {i.name: i for i in self.computations.get(comp, [])}

    def _root_of(self, comp: str):
        instrs = self.computations.get(comp, [])
        for i in instrs:
            if i.is_root:
                return i
        return instrs[-1] if instrs else None

    # ---- trip counts -------------------------------------------------------
    def _trip_count(self, cond_comp: str, body_comp: str) -> int | None:
        """Loop trip count from the condition's `compare(ind, const), LT`."""
        syms = self._symbols(cond_comp)
        for ins in self.computations.get(cond_comp, []):
            if ins.opcode != "compare":
                continue
            for op in ins.operands:
                ref = syms.get(op)
                if ref is not None and ref.opcode == "constant":
                    m = _CONST_INT_RE.search(ref.type_str + " constant(" + ref.rest)
                    m2 = re.search(r"constant\((\d+)\)", "constant(" + ref.rest)
                    if m2:
                        return int(m2.group(1))
                    if m:
                        return int(m.group(1))
        return None

    # ---- group size --------------------------------------------------------
    @staticmethod
    def _group_size(rest: str) -> int:
        m = _GROUPS_IOTA_RE.search(rest)
        if m:
            return max(int(m.group(2)), 1)
        m = _GROUPS_LIST_RE.search(rest)
        if m:
            return max(len(m.group(1).split(",")), 1)
        return 1

    # ---- dot flops ---------------------------------------------------------
    def _dot_flops(self, ins: Instr, syms: dict) -> float:
        out_elems = shape_elems(ins.type_str)
        k = 1
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
        if m and ins.operands:
            lhs = syms.get(ins.operands[0])
            if lhs is not None:
                dims_m = _SHAPE_RE.search(lhs.type_str)
                if dims_m:
                    dims = [int(d) for d in dims_m.group(2).split(",") if d]
                    for ci in m.group(1).split(","):
                        if ci and int(ci) < len(dims):
                            k *= dims[int(ci)]
        return 2.0 * out_elems * max(k, 1)

    # ---- per-computation cost ----------------------------------------------
    def computation_cost(self, comp: str) -> Cost:
        if comp in self._cost_cache:
            return self._cost_cache[comp]
        self._cost_cache[comp] = Cost()  # break recursion
        total = Cost()
        syms = self._symbols(comp)
        for ins in self.computations.get(comp, []):
            total += self._instr_cost(ins, syms)
        self._cost_cache[comp] = total
        return total

    def _operand_bytes(self, ins: Instr, syms: dict) -> float:
        b = 0
        for op in ins.operands:
            ref = syms.get(op)
            if ref is not None:
                b += shape_bytes(ref.type_str)
        return b

    def _instr_cost(self, ins: Instr, syms: dict) -> Cost:
        op = ins.opcode
        c = Cost()
        if op in _FREE_OPS:
            return c
        called = _CALLS_RE.findall(ins.rest)

        if op == "while":
            body = cond = None
            mb = re.search(r"body=(%?[\w.\-]+)", ins.rest)
            mc = re.search(r"condition=(%?[\w.\-]+)", ins.rest)
            if mb:
                body = mb.group(1).lstrip("%")
            if mc:
                cond = mc.group(1).lstrip("%")
            inner = Cost()
            if body:
                inner += self.computation_cost(body)
            if cond:
                inner += self.computation_cost(cond)
            # primary source: XLA records the analysed trip count on the op
            trip = None
            mt = re.search(r'known_trip_count[^}]*"n"\s*:\s*"(\d+)"', ins.rest)
            if mt:
                trip = int(mt.group(1))
            if trip is None and cond:
                trip = self._trip_count(cond, body)
            if trip is None:
                c += inner
                c.unknown_trips += 1
            else:
                c += inner.scaled(trip)
            return c

        if op == "conditional":
            names = list(called)
            mb = _BRANCHES_RE.search(ins.rest)
            if mb:
                names += [x.strip() for x in mb.group(1).split(",") if x.strip()]
            branches = [self.computation_cost(x.lstrip("%")) for x in names]
            if branches:
                c += max(branches, key=lambda b: b.flops + b.hbm)
            return c

        if op in ("fusion", "call", "async-start"):
            inner = Cost()
            for comp in called:
                inner += self.computation_cost(comp.lstrip("%"))
            c.flops += inner.flops
            c.wire += inner.wire
            c.unknown_trips += inner.unknown_trips
            for k, v in inner.comm.items():
                c.comm[k] = c.comm.get(k, 0.0) + v
            if op != "fusion":
                c.hbm += inner.hbm  # real calls execute their bodies
                return c
            # fusion: internals live in registers/VMEM — only boundary buffers
            # move. If the fused root is a dynamic-update-slice the big buffer
            # is updated in place: only the slice moves.
            root = self._root_of(called[0].lstrip("%")) if called else None
            if root is not None and root.opcode == "dynamic-update-slice":
                fsyms = self._symbols(called[0].lstrip("%"))
                upd = fsyms.get(root.operands[1]) if len(root.operands) > 1 else None
                slice_b = shape_bytes(upd.type_str) if upd is not None else 0
                ops_b = [shape_bytes(syms[o].type_str) for o in ins.operands if o in syms]
                big = max(ops_b) if ops_b else 0
                c.hbm += sum(ops_b) - big + 2 * slice_b
            else:
                c.hbm += shape_bytes(ins.type_str) + self._operand_bytes(ins, syms)
            return c

        base = op[:-6] if op.endswith("-start") else op
        if base in COLLECTIVES:
            if op.endswith("-done"):
                return c
            b = self._operand_bytes(ins, syms)
            if b == 0:  # e.g. operands not in scope table
                b = shape_bytes(ins.type_str)
            n = self._group_size(ins.rest)
            c.comm[base] = c.comm.get(base, 0.0) + b
            if base == "all-reduce":
                c.wire += 2.0 * b * (n - 1) / max(n, 1)
            elif base in ("all-gather",):
                c.wire += b * (n - 1)  # operand is the shard
            elif base in ("reduce-scatter", "all-to-all"):
                c.wire += b * (n - 1) / max(n, 1)
            else:  # collective-permute
                c.wire += b
            c.hbm += b + shape_bytes(ins.type_str)
            return c

        if op == "dynamic-update-slice":  # in-place: only the slice moves
            upd = syms.get(ins.operands[1]) if len(ins.operands) > 1 else None
            c.hbm += 2 * (shape_bytes(upd.type_str) if upd is not None else 0)
            return c
        if op == "dynamic-slice":
            c.hbm += 2 * shape_bytes(ins.type_str)
            return c

        # generic op
        rb = shape_bytes(ins.type_str)
        c.hbm += rb + self._operand_bytes(ins, syms)
        if op == "dot":
            c.flops += self._dot_flops(ins, syms)
        elif op == "convolution":
            c.flops += 2.0 * shape_elems(ins.type_str)  # rough (none expected)
        elif op not in _MOVE_OPS:
            c.flops += shape_elems(ins.type_str)  # 1 flop/elem elementwise-ish
        return c

    def entry_cost(self) -> Cost:
        return self.computation_cost(self.entry)


def analyze(hlo_text: str) -> dict:
    mod = HloModule(hlo_text)
    c = mod.entry_cost()
    return {
        "flops_per_device": c.flops,
        "hbm_bytes_per_device": c.hbm,
        "comm_bytes_per_device": dict(c.comm),
        "comm_bytes_total_per_device": sum(c.comm.values()),
        "wire_bytes_per_device": c.wire,
        "unknown_trip_loops": c.unknown_trips,
        "n_computations": len(mod.computations),
    }


def top_collectives(hlo_text: str, k: int = 15) -> list[dict]:
    """Diagnostic: the k largest collectives, trip-multiplied, with the loop
    nest they live in — the §Perf 'where is the wire time going' view."""
    mod = HloModule(hlo_text)
    # trip multiplier per computation (1 for entry, × for while bodies)
    mult: dict[str, float] = {}

    def fill(comp: str, m: float):
        if comp in mult and mult[comp] >= m:
            return
        mult[comp] = m
        for ins in mod.computations.get(comp, []):
            called = _CALLS_RE.findall(ins.rest)
            if ins.opcode == "while":
                trip = None
                mt = re.search(r'known_trip_count[^}]*"n"\s*:\s*"(\d+)"', ins.rest)
                if mt:
                    trip = int(mt.group(1))
                for c2 in called:
                    fill(c2.lstrip("%"), m * (trip or 1))
            else:
                for c2 in called:
                    fill(c2.lstrip("%"), m)

    fill(mod.entry, 1.0)
    out = []
    for comp, instrs in mod.computations.items():
        m = mult.get(comp, 0.0)
        if m == 0:
            continue
        syms = {i.name: i for i in instrs}
        for ins in instrs:
            base = ins.opcode[:-6] if ins.opcode.endswith("-start") else ins.opcode
            if base not in COLLECTIVES or ins.opcode.endswith("-done"):
                continue
            b = sum(shape_bytes(syms[o].type_str) for o in ins.operands if o in syms)
            if b == 0:
                b = shape_bytes(ins.type_str)
            meta = re.search(r'op_name="([^"]*)"', ins.rest)
            out.append({
                "op": base,
                "bytes_each": b,
                "trips": m,
                "bytes_total": b * m,
                "comp": comp[:60],
                "src": (meta.group(1)[:110] if meta else ""),
            })
    out.sort(key=lambda d: -d["bytes_total"])
    return out[:k]
