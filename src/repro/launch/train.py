"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Production loop: sharded params/optimizer (preset rules), optional ZeRO-1
and gradient compression, double-buffered data feed, async checkpointing,
restart-from-latest (fault tolerance), per-step metrics.

On the CPU container this trains reduced/paper-app configs for real; on a
TPU slice the same driver scales via the same sharding rules (dry-run-proven
at 256/512 chips).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.configs import get_config
from repro.data.pipeline import TrainPipeline, batches_from_rows, pack_sequences
from repro.data.synthetic import synthetic_batches, synthetic_corpus
from repro.distributed.compression import compressed_grads, init_ef_state
from repro.distributed.sharding import (
    input_specs_sharding,
    lead_axes,
    opt_specs,
    param_specs,
    to_named,
)
from repro.models import build_model
from repro.optim.schedule import warmup_cosine


def make_train_step(bundle, cfg, *, compression="none", peak_lr=3e-4,
                    warmup=20, total=1000):
    from repro.optim.adamw import adamw_update

    def step(params, opt, ef, batch):
        loss, grads = jax.value_and_grad(bundle.train_loss)(params, batch)
        if compression != "none":
            grads, ef = compressed_grads(grads, ef, compression)
        lr = warmup_cosine(opt["step"], peak_lr, warmup, total)
        params, opt = adamw_update(grads, opt, params, lr=lr)
        return params, opt, ef, loss

    return step


def train(arch="ignis-100m", steps=100, batch=8, seq_len=256, ckpt_dir=None,
          ckpt_every=50, compression="none", data="synthetic", reduced=False,
          mesh=None, log_every=10, resume=True, seed=0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    bundle = build_model(cfg)
    key = jax.random.PRNGKey(seed)

    if mesh is None:
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh(len(jax.devices()), 1)

    params = bundle.init(key)
    opt = bundle.init_opt(params)
    ef = init_ef_state(params) if compression != "none" else None

    psp = param_specs(params, cfg, mesh)
    params = jax.device_put(params, to_named(psp, mesh))
    opt = jax.device_put(opt, to_named(opt_specs(opt, psp, cfg, mesh), mesh))

    start = 0
    ckptr = None
    if ckpt_dir:
        ckptr = AsyncCheckpointer(ckpt_dir)
        last = latest_step(ckpt_dir) if resume else None
        if last is not None:
            state = restore(ckpt_dir, last, {"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
            start = last
            print(f"[train] resumed from step {last}")

    step_fn = jax.jit(
        make_train_step(bundle, cfg, compression=compression, total=steps),
        donate_argnums=(0, 1, 2),
    )

    if data == "synthetic":
        it = synthetic_batches(cfg.vocab_size, batch, seq_len, seed)
    else:  # the hybrid path: dataflow-prepared corpus
        from repro.data.pipeline import byte_tokenize

        docs = [byte_tokenize(d) for d in synthetic_corpus(seed=seed)]
        rows = pack_sequences(docs, seq_len)
        it = batches_from_rows(rows, batch, seed=seed)

    from jax.sharding import NamedSharding, PartitionSpec as P

    lead = lead_axes(cfg, mesh, batch, "train")
    bsh = NamedSharding(mesh, P(lead, None)) if lead else NamedSharding(mesh, P())
    pipe = TrainPipeline(it, sharding=bsh)

    losses = []
    t0 = time.time()
    for i, hb in enumerate(pipe):
        s = start + i
        if s >= steps:
            break
        batch_dev = {k: jnp.asarray(v) for k, v in hb.items()}
        params, opt, ef, loss = step_fn(params, opt, ef, batch_dev)
        if (s + 1) % log_every == 0 or s == steps - 1:
            l = float(jax.device_get(loss))
            losses.append((s + 1, l))
            dt = time.time() - t0
            print(f"[train] step {s+1}/{steps} loss={l:.4f} ({dt:.1f}s)", flush=True)
        if ckptr and (s + 1) % ckpt_every == 0:
            ckptr.save(s + 1, {"params": params, "opt": opt})
    pipe.close()
    if ckptr:
        ckptr.save(steps, {"params": params, "opt": opt})
        ckptr.wait()
    return params, opt, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ignis-100m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compression", default="none", choices=["none", "int8", "topk"])
    ap.add_argument("--data", default="synthetic", choices=["synthetic", "corpus"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    _, _, losses = train(
        a.arch, a.steps, a.batch, a.seq_len, a.ckpt_dir, a.ckpt_every,
        a.compression, a.data, a.reduced, seed=a.seed,
    )
    print(json.dumps({"final_loss": losses[-1][1] if losses else None}))


if __name__ == "__main__":
    main()
