"""Sharding-aware checkpoint / restart (fault tolerance, paper §3.5 adapted).

Layout: <dir>/step_<N>/  manifest.json + one .npy per leaf (path-keyed).
The manifest records logical shapes/dtypes + content hashes, so restore can
(1) verify integrity, (2) place leaves onto ANY mesh/sharding — elastic
scaling: a checkpoint written at DP=16 restores at DP=4 or 64 (the MPI-3
dynamic-process-join analogue; see distributed/elastic.py).

AsyncCheckpointer overlaps serialization with the next train step (a
background thread owns the host copies — the device never waits on disk).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


def save(ckpt_dir: str, step: int, tree: Any, keep: int = 3) -> str:
    """Blocking save. Returns the step directory."""
    flat, _ = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    return _write(ckpt_dir, step, host, keep)


def _wire_view(v: np.ndarray) -> np.ndarray:
    """npy-safe view: numpy can't serialise ml_dtypes (bf16/f8) natively."""
    if v.dtype.kind == "V" or str(v.dtype) in ("bfloat16",) or "float8" in str(v.dtype):
        return v.view(np.uint16 if v.dtype.itemsize == 2 else np.uint8)
    try:
        np.dtype(str(v.dtype))
        return v
    except TypeError:
        return v.view(np.uint16 if v.dtype.itemsize == 2 else np.uint8)


def _unwire(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    if str(arr.dtype) == dtype_str:
        return arr
    import jax.numpy as jnp

    return arr.view(jnp.dtype(dtype_str))


def _write(ckpt_dir: str, step: int, host: dict, keep: int) -> str:
    sdir = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = sdir + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": {}}
    for i, (k, v) in enumerate(sorted(host.items())):
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), _wire_view(v))
        with open(os.path.join(tmp, fname), "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()[:16]
        manifest["leaves"][k] = {
            "file": fname,
            "shape": list(v.shape),
            "dtype": str(v.dtype),
            "sha256_16": digest,
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(sdir):
        shutil.rmtree(sdir)
    os.rename(tmp, sdir)  # atomic publish
    _gc(ckpt_dir, keep)
    return sdir


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def restore(ckpt_dir: str, step: int, target: Any, shardings: Any = None,
            verify: bool = True) -> Any:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching pytree of
    NamedShardings — THIS is where elastic re-placement happens."""
    sdir = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(sdir, "manifest.json")) as f:
        manifest = json.load(f)
    flat_t, treedef = _flatten(target)
    flat_s, _ = _flatten(shardings) if shardings is not None else ({}, None)
    out = {}
    for k, leaf in flat_t.items():
        meta = manifest["leaves"][k]
        path = os.path.join(sdir, meta["file"])
        if verify:
            with open(path, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()[:16]
            if digest != meta["sha256_16"]:
                raise IOError(f"checkpoint corruption in leaf {k!r}")
        arr = _unwire(np.load(path), meta["dtype"])
        expect = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != expect:
            raise ValueError(f"leaf {k!r}: checkpoint {arr.shape} != target {expect}")
        if k in flat_s and flat_s[k] is not None:
            out[k] = jax.device_put(arr, flat_s[k])
        else:
            out[k] = jax.device_put(arr)
    ordered = [out[k] for k in flat_t]
    return jax.tree_util.tree_unflatten(treedef, ordered)


class AsyncCheckpointer:
    """Background-thread writer: device→host copy happens on ``save`` (cheap,
    async dispatch), serialization + fsync happen off-thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, step: int, tree: Any):
        self.wait()
        flat, _ = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

        def run():
            self.last_path = _write(self.dir, step, host, self.keep)

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
