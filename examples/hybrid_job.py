"""One scheduled job across programming models (paper §3.2, Figs. 2–3, 12).

The quickstart runs the hybrid wordcount eagerly, one action at a time.
This driver submits TWO independent branches into a single ``IJob``:

  * branch A (dataflow → native → dataflow): tokens resharded to an SPMD
    worker via importData, counted by a native wordcount app, collected;
  * branch B (pure dataflow): line-length histogram on the original worker.

The scheduler cuts each lineage at task boundaries (stage / native /
reshard / action), deduplicates shared subgraphs, and overlaps the
branches across the two workers — ``job.explain()`` shows the scheduled
cross-worker DAG (docs/driver.md).

Run:  PYTHONPATH=src python examples/hybrid_job.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Ignis, ICluster, IProperties, IWorker
from repro.core.native import ignis_export
from repro.data.synthetic import synthetic_corpus


@ignis_export("wordcount_spmd")
def wordcount_spmd(ctx, data=None, valid=None):
    vocab = int(ctx.var("vocab"))
    counts = jnp.bincount(jnp.where(valid, data, vocab), length=vocab + 1)[:-1]
    keys = jnp.arange(vocab, dtype=jnp.int32)
    return {"key": keys, "value": counts}, counts > 0


def main():
    Ignis.start()
    props = IProperties()
    props["ignis.executor.instances"] = str(len(jax.devices()))
    cluster = ICluster(props)
    dataflow = IWorker(cluster, "python")
    spmd = IWorker(cluster, "spmd")

    corpus_path = "/tmp/ignis_hybrid_job.txt"
    lines = synthetic_corpus(60, 30)
    with open(corpus_path, "w") as f:
        f.write("\n".join(lines))

    # branch A: dataflow tokens → importData reshard → native SPMD wordcount
    words = dataflow.text_file(corpus_path, as_tokens=True)
    vocab = len(dataflow._text_vocab)
    counts = spmd.call("wordcount_spmd", spmd.import_data(words), vocab=vocab)

    # branch B: independent dataflow histogram of line lengths
    lens = dataflow.text_file(corpus_path).map(lambda r: r[1] % 16)

    job = Ignis.job("hybrid-wordcount")
    f_counts = counts.collect_async(job=job)
    f_hist = lens.count_by_value_async(job=job)
    f_tokens = words.count_async(job=job)

    rows, hist, n_tokens = f_counts.result(), f_hist.result(), f_tokens.result()
    total = sum(int(np.asarray(r["value"])) for r in rows)
    print(job.explain())
    st = job.stats()
    print(
        f"job stats: {st['tasks']} tasks "
        f"({st['native']} native, {st['reshard']} reshard, {st['stage']} stage, "
        f"{st['actions']} actions) on workers {st['workers']}"
    )
    print(f"wordcount: {vocab} distinct words, {total} total (tokens={n_tokens})")
    print(f"line-length histogram buckets: {len(hist)}")
    assert total == n_tokens
    assert st["failed"] == 0 and st["native"] == 1 and st["reshard"] >= 1
    Ignis.stop()
    print("OK")


if __name__ == "__main__":
    main()
