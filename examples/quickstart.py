"""Quickstart — the paper's hybrid Wordcount (Fig. 12).

Big-Data tasks prepare the data on the dataflow worker; the
compute-intensive task is a native SPMD program invoked with worker.call;
results come back as an IDataFrame and are saved as json — all on one
fabric, no host round-trips between stages.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import Ignis, ICluster, IProperties, IWorker
from repro.core.native import ignis_export
from repro.data.synthetic import synthetic_corpus


# --- the "MPI" part: a native SPMD histogram (the paper's wordcount lib) ---
@ignis_export("wordcount")
def wordcount(ctx, data=None, valid=None):
    vocab = int(ctx.var("vocab"))
    counts = jnp.bincount(jnp.where(valid, data, vocab), length=vocab + 1)[:-1]
    keys = jnp.arange(vocab, dtype=jnp.int32)
    return {"key": keys, "value": counts}, counts > 0


def main():
    Ignis.start()
    props = IProperties()
    props["ignis.executor.instances"] = str(len(jax.devices()))
    cluster = ICluster(props)
    worker = IWorker(cluster, "python")

    # Task 1+2 (dataflow): corpus → tokens
    corpus_path = "/tmp/ignis_quickstart.txt"
    with open(corpus_path, "w") as f:
        f.write("\n".join(synthetic_corpus(50, 40)))
    words = worker.text_file(corpus_path, as_tokens=True)
    vocab = len(worker._text_vocab)

    # Task 3 (native SPMD): wordcount over the shared fabric
    worker.load_library("repro.apps.minebench")  # (library loading demo)
    counts = worker.call("wordcount", words, vocab=vocab)

    # Task 4 (dataflow): save as json
    out = "/tmp/ignis_quickstart_counts.json"
    counts.save_as_json_file(out)

    total = sum(r["value"] for r in __import__("json").load(open(out)))
    n_tokens = words.count()
    print(f"wordcount: {vocab} distinct words, {total} total (tokens={n_tokens})")
    assert total == n_tokens
    Ignis.stop()
    print("OK")


if __name__ == "__main__":
    main()
