"""Running native HPC (SPMD/"MPI") applications inside the framework —
the paper's §5 (LULESH example, Figs. 9–11).

The stencil and CG proxy apps are plain collective programs; the framework
integration is the @ignis_export wrapper + context argument parsing (the
paper's +17…75 SLOC). This driver runs both through worker.call and checks
the result matches executing them natively (paper's ≤2% overhead claim is
measured in benchmarks/bench_hpc_native.py).

Run:  PYTHONPATH=src python examples/native_hpc_app.py
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import Ignis, ICluster, IProperties, IWorker
from repro.apps.stencil import cg_native, laplacian_matvec_ref, stencil_native


def main():
    Ignis.start()
    cluster = ICluster(IProperties())
    worker = IWorker(cluster, "cpp")  # the paper's C++ worker
    worker.load_library("repro.apps.stencil")

    mesh, axis = worker.context.comm()

    # ---- stencil (LULESH/miniAMR analogue) --------------------------------
    grid = np.random.default_rng(0).normal(size=(32, 16)).astype(np.float32)
    out_fw = worker.call("stencil_app", worker.parallelize(grid), iters=8)
    got = np.stack([np.asarray(r) for r in out_fw.collect()])
    native = np.asarray(stencil_native(mesh, axis, jnp.asarray(grid), 8))
    print("stencil framework==native:", np.allclose(got, native, atol=1e-6))
    assert np.allclose(got, native, atol=1e-6)

    # ---- CG solver (AMG analogue) ------------------------------------------
    b = np.random.default_rng(1).normal(size=128).astype(np.float32)
    x_df = worker.call("cg_app", worker.parallelize(b), iters=200)
    x = jnp.asarray([np.asarray(r) for r in x_df.collect()])
    res = float(jnp.abs(laplacian_matvec_ref(x) - jnp.asarray(b)).max())
    print(f"CG residual: {res:.2e}")
    assert res < 1e-3

    Ignis.stop()
    print("OK")


if __name__ == "__main__":
    main()
