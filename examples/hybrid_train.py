"""End-to-end hybrid driver: the paper's pattern at training scale.

Phase 1 (Big-Data, dataflow worker): corpus ingestion — tokenize, length-
filter, dedup (distinct), pack — all as IDataFrame ops on the fabric.
Phase 2 (HPC, SPMD): train the ~100M-param `ignis-100m` LM on the packed
rows with the production train loop (sharded params, checkpointing,
restart). One job, one mesh, two programming models.

Run:  PYTHONPATH=src python examples/hybrid_train.py [--steps 200]
(CPU-friendly default sizes; --full uses the true 100M config.)
"""
import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import Ignis, ICluster, IProperties, IWorker
from repro.data.pipeline import byte_tokenize, pack_sequences
from repro.data.synthetic import synthetic_corpus
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="train the full 100M config (slow on CPU)")
    a = ap.parse_args()

    Ignis.start()
    cluster = ICluster(IProperties())
    worker = IWorker(cluster, "python")

    # ---- Phase 1: dataflow corpus preparation -----------------------------
    docs = synthetic_corpus(n_docs=300, words_per_doc=100)
    # rows: (doc_id, length) — filter short docs, dedup identical lengths per
    # bucket via the dataflow ops (illustrative of the API on the fabric)
    lengths = worker.parallelize(
        np.asarray([[i, len(d)] for i, d in enumerate(docs)], np.int32)
    )
    kept = lengths.filter(lambda r: r[1] >= 200).cache()
    ids = sorted(int(np.asarray(r[0])) for r in kept.collect())
    print(f"[hybrid] dataflow filter kept {len(ids)}/{len(docs)} docs")

    toks = [byte_tokenize(docs[i]) for i in ids]
    rows = pack_sequences(toks, a.seq_len)
    np.save("/tmp/ignis_hybrid_rows.npy", rows)
    print(f"[hybrid] packed {rows.shape[0]} training rows of len {rows.shape[1]}")

    # ---- Phase 2: SPMD training -------------------------------------------
    arch = "ignis-100m" if a.full else "ignis-tiny"
    params, opt, losses = train(
        arch=arch, steps=a.steps, batch=a.batch, seq_len=a.seq_len,
        ckpt_dir="/tmp/ignis_hybrid_ckpt", ckpt_every=max(a.steps // 2, 1),
        data="corpus",
    )
    first, last = losses[0][1], losses[-1][1]
    print(f"[hybrid] loss {first:.3f} → {last:.3f}")
    assert last < first, "training did not reduce loss"
    Ignis.stop()
    print("OK")


if __name__ == "__main__":
    main()
