"""Transitive Closure — the paper's driver example (Fig. 6), including the
two-worker (multi-programming-model) structure with importData between them.

Run:  PYTHONPATH=src python examples/transitive_closure.py
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core import Ignis, ICluster, IProperties, IWorker
from repro.apps.graph import make_graph, tc_reference


def main():
    # Initialization of the framework (Fig. 6 line 6)
    Ignis.start()
    prop = IProperties()
    prop["ignis.executor.image"] = "ignishpc/full"
    prop["ignis.executor.instances"] = str(len(jax.devices()))
    prop["ignis.executor.cores"] = "1"
    cluster = ICluster(prop)

    # Task 1: a Python worker tokenizes the edge list (paper Fig. 6 stores
    # them reversed and un-reverses in the joined map; we key by source)
    worker_a = IWorker(cluster, "python")
    edges_np = make_graph(16, 36, seed=7)
    tc = worker_a.parallelize(edges_np).map(lambda e: (e[0], e[1]))
    edges = tc.map(lambda e: {"key": e[0], "value": e[1]}).cache()

    # Task 2: a second worker (the paper's C++ worker) receives the data
    # through the inter-worker communicator (importData, paper Fig. 4)
    worker_b = IWorker(cluster, "cpp")
    tc2 = worker_b.import_data(tc).distinct().cache()
    edges_b = worker_b.import_data(edges).cache()

    old_count = 0
    next_count = tc2.count()
    while next_count != old_count:
        old_count = next_count
        lhs = tc2.map(lambda e: {"key": e[1], "value": e[0]})
        new_edges = lhs.join(edges_b, max_matches=8).map(
            lambda r: (r["value"][0], r["value"][1])
        )
        # compact() bounds capacity growth across fixed-point rounds
        tc2 = tc2.union(new_edges).distinct().compact().cache()
        next_count = tc2.count()

    print(f"TC has {next_count} edges")
    exp = tc_reference(edges_np)
    got = {(int(np.asarray(a)), int(np.asarray(b))) for a, b in tc2.collect()}
    assert got == exp, (len(got), len(exp))
    Ignis.stop()
    print("OK")


if __name__ == "__main__":
    main()
