#!/usr/bin/env python
"""Docs link check: fail if a source file cites a ``*.md`` document that
does not exist in the repo.

Source files reference design docs by name (``DESIGN.md §2``,
``EXPERIMENTS.md §Perf``); for a while several of those documents did not
exist. This check keeps citations honest — runs in CI after the tests.

Usage: python tools/check_doc_links.py [repo_root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# markdown-document tokens, optionally with a relative path prefix
_MD_REF = re.compile(r"\b([A-Za-z0-9_\-./]+\.md)\b")
_SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")


def cited_docs(root: Path):
    """Yield (source_file, lineno, doc_name) for every *.md citation."""
    for d in _SCAN_DIRS:
        for py in sorted((root / d).rglob("*.py")):
            for lineno, line in enumerate(py.read_text(encoding="utf-8").splitlines(), 1):
                for m in _MD_REF.finditer(line):
                    yield py, lineno, m.group(1)


def resolve(root: Path, src: Path, name: str) -> bool:
    """A citation resolves if the doc exists at the repo root, under docs/,
    or relative to the citing file."""
    candidates = [root / name, root / "docs" / Path(name).name, src.parent / name]
    return any(c.is_file() for c in candidates)


def main() -> int:
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(__file__).resolve().parents[1]
    missing = []
    checked = 0
    for src, lineno, name in cited_docs(root):
        checked += 1
        if not resolve(root, src, name):
            missing.append(f"{src.relative_to(root)}:{lineno}: cites missing doc {name!r}")
    if missing:
        print("Broken doc citations:")
        print("\n".join(f"  {m}" for m in missing))
        return 1
    print(f"doc link check OK ({checked} citations resolve)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
