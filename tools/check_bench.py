#!/usr/bin/env python
"""Perf-regression gate: compare perf-smoke JSON against the committed
baseline (BENCH_baseline.json) and fail the build on a real regression.

Three checks, in decreasing order of signal:

1. **Counter gate** (machine-independent, zero tolerance): any increase in
   an ``retries=``/``recompiles=`` counter embedded in a row's ``derived``
   field fails — an overflow retry or a wide-stage recompile that the
   capacity memory used to absorb is a regression regardless of hardware
   (DESIGN.md §6).
2. **Derived-factor floors** (machine-independent): a row whose derived
   field carries both ``<metric>=<X>x`` and ``target=<Y>`` must satisfy
   X ≥ Y (e.g. ``gang_vs_lockstep=1.76x target=1.3`` from bench_groups).
3. **Wall-clock gate via self-normalized factors**: a ``target``-bearing
   row's speedup factor is a ratio of two wall-clocks measured seconds
   apart in one process, so machine speed cancels; it must not drop more
   than ``--tolerance`` (default 75%) below its baseline value — the
   floor (check 2) is the tight bound (observed factor swing on shared
   runners is ~2.5x, so the baseline check only catches a big win
   collapsing outright while still clearing its floor). Absolute
   per-row times are NOT gated: measured run-to-run variance on shared
   CI/dev machines exceeds 2x, which would swamp any useful threshold —
   a bench that wants its wall-clock gated declares a ``target`` (i.e.
   claims its factor is stable) and gets both the floor and the
   regression check. Only declare a target when BOTH arms of the ratio
   co-scale with machine speed: ``bench_terasort``'s ignis-vs-spark ratio
   does not (one arm is GIL-bound, the other device-bound; observed
   1.6x-7.9x) and declares none. ``bench_hybrid``'s overlap factor
   declares a MACHINE-AWARE target (the row's own ``target=`` token, read
   per current run): 1.15 on ≥4-core hosts where the async job must
   genuinely overlap the CG's XLA threads with the dataflow Python, 1.05
   on 2-3-core hosts where the two compete for the single spare core and
   a hard 1.15 would turn perf variance into red builds, and 0.90 on
   single-core hosts where both arms are CPU-equivalent and the floor
   only asserts the nonblocking path adds no overhead. Targets are
   self-describing per row precisely so a bench can scale its own claim
   to the hardware it ran on.

Rows present in the baseline but missing from the current run fail loudly:
a silently dropped bench must not read as "no regression". ``*_FAILED``
rows fail immediately.

Usage:
  python tools/check_bench.py --baseline BENCH_baseline.json bench-*.json
  python tools/check_bench.py --write-baseline BENCH_baseline.json bench-*.json
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

# counters whose increase is a regression on any machine; matched by exact
# name OR suffix (``kernel_recompiles`` gates like ``recompiles`` —
# bench_kernels' repeat-warm row; ``batches_replayed``/``shed`` gate the
# streaming clean arms, and ``faulted_batches_replayed`` pins the recovery
# arm's replay count at its baseline of exactly 1 — docs/streaming.md)
_GATED_COUNTERS = ("retries", "recompiles", "retunes", "replayed", "shed")
_KV = re.compile(r"\b([A-Za-z_][A-Za-z0-9_]*)=([0-9.]+)(x?)\b")


def load_rows(paths: list[str]) -> dict:
    """Merge JSON row files → {name: record}; later files win on dup names."""
    rows: dict[str, dict] = {}
    for p in paths:
        for rec in json.loads(Path(p).read_text()):
            rows[rec["name"]] = rec
    return rows


def derived_fields(rec: dict) -> dict:
    """Parse ``k=v`` tokens out of a row's derived string.

    Values suffixed ``x`` (speedup factors) keep the suffix marker so the
    floor check can tell ``1.76x`` apart from plain counters."""
    out = {}
    for k, v, is_factor in _KV.findall(rec.get("derived", "")):
        out[k] = (float(v), bool(is_factor))
    return out


def check(current: dict, baseline: dict, tolerance: float) -> list[str]:
    errors: list[str] = []

    for name in current:
        if name.endswith("_FAILED"):
            errors.append(f"{name}: bench failed: {current[name].get('derived')}")

    for name, base in baseline.items():
        if name.startswith("_"):
            continue
        cur = current.get(name)
        if cur is None:
            errors.append(f"{name}: present in baseline but missing from this run")
            continue
        bf, cf = derived_fields(base), derived_fields(cur)
        for k in cf:
            gated = any(k == c or k.endswith("_" + c) for c in _GATED_COUNTERS)
            if gated and k in bf and cf[k][0] > bf[k][0]:
                errors.append(
                    f"{name}: {k} increased "
                    f"{bf[k][0]:g} -> {cf[k][0]:g}")

    # derived-factor floors are self-describing (checked on current rows
    # only — a new bench gets its floor enforced before it has a baseline),
    # and target-bearing factors also gate against their baseline value
    for name, cur in current.items():
        fields = derived_fields(cur)
        target = fields.get("target")
        if target is None:
            continue
        base_fields = derived_fields(baseline.get(name, {}))
        for k, (v, is_factor) in fields.items():
            if not is_factor:
                continue
            if v < target[0]:
                errors.append(f"{name}: {k}={v:.2f}x below target={target[0]:g}")
            bv = base_fields.get(k)
            if bv is not None and bv[1] and v < bv[0] * (1.0 - tolerance):
                errors.append(
                    f"{name}: {k}={v:.2f}x regressed more than "
                    f"{tolerance:.0%} below baseline {bv[0]:.2f}x")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+", help="bench JSON files from run.py --json")
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.75,
                    help="allowed drop of a target-bearing factor below its baseline value")
    ap.add_argument("--write-baseline", metavar="PATH",
                    help="merge the given files into a new baseline and exit")
    args = ap.parse_args()

    current = load_rows(args.files)
    if args.write_baseline:
        recs = sorted(current.values(), key=lambda r: r["name"])
        Path(args.write_baseline).write_text(json.dumps(recs, indent=1) + "\n")
        print(f"wrote {len(recs)} rows to {args.write_baseline}")
        return 0

    base_path = Path(args.baseline)
    if not base_path.is_file():
        print(f"no baseline at {base_path} — nothing to compare", file=sys.stderr)
        return 1
    baseline = load_rows([str(base_path)])
    errors = check(current, baseline, args.tolerance)
    if errors:
        print("perf gate FAILED:")
        print("\n".join(f"  {e}" for e in errors))
        return 1
    print(f"perf gate OK ({len(current)} rows vs baseline {base_path})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
