#!/usr/bin/env python
"""Coverage floor gate (the tools/check_bench.py analogue for test depth):
parse a Cobertura ``coverage.xml`` produced by ``pytest --cov`` and fail the
build when line coverage over the measured package drops below the
committed floor.

Policy (mirrors the perf gate's philosophy):

* The floor is a COMMITTED number (the ``--min`` value in ci.yml), not a
  moving average — a PR that deletes tests or adds uncovered hot-path code
  must fail loudly, and raising the floor is an explicit, reviewed act.
* The floor is deliberately below the observed value (observed ≈ 0.85+ for
  ``repro.core`` under the core-focused test selection): coverage jitters a
  few points with test re-ordering and platform-dependent branches
  (compat shims, p>1-only paths), and the gate must not be flaky.
* Per-file rates are printed for the CI log, worst-first, so a failing run
  shows WHERE the depth went, but only the aggregate is gated — per-file
  floors would punish small files for single-line changes.

Usage:
  python tools/check_coverage.py --min 0.75 coverage.xml
"""
from __future__ import annotations

import argparse
import sys
import xml.etree.ElementTree as ET


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("xml", help="Cobertura XML from pytest --cov-report=xml:...")
    ap.add_argument("--min", type=float, default=0.75,
                    help="committed aggregate line-rate floor (0..1)")
    args = ap.parse_args()

    root = ET.parse(args.xml).getroot()
    rate = float(root.get("line-rate", 0.0))

    per_file = []
    for cls in root.iter("class"):
        per_file.append((float(cls.get("line-rate", 0.0)), cls.get("filename")))
    for r, name in sorted(per_file):
        print(f"  {r:6.1%}  {name}")
    covered = root.get("lines-covered", "?")
    valid = root.get("lines-valid", "?")
    print(f"aggregate line coverage: {rate:.1%} ({covered}/{valid} lines)")

    if rate < args.min:
        print(f"coverage gate FAILED: {rate:.1%} < committed floor {args.min:.1%}",
              file=sys.stderr)
        return 1
    print(f"coverage gate OK ({rate:.1%} >= floor {args.min:.1%})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
