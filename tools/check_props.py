#!/usr/bin/env python
"""Property-registry docs check: fail if a registered ``ignis.*`` property
is missing from the documentation, or if docs/source reference an
``ignis.*`` key the registry does not know.

PR 9 consolidated configuration into a typed registry
(``repro.core.properties.REGISTRY``); docs/properties.md is its
human-readable mirror. This check keeps the two honest in both
directions — runs in CI next to check_doc_links.py. A line that must
reference an unknown key (the registry's own negative tests) opts out
with a ``# props: ignore`` comment.

Usage: python tools/check_props.py [repo_root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

_PROP = re.compile(r"\bignis\.[a-z][a-z0-9.]*[a-z0-9]\b")
_SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")
_DOC_FILES = ("docs/properties.md",)


def registry(root: Path) -> dict:
    sys.path.insert(0, str(root / "src"))
    from repro.core.properties import REGISTRY

    return REGISTRY


def referenced_keys(root: Path):
    """Yield (file, lineno, key) for every ignis.* token in source dirs."""
    for d in _SCAN_DIRS:
        for py in sorted((root / d).rglob("*.py")):
            text = py.read_text(encoding="utf-8")
            for lineno, line in enumerate(text.splitlines(), 1):
                if "props: ignore" in line:
                    continue  # negative tests reference unknown keys on purpose
                for m in _PROP.finditer(line):
                    yield py, lineno, m.group(0)


def main() -> int:
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(__file__).resolve().parents[1]
    reg = registry(root)
    doc_text = "\n".join(
        (root / f).read_text(encoding="utf-8")
        for f in _DOC_FILES if (root / f).is_file()
    )

    problems = []

    # 1. every registered property must appear in the docs
    for name in sorted(reg):
        if name not in doc_text:
            problems.append(f"docs/properties.md: missing registered property {name!r}")

    # 2. every ignis.* key referenced in source must be registered (or a
    #    registered prefix — e.g. a docstring citing "ignis.stream.")
    known = set(reg)
    for src, lineno, key in referenced_keys(root):
        if key in known:
            continue
        if any(k.startswith(key) for k in known):  # cited prefix of a family
            continue
        problems.append(
            f"{src.relative_to(root)}:{lineno}: unregistered property {key!r}")

    if problems:
        print("Property registry violations:")
        print("\n".join(f"  {p}" for p in problems))
        return 1
    print(f"property check OK ({len(reg)} registered props documented, "
          f"all source references registered)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
