"""Kernel tier (docs/kernels.md, DESIGN.md §11): per-kernel cost vs the
jnp oracles, wide-stage speedup with the tier on vs off, and the
repeat-run counter gate.

Three row groups:

* ``kern_*`` — each shuffle-tier kernel (prefix_scan / segment_totals /
  bucket_route) timed under jit against its always-available jnp oracle,
  in the mode the registry would actually pick for this backend
  (compiled on TPU, interpret elsewhere). The ratio is informational
  (no ``target=``): interpreted Pallas is EXPECTED to lose to the oracle
  on CPU — that asymmetry is exactly why auto mode never interprets.
* ``kernels_wide_*`` — terasort-style reduceByKey and a pagerank-style
  join+reduceByKey chain with ``ignis.kernels=auto`` vs ``off``,
  interleaved within each iteration with a per-iteration ratio
  (the bench_hybrid lesson: separate timing blocks let machine-load
  drift skew the headline). The floor is machine-aware and
  self-describing via the row's ``target=`` token
  (tools/check_bench.py): on a compiled-Pallas backend the kernel tier
  must win outright (1.5x); on an interpret-only host auto mode
  selects the bit-identical plain-JAX fallback, so the floor is parity
  with 10% noise headroom (0.9x) — the row then guards "the kernel
  tier's selection layer adds no overhead", not a speedup.
* ``kernels_repeat_warm`` — a repeat lineage on the forced-interpret
  tier must be plan-warm and tune-warm: ``kernel_recompiles`` (wide-plan
  misses during the repeats) and ``kernel_retunes`` (autotune sweeps
  during the repeats) are CI-gated at zero via the counter gate.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core import ICluster, IProperties, IWorker
from repro.core.shuffle import segmented_reduce
from repro.kernels.moe_route import bucket_route, bucket_route_ref
from repro.kernels.registry import compiled_backend
from repro.kernels.segment_reduce import segment_totals
from repro.kernels.ssd_scan import prefix_scan, prefix_scan_ref


def _per_kernel_rows(n: int):
    interpret = not compiled_backend()
    tag = "interpret" if interpret else "compiled"
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-1000, 1000, n).astype(np.int32))
    keys = jnp.sort(jnp.asarray(rng.integers(0, 512, n).astype(np.int32)))
    valid = jnp.asarray(rng.random(n) < 0.9)
    dest = jnp.asarray(rng.integers(0, 8, n).astype(np.int32))
    cap = max(n // 4, 1)

    pairs = [
        ("prefix_scan",
         jax.jit(lambda v: prefix_scan(v, "sum", 512, interpret)),
         jax.jit(lambda v: prefix_scan_ref(v)), x),
        ("segment_totals",
         jax.jit(lambda v: segment_totals(keys, valid, v, "sum",
                                          jnp.int32(0), 512, interpret)),
         jax.jit(lambda v: segmented_reduce(keys, valid, v,
                                            jnp.add, jnp.int32(0))), x),
        ("bucket_route",
         jax.jit(lambda v: bucket_route(v, 8, cap, 512, interpret)),
         jax.jit(lambda v: bucket_route_ref(v, 8, cap)), dest),
    ]
    rows = []
    for name, kern, oracle, arg in pairs:
        t_k = timeit(lambda: kern(arg), warmup=1, iters=3)
        t_o = timeit(lambda: oracle(arg), warmup=1, iters=3)
        rows.append(row(
            f"kern_{name}", t_k,
            f"mode={tag} oracle_us={t_o*1e6:.1f} n={n}"))
    return rows


def _wide_stage_rows(n: int, iters: int):
    rng = np.random.default_rng(1)
    vals = rng.integers(0, 100_000, n).astype(np.int32)
    edges = rng.integers(0, 64, (max(n // 50, 64), 2)).astype(np.int32)

    def make(mode):
        return IWorker(ICluster(IProperties({"ignis.kernels": mode})),
                       "python")

    def terasort_stage(w):
        return (w.parallelize(vals)
                .map(lambda x: {"key": x % 97, "value": jnp.int32(1)})
                .reduce_by_key(lambda a, b: a + b, 0).count())

    def pagerank_stage(w):
        src = w.parallelize(edges[:, 0]).map(
            lambda s: {"key": s, "value": jnp.float32(1.0)})
        dst = w.parallelize(edges[:, 1]).map(
            lambda d: {"key": d, "value": jnp.float32(0.5)})
        contrib = src.join(dst, max_matches=64).map(
            lambda r: {"key": r["key"], "value": r["value"][0] * r["value"][1]})
        return contrib.reduce_by_key(lambda a, b: a + b, 0.0).count()

    w_auto, w_off = make("auto"), make("off")
    # machine-aware floor (the bench_hybrid precedent): a compiled-Pallas
    # backend must beat the oracle outright; an interpret-only host runs
    # the SAME fallback code in auto mode, so the floor is parity-with-
    # noise-headroom and the row guards selection overhead, not a win
    floor = 1.5 if compiled_backend() else 0.9
    backend = jax.default_backend()
    rows = []
    for name, stage in (("terasort", terasort_stage),
                        ("pagerank", pagerank_stage)):
        stage(w_auto), stage(w_off)  # warm: tunes, plans, capacity memory
        ta, to, ratios = [], [], []
        for _ in range(iters):
            t0 = time.perf_counter()
            stage(w_auto)
            t1 = time.perf_counter()
            stage(w_off)
            t2 = time.perf_counter()
            ta.append(t1 - t0)
            to.append(t2 - t1)
            ratios.append((t2 - t1) / (t1 - t0))
        t_auto = sorted(ta)[len(ta) // 2]
        t_off = sorted(to)[len(to) // 2]
        factor = sorted(ratios)[len(ratios) // 2]
        rows.append(row(f"kernels_wide_{name}_auto", t_auto,
                        f"n={n} backend={backend}"))
        rows.append(row(
            f"kernels_wide_{name}", t_off,
            f"off_vs_auto={factor:.2f}x backend={backend} target={floor}"))
    s = w_auto.shuffle_stats()
    rows.append(row(
        "kernels_auto_selection", 0.0,
        f"hits={s['kernel_hits']} fallbacks={s['kernel_fallbacks']} "
        f"autotune_runs={s['autotune_runs']}"))
    return rows


def _repeat_rows(n: int):
    w = IWorker(ICluster(IProperties({"ignis.kernels": "interpret"})),
                "python")
    vals = np.random.default_rng(2).integers(0, 100_000, n).astype(np.int32)

    def run():
        return (w.parallelize(vals)
                .map(lambda x: {"key": x % 53, "value": x})
                .reduce_by_key(lambda a, b: a + b, 0).count())

    run()  # first lineage: tune + compile
    s1 = w.shuffle_stats()
    t = timeit(run, warmup=0, iters=3)
    s2 = w.shuffle_stats()
    assert s2["kernel_hits"] > s1["kernel_hits"] >= 1, (s1, s2)
    return [row(
        "kernels_repeat_warm", t,
        # both counters are CI-gated at zero (tools/check_bench.py):
        # a repeat lineage must be plan-warm AND tune-warm
        f"kernel_recompiles={s2['wide_plan_misses'] - s1['wide_plan_misses']} "
        f"kernel_retunes={s2['autotune_runs'] - s1['autotune_runs']} "
        f"kernel_hits={s2['kernel_hits']}")]


def bench(n: int = 100_000, iters: int = 3):
    return (_per_kernel_rows(min(n, 1 << 16))
            + _wide_stage_rows(n, iters)
            + _repeat_rows(n))


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(bench())
