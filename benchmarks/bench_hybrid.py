"""Hybrid job: native SPMD stage + MapReduce stage in ONE scheduled job
(paper §3.2 / Fig. 12; docs/driver.md).

Two independent branches — a CG solve (``worker.call`` on an "spmd" worker)
and a reduceByKey pipeline (on a "dataflow" worker) — are measured eagerly
(back-to-back: sum of stage wall-clocks) and then submitted asynchronously
into one ``IJob``, where the scheduler overlaps them across the two
workers. The three arms are timed INTERLEAVED within each iteration and
the overlap factor is the median of per-iteration ratios (the
bench_groups lesson — separate timing blocks let machine-load drift skew
the headline). The balancing is two-sided: whichever branch is cheaper per
action repeats R times so both branches cost roughly the same eagerly,
which makes the ideal async speedup ~2x and keeps the comparison honest at
any machine speed. (It must be two-sided: with persistent collective plans
the CG app no longer re-traces per call — DESIGN.md §10 — so the native
action is device-bound and cheap, and it is the DATAFLOW branch that sets
the floor.) The native branch's warm calls run almost entirely inside XLA
with the GIL released, which is exactly what lets the Python-heavy
dataflow branch make progress concurrently; the derived overlap factor
(eager sum / async wall) must meet its declared target. The target is
cores-aware: real overlap needs a second core for the XLA executor to run
on — on a single-core host the factor's floor is only "the nonblocking
path adds no overhead" (see the comment at the derived row).
"""
from __future__ import annotations

import os
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core import ICluster, IProperties, IWorker
from repro.core.job import IJob


def bench(n: int = 1 << 16, cg_iters: int = 200, iters: int = 3,
          n_cg: int = 1 << 16):
    cluster = ICluster(IProperties())
    ws = IWorker(cluster, "spmd")
    ws.load_library("repro.apps.stencil")
    wd = IWorker(cluster, "python")
    rng = np.random.default_rng(0)
    # n_cg sets how device-heavy the native branch is: the CG solve must be
    # dominated by in-flight XLA work (not dispatch) for the async job to
    # have anything to overlap the dataflow branch's Python against
    b = rng.normal(size=n_cg).astype(np.float32)
    vals = rng.integers(0, 100_000, n).astype(np.int32)
    base = wd.parallelize(vals)

    # a FRESH lineage per evaluation in BOTH arms and BOTH branches: a job's
    # shared memo (or a reused node's cache) would otherwise evaluate once
    # and hand the async arm R-1 free hits the eager arm pays for
    def make_native():
        return ws.call("cg_app", ws.parallelize(b), iters=cg_iters)

    def make_mapred():
        return base.map(lambda x: {"key": x % 97, "value": jnp.int32(1)}).reduce_by_key(
            lambda a, b: a + b, 0
        )

    # correctness parity: async futures return what the eager actions return
    # (this also warms the CG persistent plan, so the timed section below
    # measures invoke-many steady state, not the one-off init/compile)
    native, mapred = make_native(), make_mapred()
    job0 = IJob("hybrid-parity")
    fn, fm = native.count_async(job=job0), mapred.count_async(job=job0)
    assert fn.result() == make_native().count()
    assert fm.result() == make_mapred().count()

    # single-action costs → self-balancing repeat factors: the cheaper
    # branch repeats so the two eager stages cost about the same
    t_native_1 = timeit(lambda: make_native().count(), warmup=0, iters=1)
    t_mapred_1 = timeit(lambda: make_mapred().count(), warmup=0, iters=1)
    rn = max(1, min(64, round(t_mapred_1 / max(t_native_1, 1e-5))))
    rm = max(1, min(64, round(t_native_1 / max(t_mapred_1, 1e-5))))

    def native_stage():
        for _ in range(rn):
            make_native().count()

    def dataflow_stage():
        for _ in range(rm):
            make_mapred().count()

    def async_job():
        job = IJob("hybrid")
        futs = [make_native().count_async(job=job) for _ in range(rn)]
        futs += [make_mapred().count_async(job=job) for _ in range(rm)]
        for f in futs:
            f.result()

    # INTERLEAVED timing with a PER-ITERATION ratio (the bench_groups
    # lesson, EXPERIMENTS.md §Groups): all three arms alternate within each
    # iteration and the headline factor is the median of per-iteration
    # (eager native + eager dataflow) / async ratios. Timing the arms in
    # separate blocks lets machine-load drift skew the ratio of medians —
    # the block-timed version of this bench swung 0.78–1.09x across
    # back-to-back runs on a loaded 1-core host, which a hard CI floor
    # would turn into red builds on perf-variance events.
    tn, tm, ta, ratios = [], [], [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        native_stage()
        t1 = time.perf_counter()
        dataflow_stage()
        t2 = time.perf_counter()
        async_job()
        t3 = time.perf_counter()
        tn.append(t1 - t0)
        tm.append(t2 - t1)
        ta.append(t3 - t2)
        ratios.append((t2 - t0) / (t3 - t2))
    t_native = sorted(tn)[len(tn) // 2]
    t_mapred = sorted(tm)[len(tm) // 2]
    t_async = sorted(ta)[len(ta) // 2]

    eager_sum = t_native + t_mapred
    # The floor scales with the machine's physics (tools/check_bench.py
    # reads target= off this row, so the gate is machine-aware by
    # construction). With ≥4 cores the CG's XLA executor threads have
    # spare cores beside the GIL-bound dataflow Python, so the async job
    # must genuinely overlap them (≥1.15x, the CI hard gate). On 2-3 cores
    # the XLA pool and the dataflow Python compete for the single spare
    # core, which makes 1.15 marginal on constrained CI runners — overlap
    # is still required, just with slack (1.05). On a single core there is
    # nothing to overlap WITH — both arms are CPU-equivalent by
    # construction (measured utilisation 1.00 either way) — so the floor
    # degenerates to "the nonblocking path adds no overhead": the
    # regression this row guards showed up as async ≈ 0.75-0.88x of eager
    # (actions blocking on the device queue while holding the worker's job
    # lock), which 0.90 still catches.
    cores = os.cpu_count() or 1
    floor = 1.15 if cores >= 4 else (1.05 if cores >= 2 else 0.90)
    factor = sorted(ratios)[len(ratios) // 2]
    return [
        row("hybrid_native_eager", t_native, f"cg_iters={cg_iters} repeats={rn}"),
        row("hybrid_mapreduce_eager", t_mapred, f"n={n} repeats={rm}"),
        row("hybrid_async_job", t_async, "one IJob, two workers"),
        row(
            "hybrid_overlap",
            0.0,
            f"async_vs_eager_sum={factor:.2f}x "
            f"overlap_ok={factor >= floor} cores={cores} target={floor}",
        ),
    ]


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(bench())
