"""Hybrid job: native SPMD stage + MapReduce stage in ONE scheduled job
(paper §3.2 / Fig. 12; docs/driver.md).

Two independent branches — a CG solve (``worker.call`` on an "spmd" worker)
and a reduceByKey pipeline (on a "dataflow" worker) — are measured eagerly
(back-to-back: sum of stage wall-clocks) and then submitted asynchronously
into one ``IJob``, where the scheduler overlaps them across the two
workers. The dataflow stage self-balances: it repeats its action R times
with R chosen so both branches cost roughly the same eagerly, which makes
the ideal async speedup ~2x and keeps the comparison honest at any machine
speed. The derived overlap factor (eager sum / async wall) must be > 1.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core import ICluster, IProperties, IWorker
from repro.core.job import IJob


def bench(n: int = 1 << 16, cg_iters: int = 200, iters: int = 3):
    cluster = ICluster(IProperties())
    ws = IWorker(cluster, "spmd")
    ws.load_library("repro.apps.stencil")
    wd = IWorker(cluster, "python")
    rng = np.random.default_rng(0)
    b = rng.normal(size=4096).astype(np.float32)
    vals = rng.integers(0, 100_000, n).astype(np.int32)
    native = ws.call("cg_app", ws.parallelize(b), iters=cg_iters)
    base = wd.parallelize(vals)

    # a FRESH lineage per evaluation in BOTH arms: a job's shared memo would
    # otherwise evaluate one reused node once and hand the async arm R-1
    # free cache hits the eager arm pays for
    def make_mapred():
        return base.map(lambda x: {"key": x % 97, "value": jnp.int32(1)}).reduce_by_key(
            lambda a, b: a + b, 0
        )

    # correctness parity: async futures return what the eager actions return
    mapred = make_mapred()
    job0 = IJob("hybrid-parity")
    fn, fm = native.count_async(job=job0), mapred.count_async(job=job0)
    assert fn.result() == native.count()
    assert fm.result() == make_mapred().count()

    # single-action costs → self-balancing repeat factor for the dataflow
    # branch (the CG app re-traces its shard_map per execution, so the
    # native stage has a large machine-dependent floor)
    t_native_1 = timeit(lambda: native.count(), warmup=0, iters=1)
    t_mapred_1 = timeit(lambda: make_mapred().count(), warmup=0, iters=1)
    R = max(1, min(64, round(t_native_1 / max(t_mapred_1, 1e-4))))

    def dataflow_stage():
        for _ in range(R):
            make_mapred().count()

    t_native = timeit(lambda: native.count(), warmup=0, iters=iters)
    t_mapred = timeit(dataflow_stage, warmup=0, iters=iters)

    def async_job():
        job = IJob("hybrid")
        futs = [native.count_async(job=job)]
        futs += [make_mapred().count_async(job=job) for _ in range(R)]
        for f in futs:
            f.result()

    t_async = timeit(async_job, warmup=0, iters=iters)

    eager_sum = t_native + t_mapred
    return [
        row("hybrid_native_eager", t_native, f"cg_iters={cg_iters}"),
        row("hybrid_mapreduce_eager", t_mapred, f"n={n} repeats={R}"),
        row("hybrid_async_job", t_async, "one IJob, two workers"),
        row(
            "hybrid_overlap",
            0.0,
            f"async_vs_eager_sum={eager_sum / t_async:.2f}x "
            f"overlap_ok={t_async < eager_sum}",
        ),
    ]


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(bench())
