"""Collective micro-benchmark: per-collective µs/call for the three call
shapes the engine offers (docs/collectives.md):

* **blocking**   — ``comm.allreduce(ctx, x)``: dispatch + wait in one call
  (itself a facade over the nonblocking path, so this prices the whole
  round trip including the plan-cache lookup).
* **nonblocking** — ``comm.iallreduce(ctx, x)`` then ``handle.wait()``:
  same work split into MPI_Start/MPI_Wait halves; the dispatch half is
  what a scheduler overlaps with other work.
* **persistent**  — ``comm.persistent(ctx, "allreduce", x)`` held across
  the loop and invoked directly: init-once/invoke-many (UCC-style), no
  per-call cache lookup or handle bookkeeping at all.

The derived row carries ``recompiles=`` — plan-cache misses accumulated
over the WARM timing loops, which must be zero (every shape reuses the
plan compiled during warmup; a miss means the cache key is unstable) —
and the counter is gated with zero tolerance by tools/check_bench.py.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.core import ICluster, IProperties, IWorker, comm

_COLLS = ("allreduce", "bcast", "gather", "alltoall", "exscan", "ppermute")


def bench(n: int = 1 << 12, iters: int = 30):
    cluster = ICluster(IProperties())
    ctx = IWorker(cluster, "python").context
    rng = np.random.default_rng(0)
    x = comm.shard_rows(ctx, rng.normal(size=n).astype(np.float32))

    blocking = {
        "allreduce": lambda: comm.allreduce(ctx, x),
        "bcast": lambda: comm.bcast(ctx, x),
        "gather": lambda: comm.gather(ctx, x),
        "alltoall": lambda: comm.alltoall(ctx, x),
        "exscan": lambda: comm.exscan(ctx, x),
        "ppermute": lambda: comm.ppermute(ctx, x, shift=1),
    }
    nonblocking = {
        "allreduce": lambda: comm.iallreduce(ctx, x).wait(),
        "bcast": lambda: comm.ibcast(ctx, x).wait(),
        "gather": lambda: comm.igather(ctx, x).wait(),
        "alltoall": lambda: comm.ialltoall(ctx, x).wait(),
        "exscan": lambda: comm.iexscan(ctx, x).wait(),
        "ppermute": lambda: comm.ippermute(ctx, x, shift=1).wait(),
    }

    rows = []
    for coll in _COLLS:
        rows.append(row(f"coll_{coll}_blocking",
                        timeit(blocking[coll], warmup=1, iters=iters),
                        f"n={n}"))
        rows.append(row(f"coll_{coll}_nonblocking",
                        timeit(nonblocking[coll], warmup=1, iters=iters),
                        "i*().wait()"))
        plan = comm.persistent(ctx, coll, x,
                               **({"shift": 1} if coll == "ppermute" else {}))
        rows.append(row(f"coll_{coll}_persistent",
                        timeit(lambda p=plan: p(x), warmup=1, iters=iters),
                        "init-once/invoke-many"))

    # every timed call above ran against a plan warmed during its warmup
    # call; misses accumulated SINCE then are recompiles the cache failed
    # to absorb. Snapshot-diff keeps the counter meaningful when other
    # benches in the same process already populated the cache.
    before = comm.comm_stats()["coll_plan_misses"]
    for coll in _COLLS:
        blocking[coll]()
        nonblocking[coll]()
    recompiles = comm.comm_stats()["coll_plan_misses"] - before
    stats = comm.comm_stats()
    rows.append(row(
        "coll_plan_cache", 0.0,
        f"recompiles={recompiles} hits={stats['coll_plan_hits']} "
        f"misses={stats['coll_plan_misses']}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(bench())
