"""Recovery overhead (docs/fault_tolerance.md): an 8-device terasort with
one injected executor kill per iteration, against the clean run and against
the no-lineage alternative (full recompute from the source).

Three timed arms over one pipeline — a cached (persisted) pre-sort map of
``blocks=8`` feeding a PSRS sort:

  * **clean**: re-run the sort action with the cache intact;
  * **faulted**: ``worker.kill_executor(rank)`` first — the cached map and
    the source each lose one block, and the next action repairs them
    block-wise from lineage before sorting (paper §3.5, Fig. 3);
  * **cold**: drop the WHOLE cached map — what recovery would cost without
    block-wise lineage (recompute all 8 blocks from the source).

Derived factors are per-iteration-interleaved ratio medians (machine-load
drift cancels, same protocol as bench_groups):

  * ``recovery_vs_clean`` — the headline overhead of losing one executor
    (~1-2.5x at smoke sizes: one repaired block plus an extra action's
    dispatch). Not target-gated: it sits inside single-action jitter.
  * ``repair_vs_cold`` (target ≥ 0.5) and ``clean_vs_faulted`` (target ≥
    0.25) — catastrophic-regression floors only: block-wise repair must
    not become slower than recomputing everything, and a faulted action
    must stay within ~4x of a clean one. Both arms are sort-dominated
    ~20 ms quantities whose ratio swings ±2x on shared runners, so tight
    floors would gate noise.

The ``retries=``/``recompiles=`` counters in derived are the TIGHT gate
(tools/check_bench.py): a recovery that starts overflowing or recompiling
wide stages regressed regardless of hardware.

Needs 8 devices, so ``bench()`` re-executes this file in a subprocess with
``--xla_force_host_platform_device_count=8`` (the flag must never leak into
the caller — same isolation rule as tests/test_distributed.py).
"""
from __future__ import annotations

import os
import subprocess
import sys


def _child(n: int, iters: int) -> list:
    import time

    import numpy as np

    from benchmarks.common import row
    from repro.core import ICluster, IProperties, IWorker

    w = IWorker(ICluster(IProperties({"ignis.executor.instances": "8"})), "python")
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 2**31 - 1, n).astype(np.int32)

    frame = w.parallelize(keys, blocks=8).map(lambda x: x ^ np.int32(0x5A5A)).persist()
    sorted_df = frame.sort()
    oracle = sorted_df.count()

    def action():
        assert sorted_df.count() == oracle

    action()  # warm: capacity memory + compiled plans for every arm
    tc, tf, td, r_clean, r_cold = [], [], [], [], []
    for i in range(iters):
        t0 = time.perf_counter()
        action()  # clean
        t1 = time.perf_counter()
        lost = w.kill_executor(i % 8, blacklist=False)
        assert lost >= 1, "executor kill must cost at least one cached block"
        action()  # faulted: block-wise lineage repair + sort
        t2 = time.perf_counter()
        frame.node.result = None  # cold: no block-wise lineage to lean on
        action()  # recomputes all 8 blocks and re-caches (node stays cached)
        t3 = time.perf_counter()
        tc.append(t1 - t0)
        tf.append(t2 - t1)
        td.append(t3 - t2)
        r_clean.append((t2 - t1) / (t1 - t0))
        r_cold.append((t3 - t2) / (t2 - t1))

    med = lambda xs: sorted(xs)[len(xs) // 2]  # noqa: E731
    st = w.shuffle_stats()
    eng = w.stage_stats()
    return [
        row("recovery_clean", med(tc), f"n={n} blocks=8 world=8"),
        row("recovery_faulted", med(tf),
            f"block_repairs={eng['block_recomputes']} "
            f"retries={st['overflow_retries']} "
            f"recompiles={st['wide_plan_misses']}"),
        row("recovery_cold", med(td), "whole cached map dropped"),
        # no target= on this row: the factor sits inside single-action
        # jitter, so a gate here would gate noise (docstring)
        row("recovery_overhead", 0.0,
            f"recovery_vs_clean={med(r_clean):.2f}x kills={iters}"),
        row("recovery_repair", 0.0,
            f"repair_vs_cold={med(r_cold):.2f}x target=0.5 "
            f"retries={st['overflow_retries']}"),
        row("recovery_bound", 0.0,
            f"clean_vs_faulted={med([1.0 / r for r in r_clean]):.2f}x target=0.25"),
    ]


def bench(n: int = 200_000, iters: int = 5) -> list:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root, env.get("PYTHONPATH", "")])
    r = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child", str(n), str(iters)],
        env=env, capture_output=True, text=True, timeout=1200, cwd=root,
    )
    if r.returncode != 0:
        raise RuntimeError(f"bench_recovery child failed:\n{r.stderr[-2000:]}")
    rows = [ln[len("ROW "):] for ln in r.stdout.splitlines()
            if ln.startswith("ROW ")]
    if not rows:
        raise RuntimeError(f"bench_recovery child emitted no rows:\n{r.stdout}")
    return rows


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--child":
        n, iters = (int(x) for x in sys.argv[2:4])
        for r in _child(n, iters):
            print(f"ROW {r}")
    else:
        from benchmarks.common import emit

        emit(bench())
