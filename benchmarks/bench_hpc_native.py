"""Native HPC apps inside the framework (paper Figs. 19–22): the overhead of
worker.call vs executing the same collective program natively must be ≤ ~2%.
Stencil = LULESH/miniAMR pattern (halo ppermute); CG = AMG (Allreduce)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.apps.stencil import cg_native, stencil_native
from repro.core import ICluster, IProperties, IWorker


def bench(grid=(256, 128), n_cg: int = 4096, iters: int = 30):
    w = IWorker(ICluster(IProperties()), "cpp")
    w.load_library("repro.apps.stencil")
    mesh, axis = w.context.comm()
    rows = []

    g = np.random.default_rng(0).normal(size=grid).astype(np.float32)
    t_nat = timeit(lambda: stencil_native(mesh, axis, jnp.asarray(g), iters),
                   warmup=1, iters=5)
    df = w.parallelize(g)
    t_fw = timeit(lambda: w.call("stencil_app", df, iters=iters)._blocks(),
                  warmup=1, iters=5)
    ovh = (t_fw - t_nat) / t_nat * 100
    rows.append(row("stencil_native", t_nat, f"cell_iters/s={g.size*iters/t_nat:.2e}"))
    rows.append(row("stencil_framework", t_fw, f"overhead_pct={ovh:.2f}"))

    b = np.random.default_rng(1).normal(size=n_cg).astype(np.float32)
    t_nat = timeit(lambda: cg_native(mesh, axis, jnp.asarray(b), iters),
                   warmup=1, iters=5)
    dfb = w.parallelize(b)
    t_fw = timeit(lambda: w.call("cg_app", dfb, iters=iters)._blocks(),
                  warmup=1, iters=5)
    ovh = (t_fw - t_nat) / t_nat * 100
    rows.append(row("cg_native", t_nat, f"matvecs/s={iters/t_nat:.1f}"))
    rows.append(row("cg_framework", t_fw, f"overhead_pct={ovh:.2f}"))
    return rows
