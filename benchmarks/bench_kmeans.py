"""K-Means (paper Fig. 16): the iterative pattern. ignis = whole loop fused
on the fabric (no driver evaluations, paper §3.6); spark = per-iteration
driver round-trip. The gap widens with iteration count — exactly the
paper's observation about many short iterations."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.apps.kmeans import kmeans_driver_eval, kmeans_on_device, make_points


def bench(n: int = 8192, d: int = 32, k: int = 16, iters: int = 20):
    pts, _ = make_points(n, d, k, seed=0)
    pts_dev = jnp.asarray(pts)
    init = pts_dev[:k]
    on_dev = jax.jit(lambda p, c: kmeans_on_device(p, c, iters))

    rows = []
    t_ignis = timeit(lambda: on_dev(pts_dev, init), warmup=1, iters=3)
    t_spark = timeit(lambda: kmeans_driver_eval(pts_dev, init, iters), warmup=1, iters=3)
    # correctness parity between the two execution strategies
    a = on_dev(pts_dev, init)
    b = kmeans_driver_eval(pts_dev, init, iters)
    assert float(jnp.abs(a - b).max()) < 1e-3
    rows.append(row("kmeans_ignis_fused", t_ignis, f"iters/s={iters/t_ignis:.1f}"))
    rows.append(row("kmeans_spark_drivereval", t_spark, f"iters/s={iters/t_spark:.1f}"))
    rows.append(row("kmeans_speedup", 0.0, f"ignis_vs_spark={t_spark/t_ignis:.2f}x"))
    return rows
