"""Productivity (paper Table 5): source lines added to integrate a native
SPMD app into the framework = the @ignis_export wrapper + context parsing.

Measured directly from the app sources: lines of the native program vs
lines of its framework wrapper function.
"""
from __future__ import annotations

import ast
import inspect

from benchmarks.common import row
from repro.apps import minebench, stencil


def _fn_sloc(module, fn_name: str) -> int:
    src = inspect.getsource(getattr(module, fn_name))
    tree = ast.parse(src.lstrip() if not src.startswith("def") and not src.startswith("@") else src)
    node = tree.body[0]
    return (node.end_lineno or 0) - node.lineno + 1


def bench():
    rows = []
    for module, native, wrapper in [
        (stencil, "stencil_native", "stencil_app"),
        (stencil, "cg_native", "cg_app"),
        (minebench, "minebench_native", "minebench_native"),
    ]:
        n = _fn_sloc(module, native)
        w = _fn_sloc(module, wrapper)
        extra = w if native != wrapper else w  # the wrapper IS the addition
        rows.append(row(f"sloc_{native}", 0.0,
                        f"native_sloc={n};wrapper_sloc={extra}"))
    return rows
