"""Minebench (paper Figs. 13–14): chained data-/compute-intensive maps over
real SHA-256 (map₁ merkle reduction, map₂ nonce mining).

ignis mode vs spark mode (per-element pickle pipe, PySpark batch semantics),
single-worker and the multi-worker (importData) variant — the paper's
Python & C++ split. Pipelines are built once; timing re-evaluates the same
DAG nodes (warm jit caches, like steady-state cluster operation).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.apps.minebench import make_blocks, make_map2_fn, map1_fn
from repro.core import ICluster, IProperties, IWorker


def bench(n_blocks: int = 256, txs: int = 8):
    blocks = make_blocks(n_blocks, txs)
    map2 = make_map2_fn(iters=16, difficulty_bits=8)
    rows = []
    results = {}
    for mode in ("ignis", "spark"):
        for multi in (False, True):
            props = IProperties({"ignis.mode": mode})
            cluster = ICluster(props)
            w = IWorker(cluster, "python")
            df = w.parallelize(blocks)
            roots = df.map(map1_fn)
            if multi:
                w2 = IWorker(cluster, "cpp")
                roots = w2.import_data(roots)
            mined = roots.map(map2)
            t = timeit(lambda: mined.count(), warmup=1, iters=3)
            results[(mode, multi)] = t
            tag = "multi" if multi else "single"
            rows.append(row(f"minebench_{mode}_{tag}", t,
                            f"blocks/s={n_blocks/t:.1f}"))
    sp1 = results[("spark", False)] / results[("ignis", False)]
    sp2 = results[("spark", True)] / results[("ignis", True)]
    rows.append(row("minebench_speedup_single", 0.0, f"ignis_vs_spark={sp1:.2f}x"))
    rows.append(row("minebench_speedup_multiworker", 0.0, f"ignis_vs_spark={sp2:.2f}x"))
    return rows
