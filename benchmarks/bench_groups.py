"""Communicator groups (docs/collectives.md): gang-scheduled concurrent
jobs on disjoint sub-meshes vs. the flat-world lock-overlap scheduler.

Two independent jobs — each a native CG solve (Allreduce-heavy, the AMG
pattern) plus a reduceByKey wide action — run on one 8-executor worker:

  * **lockstep** (the PR-3 baseline): both jobs submit async into the
    scheduler WITHOUT groups. Every task needs the worker's job lock and
    every collective spans the full 8-way world communicator, so the jobs
    time-slice — the flat-`MPI_COMM_WORLD` multiplexing cost
    (PAPERS.md: Pilot-Abstraction; Spark-on-HPC).
  * **gang**: each job is pinned to one of two disjoint 4-executor groups
    (``worker.groups(2)`` = ``MPI_Comm_split``). Tasks hold per-group
    locks, so the jobs run CONCURRENTLY on different slices of the mesh,
    and every collective spans only 4 executors — fewer rendezvous
    participants per step plus real wall-clock overlap.

The derived ``gang_vs_lockstep`` factor is the headline: space-partitioning
must beat time-slicing (target ≥ 1.3x on an 8-device host-platform mesh).

Needs 8 devices, so ``bench()`` re-executes this file in a subprocess with
``--xla_force_host_platform_device_count=8`` (the same isolation rule as
tests/test_distributed.py — the flag must never leak into the caller).
"""
from __future__ import annotations

import os
import subprocess
import sys


def _child(size: int, cg_iters: int, n: int, iters: int) -> list:
    import numpy as np
    import jax.numpy as jnp

    from benchmarks.common import row
    from repro.core import ICluster, IProperties, IWorker
    from repro.core.job import IJob

    cluster = ICluster(IProperties({"ignis.executor.instances": "8"}))
    w = IWorker(cluster, "spmd")
    w.load_library("repro.apps.stencil")
    g0, g1 = w.groups(2)

    rng = np.random.default_rng(0)
    b0 = rng.normal(size=size).astype(np.float32)
    b1 = rng.normal(size=size).astype(np.float32)
    vals0 = rng.integers(0, 100_000, n).astype(np.int32)
    vals1 = rng.integers(0, 100_000, n).astype(np.int32)

    def submit_job(name, bvec, vals, group):
        """One job: a CG solve + a reduceByKey pipeline, submitted async.
        Fresh lineage per call — a reused node would hand later runs free
        memo hits and fake the comparison (same rule as bench_hybrid)."""
        job = IJob(name, group=group)
        cg = w.call("cg_app", w.parallelize(bvec), iters=cg_iters)
        f1 = cg.count_async(job=job)
        kv = w.parallelize(vals).map(lambda x: {"key": x % 97, "value": jnp.int32(1)})
        f2 = kv.reduce_by_key(lambda a, b: a + b, 0).count_async(job=job)
        return [f1, f2]

    def run_pair(groups):
        futs = submit_job("a", b0, vals0, groups[0]) + submit_job(
            "b", b1, vals1, groups[1])
        return [f.result(600) for f in futs]

    # correctness parity (and compile warm-up for BOTH communicator widths:
    # the world p=8 stages and each group's p=4 stages)
    res_lockstep = run_pair((None, None))
    res_gang = run_pair((g0, g1))
    assert res_lockstep == res_gang, (res_lockstep, res_gang)

    # INTERLEAVED timing: lockstep and gang alternate within each
    # iteration and the headline factor is the median of PER-ITERATION
    # ratios — machine-load drift between two separate timing blocks would
    # otherwise skew a ratio of medians (observed ±40% on shared runners)
    import time as _time

    tl, tg, ratios = [], [], []
    for _ in range(iters):
        t0 = _time.perf_counter()
        run_pair((None, None))
        t1 = _time.perf_counter()
        run_pair((g0, g1))
        t2 = _time.perf_counter()
        tl.append(t1 - t0)
        tg.append(t2 - t1)
        ratios.append((t1 - t0) / (t2 - t1))
    t_lockstep = sorted(tl)[len(tl) // 2]
    t_gang = sorted(tg)[len(tg) // 2]

    st = w.shuffle_stats()
    speedup = sorted(ratios)[len(ratios) // 2]
    return [
        row("groups_pair_lockstep", t_lockstep,
            f"cg_iters={cg_iters} size={size} n={n} world=8"),
        row("groups_pair_gang", t_gang, "two disjoint 4-executor groups"),
        row("groups_speedup", 0.0,
            f"gang_vs_lockstep={speedup:.2f}x target=1.3 "
            f"group_reshards={st['group_reshards']} "
            f"retries={st['overflow_retries']}"),
    ]


def bench(size: int = 2048, cg_iters: int = 1000, n: int = 1 << 13,
          iters: int = 3) -> list:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root, env.get("PYTHONPATH", "")])
    r = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child", str(size),
         str(cg_iters), str(n), str(iters)],
        env=env, capture_output=True, text=True, timeout=1200, cwd=root,
    )
    if r.returncode != 0:
        raise RuntimeError(f"bench_groups child failed:\n{r.stderr[-2000:]}")
    rows = [ln[len("ROW "):] for ln in r.stdout.splitlines()
            if ln.startswith("ROW ")]
    if not rows:
        raise RuntimeError(f"bench_groups child emitted no rows:\n{r.stdout}")
    return rows


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--child":
        size, cg_iters, n, iters = (int(x) for x in sys.argv[2:6])
        for r in _child(size, cg_iters, n, iters):
            print(f"ROW {r}")
    else:
        from benchmarks.common import emit

        emit(bench())
