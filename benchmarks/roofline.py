"""Render the §Roofline table from the dry-run results (dryrun.jsonl).

Adds a kernel-adjusted memory term: the parsed HBM bytes include the O(S²)
attention-score traffic the chunked-jnp baseline materialises; the Pallas
flash kernel (validated in kernels/flash_attention) keeps scores in VMEM,
so the adjusted term subtracts an analytic estimate of that traffic. Both
numbers are reported — parsed is the honest compiled artifact, adjusted is
the modelled kernel effect (labelled as such).
"""
from __future__ import annotations

import json
import os

from repro.configs import SHAPES, get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

HERE = os.path.dirname(__file__)
JSONL = os.path.join(HERE, "results", "dryrun.jsonl")


def load(jsonl=JSONL):
    recs = {}
    with open(jsonl) as f:
        for line in f:
            r = json.loads(line)
            recs[r["key"]] = r  # later lines win (retries)
    return recs


def scores_traffic_estimate(cfg, cell, chips: int) -> float:
    """Per-device HBM bytes of materialised attention scores in the jnp path
    (fwd ~2 passes + bwd ~4, f32) — what the flash kernel removes."""
    if cfg.family == "ssm":
        return 0.0
    S = cell.seq_len if cell.kind != "decode" else 1
    Skv = cell.seq_len
    B = cell.global_batch
    H = cfg.num_heads
    layers = cfg.num_layers if cfg.family != "hybrid" else cfg.num_layers // cfg.attn_period
    per = 4.0 * B * H * S * Skv  # one f32 materialisation
    passes = 3 if cell.kind == "train" else 2
    return per * passes * layers / chips


def table(recs, mesh="single"):
    rows = []
    for key, r in sorted(recs.items()):
        if r.get("mesh") != mesh or not r.get("ok"):
            continue
        cfg = get_config(r["arch"])
        cell = SHAPES[r["shape"]]
        p = r["parsed"]
        rf = r["roofline"]
        est = scores_traffic_estimate(cfg, cell, r["chips"])
        # never credit the kernel with more than 75% of the parsed traffic —
        # CPU-HLO parsing overstates fusion misses, so the bound keeps the
        # adjustment conservative and clearly below the honest parsed number
        adj_mem = (p["hbm_bytes_per_device"] - min(est, 0.75 * p["hbm_bytes_per_device"])) / HBM_BW
        dom_adj = max(
            [("compute", rf["compute_s"]), ("memory", adj_mem),
             ("collective", rf["collective_s"])],
            key=lambda t: t[1],
        )[0]
        rows.append({
            "cell": f"{r['arch']}|{r['shape']}",
            "compute_s": rf["compute_s"],
            "memory_s": rf["memory_s"],
            "memory_s_flashadj": adj_mem,
            "collective_s": rf["collective_s"],
            "dominant": rf["dominant"],
            "dominant_flashadj": dom_adj,
            "model_flops": rf["model_flops"],
            "useful_ratio": rf["useful_ratio"],
            "roofline_fraction": rf["compute_s"] / max(rf["compute_s"], adj_mem,
                                                       rf["collective_s"]),
            "hbm_fits_16g": r["memory"]["per_device_total"] < 16 * 2**30,
        })
    return rows


def bench():
    from benchmarks.common import row as _row

    recs = load()
    rows = []
    n_ok = sum(1 for r in recs.values() if r.get("ok"))
    rows.append(_row("dryrun_cells_ok", 0.0, f"ok={n_ok}/{len(recs)}"))
    for t in table(recs):
        rows.append(_row(
            f"roofline_{t['cell']}", t["compute_s"] * 1e-0,
            f"dom={t['dominant']};dom_adj={t['dominant_flashadj']};"
            f"frac={t['roofline_fraction']:.3f};useful={t['useful_ratio']:.3f}",
        ))
    return rows


if __name__ == "__main__":
    recs = load()
    hdr = ("cell", "compute_s", "memory_s", "memory_s_flashadj", "collective_s",
           "dominant", "dominant_flashadj", "useful_ratio", "roofline_fraction",
           "hbm_fits_16g")
    print(",".join(hdr))
    for t in table(recs):
        print(",".join(str(t[h]) if not isinstance(t[h], float) else f"{t[h]:.5g}"
                       for h in hdr))
